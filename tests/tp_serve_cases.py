"""Child-side case functions for the tensor-parallel serving rig.

Imported inside ``tp_rig.run_under_devices`` subprocesses (forced host
devices) — every function here must be importable with only src/ and
tests/ on the path and must return JSON-serialisable data.  The model is
rebuilt from fixed PRNG seeds in every child, so tp=1 and tp=N processes
score byte-identical parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.distributed.compat import make_mesh
from repro.models.model_zoo import build
from repro.serving import ServeEngine, SpecConfig, to_codebook_params

PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10], [11, 12, 13, 14]]
MAX_NEW = 6
MAX_LEN = 64
PAGE = 8
SPEC = dict(draft="ngram", k=3)


def _model_params():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    cp = to_codebook_params(pq, wq, state, min_size=1024)
    return model, params, cp


def _mesh(tp: int):
    return None if tp == 1 else make_mesh((1, tp), ("data", "model"))


def serve_matrix(tp: int = 1) -> dict:
    """Token outputs for every (backend × cache mode × spec mode) serve
    case at TP degree ``tp`` — the parity matrix of ISSUE 4: tp=N must be
    token-for-token identical to tp=1 for all of them.
    """
    model, params, cp = _model_params()
    mesh = _mesh(tp)
    out = {}
    for be in ("dense", "codebook", "lut"):
        p = params if be == "dense" else cp
        for mode, mkw in (("contig", {}),
                          ("paged", dict(paged=True, page_size=PAGE))):
            for sp, skw in (("plain", {}),
                            ("spec", dict(spec=SpecConfig(**SPEC)))):
                eng = ServeEngine(model, p, max_len=MAX_LEN, max_batch=2,
                                  mesh=mesh, backend=be, **mkw, **skw)
                out[f"{be}/{mode}/{sp}"] = eng.serve(PROMPTS,
                                                     max_new=MAX_NEW)
    # int8 pages ride along (quantized serving state under TP)
    eng = ServeEngine(model, params, max_len=MAX_LEN, max_batch=2, mesh=mesh,
                      paged=True, page_size=PAGE, kv_dtype="int8")
    out["dense/paged-int8/plain"] = eng.serve(PROMPTS, max_new=MAX_NEW)
    return out


def probes_matrix(tp: int = 1) -> dict:
    """Probes-ON serve tokens + numerics summaries for the plain
    backend × cache-mode grid at TP degree ``tp`` (ISSUE 8): tokens must
    match the probes-off ``serve_matrix`` rows (instrumentation is
    write-only) and the counter summaries must agree across degrees —
    the probe state is replicated, taps fire on the full pre-shard_map
    activations, and sharded inner sites are trace-fenced out."""
    model, params, cp = _model_params()
    mesh = _mesh(tp)
    out = {}
    for be in ("dense", "codebook", "lut"):
        p = params if be == "dense" else cp
        for mode, mkw in (("contig", {}),
                          ("paged", dict(paged=True, page_size=PAGE))):
            eng = ServeEngine(model, p, max_len=MAX_LEN, max_batch=2,
                              mesh=mesh, backend=be, probes=True, **mkw)
            toks = eng.serve(PROMPTS, max_new=MAX_NEW)
            out[f"{be}/{mode}/plain"] = {"tokens": toks,
                                         "numerics": eng.numerics()}
    eng = ServeEngine(model, params, max_len=MAX_LEN, max_batch=2, mesh=mesh,
                      paged=True, page_size=PAGE, kv_dtype="int8", probes=True)
    out["dense/paged-int8/plain"] = {"tokens": eng.serve(PROMPTS,
                                                         max_new=MAX_NEW),
                                     "numerics": eng.numerics()}
    return out


def sched_trace_case(tp: int = 1) -> dict:
    """Contended multi-tenant trace through the AsyncScheduler at TP
    degree ``tp`` (ISSUE 5): the pool allocator, admission gate, and
    preemption policy all run on the host, so the event log, preemption
    decisions, and every request's stream must be identical across
    degrees — scheduling is shard-invariant by construction."""
    from repro.serving.server import (CONTENDED_ENGINE_KW, Server,
                                      contended_trace)

    model, params, _ = _model_params()
    eng = ServeEngine(model, params, mesh=_mesh(tp), **CONTENDED_ENGINE_KW)
    trace = contended_trace(1, model.cfg.vocab)
    srv = Server(eng)
    rep = srv.replay(trace)
    return {"events": [list(e) for e in srv.sched.events],
            "streams": {str(h.rid): list(h.tokens)
                        for h in srv.sched.handles.values()},
            "preemptions": rep.preemptions,
            "pages_swapped_out": rep.pages_swapped_out,
            "admission_order": rep.admission_order}


def golden_serve_case(tp: int = 2) -> list:
    """Greedy serve tokens for the golden-file tp row (dense contiguous,
    the two golden prompts) — rebuilt from fixed seeds in the child so
    the fingerprint is machine-independent."""
    model, params, _ = _model_params()
    eng = ServeEngine(model, params, max_len=64, max_batch=2,
                      mesh=_mesh(tp))
    return eng.serve(PROMPTS[:2], max_new=MAX_NEW)


def lut_acc_psum_case(tp: int = 1) -> dict:
    """The §10 row-parallel lut contract at the *accumulator* level: psum
    over int32 partial accumulators must be bit-identical to the
    single-device int32 accumulation (integer addition is associative —
    unlike the float psum of the codebook backend, which is only ever
    close).  Runs the real w2 row-parallel site (K=256 reduction) from the
    quantized model, through the same ``_lut_acc`` + replicated
    precomputed table the engine traces.

    Returns raw int32 accumulators AND the decoded backend_matmul floats
    (bit-stable too: decode is a deterministic function of the acc), both
    as exact int/float lists for cross-process comparison.
    """
    from repro.kernels import dispatch

    model, params, cp = _model_params()
    site = cp["blocks"]["mlp"]["w2"]
    w_idx = jnp.asarray(site["w_idx"][0])                 # (K=256, N=128)
    codebook = jnp.asarray(site["codebook"][0])           # (|W|=256,)
    K, N = w_idx.shape
    spec = dispatch.make_lut_spec(codebook, fan_in=K)
    table = dispatch.build_lut_table(codebook, spec)      # replicated
    rng = np.random.default_rng(42)
    x2 = jnp.asarray(rng.standard_normal((8, K)) * 2.0, jnp.float32)

    da = spec.da
    a_idx = jnp.clip(jnp.round((x2 - spec.a_min) / da),
                     0, spec.levels - 1).astype(jnp.int32)
    mesh = _mesh(tp)
    if mesh is None:
        acc = dispatch._lut_acc(x2, w_idx, codebook, spec, table)
    else:
        from jax.sharding import PartitionSpec as P

        from repro.distributed.compat import shard_map

        def body(al, wl):
            from repro.kernels import ops
            return jax.lax.psum(ops.lut_matmul(al, wl, table), "model")

        f = shard_map(jax.jit(body), mesh=mesh,
                      in_specs=(P(None, "model"), P("model", None)),
                      out_specs=P(None, None), check_vma=False)
        acc = f(a_idx, w_idx)

    with dispatch.use_backend("lut", spec, mesh):
        y = dispatch.backend_matmul(x2, w_idx, codebook, kind="row",
                                    table=table)
    return {"acc": np.asarray(acc).astype(int).tolist(),
            "y": [[float(v) for v in row] for row in np.asarray(y)],
            "K": int(K), "N": int(N), "s": spec.s}


# --- collective-bytes accounting --------------------------------------------

_COLLECTIVES = ("psum", "pmax", "pmin", "all_gather", "all_to_all",
                "ppermute", "reduce_scatter", "psum_scatter",
                "all_gather_invariant")


def _jaxpr_collective_bytes(closed) -> int:
    """Max output bytes over every collective primitive, recursing through
    scan/while/pjit/shard_map sub-jaxprs.  shard_map payload shapes are
    shard-local — exactly the per-shard wire bytes of each psum."""
    import jax.core as jcore

    worst = 0

    def visit(jaxpr):
        nonlocal worst
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if any(name.startswith(c) for c in _COLLECTIVES):
                for var in list(eqn.outvars) + list(eqn.invars):
                    aval = getattr(var, "aval", None)
                    if aval is not None and hasattr(aval, "shape"):
                        worst = max(worst, int(np.prod(aval.shape or (1,)))
                                    * aval.dtype.itemsize)
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    if isinstance(sub, jcore.ClosedJaxpr):
                        visit(sub.jaxpr)
                    elif isinstance(sub, jcore.Jaxpr):
                        visit(sub)

    visit(closed.jaxpr)
    return worst


_HLO_OPS = ("all-gather", "all-reduce", "all-to-all", "collective-permute",
            "reduce-scatter")
_DT_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
             "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1}


def _hlo_collective_bytes(text: str) -> int:
    """Max per-instruction result bytes over the compiled module's
    collective ops (catches GSPMD-inserted resharding collectives the
    jaxpr cannot show)."""
    import re

    worst = 0
    for line in text.splitlines():
        if not any(f" {op}(" in line or f"{op}-start(" in line
                   for op in _HLO_OPS):
            continue
        lhs = line.split("=")[0] if "=" in line else line
        body = line[len(lhs):]
        shapes = re.findall(r"(\w+)\[([0-9,]*)\]", body.split("(")[0])
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            worst = max(worst, n * _DT_BYTES.get(dt, 4))
    return worst


def collective_bounds(tp: int = 2) -> dict:
    """Trace + compile one decode step (contiguous and paged) under TP and
    measure the largest collective payload, jaxpr- and HLO-level.

    Returns the measured maxima plus the model's O(B·H·hd) unit and the
    per-layer cache-slice bytes the §5/§10 layout must never move.
    """
    model, params, _ = _model_params()
    cfg = model.cfg
    mesh = _mesh(tp)
    B, S = 4, 256
    toks = jnp.ones((B, 1), jnp.int32)
    res = {"tp": tp,
           "unit_bytes": B * cfg.n_heads * cfg.hd * 4,
           "layer_cache_bytes": B * S * cfg.n_kv * cfg.hd * 4}

    # contiguous: per-slot positions, S-sharded slab
    cache = model.init_cache(B, S, dtype=jnp.float32)
    cache = {**cache, "pos": jnp.full((B,), 9, jnp.int32)}
    fn = lambda p, t, c: model.decode(p, t, c, mesh)   # noqa: E731
    res["contig_jaxpr_bytes"] = _jaxpr_collective_bytes(
        jax.make_jaxpr(fn)(params, toks, cache))
    hlo = jax.jit(fn).lower(params, toks, cache).compile().as_text()
    res["contig_hlo_bytes"] = _hlo_collective_bytes(hlo)

    # paged: page-table decode over the in-page-sharded pool
    page, n_pages = 16, 2 + B * (S // 16)
    pool = model.init_paged_cache(n_pages, page, jnp.float32)
    pt = jnp.asarray(
        np.arange(1, 1 + B * (S // 16)).reshape(B, S // 16), jnp.int32)
    pcache = {**pool, "page_table": pt, "pos": jnp.full((B,), 9, jnp.int32)}
    res["paged_jaxpr_bytes"] = _jaxpr_collective_bytes(
        jax.make_jaxpr(fn)(params, toks, pcache))
    hlo = jax.jit(fn).lower(params, toks, pcache).compile().as_text()
    res["paged_hlo_bytes"] = _hlo_collective_bytes(hlo)
    return res
