"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,k,n", [(4, 8, 4), (5, 37, 9), (128, 128, 128),
                                   (130, 200, 260), (1, 512, 7)])
@pytest.mark.parametrize("idt", [jnp.int8, jnp.int16, jnp.int32])
@pytest.mark.parametrize("xdt", [jnp.float32, jnp.bfloat16])
def test_codebook_matmul_sweep(m, k, n, idt, xdt):
    W = min(int(jnp.iinfo(idt).max), 1000)
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (m, k), xdt)
    wi = jax.random.randint(ks[1], (k, n), 0, W).astype(idt)
    book = jax.random.normal(ks[2], (W,), jnp.float32)
    out = ops.codebook_matmul(x, wi, book)
    exp = ref.codebook_matmul_ref(x, wi, book)
    tol = 2e-2 if xdt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=tol, atol=tol * k)


def test_codebook_matmul_grads():
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (8, 16))
    wi = jax.random.randint(ks[1], (16, 12), 0, 32)
    book = jax.random.normal(ks[2], (32,))
    g = jax.grad(lambda x, b: jnp.sum(ops.codebook_matmul(x, wi, b) ** 2),
                 argnums=(0, 1))(x, book)
    gr = jax.grad(lambda x, b: jnp.sum(ref.codebook_matmul_ref(x, wi, b) ** 2),
                  argnums=(0, 1))(x, book)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n,R,C", [(4, 10, 6, 17, 33),
                                       (129, 257, 131, 33, 1001),
                                       (8, 128, 128, 9, 257)])
def test_lut_matmul_bit_exact(m, k, n, R, C):
    a = jax.random.randint(KEY, (m, k), 0, R)
    w = jax.random.randint(jax.random.fold_in(KEY, 1), (k, n), 0, C)
    t = jax.random.randint(jax.random.fold_in(KEY, 2), (R, C), -1000, 1000)
    np.testing.assert_array_equal(np.asarray(ops.lut_matmul(a, w, t)),
                                  np.asarray(ref.lut_matmul_ref(a, w, t)))


@pytest.mark.parametrize("kind", ["tanh", "relu6", "sigmoid", "rtanh"])
@pytest.mark.parametrize("levels", [2, 16, 256])
@pytest.mark.parametrize("shape", [(7,), (3, 5), (2, 130, 9)])
def test_act_quant_sweep(kind, levels, shape):
    x = jax.random.normal(KEY, shape) * 3
    y = ops.act_quant(x, kind, levels)
    yr = ref.act_quant_ref(x, kind, levels)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-6)
    g = jax.grad(lambda v: jnp.sum(ops.act_quant(v, kind, levels)))(x)
    gr = jax.grad(lambda v: jnp.sum(ref.act_quant_ref(v, kind, levels)))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-5)


@pytest.mark.parametrize("n,k", [(100, 3), (10_001, 100), (5000, 257)])
def test_kmeans_assign_sweep(n, k):
    v = jax.random.laplace(KEY, (n,))
    c = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 3), (k,)))
    idx, sums, counts = ops.kmeans_assign(v, c)
    idr, sr, cr = ref.kmeans_assign_ref(v, c)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idr))
    # sums differ only by f32 accumulation order (chunked matmul vs
    # segment_sum); bound relative to the magnitude of what was summed
    scale = np.abs(np.asarray(v)).sum() / max(len(np.asarray(c)), 1)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sr),
                               rtol=1e-3, atol=1e-4 * scale + 1e-3)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(cr), atol=0.5)


def test_kmeans_assign_full_lloyd_step():
    """One Lloyd update from kernel partials == segment_sum update."""
    v = jax.random.laplace(KEY, (20_000,)) * 0.5
    c = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 9), (64,)))
    _, sums, counts = ops.kmeans_assign(v, c)
    new = np.where(np.asarray(counts) > 0,
                   np.asarray(sums) / np.maximum(np.asarray(counts), 1), c)
    idr, sr, cr = ref.kmeans_assign_ref(v, c)
    exp = np.where(np.asarray(cr) > 0,
                   np.asarray(sr) / np.maximum(np.asarray(cr), 1), c)
    np.testing.assert_allclose(new, exp, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 32, 16), (32, 8, 64), (64, 64, 32)])
def test_codebook_matmul_block_shapes(bm, bn, bk):
    """BlockSpec tiling sweep: results must be block-shape invariant."""
    from repro.kernels.codebook_matmul import codebook_matmul_pallas
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (70, 90))
    wi = jax.random.randint(ks[1], (90, 50), 0, 128).astype(jnp.int16)
    book = jax.random.normal(ks[2], (128,))
    out = codebook_matmul_pallas(x, wi, book, bm=bm, bn=bn, bk=bk,
                                 interpret=True)
    exp = ref.codebook_matmul_ref(x, wi, book)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 16, 8), (16, 64, 32)])
def test_lut_matmul_block_shapes(bm, bn, bk):
    from repro.kernels.lut_matmul import lut_matmul_pallas
    a = jax.random.randint(KEY, (33, 49), 0, 9)
    w = jax.random.randint(jax.random.fold_in(KEY, 1), (49, 21), 0, 65)
    t = jax.random.randint(jax.random.fold_in(KEY, 2), (9, 65), -500, 500)
    out = lut_matmul_pallas(a, w, t, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.lut_matmul_ref(a, w, t)))


def test_kmeans_assign_block_sizes():
    from repro.kernels.kmeans1d import kmeans_assign_pallas
    v = jax.random.laplace(KEY, (3000,))
    c = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 7), (65,)))
    ref_idx, ref_s, ref_c = ref.kmeans_assign_ref(v, c)
    for bv in (256, 1024, 4096):
        idx, s, cnt = kmeans_assign_pallas(v, c, bv=bv, interpret=True)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
        np.testing.assert_allclose(np.asarray(s), np.asarray(ref_s),
                                   rtol=1e-3, atol=1e-2)


# --- property-based parity (ISSUE 6): randomized ragged shapes ---------------
# conftest installs tests/_hypothesis_fallback.py as `hypothesis` when the
# real package is absent, so these properties always run, deterministically.
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.codebook_matmul import (codebook_matmul_pallas,  # noqa: E402
                                           codebook_matmul_xla)
from repro.kernels.lut_matmul import (lut_matmul_pallas,  # noqa: E402
                                      lut_matmul_xla)


def _lut_case(seed, m, k, n, R, C, mag):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, R, (m, k)), jnp.int32)
    w = jnp.asarray(rng.integers(0, C, (k, n)), jnp.int32)
    t = jnp.asarray(rng.integers(-mag, mag, (R, C)), jnp.int32)
    return a, w, t


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 33), st.integers(1, 40), st.integers(1, 33),
       st.sampled_from([3, 9, 257]), st.sampled_from([5, 65, 256]),
       # 1 << 25 is overflow-adjacent: k*mag approaches but stays inside
       # int32, so any double-count or dropped mask term wraps visibly
       st.sampled_from([1000, 1 << 25]),
       st.integers(0, 10_000))
def test_lut_parity_property(m, k, n, R, C, mag, seed):
    """Every route — XLA rows/flat at several chunk sizes, Pallas interpret
    with blocks larger AND smaller than the dims — must equal the pure-jnp
    oracle bit-for-bit on ragged shapes (integer accumulators: no
    tolerance, exact)."""
    a, w, t = _lut_case(seed, m, k, n, R, C, mag)
    want = np.asarray(ref.lut_matmul_ref(a, w, t))
    for variant in ("rows", "flat"):
        got = lut_matmul_xla(a, w, t, kc=32, variant=variant)
        np.testing.assert_array_equal(np.asarray(got), want, err_msg=variant)
    got = lut_matmul_pallas(a, w, t, bm=8, bn=16, bk=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want, err_msg="pallas")


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 33), st.integers(1, 40), st.integers(1, 33),
       st.sampled_from([jnp.float32, jnp.bfloat16]),
       st.integers(0, 10_000))
def test_codebook_parity_property(m, k, n, xdt, seed):
    """XLA fallback and Pallas interpret vs oracle on ragged shapes."""
    rng = np.random.default_rng(seed)
    W = 64
    x = jnp.asarray(rng.standard_normal((m, k)), xdt)
    wi = jnp.asarray(rng.integers(0, W, (k, n)), jnp.int32)
    book = jnp.asarray(rng.standard_normal((W,)), jnp.float32)
    want = np.asarray(ref.codebook_matmul_ref(x, wi, book), np.float32)
    tol = 2e-2 if xdt == jnp.bfloat16 else 2e-5
    for got in (codebook_matmul_xla(x, wi, book),
                codebook_matmul_pallas(x, wi, book, bm=8, bn=16, bk=16,
                                       interpret=True)):
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=tol, atol=tol * k)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20), st.integers(1, 24), st.integers(1, 20),
       st.integers(0, 10_000))
def test_lut_negative_ids_canonicalize(m, k, n, seed):
    """Narrow signed ids are unsigned-intended: every kernel route must
    treat a negative id as id + table_dim.  The oracle is ref on the
    explicitly canonicalized indices (ref itself does raw flat addressing
    and is NOT the contract for negative inputs)."""
    rng = np.random.default_rng(seed)
    R, C = 300, 256
    a8 = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w8 = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    t = jnp.asarray(rng.integers(-1000, 1000, (R, C)), jnp.int32)
    a_can = jnp.where(a8 < 0, a8.astype(jnp.int32) + R, a8).astype(jnp.int32)
    w_can = jnp.where(w8 < 0, w8.astype(jnp.int32) + C, w8).astype(jnp.int32)
    want = np.asarray(ref.lut_matmul_ref(a_can, w_can, t))
    for variant in ("rows", "flat"):
        got = lut_matmul_xla(a8, w8, t, kc=16, variant=variant)
        np.testing.assert_array_equal(np.asarray(got), want, err_msg=variant)
    got = lut_matmul_pallas(a8, w8, t, bm=8, bn=16, bk=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want, err_msg="pallas")


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20), st.integers(1, 24), st.integers(1, 20),
       st.integers(0, 10_000))
def test_codebook_negative_ids_canonicalize(m, k, n, seed):
    """int8 codebook ids with |W| = 256: -1 must address entry 255 on
    every route (two's-complement reinterpretation, DESIGN.md §12)."""
    rng = np.random.default_rng(seed)
    W = 256
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wi8 = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    book = jnp.asarray(rng.standard_normal((W,)), jnp.float32)
    wi_can = jnp.where(wi8 < 0, wi8.astype(jnp.int32) + W, wi8)
    want = np.asarray(ref.codebook_matmul_ref(x, wi_can.astype(jnp.int32),
                                              book))
    for got in (codebook_matmul_xla(x, wi8, book),
                codebook_matmul_pallas(x, wi8, book, bm=8, bn=16, bk=16,
                                       interpret=True)):
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5,
                                   atol=2e-3)


# --- edge shapes + masking explicitness --------------------------------------

@pytest.mark.parametrize("m,k,n", [(1, 3, 7), (1, 1, 1), (2, 5, 128),
                                   (7, 200, 3), (128, 1, 5)])
def test_lut_edge_shapes_exact(m, k, n):
    """M=1, K < bk, N odd, degenerate dims — through the routed op AND
    every explicit route."""
    a, w, t = _lut_case(99, m, k, n, 17, 33, 1000)
    want = np.asarray(ref.lut_matmul_ref(a, w, t))
    np.testing.assert_array_equal(np.asarray(ops.lut_matmul(a, w, t)), want)
    for kc in (1, 64, 128):
        for variant in ("rows", "flat"):
            got = lut_matmul_xla(a, w, t, kc=kc, variant=variant)
            np.testing.assert_array_equal(np.asarray(got), want)
    got = lut_matmul_pallas(a, w, t, bm=128, bn=128, bk=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("m,k,n", [(1, 3, 7), (2, 5, 128), (7, 200, 3)])
def test_codebook_edge_shapes(m, k, n):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wi = jnp.asarray(rng.integers(0, 16, (k, n)), jnp.int32)
    book = jnp.asarray(rng.standard_normal((16,)), jnp.float32)
    want = np.asarray(ref.codebook_matmul_ref(x, wi, book))
    np.testing.assert_allclose(
        np.asarray(ops.codebook_matmul(x, wi, book)), want, rtol=2e-5,
        atol=2e-4)
    got = codebook_matmul_pallas(x, wi, book, bm=128, bn=128, bk=128,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-4)


def test_lut_ragged_k_masking_is_explicit():
    """K ragged vs bk, with table[0, 0] deliberately nonzero and all ids 0:
    any unmasked tail contribution adds a multiple of table[0, 0] — exact
    equality proves the tail handling is explicit masking, not an
    assumption that padded/OOB gathers read zeros."""
    R, C = 4, 4
    t = jnp.full((R, C), 7, jnp.int32)         # every entry visible
    for (m, k, n, bk) in [(3, 5, 4, 16), (1, 1, 1, 8), (4, 37, 3, 16)]:
        a = jnp.zeros((m, k), jnp.int32)
        w = jnp.zeros((k, n), jnp.int32)
        want = np.full((m, n), 7 * k, np.int64).astype(np.int32)
        got = lut_matmul_pallas(a, w, t, bm=8, bn=8, bk=bk, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), want)
        for variant in ("rows", "flat"):
            got = lut_matmul_xla(a, w, t, kc=bk, variant=variant)
            np.testing.assert_array_equal(np.asarray(got), want)


def test_codebook_ragged_k_masking_is_explicit():
    """Same masking probe for the float kernel: book[0] nonzero, plus
    non-finite activations — the kernel masks BOTH operands on the ragged
    tail (an unmasked NaN times a masked-to-zero weight would still
    poison the accumulator)."""
    book = jnp.asarray([5.0, -1.0], jnp.float32)
    m, k, n = 3, 5, 4
    x = jnp.ones((m, k), jnp.float32)
    wi = jnp.zeros((k, n), jnp.int32)
    want = np.full((m, n), 5.0 * k, np.float32)
    got = codebook_matmul_pallas(x, wi, book, bm=8, bn=8, bk=16,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


# --- page gather: ragged tables + id clamping --------------------------------

@pytest.mark.parametrize("B,P,pages,rest", [(1, 1, 2, (4, 2, 3)),
                                            (3, 5, 7, (4, 2)),
                                            (2, 9, 16, (8,))])
def test_page_gather_parity(B, P, pages, rest):
    from repro.kernels.page_gather import page_gather_pallas
    rng = np.random.default_rng(11)
    pool = jnp.asarray(rng.standard_normal((pages,) + rest), jnp.float32)
    pt = jnp.asarray(rng.integers(0, pages, (B, P)), jnp.int32)
    got = page_gather_pallas(pool, pt, interpret=True)
    want = np.asarray(pool)[np.asarray(pt)]
    np.testing.assert_array_equal(np.asarray(got), want)
    # the ops-level CPU fallback must agree with the kernel
    np.testing.assert_array_equal(np.asarray(ops.gather_pages(pool, pt)),
                                  want)


def test_page_gather_oob_ids_clamp():
    """Out-of-range page ids (negative or >= n_pages) clamp into the pool
    on BOTH routes — the bounded-garbage contract: a bad id degrades to a
    valid page read, never UB / NaN / INT_MIN fill."""
    from repro.kernels.page_gather import page_gather_pallas
    rng = np.random.default_rng(5)
    pool = jnp.asarray(rng.standard_normal((4, 2, 3)), jnp.float32)
    pt = jnp.asarray([[-3, 0], [2, 9]], jnp.int32)
    clamped = np.clip(np.asarray(pt), 0, 3)
    want = np.asarray(pool)[clamped]
    got = page_gather_pallas(pool, pt, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)
    np.testing.assert_array_equal(np.asarray(ops.gather_pages(pool, pt)),
                                  want)
