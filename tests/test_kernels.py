"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,k,n", [(4, 8, 4), (5, 37, 9), (128, 128, 128),
                                   (130, 200, 260), (1, 512, 7)])
@pytest.mark.parametrize("idt", [jnp.int8, jnp.int16, jnp.int32])
@pytest.mark.parametrize("xdt", [jnp.float32, jnp.bfloat16])
def test_codebook_matmul_sweep(m, k, n, idt, xdt):
    W = min(int(jnp.iinfo(idt).max), 1000)
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (m, k), xdt)
    wi = jax.random.randint(ks[1], (k, n), 0, W).astype(idt)
    book = jax.random.normal(ks[2], (W,), jnp.float32)
    out = ops.codebook_matmul(x, wi, book)
    exp = ref.codebook_matmul_ref(x, wi, book)
    tol = 2e-2 if xdt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=tol, atol=tol * k)


def test_codebook_matmul_grads():
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (8, 16))
    wi = jax.random.randint(ks[1], (16, 12), 0, 32)
    book = jax.random.normal(ks[2], (32,))
    g = jax.grad(lambda x, b: jnp.sum(ops.codebook_matmul(x, wi, b) ** 2),
                 argnums=(0, 1))(x, book)
    gr = jax.grad(lambda x, b: jnp.sum(ref.codebook_matmul_ref(x, wi, b) ** 2),
                  argnums=(0, 1))(x, book)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n,R,C", [(4, 10, 6, 17, 33),
                                       (129, 257, 131, 33, 1001),
                                       (8, 128, 128, 9, 257)])
def test_lut_matmul_bit_exact(m, k, n, R, C):
    a = jax.random.randint(KEY, (m, k), 0, R)
    w = jax.random.randint(jax.random.fold_in(KEY, 1), (k, n), 0, C)
    t = jax.random.randint(jax.random.fold_in(KEY, 2), (R, C), -1000, 1000)
    np.testing.assert_array_equal(np.asarray(ops.lut_matmul(a, w, t)),
                                  np.asarray(ref.lut_matmul_ref(a, w, t)))


@pytest.mark.parametrize("kind", ["tanh", "relu6", "sigmoid", "rtanh"])
@pytest.mark.parametrize("levels", [2, 16, 256])
@pytest.mark.parametrize("shape", [(7,), (3, 5), (2, 130, 9)])
def test_act_quant_sweep(kind, levels, shape):
    x = jax.random.normal(KEY, shape) * 3
    y = ops.act_quant(x, kind, levels)
    yr = ref.act_quant_ref(x, kind, levels)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-6)
    g = jax.grad(lambda v: jnp.sum(ops.act_quant(v, kind, levels)))(x)
    gr = jax.grad(lambda v: jnp.sum(ref.act_quant_ref(v, kind, levels)))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-5)


@pytest.mark.parametrize("n,k", [(100, 3), (10_001, 100), (5000, 257)])
def test_kmeans_assign_sweep(n, k):
    v = jax.random.laplace(KEY, (n,))
    c = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 3), (k,)))
    idx, sums, counts = ops.kmeans_assign(v, c)
    idr, sr, cr = ref.kmeans_assign_ref(v, c)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idr))
    # sums differ only by f32 accumulation order (chunked matmul vs
    # segment_sum); bound relative to the magnitude of what was summed
    scale = np.abs(np.asarray(v)).sum() / max(len(np.asarray(c)), 1)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sr),
                               rtol=1e-3, atol=1e-4 * scale + 1e-3)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(cr), atol=0.5)


def test_kmeans_assign_full_lloyd_step():
    """One Lloyd update from kernel partials == segment_sum update."""
    v = jax.random.laplace(KEY, (20_000,)) * 0.5
    c = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 9), (64,)))
    _, sums, counts = ops.kmeans_assign(v, c)
    new = np.where(np.asarray(counts) > 0,
                   np.asarray(sums) / np.maximum(np.asarray(counts), 1), c)
    idr, sr, cr = ref.kmeans_assign_ref(v, c)
    exp = np.where(np.asarray(cr) > 0,
                   np.asarray(sr) / np.maximum(np.asarray(cr), 1), c)
    np.testing.assert_allclose(new, exp, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 32, 16), (32, 8, 64), (64, 64, 32)])
def test_codebook_matmul_block_shapes(bm, bn, bk):
    """BlockSpec tiling sweep: results must be block-shape invariant."""
    from repro.kernels.codebook_matmul import codebook_matmul_pallas
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (70, 90))
    wi = jax.random.randint(ks[1], (90, 50), 0, 128).astype(jnp.int16)
    book = jax.random.normal(ks[2], (128,))
    out = codebook_matmul_pallas(x, wi, book, bm=bm, bn=bn, bk=bk,
                                 interpret=True)
    exp = ref.codebook_matmul_ref(x, wi, book)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 16, 8), (16, 64, 32)])
def test_lut_matmul_block_shapes(bm, bn, bk):
    from repro.kernels.lut_matmul import lut_matmul_pallas
    a = jax.random.randint(KEY, (33, 49), 0, 9)
    w = jax.random.randint(jax.random.fold_in(KEY, 1), (49, 21), 0, 65)
    t = jax.random.randint(jax.random.fold_in(KEY, 2), (9, 65), -500, 500)
    out = lut_matmul_pallas(a, w, t, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.lut_matmul_ref(a, w, t)))


def test_kmeans_assign_block_sizes():
    from repro.kernels.kmeans1d import kmeans_assign_pallas
    v = jax.random.laplace(KEY, (3000,))
    c = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 7), (65,)))
    ref_idx, ref_s, ref_c = ref.kmeans_assign_ref(v, c)
    for bv in (256, 1024, 4096):
        idx, s, cnt = kmeans_assign_pallas(v, c, bv=bv, interpret=True)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
        np.testing.assert_allclose(np.asarray(s), np.asarray(ref_s),
                                   rtol=1e-3, atol=1e-2)
