"""Distribution substrate: gradient compression codec, sharding specs, and
multi-device equivalence (the latter in a subprocess with 8 fake devices so
the main pytest process keeps the real single-device view)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.grad_compression import lap_dequantize, lap_quantize
from repro.distributed import sharding as SH

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_lap_codec_error_bounded():
    key = jax.random.PRNGKey(0)
    for scale in (1e-4, 1.0, 37.0):
        g = jax.random.laplace(key, (20_000,)) * scale + 0.3 * scale
        idx, a, b = lap_quantize(g)
        q = lap_dequantize(idx, a, b)
        assert idx.dtype == jnp.uint8
        rel = float(jnp.linalg.norm(q - g) / jnp.linalg.norm(g))
        # L1-optimal (not L2-optimal) 256-level grid: ~6% rel-L2 error
        assert rel < 0.08, (scale, rel)


def test_lap_codec_wire_format_small():
    """8-bit index + two scalars per tensor: 4x fewer wire bytes than f32."""
    g = jax.random.normal(jax.random.PRNGKey(1), (1000,))
    idx, a, b = lap_quantize(g)
    assert idx.nbytes * 4 + 8 <= g.nbytes + 8


def test_param_specs_cover_all_leaves():
    import repro.configs as C
    from repro.models.model_zoo import build
    from repro.launch import steps as ST
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)
    for name in ("qwen3-1.7b", "qwen3-moe-30b-a3b", "zamba2-2.7b",
                 "rwkv6-7b", "whisper-small"):
        model = build(C.get(name).reduced())
        params = ST.abstract_params(model)
        specs = ST.params_partition_specs(model, mesh)
        ps, ss = jax.tree.leaves(params), jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert len(ps) == len(ss), name
        for p, s in zip(ps, ss):
            assert len(s) <= p.ndim, (name, p.shape, s)


_MULTIDEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.grad_compression import (compressed_psum_tree,
                                                init_error_state)

from repro.distributed.compat import make_mesh, shard_map
mesh = make_mesh((2, 4), ("pod", "data"))
key = jax.random.PRNGKey(0)
grads = {"w": jax.random.laplace(key, (2, 4, 64)),
         "b": jax.random.laplace(jax.random.fold_in(key, 1), (2, 8))}

def exchange(g, e):
    red, ne = compressed_psum_tree(g, e, "pod")
    return red, ne

fn = shard_map(exchange, mesh=mesh,
                   in_specs=({"w": P("pod"), "b": P("pod")},
                             {"w": P("pod"), "b": P("pod")}),
                   out_specs=({"w": P("pod"), "b": P("pod")},
                              {"w": P("pod"), "b": P("pod")}),
                   check_vma=False)
err = init_error_state(grads)
red, err2 = jax.jit(fn)(grads, err)
# exact mean over the pod axis as reference: dim 0 is pod-sharded in halves
def pod_mean(v):
    half = (v[:1] + v[1:]) / 2.0
    return jnp.concatenate([half, half], axis=0)
exact = {k: pod_mean(v) for k, v in grads.items()}
rel = float(jnp.linalg.norm(red["w"] - exact["w"]) /
            jnp.linalg.norm(exact["w"]))
# error feedback: residual nonzero, bounded
enorm = float(jnp.linalg.norm(err2["w"]))
print(json.dumps({"rel": rel, "enorm": enorm}))
assert rel < 0.08, rel

# multi-device train-step equivalence: 4-device mesh == single device
import repro.configs as C
from repro.models.model_zoo import build
from repro.launch import steps as ST
from repro.optim import OptConfig, init_opt_state
cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = init_opt_state(params, OptConfig(lr=1e-3))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0,
                                      cfg.vocab)}
from repro.distributed.compat import make_mesh
mesh2 = make_mesh((2, 2), ("data", "model"))
pspec = ST.params_partition_specs(model, mesh2)
psh = ST.shardings_for(pspec, mesh2)
step1 = jax.jit(ST.make_train_step(model, OptConfig(lr=1e-3), None))
p1, _, m1 = step1(params, opt, batch)
step2 = jax.jit(ST.make_train_step(model, OptConfig(lr=1e-3), mesh2),
                in_shardings=(psh, None, None))
p2, _, m2 = step2(params, opt, batch)
d = max(float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
print(json.dumps({"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
                  "max_param_delta": d}))
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
assert d < 1e-4
print("MULTIDEV_OK")
"""


@pytest.mark.tier2
def test_multidevice_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", _MULTIDEV], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "MULTIDEV_OK" in out.stdout, out.stdout + out.stderr


_DECODE_MESH = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
import repro.configs as C
from repro.models import transformer as T

from repro.distributed.compat import make_mesh
mesh = make_mesh((2, 8), ("data", "model"))
key = jax.random.PRNGKey(0)
for name, kvq in (("llama3.2-3b", False), ("codeqwen1.5-7b", True),
                  ("zamba2-2.7b", False)):
    cfg = C.get(name).reduced().replace(kv_quant=kvq, kv_block=8)
    p = T.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    c1 = T.init_cache(cfg, B, 32, dtype=jnp.float32)
    c2 = jax.tree.map(lambda x: x, c1)
    sl = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
    sm = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c, mesh))
    for t in range(S):
        l1, c1 = sl(p, toks[:, t:t + 1], c1)
        l2, c2 = sm(p, toks[:, t:t + 1], c2)
    err = float(jnp.max(jnp.abs(l1 - l2)))
    # noise floor: bf16 psum payload (~0.4% of partial outputs); int8 KV
    # adds its own quantization noise on top (measured ~0.02 on the 0.4.x
    # CPU backend — keep a margin above the floor, not at it)
    assert err < (3e-2 if kvq else 8e-3), (name, err)
    print(name, "ok", err)
print("DECODE_MESH_OK")
"""


@pytest.mark.tier2
def test_shardmap_flash_decode_matches_local():
    """The §Perf(a) explicit flash-decode (shard_map over the S-sharded
    cache, int8 or bf16) must be numerically identical to the single-device
    decode path, ring buffers included."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", _DECODE_MESH], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "DECODE_MESH_OK" in out.stdout, out.stdout + out.stderr
