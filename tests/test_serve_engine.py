"""ServeEngine: jitted prefill/decode loop, continuous batching, backends.

Ground truth throughout is the *incremental* path: one request at a time,
prompt fed token-by-token through ``decode_step`` from an empty cache (the
seed engine's semantics).  The batched prefill, the while_loop decode, the
slot-pool continuous batching, and the codebook/lut backends must all
reproduce it greedily, token for token.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params

PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10], [11, 12, 13, 14]]


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _incremental(model, params, prompt, max_new, max_len=64):
    """Seed-style reference: token-by-token feed, greedy, batch of one."""
    cfg = model.cfg
    cache = model.init_cache(1, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, t, c: model.decode(p, t, c))
    logits = None
    for t in prompt:
        logits, cache = step(params, jnp.asarray([[t]], jnp.int32), cache)
    out = list(prompt)
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        out.append(nxt)
        logits, cache = step(params, jnp.asarray([[nxt]], jnp.int32), cache)
    return out


def test_prefill_matches_incremental_decode(tiny):
    """One jitted prefill == feeding the prompt token-by-token."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64)
    got = eng.generate(PROMPTS, max_new=6)
    want = [_incremental(model, params, p, 6) for p in PROMPTS]
    assert got == want


def test_generate_deterministic_and_shaped(tiny):
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64)
    o1 = eng.generate(PROMPTS, max_new=5)
    o2 = eng.generate(PROMPTS, max_new=5)
    assert o1 == o2
    assert [len(o) for o in o1] == [len(p) + 5 for p in PROMPTS]
    assert all(0 <= t < cfg.vocab for o in o1 for t in o)


def test_continuous_batching_join_leave(tiny):
    """A 2-slot pool over 4 requests with unequal stop lengths: every
    request's tokens must be independent of who shared the batch with it."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2)
    stops = [6, 3, 5, 1]
    got = eng.serve(PROMPTS, max_new=stops)
    want = [_incremental(model, params, p, s) for p, s in zip(PROMPTS, stops)]
    assert got == want


def test_serve_single_slot_queue(tiny):
    """max_batch=1 degenerates to sequential serving — still correct."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=1)
    got = eng.serve(PROMPTS[:2], max_new=4)
    want = [_incremental(model, params, p, 4) for p in PROMPTS[:2]]
    assert got == want


def test_backends_agree_greedy(tiny):
    """dense / codebook / lut backends produce identical greedy tokens on
    index-form params (lut within its 4096-level activation grid)."""
    cfg, model, params = tiny
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    cp = to_codebook_params(pq, wq, state, min_size=1024)
    outs = {be: ServeEngine(model, cp, max_len=64,
                            backend=be).generate(PROMPTS[:2], max_new=5)
            for be in ("dense", "codebook", "lut")}
    assert outs["codebook"] == outs["dense"]
    assert outs["lut"] == outs["dense"]


def test_backend_requires_index_params(tiny):
    cfg, model, params = tiny
    with pytest.raises(ValueError, match="codebook-index"):
        ServeEngine(model, params, backend="codebook")
    with pytest.raises(ValueError, match="backend"):
        ServeEngine(model, params, backend="nope")


def test_engine_rejects_recurrent_families():
    cfg = C.get("rwkv6-7b").reduced().replace(n_layers=1, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="KV-cache"):
        ServeEngine(model, params)


def test_temperature_sampling_reproducible(tiny):
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, temperature=0.8)
    k = jax.random.PRNGKey(7)
    o1 = eng.generate(PROMPTS[:2], max_new=5, key=k)
    o2 = eng.generate(PROMPTS[:2], max_new=5, key=k)
    o3 = eng.generate(PROMPTS[:2], max_new=5, key=jax.random.PRNGKey(8))
    assert o1 == o2
    assert all(0 <= t < cfg.vocab for o in o1 for t in o)
    # a different key must actually reach the sampler
    assert o1 != o3, "temperature sampling ignored the PRNG key"


def test_int8_kv_cache_serving(tiny):
    """kv_quant engine path: int8 cache with per-slot positions stays close
    to the float path (greedy tokens may differ under quantization noise,
    but the machinery must run and produce valid tokens)."""
    cfg, model, params = tiny
    qcfg = cfg.replace(kv_quant=True)
    qmodel = build(qcfg)
    eng = ServeEngine(qmodel, params, max_len=64, max_batch=2)
    got = eng.serve(PROMPTS[:3], max_new=4)
    assert [len(o) for o in got] == [len(p) + 4 for p in PROMPTS[:3]]
    assert all(0 <= t < cfg.vocab for o in got for t in o)
