"""Golden-file decode regression: tokens + logit fingerprints per backend.

Cross-PR drift in the serving stack (like the §8 int8 chunked-prefill
readback caveat) used to surface only as silently shifted benchmark
numbers.  This pins, for the fixed seed-0 test model:

* the greedy continuation of two fixed prompts per backend
  (dense / codebook / lut), token for token,
* a prefill logit fingerprint (probe values, argmax id, logsumexp at each
  prompt's last position) compared under a small absolute tolerance —
  loose enough for BLAS reduction-order noise across machines (~1e-5),
  tight enough that any real numerics change fails loudly, and
* two serving-path rows the scheduler refactors lean on (ISSUE 5):
  ``paged_spec`` (chunked prefill + speculative rounds + page rollback)
  and ``tp2`` (the tensor-parallel decode join, run on 2 forced host
  devices through ``tests/tp_rig.py``) — token drift in either fails
  here instead of surfacing as shifted benchmark numbers.

Regenerate intentionally with:
    GOLDEN_UPDATE=1 PYTHONPATH=src pytest -q tests/test_golden_decode.py
and review the diff like any other behaviour change.
"""

import json
import os

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, SpecConfig, to_codebook_params
from tp_rig import run_under_devices

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_decode.json")
PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8]]
MAX_NEW = 6
PROBE_IDS = [0, 17, 63, 111, 256, 301, 449, 511]
ATOL = 1e-3


@pytest.fixture(scope="module")
def engines():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    cp = to_codebook_params(pq, wq, state, min_size=1024)
    return {be: ServeEngine(model, params if be == "dense" else cp,
                            max_len=64, backend=be)
            for be in ("dense", "codebook", "lut")}


def _fingerprint(eng):
    toks, lens = eng._pad_prompts(PROMPTS)
    logits, _ = eng._prefill(eng.params, toks, lens)
    lg = np.asarray(logits[:, -1, :eng.model.cfg.vocab], np.float64)
    return {
        "tokens": eng.generate(PROMPTS, max_new=MAX_NEW),
        "argmax": np.argmax(lg, axis=-1).tolist(),
        "lse": [round(float(v), 4) for v in
                np.log(np.sum(np.exp(lg - lg.max(-1, keepdims=True)), -1))
                + lg.max(-1)],
        "probe": [[round(float(lg[b, i]), 4) for i in PROBE_IDS]
                  for b in range(lg.shape[0])],
    }


def _serving_rows(engines):
    """Token-only rows for the serving paths the scheduler drives:
    paged + speculative serve, and tp=2 serve (subprocess rig)."""
    dense = engines["dense"]
    spec_eng = ServeEngine(dense.model, dense.params, max_len=64,
                           max_batch=2, paged=True, page_size=8,
                           spec=SpecConfig(draft="ngram", k=3))
    return {
        "paged_spec": {"tokens": spec_eng.serve(PROMPTS, max_new=MAX_NEW)},
        "tp2": {"tokens": run_under_devices(
            "tp_serve_cases:golden_serve_case", {"tp": 2}, n_devices=2)},
    }


def test_golden_decode_fingerprints(engines):
    got = {be: _fingerprint(eng) for be, eng in engines.items()}
    got.update(_serving_rows(engines))
    if os.environ.get("GOLDEN_UPDATE"):
        with open(GOLDEN, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
        pytest.skip("golden file regenerated — review and commit the diff")
    with open(GOLDEN) as f:
        want = json.load(f)
    assert set(got) == set(want)
    for be in want:
        assert got[be]["tokens"] == want[be]["tokens"], \
            f"{be}: greedy tokens drifted from the golden file"
        if "argmax" not in want[be]:
            continue                     # token-only serving rows
        assert got[be]["argmax"] == want[be]["argmax"], be
        np.testing.assert_allclose(got[be]["lse"], want[be]["lse"],
                                   atol=ATOL, err_msg=be)
        np.testing.assert_allclose(got[be]["probe"], want[be]["probe"],
                                   atol=ATOL, err_msg=be)
