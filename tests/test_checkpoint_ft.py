"""Checkpoint/restart fault tolerance: atomicity, resume-exactness,
failure injection, straggler monitoring, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro import checkpoint as CKPT
from repro.distributed.fault_tolerance import (FailureInjector,
                                               SimulatedFailure,
                                               StragglerMonitor)
from repro.launch.train import TrainLoopConfig, train


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    CKPT.save(str(tmp_path), 7, tree, extra={"step": 7})
    assert CKPT.latest_step(str(tmp_path)) == 7
    out, extra = CKPT.restore(str(tmp_path), 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert extra["step"] == 7


def test_incomplete_checkpoint_ignored(tmp_path):
    tree = {"a": jnp.ones((2,))}
    CKPT.save(str(tmp_path), 5, tree)
    os.makedirs(tmp_path / "step_00000009.tmp")       # crashed mid-save
    os.makedirs(tmp_path / "step_00000010")           # no manifest
    assert CKPT.latest_step(str(tmp_path)) == 5


def test_injected_failure_then_resume_matches_uninterrupted(tmp_path):
    """Kill at step 12, restart, final losses must match an uninterrupted
    run exactly (params + opt + data cursor all restored)."""
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=1)
    loop = TrainLoopConfig(steps=20, batch=2, seq=16, ckpt_dir=str(tmp_path),
                           ckpt_every=5, log_every=1)
    with pytest.raises(SimulatedFailure):
        train(cfg, loop, injector=FailureInjector(fail_at_step=12),
              log=lambda *a: None)
    assert CKPT.latest_step(str(tmp_path)) == 10
    _, _, hist_resumed = train(cfg, loop, log=lambda *a: None)

    loop2 = TrainLoopConfig(steps=20, batch=2, seq=16, ckpt_dir="",
                            log_every=1)
    _, _, hist_clean = train(cfg, loop2, log=lambda *a: None)
    resumed = {h["step"]: h["loss"] for h in hist_resumed}
    clean = {h["step"]: h["loss"] for h in hist_clean}
    for s in range(11, 20):
        assert abs(resumed[s] - clean[s]) < 1e-5, (s, resumed[s], clean[s])


def test_straggler_monitor():
    m = StragglerMonitor(factor=3.0, warmup=2)
    for _ in range(4):
        assert not m.observe(1.0)
    assert m.observe(10.0)
    assert not m.observe(1.1)
    assert m.stragglers == 1


def test_elastic_restore_new_sharding(tmp_path):
    """A checkpoint restores onto a different mesh (elastic re-shard)."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    CKPT.save(str(tmp_path), 1, tree)
    mesh = make_local_mesh(1, 1)   # whatever devices exist
    out, _ = CKPT.restore(str(tmp_path), 1, tree, mesh=mesh,
                          specs={"w": P(None, None)})
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding.mesh.shape == mesh.shape
