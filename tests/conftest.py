# NB: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real (single) CPU device; only launch/dryrun.py and the tp_rig
# subprocesses force fake device counts.
import sys
import types

import numpy as np
import pytest

# Import-safe hypothesis guard: the property suites do
# `pytest.importorskip("hypothesis")` / `from hypothesis import ...`.
# When the real dev extra is absent, register the deterministic fallback
# shim (tests/_hypothesis_fallback.py) under the same names so the
# property tests RUN instead of skipping.  The real package wins when
# installed.
try:
    import hypothesis  # noqa: F401
except ImportError:
    from _hypothesis_fallback import given, settings, strategies

    _shim = types.ModuleType("hypothesis")
    _shim.given = given
    _shim.settings = settings
    _shim.__version__ = "0.0-fallback"
    _st = types.ModuleType("hypothesis.strategies")
    for _name in dir(strategies):
        if not _name.startswith("_"):
            setattr(_st, _name, getattr(strategies, _name))
    _shim.strategies = _st
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)
