"""Per-architecture smoke tests (reduced configs, per brief) + quantized mode.

Every assigned arch: one forward + one train step on CPU, asserting output
shapes and no NaNs; decode==teacher-forced-forward equivalence for one arch
per family; the paper's technique (|A|, |W|) applied to an LM.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.registry import ASSIGNED
from repro.models import transformer as T
from repro.models.model_zoo import build
from repro.launch import steps as ST
from repro.optim import OptConfig

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model))
        b["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None],
                                          (3, B, S)).astype(jnp.int32)
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(KEY, (B, cfg.enc_len, cfg.d_model))
    return b


@pytest.mark.parametrize("name", ASSIGNED)
def test_arch_smoke_forward_and_train_step(name):
    cfg = C.get(name).reduced()
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits = model.forward(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), name

    step = jax.jit(ST.make_train_step(model, OptConfig(lr=1e-3), None))
    from repro.optim import init_opt_state
    opt = init_opt_state(params, OptConfig(lr=1e-3))
    p2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), name
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0, name


@pytest.mark.parametrize("name", ["llama3.2-3b", "qwen3-moe-30b-a3b",
                                  "rwkv6-7b", "zamba2-2.7b", "whisper-small"])
def test_decode_matches_forward(name):
    cfg = C.get(name).reduced().replace(moe_capacity=16.0)
    model = build(cfg)
    params = model.init(KEY)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    cache = model.init_cache(B, 24, dtype=jnp.float32)
    if cfg.family == "audio":
        cache["memory"] = T._encoder(params, cfg, batch["frames"], None) \
            .astype(cache["memory"].dtype)
    step = jax.jit(lambda p, t, c: model.decode(p, t, c))
    outs = []
    for t in range(S):
        lg, cache = step(params, batch["tokens"][:, t:t + 1], cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    full = model.forward(params, batch)
    assert float(jnp.max(jnp.abs(dec - full))) < 2e-2, name


@pytest.mark.parametrize("name", ["qwen3-1.7b", "rwkv6-7b"])
def test_quantized_mode_trains(name):
    """The paper's working point applied to a modern LM: quantized
    activations forward + clustered weights keep a finite, decreasing loss."""
    from repro.core.quantizer import cluster_params, init_state
    cfg = C.get(name).reduced().quantized(levels=16, n_weights=64)
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, 4, 32)
    step = jax.jit(ST.make_train_step(model, OptConfig(lr=5e-3), None))
    from repro.optim import init_opt_state
    opt = init_opt_state(params, OptConfig(lr=5e-3))
    losses = []
    qstate = init_state(cfg.wq)
    for i in range(8):
        if i == 4:   # one clustering event mid-run
            params, qstate = cluster_params(params, cfg.wq, qstate, 4, KEY)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    flat = np.concatenate([np.asarray(x, np.float32).ravel()
                           for x in jax.tree.leaves(params)])
    # weights moved off the codebook since the event — but the event itself
    # must have snapped everything to ≤ |W| uniques at that point
    assert qstate.codebooks[""].shape == (64,)


def test_arch_shape_grid_declared():
    """Every arch declares its applicable cells; long_500k only for
    sub-quadratic archs (documented-skip elsewhere)."""
    longs = {n for n in ASSIGNED if "long_500k" in C.get(n).shapes()}
    assert longs == {"zamba2-2.7b", "rwkv6-7b"}
    for n in ASSIGNED:
        assert "train_4k" in C.get(n).shapes()
        assert "prefill_32k" in C.get(n).shapes()
        assert "decode_32k" in C.get(n).shapes()


def test_exact_assigned_configs():
    """The full configs carry the exact assigned figures."""
    g = C.get("grok-1-314b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv, g.d_ff, g.vocab,
            g.n_experts, g.top_k) == (64, 6144, 48, 8, 32768, 131072, 8, 2)
    m = C.get("mistral-large-123b")
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv, m.d_ff, m.vocab) == \
        (88, 12288, 96, 8, 28672, 32768)
    z = C.get("zamba2-2.7b")
    assert (z.n_layers, z.d_model, z.ssm_state, z.vocab) == (54, 2560, 64, 32000)
    r = C.get("rwkv6-7b")
    assert (r.n_layers, r.d_model, r.d_ff, r.vocab) == (32, 4096, 14336, 65536)
    q = C.get("qwen3-moe-30b-a3b")
    assert (q.n_experts, q.top_k, q.d_ff) == (128, 8, 768)
