"""Optimizers, schedules, synthetic data pipelines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import (TokenPipeline, parabola_batch,
                                  pseudo_mnist_batch, smooth_images)
from repro.optim import (OptConfig, apply_updates, init_opt_state,
                         step_decay, warmup_cosine)


@pytest.mark.parametrize("name", ["sgd", "momentum", "rmsprop", "adam",
                                  "adamw"])
def test_optimizers_converge_quadratic(name):
    cfg = OptConfig(name=name, lr=0.1 if name in ("sgd", "momentum") else 0.05,
                    grad_clip=0.0)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params, cfg)
    loss = lambda p: jnp.sum((p["x"] - 1.0) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-3, name


def test_grad_clip():
    cfg = OptConfig(name="sgd", lr=1.0, grad_clip=1.0)
    params = {"x": jnp.zeros((4,))}
    state = init_opt_state(params, cfg)
    g = {"x": jnp.full((4,), 100.0)}
    p2, _, m = apply_updates(params, g, state, cfg)
    assert float(jnp.linalg.norm(p2["x"])) <= 1.0 + 1e-5
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_schedules():
    wc = warmup_cosine(10, 100)
    assert float(wc(0)) == 0.0
    assert float(wc(10)) == pytest.approx(1.0)
    assert float(wc(100)) == pytest.approx(0.1, abs=1e-6)
    sd = step_decay(10, 0.5)
    assert float(sd(0)) == 1.0 and float(sd(10)) == 0.5 and float(sd(25)) == 0.25


def test_token_pipeline_deterministic_and_learnable():
    p = TokenPipeline(vocab=64, batch=4, seq=32, seed=3)
    a, b = p.batch_at(5), p.batch_at(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = p.batch_at(6)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    t = np.asarray(p.batch_at(0)["tokens"])
    rep = np.mean(t[:, 1:] == t[:, :-1])   # learnable bigram structure
    assert 0.4 < rep < 0.8


def test_image_pipelines_shapes():
    m = pseudo_mnist_batch(0, batch=8)
    assert m["x"].shape == (8, 784) and m["y"].shape == (8,)
    s = smooth_images(0, batch=3, side=16)
    assert s["x"].shape == (3, 16, 16, 3)
    assert float(jnp.max(jnp.abs(s["x"]))) <= 1.0 + 1e-6
    pb = parabola_batch(0, batch=10)
    np.testing.assert_allclose(np.asarray(pb["y"]),
                               np.asarray(pb["x"]) ** 2, rtol=1e-5)
