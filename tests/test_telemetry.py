"""Deterministic-telemetry suite (DESIGN.md §13, ISSUE 7).

The telemetry contract, locked down four ways:

* **Byte-identity** — two fresh engine+scheduler replays of the seeded
  contended trace produce byte-identical metric snapshots, event logs,
  and Perfetto exports.  Every number derives from the virtual clock and
  deterministic allocator/tuner state; nothing reads the wall (the rule
  itself is pinned by test_scheduler_sim.test_no_wall_clock_in_serving,
  which scans serving/telemetry.py too).
* **Golden snapshot** — the contended reference replay's snapshot +
  event log join the golden-decode family (tests/golden_telemetry.json,
  regenerate with GOLDEN_UPDATE=1).  The snapshot is token-VALUE
  independent (counts derive from the trace's max_new bounds and
  allocator decisions), so the golden is machine-portable; only the
  platform-routed ``kernels`` section is excluded (xla vs pallas route
  names differ by platform — the byte-identity test above still covers
  it).
* **Cross-checks** — registry counters must agree with the independently
  computed ``ServerReport`` (preemptions, swap pages, token counts).
* **Units** — counter/gauge/histogram semantics, canonical rounding, the
  disabled null object, and the Perfetto event structure.
"""

import json
import os

import jax
import pytest

import repro.configs as C
from repro.models.model_zoo import build
from repro.serving import ServeEngine, Server
from repro.serving.server import CONTENDED_ENGINE_KW, contended_trace
from repro.serving.telemetry import NULL_TELEMETRY, TRACKS, Telemetry

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden_telemetry.json")


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _instrumented_replay(model, params, trace):
    """One fresh engine+scheduler drain of ``trace`` with telemetry on.
    Snapshots are taken HERE, immediately after the drain: the kernels
    provider reports deltas from attach time, so deferring the snapshot
    past another engine's tracing would fold that engine's counts in."""
    eng = ServeEngine(model, params, **CONTENDED_ENGINE_KW)
    tel = Telemetry()
    srv = Server(eng, telemetry=tel)
    rep = srv.replay(trace)
    return {"snapshot": tel.snapshot_json(),
            "events": tel.event_log_json(),
            "perfetto": json.dumps(tel.to_perfetto(), sort_keys=True),
            "tel": tel, "rep": rep, "sched": srv.sched}


# --- byte-identical replay ----------------------------------------------------

def test_replay_telemetry_byte_identical(tiny):
    """The acceptance criterion: the seeded contended trace replayed
    through two fresh engine+scheduler+registry stacks produces
    byte-identical snapshots, event logs, and Perfetto traces."""
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r1 = _instrumented_replay(model, params, trace)
    r2 = _instrumented_replay(model, params, trace)
    assert r1["rep"].preemptions >= 1, "trace is not contended — weak test"
    assert r1["snapshot"].encode() == r2["snapshot"].encode()
    assert r1["events"].encode() == r2["events"].encode()
    assert r1["perfetto"].encode() == r2["perfetto"].encode()


def test_telemetry_does_not_change_decisions(tiny):
    """Observability must be write-only: the instrumented replay's event
    log (admissions, preemptions, resumes, finishes, timestamps) equals
    the uninstrumented one's."""
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r = _instrumented_replay(model, params, trace)
    eng = ServeEngine(model, params, **CONTENDED_ENGINE_KW)
    srv = Server(eng)                      # telemetry disabled
    srv.replay(trace)
    assert srv.sched.events == r["sched"].events


# --- golden snapshot ----------------------------------------------------------

def test_golden_telemetry_snapshot(tiny):
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r = _instrumented_replay(model, params, trace)
    snap = json.loads(r["snapshot"])
    snap.pop("kernels", None)              # platform-routed (xla vs pallas)
    got = {"snapshot": snap, "events": json.loads(r["events"])}
    if os.environ.get("GOLDEN_UPDATE"):
        with open(GOLDEN, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
        pytest.skip("golden file regenerated — review and commit the diff")
    with open(GOLDEN) as f:
        want = json.load(f)
    assert got["snapshot"] == want["snapshot"], \
        "telemetry snapshot drifted from the golden contended replay"
    assert got["events"] == want["events"], \
        "telemetry event log drifted from the golden contended replay"


# --- registry vs report cross-checks ------------------------------------------

def test_counters_match_report(tiny):
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r = _instrumented_replay(model, params, trace)
    rep, c = r["rep"], json.loads(r["snapshot"])["counters"]
    assert c["sched.submitted"] == c["sched.arrivals"] == len(trace)
    assert c["sched.finished"] == rep.n_requests
    assert c["sched.preemptions"] == rep.preemptions
    assert c["sched.pages_swapped_out"] == rep.pages_swapped_out
    assert c["sched.pages_swapped_in"] == rep.pages_swapped_in
    assert c["sched.preemptions"] == c["sched.resumes"], \
        "every preempted request must resume on a drained trace"
    assert c["sched.swap_bytes_out"] == c["sched.swap_bytes_in"] > 0
    # every token is either the admission's prefill sample or a decode-
    # round emission; the registry splits them, the report sums them
    assert c["engine.tokens"] + c["sched.admissions"] == rep.n_tokens
    pool = json.loads(r["snapshot"])["pool"]
    # the pool releases whole reservations; the scheduler moves only the
    # data pages actually written — the canonical-naming distinction
    assert pool["swapped_out_pages"] >= c["sched.pages_swapped_out"]
    assert pool["peak_page_refs"] >= 1


# --- Perfetto export ----------------------------------------------------------

def test_perfetto_structure(tiny):
    """The exported trace must be loadable Chrome-trace JSON with a full
    lifecycle per request: thread metadata, X spans on the requests
    track, instants for every scheduler decision."""
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r = _instrumented_replay(model, params, trace)
    doc = json.loads(r["perfetto"])
    evs = doc["traceEvents"]
    assert doc["otherData"]["clock"] == "virtual"
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert procs == set(TRACKS)
    req_pid = TRACKS["requests"]
    for rid in range(len(trace)):
        named = {e["name"] for e in evs
                 if e.get("pid") == req_pid and e.get("tid") == rid}
        assert {"queued", "running", "admit", "finish"} <= named, \
            f"request {rid} is missing lifecycle events: {named}"
    for e in evs:
        if e["ph"] == "X":
            assert isinstance(e["ts"], int) and isinstance(e["dur"], int)
            assert e["dur"] >= 0
    # a preempted request shows the full detour: swapped span + resume
    pre = [e["tid"] for e in evs if e.get("pid") == req_pid
           and e["name"] == "preempt"]
    assert pre, "contended trace exported no preempt instants"
    names = {e["name"] for e in evs
             if e.get("pid") == req_pid and e.get("tid") == pre[0]}
    assert {"swapped", "resume"} <= names
    # slot-track spans exist for prefill and decode work
    slot_names = {e["name"] for e in evs
                  if e.get("pid") == TRACKS["slots"] and e["ph"] == "X"}
    assert {"prefill", "decode", "swap_out", "swap_in"} <= slot_names


def test_counter_tracks_round_trip(tiny):
    """ISSUE 8 satellite: the load-curve series (queue depth, pool
    pressure, batch occupancy) export as Perfetto "C" counter events on
    the counters track — value-carrying and identical between the event
    log and the Chrome-trace JSON."""
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r = _instrumented_replay(model, params, trace)
    cs = [e for e in r["tel"].event_log() if e["ph"] == "C"]
    names = {e["name"] for e in cs}
    assert {"sched.queue_depth", "pool.pressure",
            "engine.batch_occupancy"} <= names
    doc = json.loads(r["perfetto"])
    pcs = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert pcs and all(e["pid"] == TRACKS["counters"] for e in pcs)
    assert all(isinstance(e["ts"], int) for e in pcs)
    # same series, same order, same values in both exports
    assert [(e["name"], e["value"]) for e in cs] == \
        [(e["name"], e["args"]["value"]) for e in pcs]
    # occupancy counts decoding slots: bounded by the engine's max_batch
    occ = [e["value"] for e in cs if e["name"] == "engine.batch_occupancy"]
    assert occ and all(1 <= v <= CONTENDED_ENGINE_KW["max_batch"]
                       for v in occ)
    # queue depth actually moves on a contended trace
    qd = [e["value"] for e in cs if e["name"] == "sched.queue_depth"]
    assert max(qd) > 0


def test_counter_event_units():
    """counter() samples the injected clock and canonicalizes values the
    same way gauges do."""
    class FakeClock:
        def now(self):
            return 1.5

    tel = Telemetry()
    tel.bind_clock(FakeClock())
    tel.counter("q", 3)
    tel.counter("q", 0.1 + 0.2)
    log = tel.event_log()
    assert log[0] == {"ph": "C", "t": 1.5, "name": "q", "value": 3.0}
    assert log[1]["value"] == round(0.1 + 0.2, 9)


def test_export_files_round_trip(tiny, tmp_path):
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r = _instrumented_replay(model, params, trace)
    mpath, tpath = tmp_path / "m.json", tmp_path / "t.json"
    r["tel"].export_metrics(str(mpath))
    r["tel"].export_trace(str(tpath))
    with open(mpath) as f:
        assert json.load(f) == json.loads(r["snapshot"])
    with open(tpath) as f:
        assert json.load(f) == json.loads(r["perfetto"])


# --- registry units -----------------------------------------------------------

def test_registry_units():
    tel = Telemetry()
    tel.count("a")
    tel.count("a", 2)
    tel.gauge("g", 0.1 + 0.2)              # canonicalized to 9 decimals
    for v in (0, 1, 5, 200):
        tel.observe("h", v)
    snap = tel.snapshot()
    assert snap["counters"]["a"] == 3
    assert snap["gauges"]["g"] == round(0.1 + 0.2, 9)
    h = snap["histograms"]["h"]
    assert h["count"] == 4 and h["min"] == 0 and h["max"] == 200
    assert sum(h["counts"]) == 4
    assert h["counts"][-1] == 1            # 200 overflows the last edge
    # canonical JSON: sorted keys, stable across dict insertion order
    tel2 = Telemetry()
    tel2.gauge("g", 0.1 + 0.2)
    for v in (0, 1, 5, 200):
        tel2.observe("h", v)
    tel2.count("a", 3)
    assert tel.snapshot_json() == tel2.snapshot_json()


def test_providers_merge_under_prefix():
    tel = Telemetry()
    tel.add_provider("pool", lambda: {"x": 1})
    tel.add_provider("pool", lambda: {"y": 2.5})
    snap = tel.snapshot()
    assert snap["pool"] == {"x": 1, "y": 2.5}


def test_null_telemetry_is_inert():
    n = NULL_TELEMETRY
    assert n.enabled is False
    n.count("a")
    n.gauge("g", 1)
    n.observe("h", 1)
    n.instant("requests", 0, "x")
    n.open_span("requests", 0, "x")
    n.close_span("requests", 0, "x")
    n.span("slots", 0, "x", 0.0, 1.0)
    n.counter("c", 1)
    n.bind_clock(None)
    n.attach_kernel_counters()
    assert n.snapshot() == {}
    assert n.event_log() == []


# --- fleet telemetry (ISSUE 9) ------------------------------------------------

GOLDEN_FLEET = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "golden_fleet_telemetry.json")


def _instrumented_fleet_replay(model, params, trace):
    """One fresh 2-replica fleet drain with a shared registry: each
    replica's engine/scheduler/pool series lands under its ``rN.``
    scope, fleet-level routing counters under ``fleet.``."""
    from repro.serving import Fleet

    tel = Telemetry()
    fleet = Fleet([ServeEngine(model, params, **CONTENDED_ENGINE_KW)
                   for _ in range(2)], telemetry=tel)
    rep = fleet.replay(trace)
    return {"snapshot": tel.snapshot_json(),
            "events": tel.event_log_json(),
            "perfetto": json.dumps(tel.to_perfetto(), sort_keys=True),
            "tel": tel, "rep": rep, "fleet": fleet}


def test_fleet_telemetry_byte_identical(tiny):
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r1 = _instrumented_fleet_replay(model, params, trace)
    r2 = _instrumented_fleet_replay(model, params, trace)
    assert r1["snapshot"].encode() == r2["snapshot"].encode()
    assert r1["events"].encode() == r2["events"].encode()
    assert r1["perfetto"].encode() == r2["perfetto"].encode()


def test_golden_fleet_telemetry_snapshot(tiny):
    """The fleet joins the golden family: per-replica sections
    (``r0.pool``/``r1.pool``), scoped counters, and fleet routing stats
    pinned byte-for-byte (tests/golden_fleet_telemetry.json, regenerate
    with GOLDEN_UPDATE=1; ``kernels`` stays excluded — the provider is
    process-global and platform-routed)."""
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r = _instrumented_fleet_replay(model, params, trace)
    snap = json.loads(r["snapshot"])
    snap.pop("kernels", None)
    assert "r0.pool" in snap and "r1.pool" in snap
    assert any(k.startswith("r0.sched.") for k in snap["counters"])
    assert snap["counters"]["fleet.routed"] == len(trace)
    got = {"snapshot": snap, "events": json.loads(r["events"])}
    if os.environ.get("GOLDEN_UPDATE"):
        with open(GOLDEN_FLEET, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
        pytest.skip("golden file regenerated — review and commit the diff")
    with open(GOLDEN_FLEET) as f:
        want = json.load(f)
    assert got["snapshot"] == want["snapshot"], \
        "fleet telemetry snapshot drifted from the golden replay"
    assert got["events"] == want["events"], \
        "fleet telemetry event log drifted from the golden replay"


def test_fleet_perfetto_per_replica_tracks(tiny):
    """Replica-scoped tracks get their own Perfetto processes (dynamic
    pids above the four fixed tracks, first-appearance order) alongside
    the fleet control track; the fixed single-engine tracks keep their
    reserved pids."""
    model, params = tiny
    trace = contended_trace(1, model.cfg.vocab)
    r = _instrumented_fleet_replay(model, params, trace)
    doc = json.loads(r["perfetto"])
    evs = doc["traceEvents"]
    procs = {e["args"]["name"]: e["pid"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"r0.requests", "r0.slots", "r1.requests", "r1.slots",
            "fleet"} <= set(procs)
    fixed = set(TRACKS.values())
    for name in ("r0.requests", "r1.sched", "fleet"):
        assert procs[name] not in fixed, f"{name} collides with a fixed pid"
    # every request got routed somewhere: each replica's requests track
    # carries lifecycles for its share (tids are replica-LOCAL rids)
    tids = {name: {e["tid"] for e in evs
                   if e.get("pid") == procs[name] and e["ph"] != "M"}
            for name in ("r0.requests", "r1.requests")}
    assert sum(len(v) for v in tids.values()) == len(trace)


def test_span_timestamps_use_injected_clock():
    class FakeClock:
        t = 2.0

        def now(self):
            return self.t

    tel = Telemetry()
    tel.bind_clock(FakeClock())
    tel.open_span("requests", 7, "queued")
    tel.close_span("requests", 7, "queued")
    tel.instant("sched", 0, "tick")
    log = tel.event_log()
    assert log[0] == {"ph": "X", "t0": 2.0, "t1": 2.0, "track": "requests",
                      "tid": 7, "name": "queued"}
    assert log[1] == {"ph": "I", "t": 2.0, "track": "sched", "tid": 0,
                      "name": "tick"}
