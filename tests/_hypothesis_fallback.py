"""Import-safe stand-in for the ``hypothesis`` dev extra.

The property-based suites (test_activations / test_clustering /
test_export_serving / the PagePool state machine in test_paged_kvcache)
guard on ``import hypothesis`` and used to *skip* when the dev extras were
absent.  conftest.py now installs this minimal shim into ``sys.modules``
instead, so the guards become import-safe and the properties always run:
deterministic seeded random sampling over the small strategy subset the
suites use (floats / integers / sampled_from / booleans / lists / tuples),
``@given`` looping ``max_examples`` draws, ``@settings`` adjusting it.

This is NOT hypothesis — no shrinking, no database, no coverage-guided
generation.  When the real package is installed (CI does:
``pip install -e '.[dev]'``) conftest prefers it and this module is inert.
"""

from __future__ import annotations

import random
import zlib

__version__ = "0.0-fallback"


class _Strategy:
    def example(self, rng: random.Random):
        raise NotImplementedError


class _Floats(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = float(lo), float(hi)

    def example(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = int(lo), int(hi)

    def example(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, seq):
        self.seq = list(seq)

    def example(self, rng):
        return rng.choice(self.seq)


class _Booleans(_Strategy):
    def example(self, rng):
        return rng.random() < 0.5


class _Lists(_Strategy):
    def __init__(self, elem, min_size=0, max_size=None):
        self.elem = elem
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng) for _ in range(n)]


class _Tuples(_Strategy):
    def __init__(self, *elems):
        self.elems = elems

    def example(self, rng):
        return tuple(e.example(rng) for e in self.elems)


class strategies:
    """The ``hypothesis.strategies`` surface the test suites draw from."""

    @staticmethod
    def floats(min_value, max_value, **_):
        return _Floats(min_value, max_value)

    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(seq):
        return _SampledFrom(seq)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def lists(elem, min_size=0, max_size=None):
        return _Lists(elem, min_size=min_size, max_size=max_size)

    @staticmethod
    def tuples(*elems):
        return _Tuples(*elems)


_DEFAULT_EXAMPLES = 25


def given(*strats):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            # deterministic per-test stream: same examples every run
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = tuple(s.example(rng) for s in strats)
                fn(*args, *drawn, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._max_examples = _DEFAULT_EXAMPLES
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
