"""§4 export path: bit packing, entropy coding, memory accounting; serving
with codebook-index weights."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # dev extras absent: skip only the property test
    given = None

import repro.configs as C
from repro.core.export import (bits_per_index, entropy_bits, kv_cache_bytes,
                               memory_report, pack_indices, unpack_indices)
from repro.core.quantizer import (WeightQuantConfig, cluster_params,
                                  codebook_indices, init_state)
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params


if given is not None:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 16), st.integers(0, 2000))
    def test_pack_unpack_roundtrip(bits, n):
        rng = np.random.default_rng(bits * 1000 + n)
        idx = rng.integers(0, 2 ** bits, n)
        packed = pack_indices(idx, bits)
        assert packed.nbytes <= (n * bits + 7) // 8
        out = unpack_indices(packed, bits, n)
        np.testing.assert_array_equal(out, idx)


def test_entropy_bounds():
    rng = np.random.default_rng(0)
    uniform = rng.integers(0, 1024, 100_000)
    assert 9.9 < entropy_bits(uniform, 1024) <= 10.0
    const = np.zeros(1000, np.int64)
    assert entropy_bits(const, 1024) == 0.0


def test_paper_memory_claim():
    """§4: |W|=1000 ⇒ 10-bit indices ⇒ >69% savings vs fp32 on a large net;
    entropy coding of near-Laplacian indices ⇒ >78%."""
    assert bits_per_index(1000) == 10
    rng = np.random.default_rng(1)
    n = 5_000_000
    # near-Laplacian index distribution, as observed in trained nets (Fig. 3)
    centers_rank = np.clip(np.abs(rng.laplace(scale=25, size=n)), 0,
                           499).astype(np.int64)
    idx = 500 + np.sign(rng.normal(size=n)).astype(np.int64) * centers_rank
    idx = np.clip(idx, 0, 999)
    rep = memory_report({"w": jnp.asarray(idx)}, 1000, 32)
    # raw-index bound is 1 − 10/32 = 68.75% minus table amortisation; the
    # paper's "≥69%" rounds the same 10-vs-32-bit arithmetic.  The >78%
    # entropy figure is validated on a really-trained net in
    # benchmarks/memory_savings (distribution sharper than this synthetic).
    assert rep.savings_vs_fp32 > 0.675, rep.row()
    assert rep.entropy_savings_vs_fp32 > 0.74, rep.row()
    assert rep.entropy_bits_per_w < 8.0, rep.row()


def test_compressed_params_match_dense_forward():
    cfg = C.get("llama3.2-3b").reduced().replace(dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    params_q, state = cluster_params(params, wq, init_state(wq), 1000,
                                     jax.random.PRNGKey(1))
    cparams = to_codebook_params(params_q, wq, state, min_size=1024)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                          cfg.vocab)}
    lg_dense = model.forward(params_q, batch)
    lg_idx = model.forward(cparams, batch)
    np.testing.assert_allclose(np.asarray(lg_dense), np.asarray(lg_idx),
                               atol=2e-3, rtol=1e-3)
    # index tensors actually narrow
    leaves = jax.tree_util.tree_flatten_with_path(cparams)[0]
    idx_leaves = [v for kp, v in leaves if "w_idx" in str(kp[-1])]
    assert idx_leaves and all(v.dtype == jnp.int8 for v in idx_leaves)


def test_serve_engine_greedy_deterministic():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=1)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_len=32)
    p = [[1, 2, 3], [4, 5, 6]]
    o1 = eng.generate(p, max_new=5)
    o2 = eng.generate(p, max_new=5)
    assert o1 == o2
    assert all(len(o) == 8 for o in o1)
    assert all(0 <= t < cfg.vocab for o in o1 for t in o)


def test_kv_cache_bytes_accounting():
    """Serving-state accounting: int8 pages + scales vs a float slab, page
    rounding, and the end-to-end deployed figure in memory_report."""
    # 2 layers, 4 kv heads, hd 64: bf16 token = 2·64·2 B per head
    assert kv_cache_bytes(2, 4, 64, 10) == 2 * 4 * (2 * 64 * 2) * 10
    # int8 token = 2·64 + 4 scale bytes per head
    assert kv_cache_bytes(2, 4, 64, 10, quant=True) == 2 * 4 * (128 + 4) * 10
    # page rounding: 10 tokens at 16/page allocate a whole page
    assert (kv_cache_bytes(2, 4, 64, 10, quant=True, page_size=16)
            == kv_cache_bytes(2, 4, 64, 16, quant=True))
    # int8 pages beat the bf16 slab >2x whenever hd dominates the scale
    assert (kv_cache_bytes(2, 4, 64, 256, dtype_bytes=2)
            > 1.9 * kv_cache_bytes(2, 4, 64, 256, quant=True))

    idx = {"w": jnp.zeros((1_000_000,), jnp.int32)}
    rep = memory_report(idx, 1000, 32,
                        kv_fp_bytes=1_000_000, kv_packed_bytes=100_000)
    assert rep.deployed_fp_bytes == rep.fp32_bytes + 1_000_000
    assert rep.deployed_packed_bytes == rep.packed_bytes + 100_000
    assert 0.0 < rep.deployed_savings < 1.0
    assert "deployed" in rep.row()
    # backward compatible: kv fields default to zero and stay silent
    rep0 = memory_report(idx, 1000, 32)
    assert rep0.kv_fp_bytes == 0 and "deployed" not in rep0.row()


def test_memory_report_counts_lut_table_as_table_not_indices():
    """Attached ``lut_table`` leaves are int32 *tables*, not per-weight
    indices: they must not inflate n_params/entropy, and the table
    accounting must equal the actual attached pytree bytes."""
    from repro.kernels.dispatch import attach_lut_tables, make_lut_spec

    n_w = 256
    rng = np.random.default_rng(7)
    cb = jnp.asarray(rng.normal(scale=0.05, size=n_w), jnp.float32)
    tree = {"blocks": {"proj": {
        "w_idx": jnp.asarray(rng.integers(0, n_w, (64, 128)), jnp.int32),
        "codebook": cb}}}
    spec = make_lut_spec(cb, fan_in=64, levels=64)
    with_tables = attach_lut_tables(tree, spec)
    table = with_tables["blocks"]["proj"]["lut_table"]
    assert table.dtype == jnp.int32 and table.shape == (64, n_w)

    rep0 = memory_report(tree, n_w, spec.levels)
    rep = memory_report(with_tables, n_w, spec.levels)
    # index accounting identical with or without the attached tables
    assert rep.n_params == rep0.n_params == 64 * 128
    assert rep.entropy_bits_per_w == rep0.entropy_bits_per_w
    # table accounting = ACTUAL attached bytes (+ act table + codebook),
    # and the packed figure is indices + that — matching the real pytree
    assert rep.lut_table_bytes == table.nbytes
    assert rep.table_bytes == table.nbytes + 4 * spec.levels * 4 + n_w * 4
    assert rep.packed_bytes == (rep.n_params * rep.index_bits + 7) // 8 \
        + rep.table_bytes
    # without attached tables, the analytic (|A|+1)x(|W|+1) estimate holds
    assert rep0.lut_table_bytes == 0
    assert rep0.table_bytes == (spec.levels + 1) * (n_w + 1) * 4 \
        + 4 * spec.levels * 4 + n_w * 4


def test_codebook_indices_memory_on_trained_lm():
    """End-to-end §4 accounting on a real (reduced) LM after clustering."""
    cfg = C.get("qwen3-1.7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = WeightQuantConfig(num_weights=1000, method="laplacian_l1")
    params, state = cluster_params(params, wq, init_state(wq), 1000,
                                   jax.random.PRNGKey(3))
    idx_tree, _ = codebook_indices(params, wq, state)
    rep = memory_report(idx_tree, 1000, 32)
    assert rep.index_bits == 10
    assert rep.savings_vs_fp32 > 0.5          # small net: tables amortise less
    assert rep.entropy_bits_per_w < 10.0
