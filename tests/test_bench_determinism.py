"""Seeded-benchmark reproducibility gate (ISSUE 5 satellite).

``benchmarks/serve_throughput.py`` derives EVERY workload from ``--seed``
— prompts, shared prefixes, the spec-decode probe motifs, and the
scheduler's Poisson arrival trace — and the scheduler runs on a virtual
clock, so two ``--seed 0 --smoke`` runs must emit byte-identical
``BENCH_serve.json`` metric blocks once the wall-clock timing fields
(tok/s, speedups, elapsed seconds) are stripped.  Anything else means an
unseeded RNG or a wall-clock read leaked into a metric the perf
trajectory is tracked by.

The two smoke subprocesses run concurrently (~20s each, one pytest test).
"""

import json
import os
import subprocess
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)

# wall-clock-derived fields, stripped before comparison ("overhead_pct"
# is the telemetry overhead gate's measured timing ratio)
_TIMING_KEYS = {"speedup", "wall_s", "ms_per_request", "seed_speedup_at_8",
                "overhead_pct"}


def _strip(obj):
    if isinstance(obj, dict):
        return {k: _strip(v) for k, v in sorted(obj.items())
                if k not in _TIMING_KEYS and not k.endswith("tok_s")}
    if isinstance(obj, list):
        return [_strip(v) for v in obj]
    return obj


def test_seeded_smoke_metric_blocks_identical(tmp_path):
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    procs = []
    for i in (0, 1):
        out = tmp_path / f"bench{i}.json"
        procs.append((out, subprocess.Popen(
            [sys.executable,
             os.path.join(_ROOT, "benchmarks", "serve_throughput.py"),
             "--smoke", "--seed", "0", "--json-out", str(out)],
            env=env, cwd=str(tmp_path), stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True)))
    blocks = []
    for out, p in procs:
        log, _ = p.communicate(timeout=560)
        assert p.returncode == 0, f"smoke run failed:\n{log}"
        with open(out) as f:
            blocks.append(_strip(json.load(f)))
    assert blocks[0] == blocks[1], \
        "two --seed 0 --smoke runs disagree on non-timing metrics"
