"""Speculative decoding (DESIGN.md §9).

Ground truth is the baseline ServeEngine: at temperature=0 speculative
serve must reproduce it token for token — for both contiguous and paged
caches, across dense/codebook/lut target backends, for the n-gram
self-draft and the model draft (including the marquee pairing: a
coarse-grid lut-tier draft proposing for a codebook-tier target).  At
temperature>0 the output must be reproducible per PRNG key and compose
with top-k / top-p filtering.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, SpecConfig, to_codebook_params
from repro.serving.spec import (filter_logits, ngram_propose,
                                ngram_propose_host, spec_accept)

PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10], [11, 12, 13, 14]]
STOPS = [6, 3, 5, 1]


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def cparams(tiny):
    cfg, model, params = tiny
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    return to_codebook_params(pq, wq, state, min_size=1024)


@pytest.fixture(scope="module")
def baseline(tiny):
    cfg, model, params = tiny
    return ServeEngine(model, params, max_len=64,
                       max_batch=2).serve(PROMPTS, max_new=STOPS)


# --- pieces ------------------------------------------------------------------

def test_spec_accept_greedy_prefix():
    """T=0: accept while draft == target argmax; emission is the argmax row,
    so the emitted sequence equals k+1 baseline greedy steps."""
    logits = np.full((1, 4, 8), -5.0, np.float32)
    for i, t in enumerate((3, 1, 4, 2)):            # target argmax per pos
        logits[0, i, t] = 5.0
    n_acc, toks = spec_accept(jnp.asarray(logits),
                              jnp.asarray([[3, 1, 7]]), None,
                              jax.random.PRNGKey(0), temperature=0.0)
    assert int(n_acc[0]) == 2                       # 3, 1 accepted; 7 != 4
    assert toks[0, :3].tolist() == [3, 1, 4]        # correction at idx 2

    n_acc, toks = spec_accept(jnp.asarray(logits),
                              jnp.asarray([[3, 1, 4]]), None,
                              jax.random.PRNGKey(0), temperature=0.0)
    assert int(n_acc[0]) == 3                       # all in + bonus
    assert toks[0].tolist() == [3, 1, 4, 2]


def test_spec_accept_certain_target_always_accepts():
    """T>0 with a near-deterministic target: proposals matching its mode are
    accepted with probability ~1, mismatches rejected and corrected."""
    logits = np.full((1, 3, 8), -30.0, np.float32)
    for i, t in enumerate((5, 2, 6)):
        logits[0, i, t] = 30.0
    for seed in range(5):
        n_acc, toks = spec_accept(jnp.asarray(logits),
                                  jnp.asarray([[5, 2]]), None,
                                  jax.random.PRNGKey(seed), temperature=1.0)
        assert int(n_acc[0]) == 2 and toks[0].tolist() == [5, 2, 6]
        n_acc, toks = spec_accept(jnp.asarray(logits),
                                  jnp.asarray([[5, 0]]), None,
                                  jax.random.PRNGKey(seed), temperature=1.0)
        assert int(n_acc[0]) == 1 and toks[0, :2].tolist() == [5, 2]


def test_filter_logits_topk_topp():
    lg = jnp.asarray([[0.0, 1.0, 2.0, 3.0]])
    k2 = filter_logits(lg, top_k=2)
    assert (np.asarray(k2[0]) > -1e29).tolist() == [False, False, True, True]
    # top-p just over the top token's mass keeps the top two
    p = jax.nn.softmax(lg, -1)[0]
    pp = filter_logits(lg, top_p=float(p[3]) + 1e-3)
    assert (np.asarray(pp[0]) > -1e29).tolist() == [False, False, True, True]
    # argmax always survives any filter
    assert int(jnp.argmax(filter_logits(lg, top_k=1, top_p=0.01))) == 3


def test_ngram_propose_repeats_pattern():
    """A periodic context proposes its own continuation, on device and on
    host identically."""
    pat = [7, 8, 9]
    ctx_list = pat * 4
    C_ = 32
    ctx = np.zeros((1, C_), np.int32)
    ctx[0, :len(ctx_list)] = ctx_list
    dev = ngram_propose(jnp.asarray(ctx),
                        jnp.asarray([len(ctx_list)], jnp.int32), k=4, n=2)
    host = ngram_propose_host(ctx_list, k=4, n=2)
    assert dev[0].tolist() == host == [7, 8, 9, 7]


def test_ngram_propose_no_match_falls_back():
    ctx = np.zeros((1, 16), np.int32)
    ctx[0, :4] = [1, 2, 3, 4]
    dev = ngram_propose(jnp.asarray(ctx), jnp.asarray([4], jnp.int32),
                       k=3, n=2)
    assert dev[0].tolist() == [4, 4, 4]             # repeat last token
    assert ngram_propose_host([1, 2, 3, 4], k=3, n=2) == [4, 4, 4]


# --- greedy parity (the acceptance bar) --------------------------------------

def test_ngram_spec_matches_baseline_contiguous(tiny, baseline):
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2,
                      spec=SpecConfig(draft="ngram", k=3))
    assert eng.serve(PROMPTS, max_new=STOPS) == baseline
    assert eng.spec_stats.rounds > 0 and eng.spec_stats.emitted > 0


def test_model_draft_spec_matches_baseline(tiny, baseline):
    """Draft == target (dense): every proposal survives verification up to
    the stop-length clamp, and output is byte-identical."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2,
                      spec=SpecConfig(draft="model", k=3,
                                      draft_params=params,
                                      draft_backend="dense"))
    assert eng.serve(PROMPTS, max_new=STOPS) == baseline
    st = eng.spec_stats
    assert st.accepted > 0 and st.tokens_per_round > 1.0


def test_spec_quantized_target_backends(tiny, cparams):
    """codebook / lut targets: spec serve == baseline serve on index-form
    params, token for token."""
    cfg, model, params = tiny
    for be in ("codebook", "lut"):
        want = ServeEngine(model, cparams, max_len=64, max_batch=2,
                           backend=be).serve(PROMPTS[:2], max_new=4)
        got = ServeEngine(model, cparams, max_len=64, max_batch=2,
                          backend=be,
                          spec=SpecConfig(draft="ngram", k=3)
                          ).serve(PROMPTS[:2], max_new=4)
        assert got == want, be


def test_lut_draft_codebook_target(tiny, cparams):
    """The paper-spectrum pairing: the SAME index-form params served as a
    coarse-grid lut-tier draft proposing for the codebook-tier target —
    two backends, two LUT grids, one jitted round."""
    cfg, model, params = tiny
    want = ServeEngine(model, cparams, max_len=64, max_batch=2,
                       backend="codebook").serve(PROMPTS[:2], max_new=5)
    eng = ServeEngine(model, cparams, max_len=64, max_batch=2,
                      backend="codebook",
                      spec=SpecConfig(draft="model", k=3,
                                      draft_params=cparams,
                                      draft_backend="lut", lut_levels=512))
    assert eng.serve(PROMPTS[:2], max_new=5) == want
    assert eng.spec_stats.proposed > 0


def test_paged_spec_matches_baseline(tiny, baseline):
    """Paged spec (Python-stepped rounds + PagePool truncate/extend
    rollback) reproduces the contiguous baseline, bf16 and int8 pages."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4, spec=SpecConfig(draft="ngram", k=3))
    assert eng.serve(PROMPTS, max_new=STOPS) == baseline
    assert eng.spec_stats.rounds > 0
    pool = eng.pool
    assert pool.reserved_extra == 0                 # every claim settled
    # int8 pages: parity vs the non-spec int8 paged engine
    want8 = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                        page_size=4, kv_dtype="int8"
                        ).serve(PROMPTS, max_new=6)
    got8 = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                       page_size=4, kv_dtype="int8",
                       spec=SpecConfig(draft="ngram", k=3)
                       ).serve(PROMPTS, max_new=6)
    assert got8 == want8


def test_paged_spec_model_draft(tiny, baseline):
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4,
                      spec=SpecConfig(draft="model", k=3,
                                      draft_params=params,
                                      draft_backend="dense"))
    assert eng.serve(PROMPTS, max_new=STOPS) == baseline
    assert eng.spec_stats.accepted > 0


def test_spec_repetitive_workload_accepts(tiny):
    """On a repetitive-suffix workload the self-draft's acceptance rate is
    material — the condition under which speculation pays."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=96, max_batch=2,
                      spec=SpecConfig(draft="model", k=4,
                                      draft_params=params,
                                      draft_backend="dense"))
    eng.serve(PROMPTS[:2], max_new=24)
    assert eng.spec_stats.acceptance_rate > 0.5


# --- sampling ----------------------------------------------------------------

def test_spec_topk1_sampling_equals_greedy(tiny, baseline):
    """top_k=1 collapses sampling to argmax — with and without spec — so
    rejection sampling provably composes with the filtered distribution."""
    cfg, model, params = tiny
    want = ServeEngine(model, params, max_len=64,
                       max_batch=2).serve(PROMPTS, max_new=5)
    got_plain = ServeEngine(model, params, max_len=64, max_batch=2,
                            temperature=0.7, top_k=1
                            ).serve(PROMPTS, max_new=5)
    assert got_plain == want
    got_spec = ServeEngine(model, params, max_len=64, max_batch=2,
                           temperature=0.7, top_k=1,
                           spec=SpecConfig(draft="ngram", k=3)
                           ).serve(PROMPTS, max_new=5)
    assert got_spec == want
    # a tiny nucleus keeps only the top token: same collapse through top_p
    got_p = ServeEngine(model, params, max_len=64, max_batch=2,
                        temperature=0.7, top_p=1e-6,
                        spec=SpecConfig(draft="ngram", k=3)
                        ).serve(PROMPTS, max_new=5)
    assert got_p == want


def test_spec_sampling_reproducible_per_key(tiny):
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2,
                      temperature=0.8, top_k=50, top_p=0.9,
                      spec=SpecConfig(draft="ngram", k=3))
    o1 = eng.serve(PROMPTS[:2], max_new=5, key=jax.random.PRNGKey(7))
    o2 = eng.serve(PROMPTS[:2], max_new=5, key=jax.random.PRNGKey(7))
    o3 = eng.serve(PROMPTS[:2], max_new=5, key=jax.random.PRNGKey(8))
    assert o1 == o2
    assert o1 != o3, "spec sampling ignored the PRNG key"
    assert all(0 <= t < cfg.vocab for o in o1 for t in o)


def test_topk_topp_plain_sampling_valid(tiny):
    """Non-spec sampling path: filters restrict the support."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2,
                      temperature=1.5, top_k=5)
    outs = eng.serve(PROMPTS[:2], max_new=6, key=jax.random.PRNGKey(3))
    assert all(0 <= t < cfg.vocab for o in outs for t in o)


# --- guards ------------------------------------------------------------------

def test_spec_config_validation(tiny):
    cfg, model, params = tiny
    with pytest.raises(ValueError, match="draft_params"):
        ServeEngine(model, params, spec=SpecConfig(draft="model"))
    with pytest.raises(ValueError, match="draft"):
        ServeEngine(model, params, spec=SpecConfig(draft="nope"))
    with pytest.raises(ValueError, match="spec.k"):
        ServeEngine(model, params, spec=SpecConfig(k=0))
    with pytest.raises(ValueError, match="top_p"):
        ServeEngine(model, params, top_p=0.0)
    eng = ServeEngine(model, params, max_len=16,
                      spec=SpecConfig(draft="ngram", k=4))
    with pytest.raises(ValueError, match="headroom"):
        eng.serve([[1, 2, 3, 4]], max_new=10)       # 4+10+4 > 16
