"""Fleet-serving suite (DESIGN.md §15, ISSUE 9).

The fleet contract, locked down four ways:

* **Reduction** — ``Fleet`` with one replica IS the single server:
  token-for-token streams, identical scheduler decisions, identical
  ``ServerReport`` on the PR 5 contended reference trace.
* **Determinism** — a seeded 4-replica contended trace replays
  byte-identically (merged event log, per-request streams, report,
  digest) across fresh runs AND across permuted replica construction
  order; drain/scale-up mid-trace replays byte-identically too.  The
  streamed-trace path produces the same bytes as the list path.
* **Routing** — prefix-aware routing sends a shared-system-prompt
  workload to the replicas that already hold the prefix chain: the
  fleet-wide prefix hit rate must measurably beat round-robin.
* **Invariants** — a hypothesis state machine walks a fleet of stub
  engines (REAL ``PagePool`` allocation under each) through
  route/drain/scale/preempt transitions: no request lost or
  double-admitted, per-replica page claims conserved, drained replicas
  reach zero load in bounded rounds.

Swap accounting is cross-checked registry-vs-report: the fleet report
sums the schedulers' *data*-page counters and never the pools' released
*reference* counters (the §13 dual-unit rule).
"""

import json

import numpy as np
import pytest

from repro.serving import (Fleet, FleetRouter, ServeEngine, Server,
                           Telemetry, poisson_trace)
from repro.serving.kvcache import chain_keys
from repro.serving.scheduler import FINISHED
from repro.serving.server import (CONTENDED_ENGINE_KW, contended_trace,
                                  iter_trace, load_trace,
                                  poisson_trace_iter, save_trace)
from test_scheduler_sim import _StubEngine, tiny  # noqa: F401  (fixture)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # direct (non-pytest) imports
    from _hypothesis_fallback import given, settings, strategies as st

STUB_KW = dict(max_batch=2, n_pages=9, page_size=8)


def grouped_trace(seed, n, *, n_groups=4, page=8, rate=100.0, vocab=50,
                  max_new=(2, 6)):
    """The shared-system-prompt workload: every request opens with one of
    ``n_groups`` two-page system prefixes, then a private suffix — the
    case prefix-aware routing exists for."""
    rng = np.random.default_rng(seed)
    prefixes = [[int(x) for x in rng.integers(0, vocab, 2 * page)]
                for _ in range(n_groups)]
    t, rows = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        g = int(rng.integers(n_groups))
        sfx = [int(x) for x in
               rng.integers(0, vocab, int(rng.integers(1, page)))]
        rows.append({"arrival": round(t, 9), "prompt": prefixes[g] + sfx,
                     "max_new": int(rng.integers(max_new[0],
                                                 max_new[1] + 1)),
                     "priority": 0, "slo_ttft": None, "slo_tpot": None})
    return rows


# --- prefix-key exposure (kvcache -> router) ----------------------------------

def test_chain_keys_prefix_property():
    toks = list(range(20))
    keys, partial = chain_keys(toks, 8)
    assert len(keys) == 2 and partial is not None
    assert chain_keys(toks[:8], 8)[0] == keys[:1]
    assert chain_keys(toks[:16], 8)[0] == keys
    assert chain_keys([99] + toks[1:], 8)[0][0] != keys[0]
    assert chain_keys(toks[:16], 8)[1] is None     # aligned: no tail key
    assert chain_keys(toks[:3], 8) == ([], (("root",), (0, 1, 2)))


def test_prefix_match_pages_matches_admit_and_is_read_only():
    eng = _StubEngine(max_batch=2, n_pages=12, page_size=8)
    pool = eng.pool
    toks = list(range(20))                 # 2 full pages + a 4-token tail
    assert pool.prefix_match_pages(toks) == 0
    st_ = eng.sched_state()
    assert eng.sched_admit(st_, 0, toks, 2) is not None
    eng.sched_release(st_, 0)              # retire registers the tail too
    assert pool.prefix_match_pages(toks) == 3
    assert pool.prefix_match_pages(toks[:8]) == 1
    assert pool.prefix_match_pages(toks[:12]) == 1  # tail (8..11) unknown
    assert pool.prefix_match_pages([99] + toks[1:]) == 0
    order = list(pool.table)
    pool.prefix_match_pages(toks)          # probing must not touch the LRU
    assert list(pool.table) == order


# --- the router policy itself -------------------------------------------------

class _FakeProbe:
    def __init__(self, match=0, load=0, free=0):
        self.m, self.l, self.f = match, load, free

    def prefix_match_pages(self, toks):
        return self.m

    def load(self):
        return self.l

    def free_pages(self):
        return self.f


def test_router_scoring_and_ties():
    r = FleetRouter()
    r.add("r1", _FakeProbe(match=2))
    r.add("r0", _FakeProbe(match=0, free=5))
    assert r.route([1]) == "r1"            # prefix beats free pages
    r.probes["r0"].m = 2
    r.probes["r1"].l = 1
    assert r.route([1]) == "r0"            # equal prefix: lighter load wins
    r.probes["r1"].l = 0
    assert r.route([1]) == "r0"            # full tie: smallest id, always
    r.drain("r0")
    assert r.route([1]) == "r1"
    r.drain("r1")
    with pytest.raises(RuntimeError, match="no admitting replica"):
        r.route([1])
    with pytest.raises(ValueError, match="unknown policy"):
        FleetRouter(policy="sticky")


def test_router_round_robin_cycles_admitting():
    r = FleetRouter(policy="round_robin")
    for rep in ("r2", "r0", "r1"):
        r.add(rep, _FakeProbe())
    got = [r.route([1]) for _ in range(6)]
    assert got == ["r0", "r1", "r2", "r0", "r1", "r2"]
    r.drain("r1")
    assert {r.route([1]) for _ in range(4)} == {"r0", "r2"}


# --- fleet(N=1) == Server -----------------------------------------------------

def test_fleet_n1_matches_server(tiny):
    """One-replica fleet == single server on the PR 5 contended trace:
    same tokens, same scheduler decisions, same report."""
    model, params, _ = tiny
    trace = contended_trace(1, model.cfg.vocab)
    srv = Server(ServeEngine(model, params, **CONTENDED_ENGINE_KW))
    rep_s = srv.replay(trace)
    fleet = Fleet([ServeEngine(model, params, **CONTENDED_ENGINE_KW)])
    rep_f = fleet.replay(trace)
    assert rep_s.preemptions >= 1, "trace is not contended — weak test"
    assert rep_f.to_json() == rep_s.to_json()
    assert {frid: h.tokens for frid, h in fleet.handles.items()} == \
        {h.rid: h.tokens for h in srv.sched.handles.values()}
    # same decision record: the fleet merely tags + defers submits, every
    # scheduling event lands at the same instant with the same request
    decisions = ("arrive", "admit", "preempt", "resume", "finish")
    assert [(t, k, r) for t, _, k, r in fleet.events if k in decisions] \
        == [(t, k, r) for t, k, r in srv.sched.events if k in decisions]


# --- seeded 4-replica byte-identical replay -----------------------------------

def _fleet_replay(model, params, order, *, policy="prefix", drain_at=(),
                  scale_at=(), n=24, telemetry=None):
    engines = {rep: ServeEngine(model, params, **CONTENDED_ENGINE_KW)
               for rep in order}
    fleet = Fleet(engines, policy=policy, telemetry=telemetry)
    trace = poisson_trace(1, n, rate=60.0, vocab=model.cfg.vocab,
                          plen=(2, 9), max_new=(2, 10), priorities=(0, 1))
    rep = fleet.replay(trace, drain_at=drain_at, scale_at=scale_at)
    streams = {frid: list(h.tokens) for frid, h in fleet.handles.items()}
    return fleet, rep, streams


def test_fleet_replay_byte_identical_across_runs_and_replica_order(tiny):
    """The acceptance criterion: events, streams, report, and digest are
    identical across two fresh runs AND across a permuted replica
    construction order."""
    model, params, _ = tiny
    runs = [_fleet_replay(model, params, order) for order in
            (["r0", "r1", "r2", "r3"], ["r0", "r1", "r2", "r3"],
             ["r2", "r0", "r3", "r1"])]
    f0, rep0, st0 = runs[0]
    assert rep0.preemptions >= 1, "fleet trace is not contended — weak test"
    assert rep0.n_requests == 24
    for f, rep, st_ in runs[1:]:
        assert f.events == f0.events
        assert f.event_digest() == f0.event_digest()
        assert st_ == st0
        assert rep.to_json() == rep0.to_json()


def test_drain_and_scale_replay_byte_identical(tiny):
    """Mid-trace drain + scale-up stay inside the determinism contract,
    the drained replica reaches zero load, and the joiner takes traffic."""
    model, params, _ = tiny
    mk = lambda: ServeEngine(model, params,         # noqa: E731
                             **CONTENDED_ENGINE_KW)
    runs = [_fleet_replay(model, params, ["r0", "r1"], n=16,
                          drain_at=[(0.12, "r0")],
                          scale_at=[(0.18, "r2", mk)]) for _ in range(2)]
    f0, rep0, st0 = runs[0]
    f1, rep1, st1 = runs[1]
    assert f0.events == f1.events and st0 == st1
    assert f0.event_digest() == f1.event_digest()
    assert rep0.to_json() == rep1.to_json()
    assert f0.inflight["r0"] == 0          # drained to zero running
    assert f0.n_routed_to["r2"] > 0        # the joiner actually serves
    drained_at = next(t for t, _, k, _ in f0.events if k == "drain")
    late = [(t, rep) for t, rep, k, _ in f0.events
            if k == "route" and t > drained_at]
    assert late and all(rep != "r0" for rep in {r for _, r in late})
    assert all(h.state == FINISHED for h in f0.handles.values())


def test_fleet_streamed_replay_matches_list_replay():
    """Generator traces (one-row lookahead) and retain=False (digest-only
    log, handles released) produce the same bytes as the list path."""
    kw = dict(rate=150.0, vocab=50, plen=(2, 9), max_new=(2, 8),
              priorities=(0, 1))
    f_list = Fleet([_StubEngine(**STUB_KW) for _ in range(3)])
    rep_list = f_list.replay(poisson_trace(5, 300, **kw))
    f_iter = Fleet([_StubEngine(**STUB_KW) for _ in range(3)],
                   retain=False)
    rep_iter = f_iter.replay(poisson_trace_iter(5, 300, **kw))
    assert f_iter.event_digest() == f_list.event_digest()
    assert not f_iter.handles and not f_iter.assigned  # released as it ran
    d = rep_list.to_json()
    d["admission_order"] = []              # digest-only mode drops the log
    assert rep_iter.to_json() == d


def test_fleet_streamed_replay_rejects_unsorted_arrivals():
    rows = [{"arrival": 0.2, "prompt": [1, 2], "max_new": 2},
            {"arrival": 0.1, "prompt": [3, 4], "max_new": 2}]
    fleet = Fleet([_StubEngine(**STUB_KW)])
    with pytest.raises(ValueError, match="non-decreasing"):
        fleet.replay(iter(rows))


# --- prefix-aware routing beats round-robin -----------------------------------

def test_prefix_routing_beats_round_robin_on_shared_prefixes():
    """Four system-prompt groups over four replicas: affinity routing
    keeps each group's chain hot on one pool; round-robin scatters it.
    The fleet-wide prefix hit rate must show the gap."""
    trace = grouped_trace(0, 120)
    rates = {}
    for policy in ("prefix", "round_robin"):
        fleet = Fleet([_StubEngine(max_batch=2, n_pages=10, page_size=8)
                       for _ in range(4)], policy=policy)
        fleet.replay(trace)
        rates[policy] = fleet.prefix_hit_rate()
    assert rates["prefix"] > rates["round_robin"] + 0.1, rates
    assert rates["prefix"] > 0.5


# --- swap-stat aggregation: registry vs report (§13 dual units) ---------------

def test_fleet_swap_stats_registry_vs_report(tiny):
    """The fleet report's swap fields are per-replica sums of the
    schedulers' data-page counters — never the pools' released-reference
    counters, which count a different unit and would double-dip."""
    model, params, _ = tiny
    tel = Telemetry()
    fleet, rep, _ = _fleet_replay(model, params, ["r0", "r1"], n=24,
                                  telemetry=tel)
    assert rep.preemptions >= 1, "no contention — weak test"
    snap = tel.snapshot()
    c = snap["counters"]
    reps = sorted(fleet.replicas)
    sched_out = sum(c.get(f"{r}.sched.pages_swapped_out", 0) for r in reps)
    sched_in = sum(c.get(f"{r}.sched.pages_swapped_in", 0) for r in reps)
    assert rep.pages_swapped_out == sched_out
    assert rep.pages_swapped_in == sched_in
    assert rep.preemptions == sum(c.get(f"{r}.sched.preemptions", 0)
                                  for r in reps)
    pool_out = sum(snap[f"{r}.pool"]["swapped_out_pages"] for r in reps)
    # references released >= data pages moved (the reservation tail) —
    # summing the two vocabularies together would overcount
    assert pool_out >= sched_out
    assert rep.pages_swapped_out == sum(
        s["pages_swapped_out"] for s in fleet.replica_stats().values())
    assert rep.n_tokens == sum(
        c.get(f"{r}.engine.tokens", 0) + c.get(f"{r}.sched.admissions", 0)
        for r in reps)


# --- hypothesis state machine over the fleet ----------------------------------

class _FleetWalk:
    """Random walk over submit/step/drain/scale on stub-engine replicas,
    checking the fleet invariants after every transition, then a full
    drain: no request lost or double-admitted, per-replica page claims
    conserved, drained replicas reach zero load in bounded rounds."""

    def __init__(self, rng):
        self.rng = rng
        self.fleet = Fleet({"r0": _StubEngine(**STUB_KW),
                            "r1": _StubEngine(**STUB_KW)})
        self.drained = []
        self.scaled = False

    def submit(self):
        page = STUB_KW["page_size"]
        plen = int(self.rng.integers(1, 2 * page + 1))
        prompt = [int(t) for t in self.rng.integers(0, 3, plen)]
        dt = float(self.rng.choice([0.0, 0.0, 0.01, 0.05]))
        self.fleet.submit(prompt, int(self.rng.integers(1, 2 * page + 1)),
                          priority=int(self.rng.integers(0, 3)),
                          arrival=self.fleet.clock.now() + dt)

    def step(self):
        self.fleet.step()

    def drain(self):
        if len(self.fleet.router.admitting) > 1:
            rep = self.fleet.router.admitting[
                int(self.rng.integers(len(self.fleet.router.admitting)))]
            self.fleet.drain(rep)
            self.drained.append(rep)

    def scale(self):
        if not self.scaled:
            self.fleet.add_replica("r2", _StubEngine(**STUB_KW))
            self.scaled = True

    def check(self):
        fleet = self.fleet
        # -- conservation: every submitted request is unrouted XOR
        #    assigned to exactly one replica, never dropped, never dual
        seen = dict(fleet._rows)
        for frid, (rep, lrid) in fleet.assigned.items():
            assert frid not in seen, f"request {frid} routed AND pending"
            h = fleet.replicas[rep].handles[lrid]
            assert fleet._local2fleet[rep][lrid] == frid
            seen[frid] = h
        assert sorted(seen) == list(range(fleet._seq)), "request lost"
        for rep, sched in fleet.replicas.items():
            local = fleet._local2fleet[rep]
            assert len(set(local.values())) == len(local), \
                f"{rep}: a request admitted twice"
            unfinished = sum(1 for h in sched.handles.values()
                             if h.state != FINISHED)
            assert fleet.inflight[rep] == unfinished
            # -- per-replica page-claim conservation over the REAL pool
            pool = sched.engine.pool
            holders = {}
            for h in sched.running:
                adm = sched.st.adm[h.slot]
                for pid in adm.pids[:adm.n_live]:
                    assert pid != 0
                    holders[pid] = holders.get(pid, 0) + 1
            for pid in range(1, pool.n_pages):
                want = holders.get(pid, 0) + (1 if pid in pool.key_of
                                              else 0)
                assert pool.ref[pid] == want, \
                    f"{rep}: refcount leak on page {pid}"
            assert pool.reserved_extra == 0
        # -- drained replicas take no new work
        for rep in self.drained:
            assert rep not in fleet.router.admitting

    def run(self, n_ops=40):
        ops = [self.submit, self.submit, self.step, self.step, self.step,
               self.drain, self.scale]
        self.check()
        for _ in range(n_ops):
            ops[self.rng.integers(len(ops))]()
            self.check()
        # bounded-rounds drain: every request finishes, drained replicas
        # hit zero load (a stall fails instead of hanging)
        self.fleet.run_until_idle(max_rounds=5000)
        self.check()
        assert sum(fleet_h.state == FINISHED
                   for fleet_h in self.fleet.handles.values()) \
            == self.fleet._seq
        for h in self.fleet.handles.values():
            assert len(h.tokens) == h.max_new
        for rep in self.drained:
            assert self.fleet.inflight[rep] == 0


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_fleet_state_machine_invariants(seed):
    _FleetWalk(np.random.default_rng(seed)).run()


# --- streamed traffic plumbing ------------------------------------------------

def test_poisson_trace_iter_matches_list():
    kw = dict(rate=30.0, vocab=64, plen=(2, 6), max_new=(1, 5),
              priorities=(0, 1), slo_ttft=0.5)
    assert list(poisson_trace_iter(9, 40, **kw)) == \
        poisson_trace(9, 40, **kw)
    pref = [7, 7, 7]
    assert all(r["prompt"][:3] == pref for r in
               poisson_trace_iter(9, 10, shared_prefix=pref))


def test_trace_stream_roundtrip(tmp_path):
    """save_trace streams a generator to disk; iter_trace streams it back
    row-identical to load_trace — across buffer-boundary splits too."""
    trace = poisson_trace(3, 25, vocab=100, priorities=(0, 1),
                          slo_ttft=0.25)
    path = str(tmp_path / "trace.json")
    save_trace(path, iter(trace))          # generator, not a list
    assert load_trace(path) == trace
    assert list(iter_trace(path)) == trace
    assert list(iter_trace(path, chunk=17)) == trace  # force row splits
    assert json.load(open(path)) == trace  # still one plain JSON array
