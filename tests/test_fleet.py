"""Fleet-serving suite (DESIGN.md §15, ISSUEs 9-10).

The fleet contract, locked down four ways:

* **Reduction** — ``Fleet`` with one replica IS the single server:
  token-for-token streams, identical scheduler decisions, identical
  ``ServerReport`` on the PR 5 contended reference trace.
* **Determinism** — a seeded 4-replica contended trace replays
  byte-identically (merged event log, per-request streams, report,
  digest) across fresh runs AND across permuted replica construction
  order; drain/scale-up mid-trace replays byte-identically too.  The
  streamed-trace path produces the same bytes as the list path.
* **Routing** — prefix-aware routing sends a shared-system-prompt
  workload to the replicas that already hold the prefix chain: the
  fleet-wide prefix hit rate must measurably beat round-robin.
* **Invariants** — a hypothesis state machine walks a fleet of stub
  engines (REAL ``PagePool`` allocation under each) through
  route/drain/scale/preempt transitions: no request lost or
  double-admitted, per-replica page claims conserved, drained replicas
  reach zero load in bounded rounds.

Swap accounting is cross-checked registry-vs-report: the fleet report
sums the schedulers' *data*-page counters and never the pools' released
*reference* counters (the §13 dual-unit rule).

ISSUE 10 adds the shared-KV tentpole on top: drain-time migration
(expel/adopt) must continue token streams bit-exactly on a survivor,
the fleet-level ``SharedPrefixTier`` must serve a cross-replica prefix
hit indistinguishably from a local one, all-drained arrivals defer
until a scale-up instead of crashing, and router backpressure sheds by
SLO class — all inside the same byte-identical-replay contract.
"""

import json
import time

import numpy as np
import pytest

from repro.serving import (Fleet, FleetRouter, ServeEngine, Server,
                           Telemetry, poisson_trace)
from repro.serving.kvcache import SharedPrefixTier, chain_keys
from repro.serving.scheduler import FINISHED
from repro.serving.server import (CONTENDED_ENGINE_KW, contended_trace,
                                  iter_trace, load_trace,
                                  poisson_trace_iter, save_trace)
from test_scheduler_sim import _StubEngine, tiny  # noqa: F401  (fixture)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # direct (non-pytest) imports
    from _hypothesis_fallback import given, settings, strategies as st

STUB_KW = dict(max_batch=2, n_pages=9, page_size=8)


def grouped_trace(seed, n, *, n_groups=4, page=8, rate=100.0, vocab=50,
                  max_new=(2, 6)):
    """The shared-system-prompt workload: every request opens with one of
    ``n_groups`` two-page system prefixes, then a private suffix — the
    case prefix-aware routing exists for."""
    rng = np.random.default_rng(seed)
    prefixes = [[int(x) for x in rng.integers(0, vocab, 2 * page)]
                for _ in range(n_groups)]
    t, rows = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        g = int(rng.integers(n_groups))
        sfx = [int(x) for x in
               rng.integers(0, vocab, int(rng.integers(1, page)))]
        rows.append({"arrival": round(t, 9), "prompt": prefixes[g] + sfx,
                     "max_new": int(rng.integers(max_new[0],
                                                 max_new[1] + 1)),
                     "priority": 0, "slo_ttft": None, "slo_tpot": None})
    return rows


# --- prefix-key exposure (kvcache -> router) ----------------------------------

def test_chain_keys_prefix_property():
    toks = list(range(20))
    keys, partial = chain_keys(toks, 8)
    assert len(keys) == 2 and partial is not None
    assert chain_keys(toks[:8], 8)[0] == keys[:1]
    assert chain_keys(toks[:16], 8)[0] == keys
    assert chain_keys([99] + toks[1:], 8)[0][0] != keys[0]
    assert chain_keys(toks[:16], 8)[1] is None     # aligned: no tail key
    # rolling-digest schema (ISSUE 10): every key is a fixed-size opaque
    # digest — O(1) to hash or compare no matter how deep the chain
    assert all(isinstance(k, bytes) and len(k) == 16 for k in keys)
    assert isinstance(partial, bytes) and len(partial) == 16
    short_keys, short_partial = chain_keys(toks[:3], 8)
    assert short_keys == [] and isinstance(short_partial, bytes)
    # the tail digest is chained off the last full page, not standalone
    assert chain_keys(toks[:11], 8)[1] != chain_keys(toks[8:11], 8)[1]
    # machine-independent: same tokens -> same bytes in every process
    # (the property that lets the fleet tier share keys across pools)
    assert chain_keys([1, 2, 3], 8) == chain_keys([1, 2, 3], 8)


def test_chain_keys_are_linear_time_with_O1_hashing():
    """ISSUE 10 bugfix pin: keys used to be nested tuples whose hash and
    equality walked the whole chain — O(pages^2 * page_size) to build and
    probe a long prompt's table entries.  The rolling digest keeps key
    construction linear and every dict operation O(1); a 4096-page chain
    must build + table-probe in well under the old quadratic blowup."""
    page = 8
    toks = np.arange(4096 * page) % 50
    t0 = time.perf_counter()
    keys, partial = chain_keys(toks, page)
    table = {k: i for i, k in enumerate(keys)}
    assert all(k in table for k in keys)
    dt = time.perf_counter() - t0
    assert partial is None and len(keys) == 4096
    assert len(set(keys)) == 4096          # no chain collisions
    assert dt < 2.0, f"chain-key build+probe took {dt:.2f}s — quadratic?"


def test_prefix_match_pages_matches_admit_and_is_read_only():
    eng = _StubEngine(max_batch=2, n_pages=12, page_size=8)
    pool = eng.pool
    toks = list(range(20))                 # 2 full pages + a 4-token tail
    assert pool.prefix_match_pages(toks) == 0
    st_ = eng.sched_state()
    assert eng.sched_admit(st_, 0, toks, 2) is not None
    eng.sched_release(st_, 0)              # retire registers the tail too
    assert pool.prefix_match_pages(toks) == 3
    assert pool.prefix_match_pages(toks[:8]) == 1
    assert pool.prefix_match_pages(toks[:12]) == 1  # tail (8..11) unknown
    assert pool.prefix_match_pages([99] + toks[1:]) == 0
    order = list(pool.table)
    pool.prefix_match_pages(toks)          # probing must not touch the LRU
    assert list(pool.table) == order


# --- the router policy itself -------------------------------------------------

class _FakeProbe:
    def __init__(self, match=0, load=0, free=0, pressure=0.0):
        self.m, self.l, self.f, self.p = match, load, free, pressure

    def prefix_match_pages(self, toks):
        return self.m

    def load(self):
        return self.l

    def free_pages(self):
        return self.f

    def pressure(self):
        return self.p


def test_router_scoring_and_ties():
    r = FleetRouter()
    r.add("r1", _FakeProbe(match=2))
    r.add("r0", _FakeProbe(match=0, free=5))
    assert r.route([1]) == "r1"            # prefix beats free pages
    r.probes["r0"].m = 2
    r.probes["r1"].l = 1
    assert r.route([1]) == "r0"            # equal prefix: lighter load wins
    r.probes["r1"].l = 0
    assert r.route([1]) == "r0"            # full tie: smallest id, always
    r.drain("r0")
    assert r.route([1]) == "r1"
    r.drain("r1")
    with pytest.raises(RuntimeError, match="no admitting replica"):
        r.route([1])
    with pytest.raises(ValueError, match="unknown policy"):
        FleetRouter(policy="sticky")


def test_router_round_robin_cycles_admitting():
    r = FleetRouter(policy="round_robin")
    for rep in ("r2", "r0", "r1"):
        r.add(rep, _FakeProbe())
    got = [r.route([1]) for _ in range(6)]
    assert got == ["r0", "r1", "r2", "r0", "r1", "r2"]
    r.drain("r1")
    assert {r.route([1]) for _ in range(4)} == {"r0", "r2"}


def test_router_rr_cursor_survives_membership_changes():
    """ISSUE 10 bugfix pin: the RR cursor is policy-local and
    membership-aware — after a drain or scale-up the rotation resumes
    from the last replica actually served and stays exactly balanced,
    instead of a global route counter's modulo skewing the cycle."""
    from collections import Counter
    r = FleetRouter(policy="round_robin")
    for rep in ("r0", "r1", "r2"):
        r.add(rep, _FakeProbe())
    assert [r.route([1]) for _ in range(4)] == ["r0", "r1", "r2", "r0"]
    r.drain("r0")                       # drop the replica just served
    assert [r.route([1]) for _ in range(4)] == ["r1", "r2", "r1", "r2"]
    r.add("r3", _FakeProbe())           # joiner slots into the rotation
    assert [r.route([1]) for _ in range(6)] == \
        ["r3", "r1", "r2", "r3", "r1", "r2"]
    # exact balance over a long horizon after the churn
    cnt = Counter(r.route([1]) for _ in range(30))
    assert cnt == {"r1": 10, "r2": 10, "r3": 10}
    assert r.n_routed == 4 + 4 + 6 + 30  # statistics only, not the cursor


def test_router_decide_defer_and_shed():
    """``decide()`` wraps ``route()`` with the admission gate: defer
    when nothing admits, shed/defer by SLO class when every admitting
    replica is over the pressure threshold (ISSUE 10)."""
    r = FleetRouter(shed_policy="slo", shed_threshold=0.8)
    r.add("r0", _FakeProbe(pressure=0.9))
    r.add("r1", _FakeProbe(pressure=0.97))
    assert r.pressure() == pytest.approx(0.9)   # least-pressured admitter
    assert r.decide([1], has_slo=True) == ("shed", None)
    assert r.decide([1], has_slo=False) == ("defer", None)
    assert r.n_shed == 1
    r.probes["r0"].p = 0.2                      # one replica clears
    kind, rep = r.decide([1], has_slo=True)
    assert kind == "route" and rep in ("r0", "r1")
    # "all" sheds regardless of class; "defer" never sheds
    r_all = FleetRouter(shed_policy="all", shed_threshold=0.5)
    r_all.add("r0", _FakeProbe(pressure=0.6))
    assert r_all.decide([1]) == ("shed", None)
    r_def = FleetRouter(shed_policy="defer", shed_threshold=0.5)
    r_def.add("r0", _FakeProbe(pressure=0.6))
    assert r_def.decide([1]) == ("defer", None)
    # all replicas draining: decide defers, route() still fails loudly
    r2 = FleetRouter()
    r2.add("r0", _FakeProbe())
    r2.drain("r0")
    assert r2.decide([1]) == ("defer", None)
    assert r2.pressure() == 1.0
    with pytest.raises(RuntimeError, match="no admitting replica"):
        r2.route([1])
    with pytest.raises(ValueError, match="unknown shed policy"):
        FleetRouter(shed_policy="maybe")


# --- fleet(N=1) == Server -----------------------------------------------------

def test_fleet_n1_matches_server(tiny):
    """One-replica fleet == single server on the PR 5 contended trace:
    same tokens, same scheduler decisions, same report."""
    model, params, _ = tiny
    trace = contended_trace(1, model.cfg.vocab)
    srv = Server(ServeEngine(model, params, **CONTENDED_ENGINE_KW))
    rep_s = srv.replay(trace)
    fleet = Fleet([ServeEngine(model, params, **CONTENDED_ENGINE_KW)])
    rep_f = fleet.replay(trace)
    assert rep_s.preemptions >= 1, "trace is not contended — weak test"
    assert rep_f.to_json() == rep_s.to_json()
    assert {frid: h.tokens for frid, h in fleet.handles.items()} == \
        {h.rid: h.tokens for h in srv.sched.handles.values()}
    # same decision record: the fleet merely tags + defers submits, every
    # scheduling event lands at the same instant with the same request
    decisions = ("arrive", "admit", "preempt", "resume", "finish")
    assert [(t, k, r) for t, _, k, r in fleet.events if k in decisions] \
        == [(t, k, r) for t, k, r in srv.sched.events if k in decisions]


# --- seeded 4-replica byte-identical replay -----------------------------------

def _fleet_replay(model, params, order, *, policy="prefix", drain_at=(),
                  scale_at=(), n=24, telemetry=None):
    engines = {rep: ServeEngine(model, params, **CONTENDED_ENGINE_KW)
               for rep in order}
    fleet = Fleet(engines, policy=policy, telemetry=telemetry)
    trace = poisson_trace(1, n, rate=60.0, vocab=model.cfg.vocab,
                          plen=(2, 9), max_new=(2, 10), priorities=(0, 1))
    rep = fleet.replay(trace, drain_at=drain_at, scale_at=scale_at)
    streams = {frid: list(h.tokens) for frid, h in fleet.handles.items()}
    return fleet, rep, streams


def test_fleet_replay_byte_identical_across_runs_and_replica_order(tiny):
    """The acceptance criterion: events, streams, report, and digest are
    identical across two fresh runs AND across a permuted replica
    construction order."""
    model, params, _ = tiny
    runs = [_fleet_replay(model, params, order) for order in
            (["r0", "r1", "r2", "r3"], ["r0", "r1", "r2", "r3"],
             ["r2", "r0", "r3", "r1"])]
    f0, rep0, st0 = runs[0]
    assert rep0.preemptions >= 1, "fleet trace is not contended — weak test"
    assert rep0.n_requests == 24
    for f, rep, st_ in runs[1:]:
        assert f.events == f0.events
        assert f.event_digest() == f0.event_digest()
        assert st_ == st0
        assert rep.to_json() == rep0.to_json()


def test_drain_and_scale_replay_byte_identical(tiny):
    """Mid-trace drain + scale-up stay inside the determinism contract,
    the drained replica reaches zero load, and the joiner takes traffic."""
    model, params, _ = tiny
    mk = lambda: ServeEngine(model, params,         # noqa: E731
                             **CONTENDED_ENGINE_KW)
    runs = [_fleet_replay(model, params, ["r0", "r1"], n=16,
                          drain_at=[(0.12, "r0")],
                          scale_at=[(0.18, "r2", mk)]) for _ in range(2)]
    f0, rep0, st0 = runs[0]
    f1, rep1, st1 = runs[1]
    assert f0.events == f1.events and st0 == st1
    assert f0.event_digest() == f1.event_digest()
    assert rep0.to_json() == rep1.to_json()
    assert f0.inflight["r0"] == 0          # drained to zero running
    assert f0.n_routed_to["r2"] > 0        # the joiner actually serves
    drained_at = next(t for t, _, k, _ in f0.events if k == "drain")
    late = [(t, rep) for t, rep, k, _ in f0.events
            if k == "route" and t > drained_at]
    assert late and all(rep != "r0" for rep in {r for _, r in late})
    assert all(h.state == FINISHED for h in f0.handles.values())


# --- ISSUE 10: deferral, backpressure, migration, shared tier -----------------

def test_all_drained_arrivals_defer_until_scale_up():
    """Bugfix pin: an arrival while every replica is draining is
    deferred (stays pending, head-of-line), not a ``route()`` crash, and
    is routed the instant a scale-up joins — byte-identically."""
    trace = [{"arrival": 0.0, "prompt": [1, 2, 3], "max_new": 2},
             {"arrival": 0.3, "prompt": [4, 5, 6], "max_new": 2}]

    def run():
        fleet = Fleet({"r0": _StubEngine(**STUB_KW)})
        rep = fleet.replay(
            trace, drain_at=[(0.2, "r0")],
            scale_at=[(0.5, "r1", lambda: _StubEngine(**STUB_KW))])
        return fleet, rep

    fleet, rep = run()
    assert rep.n_requests == 2
    assert fleet.n_deferred == 1
    kinds = [k for _, _, k, _ in fleet.events]
    assert "defer" in kinds
    routed = [(t, r) for t, r, k, frid in fleet.events
              if k == "route" and frid == 1]
    assert routed and routed[0][1] == "r1" and routed[0][0] >= 0.5
    assert all(h.state == FINISHED for h in fleet.handles.values())
    f2, rep2 = run()
    assert f2.event_digest() == fleet.event_digest()
    assert rep2.to_json() == rep.to_json()


def test_all_drained_without_scale_up_fails_loudly():
    """The deferral never silently hangs: due arrivals with no admitting
    replica and no scheduled scale-up raise instead of spinning."""
    fleet = Fleet({"r0": _StubEngine(**STUB_KW)})
    trace = [{"arrival": 0.0, "prompt": [1, 2], "max_new": 1},
             {"arrival": 0.3, "prompt": [3, 4], "max_new": 1}]
    with pytest.raises(RuntimeError, match="fleet stalled"):
        fleet.replay(trace, drain_at=[(0.1, "r0")])


def test_fleet_sheds_by_slo_class_under_pressure():
    """Admission backpressure end to end: with the one replica's pool
    over the pressure threshold, the SLO-bearing arrival is shed
    (counted, logged, never admitted) while the best-effort arrival
    defers and finishes once pressure clears — deterministically."""
    big = lambda tok: {"prompt": [tok] * 16, "max_new": 8}  # noqa: E731
    trace = [
        {"arrival": 0.0, **big(1)},
        {"arrival": 0.0, **big(2)},
        {"arrival": 0.01, "prompt": [3] * 4, "max_new": 2,
         "slo_ttft": 0.05},
        {"arrival": 0.012, "prompt": [4] * 4, "max_new": 2},
    ]

    def run():
        fleet = Fleet([_StubEngine(**STUB_KW)], shed_policy="slo",
                      shed_threshold=0.5)
        rep = fleet.replay(trace)
        return fleet, rep

    f1, r1 = run()
    f2, r2 = run()
    assert r1.n_shed == 1 and f1.shed_rids == [2]
    assert r1.n_requests == 3              # the shed arrival never ran
    assert 2 not in f1.handles
    kinds = [k for _, _, k, _ in f1.events]
    assert "shed" in kinds and "defer" in kinds
    assert f1.n_deferred >= 1
    assert all(h.state == FINISHED for h in f1.handles.values())
    assert f1.event_digest() == f2.event_digest()
    assert r1.to_json() == r2.to_json()


def test_drain_migration_moves_warm_work_to_survivors():
    """Tentpole, stub level: ``migrate_on_drain=True`` expels the
    draining replica's unfinished requests (running ones as swap blobs),
    re-routes them to the survivor, nothing finishes in place on the
    drained replica, and the whole thing replays byte-identically."""
    trace = poisson_trace(3, 12, rate=200.0, vocab=20, plen=(2, 12),
                          max_new=(6, 12))

    def run():
        fleet = Fleet({"r0": _StubEngine(**STUB_KW),
                       "r1": _StubEngine(**STUB_KW)},
                      migrate_on_drain=True)
        rep = fleet.replay(trace, drain_at=[(0.05, "r0")])
        return fleet, rep

    f1, r1 = run()
    f2, r2 = run()
    assert f1.n_migrated > 0 and f1.n_migrated_pages > 0
    assert f1.migrated_from["r0"] == f1.n_migrated
    assert f1.replica_stats()["r0"]["migrated_out"] == f1.n_migrated
    kinds = [k for _, _, k, _ in f1.events]
    assert "migrate" in kinds and "expel" in kinds and "adopt" in kinds
    t_drain = next(t for t, _, k, _ in f1.events if k == "drain")
    late_r0 = [k for t, rep_, k, _ in f1.events
               if rep_ == "r0" and t > t_drain
               and k in ("admit", "resume", "finish")]
    assert not late_r0, "drained replica kept serving despite migration"
    assert f1.inflight["r0"] == 0
    assert all(h.state == FINISHED for h in f1.handles.values())
    assert all(len(h.tokens) == h.max_new for h in f1.handles.values())
    # migration is billed as swap data pages, never as preemptions
    assert r1.pages_swapped_out >= f1.n_migrated_pages
    assert f1.event_digest() == f2.event_digest()
    assert r1.to_json() == r2.to_json()


def test_migrated_request_token_parity(tiny):
    """Tentpole acceptance: a request expelled mid-flight from a
    draining replica and adopted by a survivor produces the exact token
    stream of an undisturbed single-server run — the §11 swap contract
    stretched across replicas — and the drained fleet replays
    byte-identically across permuted replica construction order."""
    model, params, _ = tiny
    trace = poisson_trace(7, 10, rate=80.0, vocab=model.cfg.vocab,
                          plen=(2, 9), max_new=(6, 10))
    srv = Server(ServeEngine(model, params, **CONTENDED_ENGINE_KW))
    srv.replay(trace)
    want = {rid: list(h.tokens) for rid, h in srv.sched.handles.items()}

    def run(order):
        engines = {rep: ServeEngine(model, params, **CONTENDED_ENGINE_KW)
                   for rep in order}
        fleet = Fleet(engines, migrate_on_drain=True)
        fleet.replay(trace, drain_at=[(0.08, "r0")])
        return fleet

    f1 = run(["r0", "r1"])
    assert f1.n_migrated > 0 and f1.n_migrated_pages > 0
    assert {frid: list(h.tokens) for frid, h in f1.handles.items()} == want
    f2 = run(["r1", "r0"])
    assert f2.event_digest() == f1.event_digest()
    assert {frid: list(h.tokens) for frid, h in f2.handles.items()} == want


def test_shared_tier_hit_matches_local_prefix_hit(tiny):
    """Tentpole, tier half: a shared-tier adoption must be
    indistinguishable from a local prefix hit — same tokens as a
    tier-less fleet, strictly fewer pages materialized (recomputed),
    and the tier/pool counters agree it happened."""
    model, params, _ = tiny
    page = CONTENDED_ENGINE_KW["page_size"]
    sys_prompt = list(range(1, 2 * page + 1))      # two full pages
    trace = [
        {"arrival": 0.0, "prompt": sys_prompt + [5, 6], "max_new": 4},
        {"arrival": 0.4, "prompt": sys_prompt + [9], "max_new": 4},
    ]

    def run(tier):
        engines = {rep: ServeEngine(model, params, **CONTENDED_ENGINE_KW)
                   for rep in ("r0", "r1")}
        fleet = Fleet(engines, policy="round_robin",
                      shared_prefix_tier=tier)
        fleet.replay(trace)                # RR: r0 warms, r1 consults
        return fleet

    base = run(False)
    f = run(True)
    assert {frid: list(h.tokens) for frid, h in f.handles.items()} == \
        {frid: list(h.tokens) for frid, h in base.handles.items()}
    stats = f.shared_tier_stats()
    assert stats is not None and stats["hits"] >= 2 and stats["puts"] >= 2
    pools = [f.replicas[r].engine.pool for r in ("r0", "r1")]
    assert sum(p.stats.shared_hit_pages for p in pools) >= 2
    assert f.materialized_pages() < base.materialized_pages()
    assert base.shared_tier_stats() is None
    assert all(p.stats.shared_hit_pages == 0 for p in
               (base.replicas[r].engine.pool for r in ("r0", "r1")))


def test_shared_tier_lru_capacity_and_idempotent_put():
    """Unit pins for the tier itself: byte-capped LRU eviction (never
    below one entry), idempotent puts, get refreshing recency."""
    page = {"k": np.zeros((1, 8, 1, 2), np.float32)}      # 64 bytes
    tier = SharedPrefixTier(capacity_bytes=200)
    tier.put(b"a", page)
    tier.put(b"a", page)                   # idempotent: no double count
    assert len(tier) == 1 and tier.puts == 1 and tier.bytes == 64
    tier.put(b"b", page)
    tier.put(b"c", page)                   # 192 bytes: a, b, c resident
    assert b"a" in tier and len(tier) == 3
    assert tier.get(b"a") is not None      # refresh a's recency
    tier.put(b"d", page)                   # over cap: evict LRU (b)
    assert b"b" not in tier and b"a" in tier and tier.evictions >= 1
    assert tier.bytes <= 200
    small = SharedPrefixTier(capacity_bytes=1)
    small.put(b"x", page)                  # oversized entry still kept
    assert b"x" in small and len(small) == 1
    st = small.stats()
    assert st["puts"] == 1 and st["entries"] == 1


def test_fleet_streamed_replay_matches_list_replay():
    """Generator traces (one-row lookahead) and retain=False (digest-only
    log, handles released) produce the same bytes as the list path."""
    kw = dict(rate=150.0, vocab=50, plen=(2, 9), max_new=(2, 8),
              priorities=(0, 1))
    f_list = Fleet([_StubEngine(**STUB_KW) for _ in range(3)])
    rep_list = f_list.replay(poisson_trace(5, 300, **kw))
    f_iter = Fleet([_StubEngine(**STUB_KW) for _ in range(3)],
                   retain=False)
    rep_iter = f_iter.replay(poisson_trace_iter(5, 300, **kw))
    assert f_iter.event_digest() == f_list.event_digest()
    assert not f_iter.handles and not f_iter.assigned  # released as it ran
    d = rep_list.to_json()
    d["admission_order"] = []              # digest-only mode drops the log
    assert rep_iter.to_json() == d


def test_fleet_streamed_replay_rejects_unsorted_arrivals():
    rows = [{"arrival": 0.2, "prompt": [1, 2], "max_new": 2},
            {"arrival": 0.1, "prompt": [3, 4], "max_new": 2}]
    fleet = Fleet([_StubEngine(**STUB_KW)])
    with pytest.raises(ValueError, match="non-decreasing"):
        fleet.replay(iter(rows))


# --- prefix-aware routing beats round-robin -----------------------------------

def test_prefix_routing_beats_round_robin_on_shared_prefixes():
    """Four system-prompt groups over four replicas: affinity routing
    keeps each group's chain hot on one pool; round-robin scatters it.
    The fleet-wide prefix hit rate must show the gap."""
    trace = grouped_trace(0, 120)
    rates = {}
    for policy in ("prefix", "round_robin"):
        fleet = Fleet([_StubEngine(max_batch=2, n_pages=10, page_size=8)
                       for _ in range(4)], policy=policy)
        fleet.replay(trace)
        rates[policy] = fleet.prefix_hit_rate()
    assert rates["prefix"] > rates["round_robin"] + 0.1, rates
    assert rates["prefix"] > 0.5


def test_shared_tier_beats_prefix_routing_alone_under_churn():
    """ISSUE 10 ordering gate at tier-1: with more prefix groups than
    the per-replica pools can pin, hot prefixes churn out of the LRU and
    affinity breaks — only the fleet tier can serve the re-
    materialization, so hit(tier) > hit(prefix) > hit(round_robin) and
    the tier run computes strictly fewer prompt pages."""
    trace = grouped_trace(0, 120, n_groups=8)
    got = {}
    for name, policy, tier in (("round_robin", "round_robin", False),
                               ("prefix", "prefix", False),
                               ("tier", "prefix", True)):
        fleet = Fleet([_StubEngine(max_batch=2, n_pages=10, page_size=8)
                       for _ in range(4)], policy=policy,
                      shared_prefix_tier=tier)
        fleet.replay(trace)
        got[name] = (fleet.prefix_hit_rate(), fleet.materialized_pages())
    assert got["tier"][0] > got["prefix"][0] > got["round_robin"][0], got
    assert got["tier"][1] < got["prefix"][1] < got["round_robin"][1], got


# --- swap-stat aggregation: registry vs report (§13 dual units) ---------------

def test_fleet_swap_stats_registry_vs_report(tiny):
    """The fleet report's swap fields are per-replica sums of the
    schedulers' data-page counters — never the pools' released-reference
    counters, which count a different unit and would double-dip."""
    model, params, _ = tiny
    tel = Telemetry()
    fleet, rep, _ = _fleet_replay(model, params, ["r0", "r1"], n=24,
                                  telemetry=tel)
    assert rep.preemptions >= 1, "no contention — weak test"
    snap = tel.snapshot()
    c = snap["counters"]
    reps = sorted(fleet.replicas)
    sched_out = sum(c.get(f"{r}.sched.pages_swapped_out", 0) for r in reps)
    sched_in = sum(c.get(f"{r}.sched.pages_swapped_in", 0) for r in reps)
    assert rep.pages_swapped_out == sched_out
    assert rep.pages_swapped_in == sched_in
    assert rep.preemptions == sum(c.get(f"{r}.sched.preemptions", 0)
                                  for r in reps)
    pool_out = sum(snap[f"{r}.pool"]["swapped_out_pages"] for r in reps)
    # references released >= data pages moved (the reservation tail) —
    # summing the two vocabularies together would overcount
    assert pool_out >= sched_out
    assert rep.pages_swapped_out == sum(
        s["pages_swapped_out"] for s in fleet.replica_stats().values())
    assert rep.n_tokens == sum(
        c.get(f"{r}.engine.tokens", 0) + c.get(f"{r}.sched.admissions", 0)
        for r in reps)


# --- hypothesis state machine over the fleet ----------------------------------

class _FleetWalk:
    """Random walk over submit/step/drain/scale on stub-engine replicas,
    checking the fleet invariants after every transition, then a full
    drain: no request lost or double-admitted, per-replica page claims
    conserved, drained replicas reach zero load in bounded rounds.

    Half the walks turn on ``migrate_on_drain`` (drains now expel and
    re-route warm work — conservation must hold across the handover: an
    expelled request is back in ``_rows``, never double-held) and half
    attach a shared prefix tier (tier promotions must keep per-pool
    refcounts conserved: an adopted page is cache-only, refcount 1)."""

    def __init__(self, rng):
        self.rng = rng
        self.fleet = Fleet({"r0": _StubEngine(**STUB_KW),
                            "r1": _StubEngine(**STUB_KW)},
                           migrate_on_drain=bool(rng.integers(2)),
                           shared_prefix_tier=bool(rng.integers(2)))
        self.drained = []
        self.scaled = False

    def submit(self):
        page = STUB_KW["page_size"]
        plen = int(self.rng.integers(1, 2 * page + 1))
        prompt = [int(t) for t in self.rng.integers(0, 3, plen)]
        dt = float(self.rng.choice([0.0, 0.0, 0.01, 0.05]))
        self.fleet.submit(prompt, int(self.rng.integers(1, 2 * page + 1)),
                          priority=int(self.rng.integers(0, 3)),
                          arrival=self.fleet.clock.now() + dt)

    def step(self):
        self.fleet.step()

    def drain(self):
        if len(self.fleet.router.admitting) > 1:
            rep = self.fleet.router.admitting[
                int(self.rng.integers(len(self.fleet.router.admitting)))]
            self.fleet.drain(rep)
            self.drained.append(rep)

    def scale(self):
        if not self.scaled:
            self.fleet.add_replica("r2", _StubEngine(**STUB_KW))
            self.scaled = True

    def check(self):
        fleet = self.fleet
        # -- conservation: every submitted request is unrouted XOR
        #    assigned to exactly one replica, never dropped, never dual
        seen = dict(fleet._rows)
        for frid, (rep, lrid) in fleet.assigned.items():
            assert frid not in seen, f"request {frid} routed AND pending"
            h = fleet.replicas[rep].handles[lrid]
            assert fleet._local2fleet[rep][lrid] == frid
            seen[frid] = h
        assert sorted(seen) == list(range(fleet._seq)), "request lost"
        for rep, sched in fleet.replicas.items():
            local = fleet._local2fleet[rep]
            assert len(set(local.values())) == len(local), \
                f"{rep}: a request admitted twice"
            unfinished = sum(1 for h in sched.handles.values()
                             if h.state != FINISHED)
            assert fleet.inflight[rep] == unfinished
            # -- per-replica page-claim conservation over the REAL pool
            pool = sched.engine.pool
            holders = {}
            for h in sched.running:
                adm = sched.st.adm[h.slot]
                for pid in adm.pids[:adm.n_live]:
                    assert pid != 0
                    holders[pid] = holders.get(pid, 0) + 1
            for pid in range(1, pool.n_pages):
                want = holders.get(pid, 0) + (1 if pid in pool.key_of
                                              else 0)
                assert pool.ref[pid] == want, \
                    f"{rep}: refcount leak on page {pid}"
            assert pool.reserved_extra == 0
        # -- drained replicas take no new work; with migration on, they
        #    additionally hold no unfinished work at all
        for rep in self.drained:
            assert rep not in fleet.router.admitting
            if fleet.migrate_on_drain:
                assert fleet.inflight[rep] == 0
                assert all(h.state == FINISHED
                           for h in fleet.replicas[rep].handles.values())

    def run(self, n_ops=40):
        ops = [self.submit, self.submit, self.step, self.step, self.step,
               self.drain, self.scale]
        self.check()
        for _ in range(n_ops):
            ops[self.rng.integers(len(ops))]()
            self.check()
        # bounded-rounds drain: every request finishes, drained replicas
        # hit zero load (a stall fails instead of hanging)
        self.fleet.run_until_idle(max_rounds=5000)
        self.check()
        assert sum(fleet_h.state == FINISHED
                   for fleet_h in self.fleet.handles.values()) \
            == self.fleet._seq
        for h in self.fleet.handles.values():
            assert len(h.tokens) == h.max_new
        for rep in self.drained:
            assert self.fleet.inflight[rep] == 0


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_fleet_state_machine_invariants(seed):
    _FleetWalk(np.random.default_rng(seed)).run()


# --- streamed traffic plumbing ------------------------------------------------

def test_poisson_trace_iter_matches_list():
    kw = dict(rate=30.0, vocab=64, plen=(2, 6), max_new=(1, 5),
              priorities=(0, 1), slo_ttft=0.5)
    assert list(poisson_trace_iter(9, 40, **kw)) == \
        poisson_trace(9, 40, **kw)
    pref = [7, 7, 7]
    assert all(r["prompt"][:3] == pref for r in
               poisson_trace_iter(9, 10, shared_prefix=pref))


def test_trace_stream_roundtrip(tmp_path):
    """save_trace streams a generator to disk; iter_trace streams it back
    row-identical to load_trace — across buffer-boundary splits too."""
    trace = poisson_trace(3, 25, vocab=100, priorities=(0, 1),
                          slo_ttft=0.25)
    path = str(tmp_path / "trace.json")
    save_trace(path, iter(trace))          # generator, not a list
    assert load_trace(path) == trace
    assert list(iter_trace(path)) == trace
    assert list(iter_trace(path, chunk=17)) == trace  # force row splits
    assert json.load(open(path)) == trace  # still one plain JSON array
