"""Paged, quantized KV cache (DESIGN.md §8).

Ground truth is the incremental path (one request, token-by-token decode
from an empty contiguous cache).  The paged engine — chunked prefill,
page-table decode, int8 pages, prefix caching, copy-on-write, page-gated
admission — must reproduce it greedily, token for token, on the dense
family across all three matmul backends.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params

PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10], [11, 12, 13, 14]]


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def reference(tiny):
    cfg, model, params = tiny
    return [_incremental(model, params, p, 6) for p in PROMPTS]


def _incremental(model, params, prompt, max_new, max_len=64):
    cfg = model.cfg
    cache = model.init_cache(1, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, t, c: model.decode(p, t, c))
    logits = None
    for t in prompt:
        logits, cache = step(params, jnp.asarray([[t]], jnp.int32), cache)
    out = list(prompt)
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        out.append(nxt)
        logits, cache = step(params, jnp.asarray([[nxt]], jnp.int32), cache)
    return out


def test_page_gather_kernel_matches_xla_gather():
    """The Pallas scalar-prefetch kernel (interpret mode off-TPU) and the
    XLA fallback implement the same gather, for K/V pages and scale pages."""
    from repro.kernels.page_gather import page_gather_pallas

    rng = np.random.default_rng(0)
    pt = jnp.asarray(rng.integers(0, 16, (3, 5)), jnp.int32)
    for shape, dtype in (((16, 4, 2, 8), jnp.float32),
                         ((16, 4, 2, 8), jnp.int8),
                         ((16, 4, 2), jnp.bfloat16)):
        pool = jnp.asarray(rng.integers(-100, 100, shape)).astype(dtype)
        got = page_gather_pallas(pool, pt, interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.take(pool, pt, axis=0)))


def test_paged_matches_incremental_multichunk(tiny, reference):
    """page=4 makes every prompt span chunks and decode cross page
    boundaries; tokens must still match the incremental path exactly."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4)
    assert eng.serve(PROMPTS, max_new=6) == reference


def test_paged_backends_match_contiguous(tiny):
    """dense / codebook / lut: the paged engine reproduces the contiguous
    engine token-for-token on index-form params."""
    cfg, model, params = tiny
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    cp = to_codebook_params(pq, wq, state, min_size=1024)
    for be in ("dense", "codebook", "lut"):
        want = ServeEngine(model, cp, max_len=64, max_batch=2,
                           backend=be).serve(PROMPTS[:2], max_new=4)
        got = ServeEngine(model, cp, max_len=64, max_batch=2, backend=be,
                          paged=True, page_size=4).serve(PROMPTS[:2],
                                                         max_new=4)
        assert got == want, be


def test_paged_int8_matches_contiguous(tiny, reference):
    """Acceptance: int8 paged cache == contiguous greedy decode token for
    token.  Single-chunk prompts make the comparison exact even against the
    contiguous int8 slab (identical quantize_kv on both sides); multi-chunk
    prefill additionally reads back quantized pages (same posture as
    vLLM-style fp8 chunked prefill), which can perturb near-ties on a
    random-init model and is therefore not asserted bitwise."""
    cfg, model, params = tiny
    got = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=8, kv_dtype="int8").serve(PROMPTS, max_new=6)
    assert got == reference                         # vs float contiguous
    qmodel = build(cfg.replace(kv_quant=True))
    want8 = ServeEngine(qmodel, params, max_len=64,
                        max_batch=2).serve(PROMPTS, max_new=6)
    assert got == want8                             # vs int8 contiguous


def test_prefix_cache_shared_pages_identical_tokens(tiny, reference):
    """A repeated prompt re-links cached pages instead of recomputing them —
    and produces the very same greedy continuation."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4)
    first = eng.serve(PROMPTS, max_new=6)
    assert first == reference
    st0 = eng.pool.stats
    assert st0.hit_pages == 0 and st0.miss_pages > 0
    again = eng.serve(PROMPTS, max_new=6)           # pool persists on engine
    assert again == reference
    assert eng.pool.stats.hit_pages > 0
    assert eng.pool.stats.hit_rate > 0


def test_refcounts_drop_to_zero_on_retirement(tiny):
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4, prefix_cache=False)
    eng.serve(PROMPTS, max_new=4)
    pool = eng.pool
    assert pool.pages_in_use() == 0                 # every ref released
    assert sorted(pool.free) == list(range(1, pool.n_pages))
    assert int(pool.ref.sum()) == 0

    # with the prefix cache on, retired pages survive at refcount 1 (the
    # cache's own hold) — and nothing else keeps them pinned
    eng2 = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                       page_size=4)
    eng2.serve(PROMPTS, max_new=4)
    pool2 = eng2.pool
    registered = set(pool2.key_of)
    assert registered and all(pool2.ref[p] == 1 for p in registered)
    assert all(pool2.ref[p] == 0 for p in range(1, pool2.n_pages)
               if p not in registered)


def test_cow_never_mutates_shared_page(tiny):
    """A request sharing a retired twin's partial tail page must copy before
    its decode writes land: the cached page's bytes stay bit-identical and
    both requests emit identical tokens."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4, kv_dtype="int8")
    prompt = [7, 8, 9, 10, 11, 12]                  # 6 % 4 ≠ 0: partial tail
    a = eng.serve([prompt], max_new=5)[0]
    pool = eng.pool
    # the partial tail page was registered at retirement
    tail_pids = [pid for pid, key in pool.key_of.items()
                 if len(key[1]) != eng.page_size]
    assert len(tail_pids) == 1
    pid = tail_pids[0]
    before = {k: np.asarray(v[:, pid]).copy() for k, v in pool.cache.items()}

    b = eng.serve([prompt], max_new=5)[0]
    assert pool.stats.cow_copies >= 1
    assert b == a
    after = {k: np.asarray(v[:, pid]) for k, v in pool.cache.items()}
    for k in before:
        np.testing.assert_array_equal(before[k], after[k], err_msg=k)


def test_pool_exhaustion_gates_admission(tiny, reference):
    """A pool too small for two requests serves them sequentially (admission
    waits on pages, not slots); a request that can never fit raises."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=4, paged=True,
                      page_size=4, n_pages=4, prefix_cache=False)
    got = eng.serve(PROMPTS[:3], max_new=6)
    assert got == reference[:3]
    assert eng.pool.stats.peak_pages_in_use <= 3    # never two in flight
    with pytest.raises(ValueError, match="never fit"):
        eng.serve([[1] * 13], max_new=4)            # needs 4 of 3 pages


def test_tight_pool_prefix_reuse_no_crash(tiny):
    """Admission accounting under pressure: a repeated request whose prefix
    hits pin the only evictable pages (and whose shared tail costs a CoW
    page) must either fit exactly or fall back to recomputing — never blow
    up mid-serve with an exhausted allocator."""
    cfg, model, params = tiny
    prompt = [7, 8, 9, 10, 11, 12]                  # needs 3 pages @ stop=5
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4, n_pages=4, kv_dtype="int8")
    a = eng.serve([prompt], max_new=5)[0]
    # full page + partial tail stay registered; one page freed
    assert eng.pool.pages_in_use() == 2 and len(eng.pool.free) == 1
    b = eng.serve([prompt], max_new=5)[0]           # sharing unaffordable:
    assert b == a                                   # falls back, stays right
    c = eng.serve([prompt, prompt], max_new=5)      # and again under queueing
    assert c == [a, a]


def test_chunked_prefill_long_prompt(tiny):
    """A prompt spanning many pages streams through page-sized chunks (no
    power-of-two prefill bucket) and still matches the incremental path."""
    cfg, model, params = tiny
    prompt = [int(t) for t in
              np.random.default_rng(3).integers(0, cfg.vocab, 19)]
    want = _incremental(model, params, prompt, 5)
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4)
    assert eng.serve([prompt], max_new=5) == [want]


def test_paged_rejects_recurrent_families():
    cfg = C.get("rwkv6-7b").reduced().replace(n_layers=1, dtype="float32")
    model = build(cfg)
    with pytest.raises(NotImplementedError):
        model.init_paged_cache(4, 4)
