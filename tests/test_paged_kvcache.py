"""Paged, quantized KV cache (DESIGN.md §8).

Ground truth is the incremental path (one request, token-by-token decode
from an empty contiguous cache).  The paged engine — chunked prefill,
page-table decode, int8 pages, prefix caching, copy-on-write, page-gated
admission — must reproduce it greedily, token for token, on the dense
family across all three matmul backends.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params

PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10], [11, 12, 13, 14]]


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def reference(tiny):
    cfg, model, params = tiny
    return [_incremental(model, params, p, 6) for p in PROMPTS]


def _incremental(model, params, prompt, max_new, max_len=64):
    cfg = model.cfg
    cache = model.init_cache(1, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, t, c: model.decode(p, t, c))
    logits = None
    for t in prompt:
        logits, cache = step(params, jnp.asarray([[t]], jnp.int32), cache)
    out = list(prompt)
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        out.append(nxt)
        logits, cache = step(params, jnp.asarray([[nxt]], jnp.int32), cache)
    return out


def test_page_gather_kernel_matches_xla_gather():
    """The Pallas scalar-prefetch kernel (interpret mode off-TPU) and the
    XLA fallback implement the same gather, for K/V pages and scale pages."""
    from repro.kernels.page_gather import page_gather_pallas

    rng = np.random.default_rng(0)
    pt = jnp.asarray(rng.integers(0, 16, (3, 5)), jnp.int32)
    for shape, dtype in (((16, 4, 2, 8), jnp.float32),
                         ((16, 4, 2, 8), jnp.int8),
                         ((16, 4, 2), jnp.bfloat16)):
        pool = jnp.asarray(rng.integers(-100, 100, shape)).astype(dtype)
        got = page_gather_pallas(pool, pt, interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.take(pool, pt, axis=0)))


def test_paged_matches_incremental_multichunk(tiny, reference):
    """page=4 makes every prompt span chunks and decode cross page
    boundaries; tokens must still match the incremental path exactly."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4)
    assert eng.serve(PROMPTS, max_new=6) == reference


def test_paged_backends_match_contiguous(tiny):
    """dense / codebook / lut: the paged engine reproduces the contiguous
    engine token-for-token on index-form params."""
    cfg, model, params = tiny
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    cp = to_codebook_params(pq, wq, state, min_size=1024)
    for be in ("dense", "codebook", "lut"):
        want = ServeEngine(model, cp, max_len=64, max_batch=2,
                           backend=be).serve(PROMPTS[:2], max_new=4)
        got = ServeEngine(model, cp, max_len=64, max_batch=2, backend=be,
                          paged=True, page_size=4).serve(PROMPTS[:2],
                                                         max_new=4)
        assert got == want, be


def test_paged_int8_matches_contiguous(tiny, reference):
    """Acceptance: int8 paged cache == contiguous greedy decode token for
    token.  Single-chunk prompts make the comparison exact even against the
    contiguous int8 slab (identical quantize_kv on both sides); multi-chunk
    prefill additionally reads back quantized pages (same posture as
    vLLM-style fp8 chunked prefill), which can perturb near-ties on a
    random-init model and is therefore not asserted bitwise."""
    cfg, model, params = tiny
    got = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=8, kv_dtype="int8").serve(PROMPTS, max_new=6)
    assert got == reference                         # vs float contiguous
    qmodel = build(cfg.replace(kv_quant=True))
    want8 = ServeEngine(qmodel, params, max_len=64,
                        max_batch=2).serve(PROMPTS, max_new=6)
    assert got == want8                             # vs int8 contiguous


def test_prefix_cache_shared_pages_identical_tokens(tiny, reference):
    """A repeated prompt re-links cached pages instead of recomputing them —
    and produces the very same greedy continuation."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4)
    first = eng.serve(PROMPTS, max_new=6)
    assert first == reference
    st0 = eng.pool.stats
    assert st0.hit_pages == 0 and st0.miss_pages > 0
    again = eng.serve(PROMPTS, max_new=6)           # pool persists on engine
    assert again == reference
    assert eng.pool.stats.hit_pages > 0
    assert eng.pool.stats.hit_rate > 0


def test_refcounts_drop_to_zero_on_retirement(tiny):
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4, prefix_cache=False)
    eng.serve(PROMPTS, max_new=4)
    pool = eng.pool
    assert pool.pages_in_use() == 0                 # every ref released
    assert sorted(pool.free) == list(range(1, pool.n_pages))
    assert int(pool.ref.sum()) == 0

    # with the prefix cache on, retired pages survive at refcount 1 (the
    # cache's own hold) — and nothing else keeps them pinned
    eng2 = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                       page_size=4)
    eng2.serve(PROMPTS, max_new=4)
    pool2 = eng2.pool
    registered = set(pool2.key_of)
    assert registered and all(pool2.ref[p] == 1 for p in registered)
    assert all(pool2.ref[p] == 0 for p in range(1, pool2.n_pages)
               if p not in registered)


def test_cow_never_mutates_shared_page(tiny):
    """A request sharing a retired twin's partial tail page must copy before
    its decode writes land: the cached page's bytes stay bit-identical and
    both requests emit identical tokens."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4, kv_dtype="int8")
    prompt = [7, 8, 9, 10, 11, 12]                  # 6 % 4 ≠ 0: partial tail
    a = eng.serve([prompt], max_new=5)[0]
    pool = eng.pool
    # the partial tail page was registered at retirement: its pid sits
    # under the LAST chain key of the (non-page-aligned) prompt
    from repro.serving import chain_keys
    tail_key = chain_keys(prompt, eng.page_size)[-1]
    assert tail_key in pool.table
    pid = pool.table[tail_key]
    before = {k: np.asarray(v[:, pid]).copy() for k, v in pool.cache.items()}

    b = eng.serve([prompt], max_new=5)[0]
    assert pool.stats.cow_copies >= 1
    assert b == a
    after = {k: np.asarray(v[:, pid]) for k, v in pool.cache.items()}
    for k in before:
        np.testing.assert_array_equal(before[k], after[k], err_msg=k)


def test_pool_exhaustion_gates_admission(tiny, reference):
    """A pool too small for two requests serves them sequentially (admission
    waits on pages, not slots); a request that can never fit raises."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=4, paged=True,
                      page_size=4, n_pages=4, prefix_cache=False)
    got = eng.serve(PROMPTS[:3], max_new=6)
    assert got == reference[:3]
    assert eng.pool.stats.peak_pages_in_use <= 3    # never two in flight
    with pytest.raises(ValueError, match="never fit"):
        eng.serve([[1] * 13], max_new=4)            # needs 4 of 3 pages


def test_tight_pool_prefix_reuse_no_crash(tiny):
    """Admission accounting under pressure: a repeated request whose prefix
    hits pin the only evictable pages (and whose shared tail costs a CoW
    page) must either fit exactly or fall back to recomputing — never blow
    up mid-serve with an exhausted allocator."""
    cfg, model, params = tiny
    prompt = [7, 8, 9, 10, 11, 12]                  # needs 3 pages @ stop=5
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4, n_pages=4, kv_dtype="int8")
    a = eng.serve([prompt], max_new=5)[0]
    # full page + partial tail stay registered; one page freed
    assert eng.pool.pages_in_use() == 2 and len(eng.pool.free) == 1
    b = eng.serve([prompt], max_new=5)[0]           # sharing unaffordable:
    assert b == a                                   # falls back, stays right
    c = eng.serve([prompt, prompt], max_new=5)      # and again under queueing
    assert c == [a, a]


def test_chunked_prefill_long_prompt(tiny):
    """A prompt spanning many pages streams through page-sized chunks (no
    power-of-two prefill bucket) and still matches the incremental path."""
    cfg, model, params = tiny
    prompt = [int(t) for t in
              np.random.default_rng(3).integers(0, cfg.vocab, 19)]
    want = _incremental(model, params, prompt, 5)
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4)
    assert eng.serve([prompt], max_new=5) == [want]


def test_paged_rejects_recurrent_families():
    cfg = C.get("rwkv6-7b").reduced().replace(n_layers=1, dtype="float32")
    model = build(cfg)
    with pytest.raises(NotImplementedError):
        model.init_paged_cache(4, 4)


# --- speculative rollback: truncate / extend (DESIGN.md §9) ------------------

def test_truncate_frees_tail_pages_and_extend_regrows(tiny):
    """Rollback returns emptied tail pages to the free list but keeps them
    reserved (invisible to admission) so extend can never deadlock."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4, prefix_cache=False)
    pool = eng.pool
    prompt = [1, 2, 3, 4, 5]                       # 2 prompt pages
    adm = pool.admit(prompt, 10)                   # worst case: 4 pages
    assert adm.reserve == adm.n_live == 4
    free0 = len(pool.free)

    freed = pool.truncate(adm, len(prompt))        # keep ceil(5/4) = 2
    assert freed == 2 and adm.n_live == 2
    assert len(pool.free) == free0 + 2
    assert pool.reserved_extra == 2
    assert adm.pids[2:] == [0, 0]                  # trash placeholders
    # the freed pages are NOT admissible supply for newcomers
    assert not pool.can_admit(len(pool.free) + pool._evictable())

    pool.extend(adm, len(prompt) + 6)              # ceil(11/4) = 3 pages
    assert adm.n_live == 3 and pool.reserved_extra == 1
    assert all(p != 0 for p in adm.pids[:3])
    pool.extend(adm, 100)                          # capped at the reserve
    assert adm.n_live == adm.reserve == 4
    assert pool.reserved_extra == 0

    with pytest.raises(ValueError, match="extend"):
        pool.truncate(adm, 64)                     # beyond the live span

    pool.retire(adm)
    assert pool.reserved_extra == 0
    assert pool.pages_in_use() == 0
    assert sorted(pool.free) == list(range(1, pool.n_pages))


def test_truncate_cow_splits_shared_boundary_page(tiny):
    """A rollback whose boundary page is shared via the prefix cache must
    copy-on-write first: the cached page's bytes and its hash entry stay
    intact while the request gets a private twin."""
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4)
    prompt = [7, 8, 9, 10, 11, 12]                 # partial tail (6 % 4)
    eng.serve([prompt], max_new=5)                 # registers tail at retire
    pool = eng.pool
    table_before = dict(pool.table)

    adm = pool.admit(prompt, 5)                    # shares the cached pages
    assert adm.cow_tail is not None
    shared_pid = adm.pids[adm.n_chunks - 1]
    assert pool.ref[shared_pid] > 1
    before = {k: np.asarray(v[:, shared_pid]).copy()
              for k, v in pool.cache.items()}

    cows0 = pool.stats.cow_copies
    pool.truncate(adm, len(prompt))                # boundary page is shared
    assert pool.stats.cow_copies == cows0 + 1
    assert adm.pids[adm.n_chunks - 1] != shared_pid
    after = {k: np.asarray(v[:, shared_pid]) for k, v in pool.cache.items()}
    for k in before:
        np.testing.assert_array_equal(before[k], after[k], err_msg=k)
    assert dict(pool.table) == table_before        # hashes consistent
    assert pool.key_of[shared_pid] in table_before
    pool.retire(adm)


def test_spec_paged_serve_pool_invariants(tiny):
    """After a speculative paged serve every rollback claim is settled:
    reserved_extra is zero, refcounts are zero or cache-only, and a second
    serve of the same prompts still earns prefix hits with identical
    output."""
    from repro.serving import SpecConfig
    cfg, model, params = tiny
    eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                      page_size=4, spec=SpecConfig(draft="ngram", k=3))
    first = eng.serve(PROMPTS, max_new=6)
    pool = eng.pool
    assert pool.reserved_extra == 0
    assert pool.stats.truncated_pages > 0          # rollback actually ran
    registered = set(pool.key_of)
    assert all(pool.ref[p] == 1 for p in registered)
    assert all(pool.ref[p] == 0 for p in range(1, pool.n_pages)
               if p not in registered)
    again = eng.serve(PROMPTS, max_new=6)
    assert again == first
    assert pool.stats.hit_pages > 0


# --- DESIGN.md §8 caveat: int8 chunked-prefill quantized readback ------------

def test_int8_chunked_prefill_drift_bounded(tiny):
    """Later chunks of an int8 paged prefill read back quantized earlier
    pages; the resulting last-position logit drift vs float pages must stay
    bounded (regression tripwire for the §8 caveat — measured ~0.6% of the
    logit spread on the test model, asserted < 5%)."""
    cfg, model, params = tiny
    prompt = [int(t) for t in
              np.random.default_rng(3).integers(0, cfg.vocab, 19)]
    lg = {}
    for kv in ("bf16", "int8"):    # 'bf16' stores f32 pages for f32 models
        eng = ServeEngine(model, params, max_len=64, max_batch=2, paged=True,
                          page_size=4, kv_dtype=kv, prefix_cache=False)
        adm = eng.pool.admit(prompt, 2)
        out = eng._chunked_prefill(eng.pool, prompt, adm)
        lg[kv] = np.asarray(out[0, 0, :cfg.vocab])
        eng.pool.retire(adm)
    spread = lg["bf16"].max() - lg["bf16"].min()
    drift = np.abs(lg["bf16"] - lg["int8"]).max()
    assert drift / spread < 0.05, (drift, spread)


# --- property-based PagePool invariants (hypothesis state machine) -----------
#
# A random-walk state machine over the host-side allocator alone (a stub
# model supplies a tiny page store): every admit/register/cow/extend/
# truncate/retire/swap_out/swap_in interleaving must conserve refcounts,
# never alias a write-target page between two live requests, never let
# speculative rollback's reserved pages deadlock a later extend, and
# always leave a swapped-out request restorable once the pool drains.  Runs under real
# hypothesis when the dev extra is installed, else under the deterministic
# conftest fallback shim — either way it is no longer skipped.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # direct (non-pytest) imports
    from _hypothesis_fallback import given, settings, strategies as st

from repro.serving.kvcache import PagePool


class _StubModel:
    """Backing store for allocator-only walks: one layer, 2-wide heads."""

    def init_paged_cache(self, n_pages, page_size, dtype):
        return {"k": jnp.zeros((1, n_pages, page_size, 1, 2), jnp.float32)}


class _PoolWalk:
    """Drives one PagePool through engine-shaped transitions and checks
    the global invariants after every step."""

    def __init__(self, rng, *, page, n_pages, prefix_cache):
        self.rng = rng
        self.page = page
        self.pool = PagePool(_StubModel(), n_pages=n_pages, page_size=page,
                             pages_per_slot=n_pages - 1,
                             kv_dtype=jnp.float32, prefix_cache=prefix_cache)
        self.live = []                   # [adm, plen, stop, cur_tokens]
        self.swapped = []                # [reserve, plen, stop, cur_tokens]

    # --- transitions (the ServeEngine's call shapes) -------------------------

    def admit(self):
        plen = int(self.rng.integers(1, 3 * self.page + 1))
        stop = int(self.rng.integers(1, 2 * self.page + 1))
        # small alphabet => prefix-cache hits actually happen
        tokens = [int(t) for t in self.rng.integers(0, 3, plen)]
        adm = self.pool.admit(tokens, stop)
        if adm is None:
            return
        self.pool.register_prefill(adm)
        self.pool.cow(adm)               # engine: CoW before decode writes
        self.live.append([adm, plen, stop, plen])

    def truncate(self):
        if not self.live:
            return
        ent = self.live[self.rng.integers(len(self.live))]
        adm, plen, _, cur = ent
        n = int(self.rng.integers(plen, cur + 1))
        self.pool.truncate(adm, n)
        ent[3] = n

    def extend(self):
        if not self.live:
            return
        ent = self.live[self.rng.integers(len(self.live))]
        adm, plen, stop, cur = ent
        hi = plen + stop - 1 + self.page         # speculative overshoot ok
        n = int(self.rng.integers(cur, hi + 1))
        self.pool.extend(adm, n)
        ent[3] = min(max(n, cur), plen + stop - 1)

    def retire(self):
        if not self.live:
            return
        i = int(self.rng.integers(len(self.live)))
        adm, _, _, _ = self.live.pop(i)
        self.pool.retire(adm)

    # scheduler preemption (DESIGN.md §11): the engine copies page
    # contents before release — the walk only audits the accounting

    def swap_out(self):
        if not self.live:
            return
        i = int(self.rng.integers(len(self.live)))
        adm, plen, stop, cur = self.live.pop(i)
        reserve = adm.reserve
        self.pool.swap_out(adm)
        self.swapped.append([reserve, plen, stop, cur])

    def swap_in(self):
        if not self.swapped:
            return
        i = int(self.rng.integers(len(self.swapped)))
        reserve, plen, stop, cur = self.swapped[i]
        adm = self.pool.swap_in(reserve)
        if adm is None:
            return                       # pool busy — request keeps waiting
        self.swapped.pop(i)
        self.live.append([adm, plen, stop, cur])

    # --- invariants ----------------------------------------------------------

    def check(self):
        pool = self.pool
        holders = {}                             # pid -> live admissions
        for adm, _, _, _ in self.live:
            for pid in adm.pids[:adm.n_live]:
                assert pid != 0, "trash page allocated to a live request"
                holders.setdefault(pid, []).append(adm)

        for pid in range(1, pool.n_pages):
            want = len(holders.get(pid, ())) + (1 if pid in pool.key_of
                                                else 0)
            assert pool.ref[pid] == want, \
                f"refcount leak on page {pid}: {pool.ref[pid]} != {want}"
        assert pool.ref[0] == 0 and 0 not in pool.key_of

        free = pool.free
        assert len(free) == len(set(free)), "free-list duplicate"
        assert set(free) == {p for p in range(1, pool.n_pages)
                             if pool.ref[p] == 0}, "free-list drift"

        # a page held by TWO live requests is only ever a registered
        # (immutable, read-only) prefix page — never a write target
        for pid, hs in holders.items():
            if len(hs) > 1:
                assert pid in pool.key_of, \
                    f"page {pid} aliased by {len(hs)} live slots unregistered"
        for adm, plen, stop, cur in self.live:
            if cur >= plen + stop - 1:
                continue                          # no further writes due
            tgt = cur // self.page                # next decode write page
            if tgt < adm.n_live:
                pid = adm.pids[tgt]
                assert pid not in pool.key_of, \
                    "decode write target is a shared registered page"
                assert pool.ref[pid] == 1, \
                    f"write-target page {pid} shared (ref {pool.ref[pid]})"

        # speculative-rollback accounting: every released-but-reserved page
        # stays claimable (free, or reclaimable by evicting a cache-only
        # page — admission counts both minus reserved_extra), so the
        # extend() transitions of this walk can never hit the allocator's
        # exhaustion error
        owed = sum(adm.reserve - adm.n_live for adm, _, _, _ in self.live)
        assert pool.reserved_extra == owed
        assert len(free) + pool._evictable() >= pool.reserved_extra, \
            "reserved rollback pages no longer claimable: extend deadlock"

        # the §11 introspection signals ARE the admission threshold
        fc = pool.free_claimable()
        assert pool.can_admit(fc) and not pool.can_admit(fc + 1)
        assert pool.pressure() == 1.0 - fc / pool.usable_pages

    def run(self, n_ops=40):
        ops = [self.admit, self.admit, self.truncate, self.extend,
               self.retire, self.swap_out, self.swap_in]
        self.check()
        for _ in range(n_ops):
            ops[self.rng.integers(len(ops))]()
            self.check()
        # drain: every swapped request must be restorable once the live
        # ones retire (its reservation never exceeded the pool)
        while self.live or self.swapped:
            if self.live:
                self.retire()
            else:
                before = len(self.swapped)
                self.swap_in()
                assert len(self.swapped) < before, \
                    "swap-in blocked on an empty pool"
            self.check()
        assert self.pool.reserved_extra == 0
        assert all(self.pool.ref[p] in (0, 1)
                   for p in range(1, self.pool.n_pages))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 4]),
       st.sampled_from([8, 12]), st.booleans())
def test_pool_state_machine_invariants(seed, page, n_pages, prefix):
    _PoolWalk(np.random.default_rng(seed), page=page, n_pages=n_pages,
              prefix_cache=prefix).run()
