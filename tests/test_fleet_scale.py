"""Tier-2 fleet scale test (ISSUE 9): a streamed 200k-request Poisson
trace through a 4-replica fleet on the tiny test model.

Two seeded runs must be byte-identical in report + event-log digest
(``retain=False``: the merged log lives only as a running SHA-256, so
determinism is checked at the digest level — any divergent event row
flips it).  The trace is a generator end to end: the test instruments it
to prove the fleet's backlog high-water mark stays a small fraction of
the trace (rows are pulled as virtual time reaches them, not
materialized up front), and bounds peak RSS growth across both runs.

Runs under the CI tier-2 ``fleet-scale`` job (deselected from tier-1 by the
default ``-m 'not tier2'`` addopts); ``FLEET_SCALE_N`` scales the trace
down for local iteration.  The run's report/digest/timing land in
``FLEET_SCALE_OUT`` (default ``BENCH_fleet_scale.json``) for the CI
artifact upload.
"""

import json
import os
import resource
import time

import jax
import pytest

import repro.configs as C
from repro.models.model_zoo import build
from repro.serving import Fleet, ServeEngine
from repro.serving.server import poisson_trace_iter

pytestmark = pytest.mark.tier2

N_REQUESTS = int(os.environ.get("FLEET_SCALE_N", "200000"))
SEED = 11
ENGINE_KW = dict(max_len=64, max_batch=16, paged=True, page_size=8,
                 n_pages=80)
# virtual service capacity: 4 replicas x 16 slots x 1 tok / 0.02 s
# decode rounds ~= 3200 tok/s ~= 450 req/s at ~7 tokens/request; rate 40
# keeps utilization high while the backlog stays bounded (the streaming
# assertion below fails loudly if arrivals ever outpace service for long)
TRACE_KW = dict(rate=40.0, plen=(2, 10), max_new=(2, 12))


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2,
                                                dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _counting(rows, fleet, stats):
    """Yield trace rows while tracking the backlog high-water mark:
    rows handed to the fleet minus requests it has already finished."""
    for row in rows:
        stats["pulled"] += 1
        stats["backlog_peak"] = max(stats["backlog_peak"],
                                    stats["pulled"] - fleet._agg["n"])
        yield row


def _run(model, params, stats=None):
    fleet = Fleet([ServeEngine(model, params, **ENGINE_KW)
                   for _ in range(4)], quantum=8, retain=False)
    rows = poisson_trace_iter(SEED, N_REQUESTS, vocab=model.cfg.vocab,
                              **TRACE_KW)
    if stats is not None:
        rows = _counting(rows, fleet, stats)
    t0 = time.monotonic()
    rep = fleet.replay(rows, max_rounds=100_000_000)
    wall = time.monotonic() - t0
    assert not fleet.handles and not fleet.assigned  # released as it ran
    return rep, fleet.event_digest(), wall


def test_fleet_scale_streamed_trace_deterministic(tiny):
    model, params = tiny
    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    stats = {"pulled": 0, "backlog_peak": 0}
    rep1, digest1, wall1 = _run(model, params, stats)
    rep2, digest2, wall2 = _run(model, params)
    rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss

    assert rep1.n_requests == N_REQUESTS
    assert digest1 == digest2
    assert rep1.to_json() == rep2.to_json()

    # streamed, not materialized: the fleet only ever holds the live
    # backlog (arrivals outpace service transiently, never cumulatively)
    assert stats["pulled"] == N_REQUESTS
    assert stats["backlog_peak"] < max(2000, N_REQUESTS // 5), stats

    # peak RSS growth across BOTH replays stays bounded (ru_maxrss is in
    # KiB on Linux); a materialized trace + retained handles would not
    rss_growth_mb = (rss1 - rss0) / 1024
    assert rss_growth_mb < 2048, f"peak RSS grew {rss_growth_mb:.0f} MiB"

    out = os.environ.get("FLEET_SCALE_OUT", "BENCH_fleet_scale.json")
    with open(out, "w") as f:
        json.dump({"n_requests": N_REQUESTS, "seed": SEED,
                   "engine": ENGINE_KW, "trace": TRACE_KW,
                   "event_digest": digest1,
                   "backlog_peak": stats["backlog_peak"],
                   "rss_growth_mb": round(rss_growth_mb, 1),
                   "wall_s": [round(wall1, 2), round(wall2, 2)],
                   "report": rep1.to_json()}, f, indent=1,
                  sort_keys=True)
        f.write("\n")
