"""Tier-2 fleet scale tests (ISSUEs 9-10): streamed 200k-request traces
through a 4-replica fleet on the tiny test model.

Two scenarios, each replayed twice and compared at the digest level
(``retain=False``: the merged event log lives only as a running SHA-256,
so any divergent event row flips it):

* **baseline** — the ISSUE 9 rig: a streamed Poisson trace, byte-
  identical across runs, backlog high-water mark bounded (rows are
  pulled as virtual time reaches them, never materialized up front),
  peak RSS growth bounded.
* **drain_migration** — the ISSUE 10 rig: a streamed grouped-prefix
  trace with a mid-trace drain under ``migrate_on_drain=True`` plus a
  cold scale-up, over a fleet-level ``SharedPrefixTier``.  The drain's
  expel/adopt handovers and the joiner's tier adoptions must stay
  inside the byte-identical contract — migration events, shed gates,
  and tier mutations all replay digest-stable — with migrated pages and
  tier hits both provably nonzero and RSS still bounded.

Runs under the CI tier-2 ``fleet-scale`` job (deselected from tier-1 by
the default ``-m 'not tier2'`` addopts); ``FLEET_SCALE_N`` scales the
traces down for local iteration.  Both scenarios merge their
report/digest/migration/tier stats into ``FLEET_SCALE_OUT`` (default
``BENCH_fleet_scale.json``) for the CI artifact upload.
"""

import json
import os
import resource
import time

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models.model_zoo import build
from repro.serving import Fleet, ServeEngine
from repro.serving.server import poisson_trace_iter

pytestmark = pytest.mark.tier2

N_REQUESTS = int(os.environ.get("FLEET_SCALE_N", "200000"))
SEED = 11
ENGINE_KW = dict(max_len=64, max_batch=16, paged=True, page_size=8,
                 n_pages=80)
# virtual service capacity: 4 replicas x 16 slots x 1 tok / 0.02 s
# decode rounds ~= 3200 tok/s ~= 450 req/s at ~7 tokens/request; rate 40
# keeps utilization high while the backlog stays bounded (the streaming
# assertion below fails loudly if arrivals ever outpace service for long)
TRACE_KW = dict(rate=40.0, plen=(2, 10), max_new=(2, 12))


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2,
                                                dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _counting(rows, fleet, stats):
    """Yield trace rows while tracking the backlog high-water mark:
    rows handed to the fleet minus requests it has already finished."""
    for row in rows:
        stats["pulled"] += 1
        stats["backlog_peak"] = max(stats["backlog_peak"],
                                    stats["pulled"] - fleet._agg["n"])
        yield row


def _merge_out(section: str, payload: dict) -> None:
    """Merge one scenario's stats into the shared CI artifact, keeping
    whatever the other scenario already wrote there."""
    out = os.environ.get("FLEET_SCALE_OUT", "BENCH_fleet_scale.json")
    doc = {}
    if os.path.exists(out):
        try:
            with open(out) as f:
                doc = json.load(f)
        except ValueError:
            doc = {}
    if not isinstance(doc, dict) or "report" in doc:
        doc = {}                       # pre-ISSUE-10 flat layout: restart
    doc[section] = payload
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def _run(model, params, stats=None):
    fleet = Fleet([ServeEngine(model, params, **ENGINE_KW)
                   for _ in range(4)], quantum=8, retain=False)
    rows = poisson_trace_iter(SEED, N_REQUESTS, vocab=model.cfg.vocab,
                              **TRACE_KW)
    if stats is not None:
        rows = _counting(rows, fleet, stats)
    t0 = time.monotonic()
    rep = fleet.replay(rows, max_rounds=100_000_000)
    wall = time.monotonic() - t0
    assert not fleet.handles and not fleet.assigned  # released as it ran
    return rep, fleet.event_digest(), wall


def test_fleet_scale_streamed_trace_deterministic(tiny):
    model, params = tiny
    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    stats = {"pulled": 0, "backlog_peak": 0}
    rep1, digest1, wall1 = _run(model, params, stats)
    rep2, digest2, wall2 = _run(model, params)
    rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss

    assert rep1.n_requests == N_REQUESTS
    assert digest1 == digest2
    assert rep1.to_json() == rep2.to_json()

    # streamed, not materialized: the fleet only ever holds the live
    # backlog (arrivals outpace service transiently, never cumulatively)
    assert stats["pulled"] == N_REQUESTS
    assert stats["backlog_peak"] < max(2000, N_REQUESTS // 5), stats

    # peak RSS growth across BOTH replays stays bounded (ru_maxrss is in
    # KiB on Linux); a materialized trace + retained handles would not
    rss_growth_mb = (rss1 - rss0) / 1024
    assert rss_growth_mb < 2048, f"peak RSS grew {rss_growth_mb:.0f} MiB"

    _merge_out("baseline", {
        "n_requests": N_REQUESTS, "seed": SEED,
        "engine": ENGINE_KW, "trace": TRACE_KW,
        "event_digest": digest1,
        "backlog_peak": stats["backlog_peak"],
        "rss_growth_mb": round(rss_growth_mb, 1),
        "wall_s": [round(wall1, 2), round(wall2, 2)],
        "report": rep1.to_json()})


# --- drain-with-migration over a shared prefix tier (ISSUE 10) ----------------

N_GROUPS = 8


def grouped_trace_iter(seed, n, *, n_groups=N_GROUPS, page=8, rate=40.0,
                       vocab=512, max_new=(2, 12)):
    """Streamed grouped-prefix workload: every request opens with one of
    ``n_groups`` two-page system prompts plus a private tail, O(1) rows
    live, arrivals non-decreasing — the trace shape the shared tier and
    drain-time migration are measured on at scale."""
    rng = np.random.default_rng(seed)
    prefixes = [[int(x) for x in rng.integers(0, vocab, 2 * page)]
                for _ in range(n_groups)]
    t = 0.0
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        g = int(rng.integers(n_groups))
        tail = [int(x) for x in
                rng.integers(0, vocab, int(rng.integers(1, page)))]
        yield {"arrival": round(t, 9), "prompt": prefixes[g] + tail,
               "max_new": int(rng.integers(max_new[0], max_new[1] + 1)),
               "priority": 0, "slo_ttft": None, "slo_tpot": None}


def _run_migration(model, params):
    """One drain-with-migration replay: drain r0 (expelling its warm
    work) a quarter into the trace, join a cold replica shortly after —
    the joiner's prefix pages come from the fleet tier, the drained
    replica's in-flight requests from expel/adopt blobs.  Round-robin
    routing (not prefix affinity) so the cold joiner takes traffic
    immediately and every replica's first contact with each prefix
    group goes through the tier."""
    span = N_REQUESTS / TRACE_KW["rate"]      # ~virtual trace duration
    fleet = Fleet([ServeEngine(model, params, **ENGINE_KW)
                   for _ in range(4)], quantum=8, retain=False,
                  policy="round_robin",
                  migrate_on_drain=True, shared_prefix_tier=True)
    rows = grouped_trace_iter(SEED + 1, N_REQUESTS, vocab=model.cfg.vocab,
                              page=ENGINE_KW["page_size"],
                              rate=TRACE_KW["rate"],
                              max_new=TRACE_KW["max_new"])
    t0 = time.monotonic()
    rep = fleet.replay(
        rows, max_rounds=100_000_000,
        drain_at=[(0.25 * span, "r0")],
        scale_at=[(0.30 * span, "r9",
                   lambda: ServeEngine(model, params, **ENGINE_KW))])
    wall = time.monotonic() - t0
    assert not fleet.handles and not fleet.assigned
    return rep, fleet, wall


def test_fleet_scale_drain_migration_deterministic(tiny):
    model, params = tiny
    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    rep1, f1, wall1 = _run_migration(model, params)
    rep2, f2, wall2 = _run_migration(model, params)
    rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss

    assert rep1.n_requests == N_REQUESTS
    # the whole drain — expel blobs, adoptions, tier scatters — replays
    # byte-identically
    assert f1.event_digest() == f2.event_digest()
    assert rep1.to_json() == rep2.to_json()

    # the drain really migrated warm work (pages, not just queued rows),
    # the drained replica went quiet, and the joiner took traffic
    assert f1.n_migrated > 0 and f1.n_migrated_pages > 0
    assert f1.migrated_from["r0"] == f1.n_migrated
    assert f1.inflight["r0"] == 0
    assert f1.n_routed_to["r9"] > 0
    tier = f1.shared_tier_stats()
    assert tier["hits"] > 0, tier          # the joiner adopted from it
    assert tier["puts"] >= 2 * N_GROUPS    # every group's prefix is held
    assert (f1.n_migrated, f1.n_migrated_pages, f2.shared_tier_stats()) \
        == (f2.n_migrated, f2.n_migrated_pages, tier)

    rss_growth_mb = (rss1 - rss0) / 1024
    assert rss_growth_mb < 2048, f"peak RSS grew {rss_growth_mb:.0f} MiB"

    _merge_out("drain_migration", {
        "n_requests": N_REQUESTS, "seed": SEED + 1,
        "n_groups": N_GROUPS, "engine": ENGINE_KW,
        "policy": "round_robin", "rate": TRACE_KW["rate"],
        "event_digest": f1.event_digest(),
        "n_migrated": f1.n_migrated,
        "n_migrated_pages": f1.n_migrated_pages,
        "shared_tier": tier,
        "materialized_pages": f1.materialized_pages(),
        "rss_growth_mb": round(rss_growth_mb, 1),
        "wall_s": [round(wall1, 2), round(wall2, 2)],
        "report": rep1.to_json()})
