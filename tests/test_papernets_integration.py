"""End-to-end reproduction slice: train the paper's MLP with both
quantizations, export the §4 tables, and check the integer engine keeps the
float network's accuracy (the paper's central claim, at CPU scale)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activations import ActQuantConfig, act_apply
from repro.core import clustering, fixedpoint as fp
from repro.core.lut import LutConfig, build_tables
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.data.synthetic import pseudo_mnist_batch, parabola_batch
from repro.models import papernets as PN
from repro.optim import OptConfig, apply_updates, init_opt_state


def _train_mlp(act_levels, n_weights, steps=250, hidden=(32,), key=0):
    kind = "tanh"
    params = PN.mlp_init(jax.random.PRNGKey(key), 784, list(hidden), 10)
    ocfg = OptConfig(name="adam", lr=2e-3)
    opt = init_opt_state(params, ocfg)
    wq = WeightQuantConfig(num_weights=n_weights, method="laplacian_l1",
                           interval=50) if n_weights else \
        WeightQuantConfig()
    qstate = init_state(wq)

    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            logits = PN.mlp_apply(p, batch["x"], kind, act_levels)
            lse = jax.nn.logsumexp(logits, -1)
            true = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
            return jnp.mean(lse - true)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = apply_updates(params, g, opt, ocfg)
        return params, opt, loss

    for s in range(steps):
        if wq.due(s):
            params, qstate = cluster_params(params, wq, qstate, s,
                                            jax.random.PRNGKey(s))
        params, opt, loss = step(params, opt, pseudo_mnist_batch(s, 64))
    if wq.enabled:   # final snap so the deployed net is exactly clustered
        params, qstate = cluster_params(params, wq, qstate, steps,
                                        jax.random.PRNGKey(steps))
    return params, qstate, wq


def _accuracy(fn, n_batches=5):
    hits = tot = 0
    for s in range(1000, 1000 + n_batches):
        b = pseudo_mnist_batch(s, 128)
        pred = np.argmax(np.asarray(fn(b["x"])), -1)
        hits += (pred == np.asarray(b["y"])).sum()
        tot += pred.size
    return hits / tot


def test_quantized_training_matches_continuous():
    """Fig. 6: |A|=32, |W|=1000-ish quantization ≈ continuous baseline."""
    p_cont, _, _ = _train_mlp(0, 0)
    acc_cont = _accuracy(lambda x: PN.mlp_apply(p_cont, x, "tanh", 0))
    p_q, _, _ = _train_mlp(32, 256)
    acc_q = _accuracy(lambda x: PN.mlp_apply(p_q, x, "tanh", 32))
    assert acc_cont > 0.85
    assert acc_q > acc_cont - 0.05, (acc_cont, acc_q)


def test_integer_engine_end_to_end():
    """Train quantized -> export §4 tables -> integer-only inference must
    match the float quantized network's predictions."""
    act = ActQuantConfig("tanh", 16)
    params, qstate, wq = _train_mlp(16, 128, steps=200, hidden=(24,))
    book = np.asarray(qstate.codebooks[""])
    fan_in = 785
    tabs = build_tables(book, LutConfig(act=act, table_entries=8192),
                        fan_in=fan_in)

    layers = []
    for i in range(len(params)):
        p = params[f"layer{i}"]
        layers.append((clustering.assign_to_centers(p["w"], jnp.asarray(book)),
                       clustering.assign_to_centers(p["b"], jnp.asarray(book))))

    def float_net(x):
        xi = fp.input_to_indices(jnp.tanh(x), act)   # bounded inputs
        lo, _ = act.out_range
        xq = lo + xi * act.step
        h = xq
        for i in range(len(params) - 1):
            h = act_apply(act, h @ params[f"layer{i}"]["w"]
                          + params[f"layer{i}"]["b"])
        last = params[f"layer{len(params) - 1}"]
        return h @ last["w"] + last["b"]

    def int_net(x):
        xi = fp.input_to_indices(jnp.tanh(x), act)
        acc = fp.int_mlp_forward(layers, xi, tabs)
        return tabs.decode(np.asarray(acc))

    b = pseudo_mnist_batch(2000, 256)
    yf = np.asarray(float_net(b["x"]))
    yi = int_net(b["x"])
    agree = np.mean(np.argmax(yf, -1) == np.argmax(yi, -1))
    assert agree > 0.97, agree        # prediction-level agreement
    assert np.max(np.abs(yf - yi)) < 0.6   # value-level (boundary snapping)


def test_parabola_regression_fig2():
    """Fig. 2: tanhD(L) fits a parabola; error shrinks as L grows."""
    def run(levels):
        params = PN.mlp_init(jax.random.PRNGKey(1), 1, [2], 1)
        ocfg = OptConfig(name="adam", lr=2e-2)
        opt = init_opt_state(params, ocfg)

        @jax.jit
        def step(params, opt, b):
            def loss_fn(p):
                pred = PN.mlp_apply(p, b["x"], "tanh", levels)
                return jnp.mean((pred - b["y"]) ** 2)
            l, g = jax.value_and_grad(loss_fn)(params)
            params, opt, _ = apply_updates(params, g, opt, ocfg)
            return params, opt, l
        for s in range(600):
            params, opt, l = step(params, opt, parabola_batch(s))
        return float(l)

    e2, e8, e256 = run(2), run(8), run(256)
    # e8 vs e256 can swap within noise at this 2-hidden-unit scale (the
    # paper itself notes quantization noise sometimes helps); the robust
    # claims are: both beat L=2, and high-L reaches the continuous fit
    assert e8 < e2 and e256 < e2
    assert e256 < 5e-3
