"""Tensor-parallel ServeEngine (DESIGN.md §10), locked down two ways:

* **Token parity**: tp=2 and tp=4 serve output must be token-for-token
  identical to tp=1 (the mesh-less engine) for dense / codebook / lut
  backends × contiguous / paged caches × plain / speculative decoding —
  13 cases per TP degree, each run on 8 forced host devices through the
  ``tp_rig`` subprocess helper (tests/tp_serve_cases.py builds identical
  params from fixed seeds in every child).
* **Collective bytes**: the decode-step jaxpr and compiled HLO under TP
  must contain no collective moving a cache-sized operand — every payload
  is bounded by O(B·H·hd) per layer (the §5 two-psum flash-decode join),
  contiguous and paged alike.

tier2: the matrix compiles ~40 jitted programs per child process — the CI
``tp`` job runs it; the default tier-1 invocation deselects it.
"""

import pytest

from tp_rig import run_under_devices

pytestmark = pytest.mark.tier2


@pytest.fixture(scope="module")
def matrix():
    """Serve-case tokens per TP degree (one rig subprocess each)."""
    return {tp: run_under_devices("tp_serve_cases:serve_matrix", {"tp": tp})
            for tp in (1, 2, 4)}


def test_matrix_covers_issue_grid(matrix):
    cases = set(matrix[1])
    for be in ("dense", "codebook", "lut"):
        for mode in ("contig", "paged"):
            for sp in ("plain", "spec"):
                assert f"{be}/{mode}/{sp}" in cases
    assert "dense/paged-int8/plain" in cases


@pytest.mark.parametrize("tp", [2, 4])
def test_tp_serve_token_parity(matrix, tp):
    """tp=N output == tp=1 output, token for token, every case."""
    ref, got = matrix[1], matrix[tp]
    assert set(got) == set(ref)
    bad = [case for case in ref if got[case] != ref[case]]
    assert not bad, f"tp={tp} diverged from tp=1 on: {bad}"


@pytest.mark.parametrize("tp", [2, 4])
def test_lut_acc_psum_bit_exact(tp):
    """The §10 int-accumulator psum: a row-parallel lut contraction's
    int32 accumulator (and its decoded float output) under tp=N is
    bit-identical to single-device — integer addition is associative, so
    sharding the K reduction cannot change a single bit.  Exercises the
    real w2 site of the quantized model with the replicated precomputed
    table (kernels/dispatch.attach_lut_tables contract)."""
    ref = run_under_devices("tp_serve_cases:lut_acc_psum_case", {"tp": 1})
    got = run_under_devices("tp_serve_cases:lut_acc_psum_case", {"tp": tp})
    assert got["acc"] == ref["acc"], f"tp={tp} int32 accumulators diverged"
    assert got["y"] == ref["y"], f"tp={tp} decoded outputs diverged"
    assert got["s"] == ref["s"]


def test_tp_probes_token_identity(matrix):
    """ISSUE 8 tier-2 row: probes-on serve at tp=1 and tp=2 is
    token-identical to the probes-off matrix, and the numerics counters
    themselves agree across TP degrees (replicated probe state, taps on
    the full pre-shard activations)."""
    probed = {tp: run_under_devices("tp_serve_cases:probes_matrix",
                                    {"tp": tp}) for tp in (1, 2)}
    plain = {k: matrix[1][k] for k in probed[1]}
    for tp in (1, 2):
        got = {k: v["tokens"] for k, v in probed[tp].items()}
        assert got == plain, f"tp={tp}: probes changed the decoded tokens"
    for case, r1 in probed[1].items():
        n1, n2 = r1["numerics"], probed[2][case]["numerics"]
        for k in ("tokens", "matmul_calls", "act_sat", "act_total",
                  "page_oob", "widx_neg", "widx_oob"):
            assert n1[k] == n2[k], (case, k, n1[k], n2[k])
        # float-derived series may differ only in the last bits
        for k in ("acc_max", "headroom_bits"):
            for a, b in zip(n1[k], n2[k]):
                assert a == pytest.approx(b, rel=1e-3, abs=1e-6), (case, k)
        # under a mesh, quantize_kv sits inside shard_map and the trace
        # fence drops its tap (DESIGN.md §14: sharded inner sites are
        # uncovered) — KV counters must read exactly zero, not garbage
        assert max(n2["kv_err_max"]) == 0.0, case
    assert max(probed[1]["dense/paged-int8/plain"]["numerics"]
               ["kv_err_max"]) > 0.0, "tp=1 int8 row lost its KV tap"


@pytest.mark.parametrize("tp", [2, 4])
def test_decode_collectives_bounded(tp):
    """No all-gather of cache-sized operands in the decode step: the
    largest collective payload (jaxpr psums AND compiled-HLO collectives,
    which include anything GSPMD inserted) stays within a small multiple
    of B·H·hd bytes and far under one layer's cache slice."""
    r = run_under_devices("tp_serve_cases:collective_bounds", {"tp": tp})
    cap = 4 * r["unit_bytes"]                 # num psum is 1× B·H·hd·4
    for mode in ("contig", "paged"):
        for level in ("jaxpr", "hlo"):
            got = r[f"{mode}_{level}_bytes"]
            assert 0 < got <= cap, (mode, level, got, cap)
            assert got * 16 <= r["layer_cache_bytes"], \
                f"{mode}/{level}: collective {got}B is cache-scale " \
                f"(layer slice {r['layer_cache_bytes']}B)"
