"""Paper §2.2: k-means / Laplacian-L1 weight clustering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st

from repro.core import clustering as CL
from repro.core.quantizer import (WeightQuantConfig, cluster_params,
                                  init_state, num_weights_at, codebook_indices)


def test_laplacian_recursion_identity():
    """The paper's Δ_i = −ln(1 − 2·e^{L_{i−1}}/N) telescopes to
    e^{−L_i} = 1 − 2i/N (linear occupancy, Fig. 5)."""
    for n in (5, 11, 101, 999):
        L = CL.laplacian_l1_levels(n)
        i = np.arange(len(L))
        np.testing.assert_allclose(np.exp(-L), 1 - 2 * i / n, atol=1e-12)
        for j in range(1, len(L)):
            d = -np.log(1 - 2 * np.exp(L[j - 1]) / n)
            assert abs((L[j] - L[j - 1]) - d) < 1e-9


def test_laplacian_spacing_widens():
    """Fig. 5: wider spacing at large amplitudes."""
    L = CL.laplacian_l1_levels(101)
    d = np.diff(L)
    assert np.all(np.diff(d) > -1e-12)


def test_assign_matches_bruteforce():
    rng = np.random.default_rng(1)
    centers = jnp.sort(jnp.asarray(rng.normal(size=37)))
    v = jnp.asarray(rng.normal(size=500) * 2)
    idx = np.asarray(CL.assign_to_centers(v, centers))
    brute = np.argmin(np.abs(np.asarray(v)[:, None]
                             - np.asarray(centers)[None, :]), axis=1)
    np.testing.assert_array_equal(idx, brute)


def test_kmeans_beats_uniform_on_laplacian():
    key = jax.random.PRNGKey(0)
    v = jax.random.laplace(key, (50_000,))
    for k in (16, 64, 256):
        km = CL.quantize_to_centers(v, CL.kmeans1d(v, k))
        un = CL.quantize_to_centers(v, CL.uniform_centers(v, k))
        lap = CL.quantize_to_centers(v, CL.laplacian_l1_centers(v, k))
        mse = lambda q: float(jnp.mean((q - v) ** 2))
        assert mse(km) < mse(un), k          # paper's case against Lin et al.
        assert mse(lap) < mse(un), k


def test_kmeans_center_count_and_idempotence():
    v = jax.random.normal(jax.random.PRNGKey(2), (10_000,))
    c = CL.kmeans1d(v, 32)
    assert c.shape == (32,)
    q = CL.quantize_to_centers(v, c)
    q2 = CL.quantize_to_centers(q, c)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    assert len(np.unique(np.asarray(q))) <= 32


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 200))
def test_laplacian_centers_symmetric(n):
    v = jax.random.laplace(jax.random.PRNGKey(4), (5000,)) * 0.3 + 0.1
    c = np.asarray(CL.laplacian_l1_centers(v, n, nudge=False))
    assert c.shape == (n,)
    a = float(jnp.mean(v))
    np.testing.assert_allclose(c + c[::-1], 2 * a, atol=1e-4)


def test_cluster_params_global_scope():
    key = jax.random.PRNGKey(0)
    params = {"a": {"w": jax.random.normal(key, (32, 64))},
              "b": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                           (16, 8)),
                    "bias": jax.random.normal(jax.random.fold_in(key, 2),
                                              (8,))}}
    wq = WeightQuantConfig(num_weights=17, method="kmeans", interval=10)
    newp, state = cluster_params(params, wq, init_state(wq), 10, key)
    allv = np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(newp)])
    assert len(np.unique(allv)) <= 17          # ONE global codebook
    assert state.codebooks[""].shape == (17,)
    # biases clustered too (paper: "all of the weights ... including the
    # bias weights")
    assert set(np.unique(np.asarray(newp["b"]["bias"]))) <= \
        set(np.unique(allv))


def test_cluster_params_per_layer_scope():
    key = jax.random.PRNGKey(0)
    params = {"a": {"w": jax.random.normal(key, (64, 64))},
              "b": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                           (64, 64)) * 3}}
    wq = WeightQuantConfig(num_weights=9, method="kmeans", scope="per_layer")
    newp, state = cluster_params(params, wq, init_state(wq), 1000, key)
    ua = np.unique(np.asarray(newp["a"]["w"]))
    ub = np.unique(np.asarray(newp["b"]["w"]))
    assert len(ua) <= 9 and len(ub) <= 9
    assert len(state.codebooks) == 2


def test_exclude_filter():
    key = jax.random.PRNGKey(0)
    params = {"mlp": {"w": jax.random.normal(key, (64, 64))},
              "norm": {"scale": jnp.ones((64,)) * 1.2345}}
    wq = WeightQuantConfig(num_weights=4, method="kmeans", exclude="norm")
    newp, _ = cluster_params(params, wq, init_state(wq), 1000, key)
    np.testing.assert_array_equal(np.asarray(newp["norm"]["scale"]),
                                  np.asarray(params["norm"]["scale"]))
    assert len(np.unique(np.asarray(newp["mlp"]["w"]))) <= 4


def test_wq_schedule_and_due():
    wq = WeightQuantConfig(num_weights=100, anneal_from=1000,
                           anneal_steps=100, interval=10)
    assert num_weights_at(wq, 0) == 1000
    assert num_weights_at(wq, 100) == 100
    assert num_weights_at(wq, 50) < 1000
    assert not wq.due(0) and wq.due(10) and not wq.due(11)


def test_codebook_indices_roundtrip():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (128, 32))}
    wq = WeightQuantConfig(num_weights=50, method="laplacian_l1")
    newp, state = cluster_params(params, wq, init_state(wq), 1000, key)
    idx_tree, books = codebook_indices(newp, wq, state)
    rec = books[""][np.asarray(idx_tree["w"])]
    np.testing.assert_allclose(np.asarray(newp["w"]), np.asarray(rec),
                               atol=1e-6)
