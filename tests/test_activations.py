"""Paper §2.1: quantized activations + underlying-derivative backward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st

from repro.core.activations import (ACT_RANGES, ActQuantConfig, act_apply,
                                    act_index, act_input_boundaries,
                                    act_levels, quantize_input)

KINDS = sorted(ACT_RANGES)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("levels", [2, 8, 32, 256])
def test_outputs_are_levels(kind, levels):
    cfg = ActQuantConfig(kind, levels)
    x = jnp.linspace(-6, 6, 4001)
    y = np.asarray(act_apply(cfg, x))
    lv = np.asarray(act_levels(cfg))
    # every output must be (numerically) one of the |A| levels
    d = np.min(np.abs(y[:, None] - lv[None, :]), axis=1)
    assert d.max() < 1e-5
    assert len(np.unique(np.round(y, 5))) <= levels


@pytest.mark.parametrize("kind", KINDS)
def test_quantization_error_bounded(kind):
    cfg = ActQuantConfig(kind, 16)
    x = jnp.linspace(-8, 8, 2001)
    y = np.asarray(act_apply(cfg, x))
    base = {"tanh": np.tanh, "relu6": lambda v: np.clip(v, 0, 6),
            "sigmoid": lambda v: 1 / (1 + np.exp(-v)),
            "rtanh": lambda v: np.maximum(np.tanh(v), 0)}[kind](np.asarray(x))
    assert np.max(np.abs(y - base)) <= cfg.step / 2 + 1e-6


@pytest.mark.parametrize("kind", KINDS)
def test_backward_is_underlying_derivative(kind):
    """Paper: 'ignore the quantization ... compute the derivatives of the
    underlying function'."""
    cfg = ActQuantConfig(kind, 8)
    x = jnp.linspace(-3, 3, 101)
    g = jax.vmap(jax.grad(lambda v: act_apply(cfg, v)))(x)
    g_base = jax.vmap(jax.grad(
        lambda v: act_apply(ActQuantConfig(kind, 0), v)))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_base), atol=1e-6)


def test_plateaus_smallest_where_slope_largest():
    """Fig. 1: input-space bins are densest where |f'| is largest."""
    b = act_input_boundaries(ActQuantConfig("tanh", 64))
    widths = np.diff(b)
    mid = len(widths) // 2
    assert widths[mid] < widths[0]
    assert widths[mid] < widths[-1]


def test_act_index_matches_value():
    cfg = ActQuantConfig("tanh", 32)
    x = jnp.linspace(-4, 4, 999)
    idx = np.asarray(act_index(cfg, x))
    lv = np.asarray(act_levels(cfg))
    np.testing.assert_allclose(lv[idx], np.asarray(act_apply(cfg, x)),
                               atol=1e-6)


def test_unbounded_kind_rejected():
    with pytest.raises(ValueError):
        ActQuantConfig("relu", 8)       # paper swaps ReLU -> ReLU6 (§3.3)
    ActQuantConfig("relu", 0)           # continuous is fine


@settings(max_examples=50, deadline=None)
@given(st.floats(-50, 50), st.sampled_from([2, 5, 16, 33]),
       st.sampled_from(KINDS))
def test_idempotent(x0, levels, kind):
    cfg = ActQuantConfig(kind, levels)
    y1 = float(act_apply(cfg, jnp.asarray(x0)))
    # quantized values are fixed points of value-quantization
    lo, _ = cfg.out_range
    q = round((y1 - lo) / cfg.step)
    assert abs(y1 - (lo + q * cfg.step)) < 1e-5


def test_quantize_input_range():
    x = jnp.linspace(-2, 2, 100)
    q = np.asarray(quantize_input(x, 32, -1.0, 1.0))
    assert q.min() >= -1.0 - 1e-6 and q.max() <= 1.0 + 1e-6
    assert len(np.unique(np.round(q, 6))) <= 32
