"""Autotune harness (kernels/autotune.py): determinism, cache replay,
config validity — the properties that make the tuning cache CI-safe."""

import json

import pytest

from repro.kernels import autotune

SHAPES = [dict(kernel="lut", plat=p, m=m, k=k, n=n, dtype="int32",
               table_shape=(4096, 256))
          for p in ("xla", "tpu") for m in (1, 8, 64)
          for (k, n) in ((128, 128), (128, 256), (256, 128))] + \
         [dict(kernel="codebook", plat=p, m=m, k=k, n=n, dtype=d,
               table_shape=(256,))
          for p in ("xla", "tpu") for m in (1, 64)
          for d in ("float32", "bfloat16") for (k, n) in ((128, 256),)]


@pytest.fixture(autouse=True)
def _fresh_memory_cache():
    autotune.clear_memory_cache()
    yield
    autotune.clear_memory_cache()


def test_two_runs_byte_identical(tmp_path):
    """Seeded cost-model tuning over the same shape set must write
    byte-identical JSON — the property that lets CI regenerate and diff
    the committed cache."""
    paths = [str(tmp_path / f"cache_{i}.json") for i in (0, 1)]
    for p in paths:
        autotune.clear_memory_cache()
        autotune.autotune_shapes(SHAPES, path=p, seed=0)
    b0 = open(paths[0], "rb").read()
    b1 = open(paths[1], "rb").read()
    assert b0 == b1
    assert len(json.loads(b0)) == len(SHAPES)


def test_shuffled_shape_order_same_bytes(tmp_path):
    """Cache contents must not depend on tuning order (sorted dump)."""
    p0, p1 = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    autotune.autotune_shapes(SHAPES, path=p0)
    autotune.clear_memory_cache()
    autotune.autotune_shapes(list(reversed(SHAPES)), path=p1)
    assert open(p0, "rb").read() == open(p1, "rb").read()


def test_cold_vs_warm_identical_configs(tmp_path):
    """Configs resolved from a warm file cache equal cold cost-model
    picks: replaying the committed cache never changes routing."""
    path = str(tmp_path / "cache.json")
    cold = {}
    for s in SHAPES:
        key = autotune.cache_key(s["kernel"], s["plat"], s["m"], s["k"],
                                 s["n"], s["dtype"], s["table_shape"])
        cold[key] = autotune.kernel_config(
            s["kernel"], s["m"], s["k"], s["n"], dtype=s["dtype"],
            plat=s["plat"], table_shape=s["table_shape"])
    autotune.autotune_shapes(SHAPES, path=path)

    # warm pass: resolve through the file via REPRO_TUNING_CACHE
    import os
    autotune.clear_memory_cache()
    old = os.environ.get("REPRO_TUNING_CACHE")
    os.environ["REPRO_TUNING_CACHE"] = path
    try:
        for s in SHAPES:
            key = autotune.cache_key(s["kernel"], s["plat"], s["m"], s["k"],
                                     s["n"], s["dtype"], s["table_shape"])
            warm = autotune.kernel_config(
                s["kernel"], s["m"], s["k"], s["n"], dtype=s["dtype"],
                plat=s["plat"], table_shape=s["table_shape"])
            assert warm == cold[key], key
    finally:
        if old is None:
            del os.environ["REPRO_TUNING_CACHE"]
        else:
            os.environ["REPRO_TUNING_CACHE"] = old


def test_configs_are_valid_candidates():
    """Every resolved config is drawn from the candidate space: xla picks
    carry impl='xla' (+ variant/kc for lut), tpu picks are Pallas tiles
    respecting lane/sublane quanta and the VMEM budget."""
    for s in SHAPES:
        cfg = autotune.kernel_config(
            s["kernel"], s["m"], s["k"], s["n"], dtype=s["dtype"],
            plat=s["plat"], table_shape=s["table_shape"])
        cands = autotune.candidates(
            s["kernel"], s["plat"], s["m"], s["k"], s["n"], s["dtype"],
            s["table_shape"])
        assert cfg in cands, (s, cfg)
        if s["plat"] == "xla":
            assert cfg["impl"] == "xla"
            if s["kernel"] == "lut":
                assert cfg["variant"] in ("rows", "flat")
                assert cfg["kc"] in (32, 64, 128)
        else:
            assert cfg["impl"] == "pallas"
            assert cfg["bm"] % 8 == 0 and cfg["bn"] % 128 == 0 \
                and cfg["bk"] % 128 == 0


def test_committed_cache_is_canonical_and_fresh():
    """The checked-in tuning_cache.json must be byte-identical to what
    autotune_shapes would write for its own keys today — i.e. regenerable
    in CI, with no stale keys from an older cost model or key schema."""
    path = autotune.default_cache_path()
    committed = json.loads(open(path).read())
    assert committed, "committed tuning cache is empty"
    for key in committed:
        kernel, plat, shape, dtype, table = key.split("|")
        assert kernel in ("lut", "codebook")
        assert plat in ("tpu", "xla")
        assert dtype == ("int32" if kernel == "lut" else dtype)
    # canonical dump round-trips byte-identically
    blob = json.dumps(committed, sort_keys=True, indent=1) + "\n"
    assert blob == open(path).read()


def test_explicit_cache_dict_short_circuits():
    """An explicit cache dict takes precedence over both the in-process
    cache and the cost model — the autotune_shapes accumulation path."""
    key = autotune.cache_key("lut", "xla", 8, 128, 128, "int32", (4096, 256))
    sentinel = {"impl": "xla", "variant": "rows", "kc": 64}
    cache = {key: sentinel}
    got = autotune.kernel_config("lut", 8, 128, 128, dtype="int32",
                                 plat="xla", table_shape=(4096, 256),
                                 cache=cache)
    assert got is sentinel
