"""In-graph numerics probes suite (DESIGN.md §14, ISSUE 8).

The probe contract, locked down five ways:

* **Token identity** — probes-on decode emits exactly the tokens
  probes-off decode does, across dense/codebook/lut × contiguous/paged
  (int8 pages on the paged rows).  Instrumentation must be write-only.
* **Oracle exactness** — a seeded saturation probe over a
  ``backend_matmul`` driven outside the lut grid reports the exact clip
  count a numpy oracle computes; the off (empty-dict) state is inert.
* **Determinism** — two fresh engine+scheduler contended replays produce
  byte-identical canonical-JSON ``numerics`` snapshots.
* **Drift sentinels** — golden scenarios' worst-layer summaries are
  committed to tests/golden_numerics.json (GOLDEN_UPDATE=1 regen) and a
  fresh measurement must stay inside the bounds policy — notably int32
  accumulator headroom > 0 bits everywhere, the runtime validation of
  ``make_lut_spec``'s static no-overflow scale choice.
* **Static audit** — the one-time w_idx scan counts negative/OOB ids the
  clip-mode gathers would silently canonicalize.

tp=2 parity for the probes-on path lives in tier-2
(tests/test_tp_serve.py::test_tp_probes_token_identity).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.quantizer import WeightQuantConfig, cluster_params
from repro.core.quantizer import init_state as quant_init_state
from repro.kernels import dispatch
from repro.kernels import probes as kprobes
from repro.models.model_zoo import build
from repro.serving import (ServeEngine, Server, SpecConfig, Telemetry,
                           to_codebook_params)
from repro.serving import probes as nprobes
from repro.serving.server import CONTENDED_ENGINE_KW, contended_trace

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden_numerics.json")

PROMPTS = nprobes.GOLDEN_PROMPTS
MAX_NEW = nprobes.GOLDEN_MAX_NEW


@pytest.fixture(scope="module")
def zoo():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, st = cluster_params(params, wq, quant_init_state(wq), 200,
                            jax.random.PRNGKey(1))
    cp = to_codebook_params(pq, wq, st, min_size=256)
    return model, params, cp


@pytest.fixture(scope="module")
def probe_runs(zoo):
    """Every golden scenario (backend × cache mode, the shared
    ``nprobes.GOLDEN_SCENARIOS`` table) served twice — probes off, then
    probes on — with the on-engine's numerics snapshot kept.  Paged rows
    use int8 pages so the KV round-trip probe sees real quantization."""
    model, params, cp = zoo
    runs = {}
    for name, (be, skw) in nprobes.GOLDEN_SCENARIOS.items():
        p = params if be == "dense" else cp
        kw = dict(max_len=48, max_batch=2, backend=be, **skw)
        off = ServeEngine(model, p, **kw).serve(PROMPTS, max_new=MAX_NEW)
        eng = ServeEngine(model, p, probes=True, **kw)
        on = eng.serve(PROMPTS, max_new=MAX_NEW)
        runs[name] = {"off": off, "on": on, "num": eng.numerics()}
    return runs


# --- token identity -----------------------------------------------------------

@pytest.mark.parametrize("name", list(nprobes.GOLDEN_SCENARIOS))
def test_probes_token_identity(probe_runs, name):
    """The acceptance criterion: instrumented decode is token-identical
    to uninstrumented decode, and the counters it leaves behind are
    internally consistent."""
    be, mode = name.split("/")
    r = probe_runs[name]
    assert r["on"] == r["off"], \
        f"{name}: probes changed the decoded tokens"
    num = r["num"]
    assert num["backend"] == be
    assert num["tokens"] > 0.0
    assert num["page_oob"] == 0.0
    if be == "dense":
        # plain float weights never route through backend_matmul
        assert all(c == 0.0 for c in num["matmul_calls"])
    else:
        assert all(c > 0.0 for c in num["matmul_calls"])
        # every layer saw the same number of routed matmuls
        assert len(set(num["matmul_calls"])) == 1
    if be == "lut":
        # acceptance: accumulator headroom > 0 bits everywhere — the
        # runtime check of make_lut_spec's static no-overflow pick
        assert all(h > 0.0 for h in num["headroom_bits"]), num
        assert all(a > 0.0 for a in num["acc_max"]), num
        assert all(t > 0.0 for t in num["act_total"])
    if mode == "paged":
        assert max(num["kv_err_max"]) > 0.0, \
            "int8 pages must show a nonzero KV round-trip error"
        assert all(0.0 <= m <= x or x == 0.0 for m, x in
                   zip(num["kv_err_mean"], num["kv_err_max"]))
    else:
        assert max(num["kv_err_max"]) == 0.0   # float cache: no quantize_kv
    if be != "dense":
        assert num["widx_total"] > 0 and num["widx_oob"] == 0


# --- oracle exactness ---------------------------------------------------------

def test_saturation_probe_matches_numpy_oracle():
    """Seeded inputs driven outside the lut grid: the jitted probe's clip
    count equals the numpy oracle's, exactly."""
    rng = np.random.default_rng(0)
    n_w, K, N, B = 16, 32, 8, 4
    cb = jnp.asarray(rng.normal(scale=0.1, size=n_w), jnp.float32)
    w_idx = jnp.asarray(rng.integers(0, n_w, (K, N)), jnp.int32)
    spec = dispatch.make_lut_spec(cb, fan_in=K, levels=64,
                                  a_range=(-2.0, 2.0))
    x = jnp.asarray(rng.uniform(-4.0, 4.0, (B, K)), jnp.float32)

    def f(x, ps):
        with kprobes.layer(ps, 0) as pb:
            y = dispatch.backend_matmul(x, w_idx, cb, kind="row")
        return y, pb.state

    with dispatch.use_backend("lut", spec):
        _, ps = jax.jit(f)(x, kprobes.init_state(1))
    xs = np.asarray(x)
    want = int(((xs < spec.a_min) | (xs > spec.a_max)).sum())
    assert want > 0, "seed produced no out-of-grid inputs — weak test"
    assert int(np.asarray(ps["act_sat"])[0]) == want
    assert float(np.asarray(ps["act_total"])[0]) == float(x.size)
    assert float(np.asarray(ps["matmul_calls"])[0]) == 1.0
    assert float(np.asarray(ps["acc_max"])[0]) > 0.0


def test_empty_state_is_inert():
    """The off state: an empty dict records nothing, allocates nothing,
    and summarizes to nothing — XLA sees zero extra pytree leaves."""
    with kprobes.layer({}, 0) as pb:
        assert not kprobes.active()
        kprobes.record("act_sat", 1.0)      # dropped: no frame open
    assert pb.state == {}
    assert kprobes.bump({}, "tokens", 1.0) == {}
    assert nprobes.summarize({}) == {}
    # taps outside any frame are no-ops even with a state in hand
    kprobes.tap_act(jnp.zeros((4,)), 0.0, 6.0)
    st = kprobes.init_state(2)
    assert all(float(np.asarray(v).sum()) == 0.0 for v in st.values())


# --- determinism --------------------------------------------------------------

def _numerics_replay(model, params):
    eng = ServeEngine(model, params, probes=True, **CONTENDED_ENGINE_KW)
    tel = Telemetry()
    srv = Server(eng, telemetry=tel)
    srv.replay(contended_trace(1, model.cfg.vocab))
    snap = json.loads(tel.snapshot_json())
    return snap, tel


def test_numerics_byte_identical_replay(zoo):
    """Two fresh engine+scheduler contended replays → byte-identical
    canonical-JSON numerics sections (and numerics counter tracks)."""
    model, params, _ = zoo
    s1, t1 = _numerics_replay(model, params)
    s2, _ = _numerics_replay(model, params)
    assert "numerics" in s1, "probes engine did not register its provider"
    b1 = json.dumps(s1["numerics"], sort_keys=True).encode()
    b2 = json.dumps(s2["numerics"], sort_keys=True).encode()
    assert b1 == b2
    assert s1["numerics"]["tokens"] > 0.0
    # the scheduler sampled the probe-derived counter tracks
    names = {e["name"] for e in t1.event_log() if e["ph"] == "C"}
    assert {"numerics.sat_rate_max", "numerics.headroom_bits_min",
            "numerics.kv_err_max"} <= names


# --- drift sentinels ----------------------------------------------------------

def test_golden_numerics_sentinels(probe_runs):
    """The committed golden scenarios, re-measured and checked against
    the bounds policy (exact static counts, bounded float drift, hard
    headroom floor).  GOLDEN_UPDATE=1 re-blesses."""
    nums = {name: r["num"] for name, r in probe_runs.items()}
    got = {name: nprobes.golden_entry(n) for name, n in nums.items()}
    if os.environ.get("GOLDEN_UPDATE"):
        with open(GOLDEN, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
        pytest.skip("golden file regenerated — review and commit the diff")
    with open(GOLDEN) as f:
        want = json.load(f)
    assert set(got) == set(want), "golden scenario set drifted"
    for name, num in nums.items():
        fails = nprobes.sentinel_check(num, want[name])
        assert not fails, f"{name}: " + "; ".join(fails)


def test_sentinel_bounds_policy():
    """Unit-level: the check passes on its own golden_entry and trips on
    each class of drift."""
    num = {"sat_rate": [0.01, 0.0], "headroom_bits": [5.0, 8.0],
           "kv_err_max": [0.01, 0.0], "widx_neg": 0, "widx_oob": 0,
           "page_oob": 0.0, "tokens": 10.0}
    g = nprobes.golden_entry(num)
    assert nprobes.sentinel_check(num, g) == []
    assert nprobes.sentinel_check(num, None)      # unblessed scenario
    assert nprobes.sentinel_check({}, g)          # probes off
    trips = {
        "headroom": dict(num, headroom_bits=[-0.5, 8.0]),
        "sat_rate": dict(num, sat_rate=[0.2, 0.0]),
        "page_oob": dict(num, page_oob=2.0),
        "widx_oob": dict(num, widx_oob=3),
        "kv_err_max": dict(num, kv_err_max=[0.5, 0.0]),
    }
    for key, bad in trips.items():
        fails = nprobes.sentinel_check(bad, g)
        assert any(key in f for f in fails), (key, fails)


# --- static audit + guard rails -----------------------------------------------

def test_static_index_audit():
    # -1 is a stored-negative id the gather wraps to 7 (in range);
    # 9 and -12 stay outside [0, 8) even after the wrap — genuine OOB
    tree = {"blk": {"w_idx": jnp.asarray([[0, -1], [-12, 9]], jnp.int32),
                    "codebook": jnp.zeros((8,), jnp.float32)},
            "float_leaf": jnp.zeros((3,))}
    audit = nprobes.static_index_audit(tree)
    assert audit == {"widx_neg": 2, "widx_oob": 2, "widx_total": 4}
    assert nprobes.static_index_audit({"w": jnp.zeros((2, 2))}) == \
        {"widx_neg": 0, "widx_oob": 0, "widx_total": 0}


def test_probes_with_spec_engine_raises(zoo):
    """Speculative serve() is not instrumented — the engine must refuse
    loudly instead of silently dropping counters."""
    model, params, _ = zoo
    with pytest.raises(NotImplementedError, match="probes"):
        ServeEngine(model, params, max_len=48, max_batch=2, probes=True,
                    spec=SpecConfig(draft="ngram", k=3))
