"""Paper §4: the multiplication-table / activation-table integer engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activations import ActQuantConfig, act_apply
from repro.core import clustering, fixedpoint as fp
from repro.core.lut import LutConfig, build_tables, choose_scale


def _small_net(key, d_in=8, hidden=24, d_out=3):
    ks = jax.random.split(key, 4)
    W1 = jax.random.normal(ks[0], (d_in, hidden)) * 0.5
    b1 = jax.random.normal(ks[1], (hidden,)) * 0.1
    W2 = jax.random.normal(ks[2], (hidden, d_out)) * 0.5
    b2 = jax.random.normal(ks[3], (d_out,)) * 0.1
    return W1, b1, W2, b2


@pytest.mark.parametrize("kind,levels", [("tanh", 16), ("tanh", 32),
                                         ("relu6", 32), ("sigmoid", 16)])
def test_engine_matches_float_net(kind, levels):
    key = jax.random.PRNGKey(0)
    act = ActQuantConfig(kind, levels)
    W1, b1, W2, b2 = _small_net(key)
    book = clustering.kmeans1d(
        jnp.concatenate([W1.ravel(), b1, W2.ravel(), b2]), 128)
    W1q, b1q, W2q, b2q = (clustering.quantize_to_centers(t, book)
                          for t in (W1, b1, W2, b2))
    tabs = build_tables(np.asarray(book),
                        LutConfig(act=act, table_entries=4096), fan_in=25)

    x = jax.random.uniform(jax.random.fold_in(key, 7), (16, 8),
                           minval=-1, maxval=1)
    if kind in ("relu6", "sigmoid"):  # inputs must lie in the level range
        x = jnp.abs(x) * (6.0 if kind == "relu6" else 1.0)
    xi = fp.input_to_indices(x, act)
    lo, _ = act.out_range
    xq = lo + xi * act.step

    h = act_apply(act, xq @ W1q + b1q)
    y_float = h @ W2q + b2q

    idx = lambda t: clustering.assign_to_centers(t, book)
    acc = fp.int_mlp_forward([(idx(W1q), idx(b1q)), (idx(W2q), idx(b2q))],
                             xi, tabs)
    y_int = tabs.decode(np.asarray(acc))
    # differences come only from Δx boundary snapping; bound them loosely
    assert np.max(np.abs(np.asarray(y_float) - y_int)) < 3 * act.step


def test_engine_is_integer_only():
    """The deployable tables are integers; the engine emits integers."""
    act = ActQuantConfig("tanh", 8)
    book = jnp.linspace(-1, 1, 32)
    tabs = build_tables(np.asarray(book), LutConfig(act=act), fan_in=10)
    assert tabs.mult.dtype == np.int32
    assert tabs.act_table.dtype == np.int32
    a = jnp.zeros((4, 10), jnp.int32)
    w = jnp.zeros((10, 5), jnp.int32)
    acc = fp.int_linear(a, w, None, tabs)
    assert acc.dtype == jnp.int32
    assert fp.acc_to_act_index(acc, tabs).dtype == jnp.int32


def test_no_overflow_guarantee():
    """fan_in · max|table entry| must fit the accumulator (paper §4)."""
    act = ActQuantConfig("tanh", 32)
    book = np.linspace(-2, 2, 1000)
    for fan_in in (10, 1000, 100_000):
        tabs = build_tables(np.asarray(book),
                            LutConfig(act=act, table_entries=128),
                            fan_in=fan_in)
        assert fan_in * np.abs(tabs.mult).max() < 2 ** 31


def test_choose_scale_rejects_impossible():
    with pytest.raises(ValueError):
        choose_scale(np.array([1e5]), 1.0, 1e-6, fan_in=10 ** 9, acc_bits=32)


def test_bias_row_and_identity_column():
    act = ActQuantConfig("tanh", 8)
    book = np.linspace(-1, 1, 16)
    tabs = build_tables(np.asarray(book), LutConfig(act=act), fan_in=4)
    scale = 2.0 ** tabs.s / tabs.dx
    # bias row encodes a ≡ 1.0; identity column encodes w ≡ 1.0
    np.testing.assert_allclose(tabs.mult[tabs.bias_row, :-1],
                               np.rint(book * scale), atol=0.51)
    lv = np.linspace(-1, 1, 8)
    np.testing.assert_allclose(tabs.mult[:-1, tabs.identity_col],
                               np.rint(lv * scale), atol=0.51)


def test_shift_equals_floor_division():
    """acc >> s ≡ floor(x/Δx) including negatives (arithmetic shift)."""
    act = ActQuantConfig("tanh", 8)
    tabs = build_tables(np.linspace(-1, 1, 16), LutConfig(act=act), fan_in=4)
    accs = jnp.asarray([-(5 << tabs.s) - 3, -1, 0, 7, (3 << tabs.s) + 1])
    bins = jax.lax.shift_right_arithmetic(accs, tabs.s)
    np.testing.assert_array_equal(np.asarray(bins),
                                  np.floor(np.asarray(accs) / 2 ** tabs.s))


def test_act_table_matches_boundaries():
    """Table lookup reproduces exact boundary quantization to within one
    Δx-snapped bin."""
    act = ActQuantConfig("tanh", 6)
    tabs = build_tables(np.linspace(-1, 1, 8),
                        LutConfig(act=act, table_entries=1024), fan_in=4)
    xs = np.linspace(-3, 3, 2001)
    accs = jnp.asarray(np.rint(xs * (2.0 ** tabs.s) / tabs.dx), jnp.int32)
    j_table = np.asarray(fp.acc_to_act_index(accs, tabs))
    from repro.core.activations import act_index
    j_exact = np.asarray(act_index(act, jnp.asarray(xs)))
    # mismatches allowed only within Δx of a true boundary
    mism = xs[j_table != j_exact]
    from repro.core.activations import act_input_boundaries
    b = act_input_boundaries(act)
    if mism.size:
        d = np.min(np.abs(mism[:, None] - b[None, :]), axis=1)
        assert d.max() <= tabs.dx
