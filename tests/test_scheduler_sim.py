"""Deterministic virtual-clock scheduler simulation suite (DESIGN.md §11).

The scheduler's contract, locked down three ways:

* **Parity** — the step-level API must reproduce ``serve()`` token for
  token (it runs the same jitted while_loop, bounded per round), and a
  preempted→swapped-out→restored request must stream tokens identical to
  an uncontended run, per backend (dense/codebook/lut) × cache layout
  (contiguous/paged, + int8 pages).
* **Determinism** — time is injected, never read: a seeded Poisson trace
  replayed twice produces the identical event log (admissions,
  preemptions, resumes, finishes), identical per-request streams, and an
  identical metrics report.  ``test_no_wall_clock_in_serving`` pins the
  rule itself: no ``time`` usage anywhere under ``serving/``.
* **Invariants** — a hypothesis state machine walks the scheduler over a
  REAL ``PagePool`` (stub decode, real allocation/refcount/swap
  accounting): no running request ever loses a page it holds, refcounts
  conserve across swap-out/swap-in, ``reserved_extra`` never deadlocks
  admission, and every draining trace finishes every request (no
  starvation).

tier2: the contended tp=2 trace rides the CI ``tp`` job through
``tests/tp_rig.py`` — scheduler decisions must be shard-invariant.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import (AsyncScheduler, PagePool, Server, ServeEngine,
                           poisson_trace, to_codebook_params)
from repro.serving.scheduler import (FINISHED, QUEUED, RUNNING, SWAPPED,
                                     VirtualClock)

PROMPTS = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 10], [11, 12, 13, 14]]
STOPS = [6, 3, 5, 4]


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get("qwen3-1.7b").reduced().replace(n_layers=2, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    cp = to_codebook_params(pq, wq, state, min_size=1024)
    return model, params, cp


def _engine(model, params, cp, backend="dense", paged=False, **kw):
    p = params if backend == "dense" else cp
    kw.setdefault("max_len", 48)
    kw.setdefault("max_batch", 2)
    if paged:
        kw.setdefault("page_size", 8)
    return ServeEngine(model, p, backend=backend, paged=paged, **kw)


# --- the virtual-clock rule itself -------------------------------------------

def test_no_wall_clock_in_serving():
    """Nothing under serving/ may read the wall: time is injected.  The
    simulation suite's determinism rests on this being a rule, not a
    habit — the telemetry subsystem (ISSUE 7) and both halves of the
    numerics probes (ISSUE 8: serving/probes.py AND the in-graph
    kernels/probes.py, which rides the jitted forward) must live under
    it too: deterministic spans/snapshots/counters depend on every
    timestamp coming from the injected clock."""
    import repro.kernels.probes as KP
    import repro.serving as S

    forbidden = ("import time", "time.time", "from time ", "datetime",
                 "perf_counter", "monotonic(")
    sdir = os.path.dirname(os.path.abspath(S.__file__))
    files = [(f"serving/{fn}", os.path.join(sdir, fn))
             for fn in sorted(os.listdir(sdir)) if fn.endswith(".py")]
    files.append(("kernels/probes.py", os.path.abspath(KP.__file__)))
    scanned = []
    for label, path in files:
        scanned.append(label)
        with open(path) as f:
            src = f.read()
        for pat in forbidden:
            assert pat not in src, f"{label} reads the wall clock ({pat!r})"
    for must in ("serving/telemetry.py", "serving/probes.py",
                 "serving/router.py", "serving/fleet.py",
                 "kernels/probes.py"):
        assert must in scanned, \
            f"{must} moved — the no-wall-clock rule no longer covers it"


# --- step-level parity with serve() ------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_serve_step_matches_serve(tiny, paged):
    """Uncontended scheduler session == batch serve(), token for token
    (same jitted decode loop, driven in per-round quanta)."""
    model, params, cp = tiny
    eng = _engine(model, params, cp, paged=paged)
    want = eng.serve(PROMPTS, max_new=STOPS)
    srv = Server(eng)
    hs = [srv.submit(p, s) for p, s in zip(PROMPTS, STOPS)]
    srv.run_until_idle()
    assert [h.result() for h in hs] == want
    assert srv.sched.n_preemptions == 0


def test_quantum_does_not_change_tokens(tiny):
    """The round quantum is a latency/throughput knob, not a semantic
    one: any quantum produces the same streams at temperature 0."""
    model, params, cp = tiny
    eng = _engine(model, params, cp)
    outs = []
    for q in (1, 3):
        srv = Server(eng, quantum=q)
        hs = [srv.submit(p, s) for p, s in zip(PROMPTS, STOPS)]
        srv.run_until_idle()
        outs.append([h.result() for h in hs])
    assert outs[0] == outs[1]


# --- preempt -> swap out -> restore parity -----------------------------------

CASES = [("dense", False, None), ("dense", True, None),
         ("dense", True, "int8"), ("codebook", False, None),
         ("codebook", True, None), ("lut", False, None),
         ("lut", True, None)]


@pytest.mark.parametrize("backend,paged,kv", CASES,
                         ids=[f"{b}-{'paged' if p else 'contig'}"
                              + (f"-{k}" if k else "")
                              for b, p, k in CASES])
def test_preempt_restore_token_parity(tiny, backend, paged, kv):
    """A high-priority late arrival preempts a running victim (slots are
    full; paged pools are tight); the victim's KV swaps out to the host
    blob and back.  Every request's stream must equal the uncontended
    batch-serve reference — preemption is invisible in the tokens."""
    model, params, cp = tiny
    kw = {}
    if paged:
        kw["n_pages"] = 7
    if kv:
        kw["kv_dtype"] = kv
    eng = _engine(model, params, cp, backend=backend, paged=paged, **kw)
    prompts = [[1, 2, 3, 4], [5, 6, 7], [8, 9, 10, 11, 12]]
    stops = [10, 8, 6]
    want = eng.serve(prompts, max_new=stops)
    srv = Server(eng)
    h0 = srv.submit(prompts[0], stops[0], priority=0, arrival=0.0)
    h1 = srv.submit(prompts[1], stops[1], priority=0, arrival=0.0)
    h2 = srv.submit(prompts[2], stops[2], priority=1, arrival=0.05)
    srv.run_until_idle()
    assert h0.n_preempt + h1.n_preempt >= 1, "no preemption happened"
    assert h2.n_preempt == 0, "the high-priority request was preempted"
    assert [h.result() for h in (h0, h1, h2)] == want
    # the victim really moved through the host store and back
    assert max(h0.pages_swapped_out, h1.pages_swapped_out) > 0


def test_no_preempt_mode_waits_instead(tiny):
    """preempt=False: the high-priority arrival waits for a slot; nobody
    is swapped; tokens still match the reference."""
    model, params, cp = tiny
    eng = _engine(model, params, cp)
    prompts = [[1, 2, 3, 4], [5, 6, 7], [8, 9, 10, 11, 12]]
    stops = [10, 8, 6]
    want = eng.serve(prompts, max_new=stops)
    srv = Server(eng, preempt=False)
    hs = [srv.submit(prompts[0], stops[0], priority=0, arrival=0.0),
          srv.submit(prompts[1], stops[1], priority=0, arrival=0.0),
          srv.submit(prompts[2], stops[2], priority=1, arrival=0.05)]
    srv.run_until_idle()
    assert srv.sched.n_preemptions == 0
    assert [h.result() for h in hs] == want


# --- deterministic trace replay ----------------------------------------------

def _replay(model, params, trace):
    """One fresh engine+scheduler over the SHARED contended pair (the
    same config the CI smoke gate and the tier-2 tp case exercise)."""
    from repro.serving.server import CONTENDED_ENGINE_KW

    eng = ServeEngine(model, params, **CONTENDED_ENGINE_KW)
    srv = Server(eng)
    rep = srv.replay(trace)
    streams = {h.rid: list(h.tokens) for h in srv.sched.handles.values()}
    return srv.sched.events, streams, rep


def test_trace_replay_bit_identical(tiny):
    """The acceptance criterion: same seeded trace → same admission
    order, same preemption decisions, same per-request streams, same
    report — across two fresh engine+scheduler instances.  Seed 1 is
    contended on the shared reference pair: preemptions fire, so the
    comparison covers the full decision surface."""
    from repro.serving.server import contended_trace

    model, params, cp = tiny
    trace = contended_trace(1, model.cfg.vocab)
    ev1, st1, rep1 = _replay(model, params, trace)
    ev2, st2, rep2 = _replay(model, params, trace)
    assert rep1.preemptions >= 1, "trace is not contended — weak test"
    assert ev1 == ev2
    assert st1 == st2
    assert rep1.to_json() == rep2.to_json()


def test_streaming_callbacks_and_metrics(tiny):
    """Tokens stream incrementally at nondecreasing virtual timestamps;
    TTFT/TPOT and SLO attainment come out of the injected clock."""
    model, params, cp = tiny
    eng = _engine(model, params, cp)
    got = []
    srv = Server(eng)
    h0 = srv.submit(PROMPTS[0], 6, slo_ttft=10.0, slo_tpot=10.0,
                    on_token=lambda h, t, ts: got.append((h.rid, t, ts)))
    h1 = srv.submit(PROMPTS[1], 4, slo_ttft=1e-9,
                    on_token=lambda h, t, ts: got.append((h.rid, t, ts)))
    srv.run_until_idle()
    assert [t for r, t, _ in got if r == h0.rid] == h0.tokens
    assert [t for r, t, _ in got if r == h1.rid] == h1.tokens
    times = [ts for _, _, ts in got]
    assert times == sorted(times)
    for h in (h0, h1):
        assert h.state == FINISHED
        assert h.ttft > 0 and h.tpot > 0
        assert h.first_token_at == h.admitted_at
    assert h0.slo_met() and not h1.slo_met()   # 1ns TTFT is unmeetable
    from repro.serving import ServerReport
    rep = ServerReport.build([h0, h1], srv.sched)
    assert rep.slo_attainment == 0.5


def test_trace_save_load_roundtrip(tmp_path):
    from repro.serving import load_trace, save_trace

    trace = poisson_trace(3, 5, vocab=100, priorities=(0, 1),
                          slo_ttft=0.25)
    path = str(tmp_path / "trace.json")
    save_trace(path, trace)
    assert load_trace(path) == trace


def test_submit_rejects_impossible_requests(tiny):
    model, params, cp = tiny
    eng = _engine(model, params, cp)
    srv = Server(eng)
    with pytest.raises(ValueError, match="max_len"):
        srv.submit(list(range(1, 47)), 40)
    with pytest.raises(ValueError, match="empty"):
        srv.submit([], 4)


# --- hypothesis state machine over the scheduler -----------------------------
#
# A stub engine implements the sched_* protocol over a REAL PagePool —
# admissions, swaps, refcounts, and the prefix cache are the production
# allocator; only decode is faked (deterministic token emission).  Walks
# are deep and fast, and every step checks the global invariants.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # direct (non-pytest) imports
    from _hypothesis_fallback import given, settings, strategies as st


class _StubModel:
    def init_paged_cache(self, n_pages, page_size, dtype):
        return {"k": jnp.zeros((1, n_pages, page_size, 1, 2), jnp.float32)}


class _StubState:
    def __init__(self, B):
        self.live = np.zeros(B, bool)
        self.adm = [None] * B
        self.pos = np.zeros(B, int)
        self.gen = np.zeros(B, int)
        self.stop = np.zeros(B, int)


class _StubEngine:
    """The engine's sched_* surface over a real PagePool, with decode
    replaced by deterministic fake emission (token == n_gen)."""

    spec = None
    paged = True

    def __init__(self, *, max_batch, n_pages, page_size):
        self.max_batch = max_batch
        self.page_size = page_size
        self.max_len = page_size * (n_pages - 1)
        self.pool = PagePool(_StubModel(), n_pages=n_pages,
                             page_size=page_size,
                             pages_per_slot=n_pages - 1,
                             kv_dtype=jnp.float32, prefix_cache=True)

    def sched_check(self, prompt, stop):
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if len(prompt) + stop > self.max_len:
            raise ValueError("prompt + max_new exceeds max_len")

    def sched_state(self, key=None):
        return _StubState(self.max_batch)

    def sched_admit(self, st, slot, prompt, stop):
        adm = self.pool.admit(list(prompt), stop)
        if adm is None:
            return None
        self.pool.register_prefill(adm)
        self.pool.cow(adm)
        st.adm[slot] = adm
        st.live[slot] = True
        st.pos[slot] = len(prompt)
        st.gen[slot], st.stop[slot] = 1, stop
        return 0

    def serve_step(self, st, quantum=1):
        toks, done = {}, []
        for b in range(self.max_batch):
            if not st.live[b] or st.gen[b] >= st.stop[b]:
                continue
            n = int(min(quantum, st.stop[b] - st.gen[b]))
            toks[b] = [int(st.gen[b] + i) for i in range(n)]
            st.gen[b] += n
            st.pos[b] += n
            if st.gen[b] >= st.stop[b]:
                done.append(b)
        return toks, done

    def sched_release(self, st, slot):
        self.pool.retire(st.adm[slot])
        st.adm[slot] = None
        st.live[slot] = False

    def sched_swap_out(self, st, slot):
        from types import SimpleNamespace
        adm = st.adm[slot]
        n_data = -(-int(st.pos[slot]) // self.page_size)
        blob = SimpleNamespace(n_pages=n_data, reserve=adm.reserve,
                               pos=int(st.pos[slot]),
                               n_gen=int(st.gen[slot]),
                               stop=int(st.stop[slot]))
        self.pool.swap_out(adm)
        st.adm[slot] = None
        st.live[slot] = False
        return blob

    def sched_swap_in(self, st, slot, blob):
        adm = self.pool.swap_in(blob.reserve)
        if adm is None:
            return False
        st.adm[slot] = adm
        st.live[slot] = True
        st.pos[slot] = blob.pos
        st.gen[slot], st.stop[slot] = blob.n_gen, blob.stop
        return True


def test_expel_adopt_rehomes_requests_across_schedulers():
    """DESIGN.md §15: ``expel()`` removes a queued, swapped, or running
    request so a fleet can re-home it with ``adopt()`` on a sibling
    scheduler sharing the clock.  A running request hands over a swap
    blob (billed like preemption's swap but NOT counted as one); the
    adopter resumes from the blob and every request still finishes with
    its full stream."""
    clock = VirtualClock()
    kw = dict(max_batch=2, n_pages=9, page_size=8)
    a = AsyncScheduler(_StubEngine(**kw), clock=clock)
    b = AsyncScheduler(_StubEngine(**kw), clock=clock)
    h_run = a.submit([1] * 10, 6)
    h_stay = a.submit([2] * 6, 4)
    h_q = a.submit([3] * 4, 3)
    a.step()                               # admit two; h_q queues behind
    assert h_run.state == RUNNING and h_q.state == QUEUED
    pre_preempt = a.n_preemptions

    hr, blob_r = a.expel(h_run.rid)
    assert hr is h_run and hr.state == SWAPPED
    assert blob_r is not None and blob_r.n_pages >= 1
    hq, blob_q = a.expel(h_q.rid)
    assert blob_q is None and hq.state == QUEUED
    assert hr.rid not in a.handles and hq.rid not in a.handles
    assert a.n_preemptions == pre_preempt  # migration is not preemption
    assert [k for _, k, _ in a.events].count("expel") == 2
    assert a.n_pages_swapped_out >= blob_r.n_pages

    hr2 = b.adopt(hr, blob=blob_r)
    hq2 = b.adopt(hq)
    assert hr2 is h_run and hr2.state == SWAPPED and hq2.state == QUEUED
    a.run_until_idle()
    b.run_until_idle()
    for h in (h_run, h_stay, h_q):
        assert h.state == FINISHED and len(h.tokens) == h.max_new
    assert [k for _, k, _ in b.events].count("adopt") == 2
    assert b.n_pages_swapped_in >= blob_r.n_pages  # blob restore path
    with pytest.raises(ValueError, match="already finished"):
        b.expel(hr2.rid)


class _SchedWalk:
    """Random walk over submit/step with invariant checks after every
    transition, then a full drain (the no-starvation check)."""

    def __init__(self, rng, *, n_pages, page, B=2):
        self.rng = rng
        self.eng = _StubEngine(max_batch=B, n_pages=n_pages,
                               page_size=page)
        self.sched = AsyncScheduler(self.eng, quantum=1)
        self.page = page
        self.held = {}                   # rid -> (admit_seq, pids tuple)

    def submit(self):
        page = self.page
        plen = int(self.rng.integers(1, 2 * page + 1))
        stop = int(self.rng.integers(1, 2 * page + 1))
        prompt = [int(t) for t in self.rng.integers(0, 3, plen)]
        dt = float(self.rng.choice([0.0, 0.0, 0.01, 0.05]))
        self.sched.submit(prompt, stop,
                          priority=int(self.rng.integers(0, 3)),
                          arrival=self.sched.clock.now() + dt)

    def step(self):
        self.sched.step()

    def check(self):
        sched, pool = self.sched, self.eng.pool
        holders = {}
        for h in sched.running:
            adm = sched.st.adm[h.slot]
            assert adm is not None and h.state == RUNNING
            pids = tuple(adm.pids[:adm.n_live])
            for pid in pids:
                assert pid != 0, "trash page held by a live request"
                holders.setdefault(pid, []).append(h.rid)
            # a running request never loses pages it holds: same
            # admission => identical page set, every ref alive
            key = self.held.get(h.rid)
            if key is not None and key[0] == h._admit_seq:
                assert key[1] == pids, \
                    f"request {h.rid} lost pages {set(key[1]) - set(pids)}"
            self.held[h.rid] = (h._admit_seq, pids)

        # refcount conservation across admit/swap-out/swap-in/retire
        for pid in range(1, pool.n_pages):
            want = len(holders.get(pid, ())) + (1 if pid in pool.key_of
                                                else 0)
            assert pool.ref[pid] == want, \
                f"refcount leak on page {pid}: {pool.ref[pid]} != {want}"
        free = pool.free
        assert len(free) == len(set(free))
        assert set(free) == {p for p in range(1, pool.n_pages)
                             if pool.ref[p] == 0}
        # no spec rollback in the scheduler path: a swapped-out request
        # holds NO claim, so reserved admission can never deadlock
        assert pool.reserved_extra == 0
        assert pool.free_claimable() >= 0

    def run(self, n_ops=40):
        ops = [self.submit, self.submit, self.step, self.step, self.step]
        self.check()
        for _ in range(n_ops):
            ops[self.rng.integers(len(ops))]()
            self.check()
        # drain: every submitted request must finish (no starvation) —
        # bounded rounds, so a stall fails instead of hanging
        self.sched.run_until_idle(max_rounds=5000)
        self.check()
        for h in self.sched.handles.values():
            assert h.state == FINISHED
            assert len(h.tokens) == h.max_new
        assert all(self.eng.pool.ref[p] in (0, 1)
                   for p in range(1, self.eng.pool.n_pages))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([6, 10]),
       st.sampled_from([2, 4]))
def test_scheduler_state_machine_invariants(seed, n_pages, page):
    _SchedWalk(np.random.default_rng(seed), n_pages=n_pages,
               page=page).run()


# --- tensor-parallel contended trace (CI `tp` job) ---------------------------

@pytest.mark.tier2
def test_contended_trace_tp2_matches_tp1():
    """Scheduler decisions are shard-invariant: the contended trace's
    event log, streams, and preemptions at tp=2 equal tp=1 exactly."""
    from tp_rig import run_under_devices

    ref = run_under_devices("tp_serve_cases:sched_trace_case", {"tp": 1})
    got = run_under_devices("tp_serve_cases:sched_trace_case", {"tp": 2})
    assert ref["preemptions"] >= 1, "trace is not contended — weak test"
    assert got == ref
