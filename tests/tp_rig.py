"""Multi-device test rig: run a function under N forced host devices.

``XLA_FLAGS=--xla_force_host_platform_device_count=N`` must be set *before*
jax initialises its backends, and the main pytest process must keep the
real single-device view (smoke tests and benches measure on it — see
conftest.py).  So multi-device cases run in a **subprocess**: the parent
calls ``run_under_devices("module:function", {...kwargs})``, the child
(this file's ``__main__``) sets the flag, imports the target from the
tests/src path, calls it with the JSON-decoded kwargs, and prints the
JSON-encoded result behind a sentinel line.  Anything JSON-serialisable
round-trips; stderr/stdout are attached to the failure message otherwise.

This composes with the existing suite (tests/test_distributed.py runs its
multi-device checks the same way, inline) and is reusable: any test module
can declare a module-level function and fan it out across device counts —
tests/test_tp_serve.py drives the tensor-parallel parity matrix through it.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(_HERE, "..", "src")
_SENTINEL = "TP_RIG_RESULT "

DEVICES = 8          # the CI `tp` job's forced host device count


def run_under_devices(target: str, kwargs: dict | None = None, *,
                      n_devices: int = DEVICES, timeout: int = 1800):
    """Run ``module:function(**kwargs)`` in a subprocess with ``n_devices``
    forced host devices; return the function's JSON-round-tripped result.

    ``module`` is imported from tests/ (or anything on PYTHONPATH/src), so
    case functions live in plain test-adjacent modules — no string-embedded
    programs.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}"
                        + " " + env.get("XLA_FLAGS", "")).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (_SRC, _HERE, env.get("PYTHONPATH", "")) if p)
    payload = json.dumps({"target": target, "kwargs": kwargs or {}})
    out = subprocess.run([sys.executable, os.path.abspath(__file__)],
                         input=payload, env=env, capture_output=True,
                         text=True, timeout=timeout)
    for line in reversed(out.stdout.splitlines()):
        if line.startswith(_SENTINEL):
            return json.loads(line[len(_SENTINEL):])
    raise RuntimeError(
        f"tp_rig subprocess for {target!r} (devices={n_devices}) produced "
        f"no result (exit {out.returncode})\n--- stdout ---\n{out.stdout}"
        f"\n--- stderr ---\n{out.stderr}")


def _child_main():
    spec = json.loads(sys.stdin.read())
    mod_name, fn_name = spec["target"].split(":")
    import importlib

    fn = getattr(importlib.import_module(mod_name), fn_name)
    result = fn(**spec["kwargs"])
    print(_SENTINEL + json.dumps(result))


if __name__ == "__main__":
    _child_main()
