"""Step builders + sharding assembly for training / prefill / decode.

Everything the dry-run and the real trainer share lives here: the jitted
step functions, in/out shardings derived from the policy in
``repro.distributed.sharding``, and ShapeDtypeStruct stand-ins.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, SHAPES
from repro.distributed import sharding as SH
from repro.models.model_zoo import Model, build
from repro.models import transformer as T
from repro.optim import OptConfig, init_opt_state, apply_updates

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "batch_specs", "cache_partition_specs", "shardings_for",
           "opt_specs", "abstract_params", "abstract_opt_state"]


def _maybe(axis_or_axes, dim_size, mesh):
    """Use the axis only if the dim divides evenly; else replicate."""
    axes = axis_or_axes if isinstance(axis_or_axes, tuple) else (axis_or_axes,)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return axis_or_axes if dim_size % total == 0 else None


def abstract_params(model: Model, key=None):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_opt_state(model: Model, ocfg: OptConfig):
    params = abstract_params(model)
    return jax.eval_shape(lambda: init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params), ocfg))


def params_partition_specs(model: Model, mesh):
    params = abstract_params(model)
    mcfg = T.moe_cfg(model.cfg) if model.cfg.n_experts else None
    return SH.param_specs(params, model.cfg, mcfg, mesh,
                          fsdp=model.cfg.fsdp)


def opt_specs(model: Model, ocfg: OptConfig, mesh):
    """Moment trees share the param specs (+ pod-ZeRO); count is replicated."""
    params = abstract_params(model)
    pspecs = params_partition_specs(model, mesh)
    mom = jax.tree.map(lambda s, p: SH.moments_spec(s, p.shape, mesh),
                       pspecs, params)
    state = abstract_opt_state(model, ocfg)
    out = {}
    for k in state:
        out[k] = P() if k == "count" else mom
    return out


def batch_specs(model: Model, shape_name: str, mesh):
    cfg = model.cfg
    sh = SHAPES[shape_name]
    dp = SH.dp_axes(mesh)
    if cfg.batch_over_model:
        wide = dp + ("model",)
        b_ax = (_maybe(wide, sh.global_batch, mesh)
                or _maybe(dp, sh.global_batch, mesh))
    else:
        b_ax = _maybe(dp, sh.global_batch, mesh)
    specs = {"tokens": P(b_ax, None)}
    if cfg.family == "vlm" and sh.kind != "decode":
        specs["embeds"] = P(b_ax, None, None)
        specs["positions"] = P(None, b_ax, None)
    if cfg.family == "audio" and sh.kind != "decode":
        specs["frames"] = P(b_ax, None, None)
    return specs


def cache_partition_specs(model: Model, shape_name: str, mesh):
    """Spec tree for the decode cache (see sharding.py docstring)."""
    cfg = model.cfg
    sh = SHAPES[shape_name]
    dp = SH.dp_axes(mesh)
    cache = model.cache_specs(shape_name)
    b_ax = _maybe(dp, sh.global_batch, mesh)

    def spec_of(path, leaf):
        name = path[-1]
        shp = leaf.shape
        if name in ("k", "v", "shared_k", "shared_v"):
            # (L/G, B, S, KV, hd): S over model
            return P(None, b_ax, _maybe("model", shp[2], mesh), None, None)
        if name in ("k_scale", "v_scale"):   # (L, B, S, KV)
            return P(None, b_ax, _maybe("model", shp[2], mesh), None)
        if name == "h":            # (L, B, H, N, P): heads over model
            return P(None, b_ax, _maybe("model", shp[2], mesh), None, None)
        if name == "s":            # (L, B, H, P, P)
            return P(None, b_ax, _maybe("model", shp[2], mesh), None, None)
        if name == "conv":         # (L, B, K-1, C): channels over model
            return P(None, b_ax, None, _maybe("model", shp[3], mesh))
        if name in ("x_tm", "x_cm"):   # (L, B, 1, D)
            return P(None, b_ax, None, _maybe("model", shp[3], mesh))
        if name == "memory":       # (B, enc_len, d)
            return P(b_ax, None, None)
        return P(*([None] * leaf.ndim))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for kp, v in leaves:
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]
        out.append(spec_of(parts, v))
    return jax.tree_util.tree_unflatten(treedef, out)


def shardings_for(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


# --- step factories ------------------------------------------------------------


def make_train_step(model: Model, ocfg: OptConfig, mesh, donate: bool = True):
    """Train step with optional microbatched gradient accumulation
    (cfg.microbatches > 1): the global batch is processed in N sequential
    slices, bounding activation memory at 1/N while keeping the same
    mathematical update (grads averaged in moments dtype)."""
    cfg = model.cfg
    nmb = max(1, cfg.microbatches)

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: model.loss(p, batch, mesh), has_aux=True)(params)

    def split_mb(batch):
        """batch-dim-0 tensors -> (nmb, B/nmb, ...); positions (3,B,S) special."""
        out = {}
        for k, x in batch.items():
            if k == "positions":                    # (3, B, S)
                out[k] = x.reshape((3, nmb, x.shape[1] // nmb) + x.shape[2:]
                                   ).swapaxes(0, 1)
            else:
                out[k] = x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])
        return out

    pspecs = params_partition_specs(model, mesh) if mesh is not None else None

    def step(params, opt_state, batch):
        if nmb == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            acc_dt = {"float32": jnp.float32,
                      "bfloat16": jnp.bfloat16}[cfg.moments_dtype]

            def body(gacc, mbatch):
                (_, metrics), g = grad_fn(params, mbatch)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt) / nmb, gacc, g)
                return gacc, metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            if pspecs is not None:
                # the accumulator is a fresh while-loop carry: without an
                # explicit constraint XLA may replicate it (= a full f32
                # copy of the params per device)
                zeros = jax.tree.map(
                    lambda z, s: jax.lax.with_sharding_constraint(
                        z, NamedSharding(mesh, s)), zeros, pspecs)
            grads, ms = jax.lax.scan(body, zeros, split_mb(batch),
                                     unroll=bool(cfg.scan_unroll))
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), ms)
        params, opt_state, om = apply_updates(params, grads, opt_state, ocfg)
        return params, opt_state, {**metrics, **om}

    return step


def make_prefill_step(model: Model, mesh):
    def step(params, batch):
        return model.prefill(params, batch, mesh)
    return step


def make_decode_step(model: Model, mesh):
    def step(params, tokens, cache):
        return model.decode(params, tokens, cache, mesh)
    return step
