"""Trainer: end-to-end loop with the paper's periodic weight clustering,
checkpoint/restart fault tolerance, straggler monitoring, and (on pod
meshes) codebook-compressed cross-pod gradient reduction.

CPU smoke run:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 60 --quant --ckpt-dir /tmp/ckpt

The same loop drives the production mesh (the dry-run proves the step
compiles there); on this container it runs reduced configs on 1 CPU device.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import checkpoint as CKPT
from repro.core.quantizer import (QuantizerState, cluster_params, init_state)
from repro.data.synthetic import TokenPipeline
from repro.distributed.fault_tolerance import FailureInjector, StragglerMonitor
from repro.launch import steps as ST
from repro.launch.mesh import make_local_mesh
from repro.models.model_zoo import build
from repro.optim import OptConfig, init_opt_state, warmup_cosine


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 64
    lr: float = 3e-3
    opt: str = "adamw"
    ckpt_dir: str = ""
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0


def train(cfg, loop: TrainLoopConfig, mesh=None, injector=None,
          log=print):
    """Returns (params, quantizer state, history).  Restart-safe."""
    model = build(cfg)
    ocfg = OptConfig(name=loop.opt, lr=loop.lr,
                     schedule=warmup_cosine(20, loop.steps),
                     moments_dtype=cfg.moments_dtype)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=loop.batch, seq=loop.seq,
                         seed=loop.seed)
    injector = injector or FailureInjector()
    monitor = StragglerMonitor(factor=loop.straggler_factor)

    params = model.init(jax.random.PRNGKey(loop.seed))
    opt_state = init_opt_state(params, ocfg)
    qstate = init_state(cfg.wq)
    start_step = 0

    ckpt = CKPT.AsyncCheckpointer(loop.ckpt_dir) if loop.ckpt_dir else None
    if loop.ckpt_dir:
        latest = CKPT.latest_step(loop.ckpt_dir)
        if latest is not None:
            tree = {"params": params, "opt": opt_state,
                    "codebooks": qstate.codebooks}
            restored, extra = CKPT.restore(loop.ckpt_dir, latest, tree)
            params, opt_state = restored["params"], restored["opt"]
            qstate = QuantizerState(codebooks=restored["codebooks"],
                                    last_step=extra.get("cluster_step", -1))
            start_step = extra["step"]
            log(f"[resume] from step {start_step}")

    step_fn = jax.jit(ST.make_train_step(model, ocfg, mesh),
                      donate_argnums=(0, 1))
    history = []
    try:
        return _loop(cfg, loop, model, step_fn, params, opt_state, qstate,
                     start_step, pipe, injector, monitor, ckpt, history, log)
    finally:
        if ckpt:
            # a crash mid-flight must not leave a half-written snapshot
            # unaccounted for: drain the async writer so the atomic rename
            # either completed or never happened
            ckpt.wait()


def _loop(cfg, loop, model, step_fn, params, opt_state, qstate, start_step,
          pipe, injector, monitor, ckpt, history, log):
    for step in range(start_step, loop.steps):
        injector.maybe_fail(step)
        with StragglerMonitor.timer(monitor) as t:
            # paper §2.2: every `interval` steps, snap all weights to |W|
            # cluster centroids, then keep training unmodified
            if cfg.wq.due(step):
                params, qstate = cluster_params(
                    params, cfg.wq, qstate, step,
                    jax.random.fold_in(jax.random.PRNGKey(loop.seed), step))
            batch = pipe.batch_at(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        if t.straggler:
            log(f"[straggler] step {step}: {t.seconds:.2f}s "
                f"(count={monitor.stragglers})")
        if step % loop.log_every == 0 or step == loop.steps - 1:
            loss = float(metrics["loss"])
            history.append({"step": step, "loss": loss,
                            "sec": round(t.seconds, 4)})
            log(f"step {step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f}")
        if ckpt and (step + 1) % loop.ckpt_every == 0:
            ckpt.save(step + 1,
                      {"params": params, "opt": opt_state,
                       "codebooks": qstate.codebooks},
                      extra={"step": step + 1,
                             "cluster_step": qstate.last_step})
    if ckpt:
        ckpt.save(loop.steps, {"params": params, "opt": opt_state,
                               "codebooks": qstate.codebooks},
                  extra={"step": loop.steps,
                         "cluster_step": qstate.last_step})
        ckpt.wait()
    return params, qstate, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--quant", action="store_true",
                    help="paper working point: |A|=32 acts, |W|=1000 weights")
    ap.add_argument("--act-levels", type=int, default=0)
    ap.add_argument("--n-weights", type=int, default=0)
    ap.add_argument("--cluster-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant:
        cfg = cfg.quantized()
    if args.act_levels or args.n_weights:
        from repro.core.quantizer import WeightQuantConfig
        cfg = cfg.replace(
            act_levels=args.act_levels or cfg.act_levels,
            wq=WeightQuantConfig(num_weights=args.n_weights,
                                 interval=args.cluster_every)
            if args.n_weights else cfg.wq)
    if cfg.wq.enabled:
        cfg = cfg.replace(wq=dataclasses.replace(cfg.wq,
                                                 interval=args.cluster_every))

    loop = TrainLoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                           lr=args.lr, opt=args.opt, ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every)
    t0 = time.time()
    _, qstate, history = train(cfg, loop)
    print(json.dumps({"history": history[-3:],
                      "wall_seconds": round(time.time() - t0, 1),
                      "codebook_sizes": {k: int(v.shape[0]) for k, v in
                                         qstate.codebooks.items()}}))


if __name__ == "__main__":
    main()
