"""Production meshes.  Functions, not constants — importing this module
never touches jax device state (per brief)."""

from __future__ import annotations

import jax

from repro.distributed.compat import make_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod (16, 16) = 256 chips or multi-pod (2, 16, 16) = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return make_mesh((data, model), ("data", "model"))
