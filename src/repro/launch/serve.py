"""Serving launcher: loads (or trains) a model, optionally compresses the
weights to codebook-index form (paper §4 / DESIGN.md §2), and runs batched
generation.

CPU smoke run:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --compress --requests 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.core.quantizer import cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params
from repro.core.export import memory_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--n-weights", type=int, default=1000)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.compress:
        wq = cfg.quantized(n_weights=args.n_weights).wq
        params, qstate = cluster_params(params, wq, init_state(wq), wq.interval,
                                        jax.random.PRNGKey(1))
        cparams = to_codebook_params(params, wq, qstate)
        from repro.core.quantizer import codebook_indices
        idx_tree, _ = codebook_indices(params, wq, qstate)
        rep = memory_report(idx_tree, wq.num_weights, max(cfg.act_levels, 32))
        print("[memory]", rep.row())
        params = cparams

    engine = ServeEngine(model, params, max_len=args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, args.prompt_len))
               for _ in range(args.requests)]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    toks = args.requests * args.max_new
    print(f"[serve] {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s on CPU, batch={args.requests})")
    print("sample:", outs[0][:args.prompt_len], "->",
          outs[0][args.prompt_len:])


if __name__ == "__main__":
    main()
