"""Serving launcher: loads (or trains) a model, optionally compresses the
weights to codebook-index form (paper §4 / DESIGN.md §2), and serves a
request stream through the continuous-batching ServeEngine (DESIGN.md §3).

Knobs:
    --backend {dense,codebook,lut}   matmul path for index-form weights
    --max-batch N                    slot-pool width (continuous batching)
    --requests N                     queue length (> max-batch exercises
                                     join/leave slot reuse)
    --uniform                        use the single fixed-batch generate()
                                     instead of the slot-pool serve()
    --paged                          paged KV cache (DESIGN.md §8): chunked
                                     prefill + page-gated admission
    --page-size N                    tokens per cache page (paged mode)
    --kv-dtype {bf16,int8}           page storage: model float dtype or
                                     int8 + per-token-per-head scales
    --prefix-cache / --no-prefix-cache
                                     content-addressed prompt-page sharing
    --spec-draft {none,ngram,model}  speculative decoding (DESIGN.md §9):
                                     parameter-free n-gram self-draft, or a
                                     lower-tier model draft (the SAME
                                     compressed params through the coarse
                                     lut grid; needs --compress)
    --spec-k N                       draft tokens per verify round
    --top-k / --top-p                sampling filters (temperature > 0)
    --tp N                           tensor parallelism over a (1, N)
                                     ('data','model') mesh (DESIGN.md §10):
                                     weights column/row-shard, the KV cache
                                     (slab or page pool) shards its
                                     sequence axis.  On CPU hosts with too
                                     few devices the launcher re-execs
                                     itself with N forced host devices.
    --server                         long-running request-server mode
                                     (DESIGN.md §11): a traffic trace plays
                                     through the virtual-clock
                                     AsyncScheduler — arrival-time
                                     admission, priorities, streaming,
                                     swap-out preemption — and a
                                     TTFT/TPOT/SLO report prints after the
                                     drain.  The wall clock is only read
                                     HERE; serving/ itself is clockless.
    --replicas N                     --server only: a FleetRouter over N
                                     independent engine replicas
                                     (DESIGN.md §15) — load- and
                                     prefix-aware routing, fleet-level
                                     report aggregation
    --route-policy {prefix,round_robin}
                                     fleet routing policy
    --drain-at T:REP                 drain replica REP at virtual time T
                                     (repeatable): stop admitting, let its
                                     running requests finish
    --scale-at T:REP                 join a fresh replica REP at virtual
                                     time T (repeatable)
    --migrate-on-drain               drain-time KV migration: a draining
                                     replica expels its queued/preempted/
                                     running requests — KV swap blobs
                                     included — and the router rehomes
                                     them to survivors, instead of the
                                     drain finishing them in place
    --shared-prefix-tier             fleet-level content-addressed prefix
                                     page tier: a replica that misses a
                                     cached prompt prefix locally adopts
                                     the pages a peer already computed
                                     instead of recomputing prefill
    --shed-policy {none,defer,slo,all}
                                     admission backpressure when EVERY
                                     admitting replica is over
                                     --shed-threshold: defer arrivals in
                                     place, shed best-effort traffic
                                     (slo), or shed everything
    --shed-threshold P               replica pressure (pool page / busy
                                     slot fraction) above which admission
                                     backpressure engages
    --probes                         in-graph numerics probes (DESIGN.md
                                     §14): per-layer activation-saturation,
                                     int32-accumulator-headroom, and int8-KV
                                     round-trip-error counters threaded
                                     through the jitted decode; a summary
                                     block prints after the run
    --numerics-out PATH              write the full numerics summary JSON
                                     there after the run (needs --probes)
    --traffic {poisson,replay}       synthetic seeded Poisson arrivals, or
                                     a JSON trace from --trace-file
    --rate R                         poisson arrivals per virtual second
    --priority-levels N              priority classes 0..N-1 (uniform)
    --quantum N                      decode tokens per scheduling round
    --no-preempt                     disable preemption (head-of-line
                                     waits instead of swapping victims)
    --slo-ttft / --slo-tpot          per-request SLOs for the report

CPU smoke runs:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --compress --requests 8 --max-batch 4 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --paged --kv-dtype int8 --requests 8 --max-batch 4 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --spec-draft ngram --spec-k 4 --requests 8 --max-new 24
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --compress --backend codebook --tp 4 --requests 8 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --server --traffic poisson --rate 40 --requests 16 --paged \
        --priority-levels 2 --slo-ttft 0.3
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --server --replicas 3 --paged --rate 80 --requests 24 \
        --drain-at 0.4:r0 --scale-at 0.6:r3
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --server --replicas 4 --paged --rate 120 --requests 32 \
        --migrate-on-drain --shared-prefix-tier --shed-policy slo \
        --drain-at 0.3:r0
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

import repro.configs as configs
from repro.core.quantizer import cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, SpecConfig, to_codebook_params
from repro.core.export import kv_cache_bytes, memory_report


def _ensure_devices(n: int):
    """Re-exec with forced host devices when a CPU box is short of --tp.

    XLA_FLAGS must be set before jax initialises its backends, so a fresh
    process is the only clean route; real TPU/GPU topologies never take
    this branch."""
    if len(jax.devices()) >= n:
        return
    if jax.default_backend() == "cpu" and "_REPRO_TP_REEXEC" not in os.environ:
        env = dict(os.environ, _REPRO_TP_REEXEC="1")
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n} "
                            + env.get("XLA_FLAGS", "")).strip()
        os.execve(sys.executable, [sys.executable] + sys.argv, env)
    raise SystemExit(f"--tp {n} needs {n} devices; found "
                     f"{len(jax.devices())} ({jax.default_backend()})")


def report_numerics(engine, out_path=""):
    """One-block probe summary (worst layer of each series) + optional
    full JSON dump — shared by batch and --server modes."""
    num = engine.numerics()
    hr = min(num["headroom_bits"] or [31.0])
    sat = max(num["sat_rate"] or [0.0])
    kv = max(num["kv_err_max"] or [0.0])
    print(f"[numerics] {num['backend']}: {num['tokens']:.0f} tokens probed, "
          f"sat rate max {100 * sat:.2f}%, acc headroom min {hr:.1f} bits, "
          f"kv err max {kv:.4f}, page_oob {num['page_oob']:.0f}, "
          f"widx neg/oob {num['widx_neg']}/{num['widx_oob']}")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(num, f, indent=1, sort_keys=True)
        print(f"[numerics] report -> {out_path}")


def _parse_at(specs, what):
    """['0.5:r0', ...] -> [(0.5, 'r0'), ...] for --drain-at/--scale-at."""
    out = []
    for s in specs:
        t, _, rep = s.partition(":")
        if not rep:
            raise SystemExit(f"{what} wants TIME:REPLICA, got {s!r}")
        out.append((float(t), rep))
    return out


def run_server(args, engine, cfg, mk_engine):
    """--server mode: drain a traffic trace through the scheduler (one
    Server, or a FleetRouter over --replicas of them) and report.  The
    ONLY wall-clock reads live here, outside serving/."""
    from repro.serving.server import Server, load_trace, poisson_trace

    if args.traffic == "replay":
        if not args.trace_file:
            raise SystemExit("--traffic replay needs --trace-file")
        trace = load_trace(args.trace_file)
    else:
        trace = poisson_trace(
            args.seed, args.requests, rate=args.rate, vocab=cfg.vocab,
            plen=(min(2, args.prompt_len), args.prompt_len),
            max_new=(min(2, args.max_new), args.max_new),
            priorities=tuple(range(args.priority_levels)),
            slo_ttft=args.slo_ttft, slo_tpot=args.slo_tpot)
    if not trace:
        raise SystemExit("--server got an empty trace (check --requests / "
                         "--trace-file)")
    tel = None
    if args.metrics_out or args.trace_out or args.probes:
        from repro.serving.telemetry import Telemetry
        tel = Telemetry()
    fleet = None
    if (args.replicas > 1 or args.drain_at or args.scale_at
            or args.migrate_on_drain or args.shared_prefix_tier
            or args.shed_policy != "none"):
        from repro.serving import Fleet
        engines = {f"r{i}": engine if i == 0 else mk_engine()
                   for i in range(args.replicas)}
        fleet = Fleet(engines, quantum=args.quantum, preempt=args.preempt,
                      telemetry=tel, policy=args.route_policy,
                      migrate_on_drain=args.migrate_on_drain,
                      shared_prefix_tier=args.shared_prefix_tier,
                      shed_policy=args.shed_policy,
                      shed_threshold=args.shed_threshold)
        scale = [(t, rep, mk_engine)
                 for t, rep in _parse_at(args.scale_at, "--scale-at")]
        t0 = time.time()
        rep = fleet.replay(trace,
                           drain_at=_parse_at(args.drain_at, "--drain-at"),
                           scale_at=scale)
        wall = time.time() - t0
    else:
        srv = Server(engine, quantum=args.quantum, preempt=args.preempt,
                     telemetry=tel)
        t0 = time.time()
        rep = srv.replay(trace)
        wall = time.time() - t0
    print(f"[server] {rep.n_requests} requests / {rep.n_tokens} tokens "
          f"drained in {wall:.2f}s wall ({rep.n_tokens / wall:.1f} tok/s), "
          f"virtual makespan {rep.makespan:.3f}s")
    print(f"[server] ttft p50/p99 {rep.p50_ttft:.3f}/{rep.p99_ttft:.3f}s, "
          f"tpot p50/p99 {rep.p50_tpot:.3f}/{rep.p99_tpot:.3f}s "
          f"(virtual clock)")
    print(f"[server] {rep.preemptions} preemptions, "
          f"{rep.pages_swapped_out} pages swapped out / "
          f"{rep.pages_swapped_in} back in, SLO attainment "
          f"{100 * rep.slo_attainment:.0f}%")
    print(f"[server] admission order: {rep.admission_order}")
    if fleet is not None:
        for r, s in sorted(fleet.replica_stats().items()):
            print(f"[fleet] {r}: {s['routed']} routed"
                  + (", draining" if s["draining"] else "")
                  + f", {s['preemptions']} preemptions, swap out/in "
                  f"{s['pages_swapped_out']}/{s['pages_swapped_in']} pages"
                  + (f", {s['migrated_out']} migrated out"
                     if s["migrated_out"] else ""))
        if args.migrate_on_drain:
            print(f"[fleet] drain migration: {fleet.n_migrated} requests / "
                  f"{fleet.n_migrated_pages} KV pages rehomed to survivors")
        if args.shed_policy != "none":
            print(f"[fleet] backpressure ({args.shed_policy} @ "
                  f"{args.shed_threshold:.2f}): {rep.n_shed} requests shed, "
                  f"{fleet.n_deferred} deferred")
        tier = fleet.shared_tier_stats()
        if tier is not None:
            print(f"[fleet] shared prefix tier: {tier['hits']} page hits / "
                  f"{tier['misses']} misses, {tier['puts']} puts, "
                  f"{tier['evictions']} evictions, {tier['entries']} entries "
                  f"({tier['bytes'] / 1e6:.2f}MB)")
        if engine.paged:
            print(f"[fleet] routing policy {args.route_policy}: fleet-wide "
                  f"prefix hit rate {100 * fleet.prefix_hit_rate():.0f}%, "
                  f"event digest {fleet.event_digest()[:16]}")
    elif engine.paged:
        st = engine.pool.stats
        print(f"[kv] pool peak {st.peak_pages_in_use}/"
              f"{engine.pool.usable_pages} pages, prefix hit rate "
              f"{100 * st.hit_rate:.0f}%, swap out/in "
              f"{st.swapped_out_pages}/{st.swapped_in_pages} pages")
    if tel is not None:
        if args.metrics_out:
            tel.export_metrics(args.metrics_out)
            print(f"[telemetry] metrics snapshot -> {args.metrics_out}")
        if args.trace_out:
            tel.export_trace(args.trace_out)
            print(f"[telemetry] Perfetto trace -> {args.trace_out} "
                  "(open at https://ui.perfetto.dev)")
        print(tel.summary())
    if args.probes:
        report_numerics(engine, args.numerics_out)
    # request 0 may have been shed under backpressure; sample any survivor
    h = (next(iter(fleet.handles.values())) if fleet is not None
         else srv.sched.handles[0])
    print("sample:", h.prompt, "->", h.tokens)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--backend", default="dense",
                    choices=("dense", "codebook", "lut"))
    ap.add_argument("--n-weights", type=int, default=1000)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--uniform", action="store_true",
                    help="fixed-batch generate() instead of the slot pool")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: chunked prefill, prefix caching, "
                         "page-gated admission (serve() only)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-dtype", default="bf16", choices=("bf16", "int8"))
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction)
    ap.add_argument("--spec-draft", default="none",
                    choices=("none", "ngram", "model"),
                    help="speculative decoding draft (DESIGN.md §9)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per verify round")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree (DESIGN.md §10)")
    ap.add_argument("--server", action="store_true",
                    help="request-server mode (DESIGN.md §11): drain a "
                         "traffic trace through the AsyncScheduler")
    ap.add_argument("--traffic", default="poisson",
                    choices=("poisson", "replay"))
    ap.add_argument("--rate", type=float, default=40.0,
                    help="poisson arrivals per virtual second")
    ap.add_argument("--trace-file", default="",
                    help="JSON trace for --traffic replay "
                         "(serving.server.save_trace format)")
    ap.add_argument("--priority-levels", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=1,
                    help="--server only: serve through a FleetRouter over "
                         "N independent engine replicas (DESIGN.md §15)")
    ap.add_argument("--route-policy", default="prefix",
                    choices=("prefix", "round_robin"),
                    help="fleet routing: longest cached prompt prefix "
                         "(ties: load, then free pages) or round-robin")
    ap.add_argument("--drain-at", action="append", default=[],
                    metavar="T:REP",
                    help="drain replica REP at virtual time T (repeatable), "
                         "e.g. --drain-at 0.5:r0")
    ap.add_argument("--scale-at", action="append", default=[],
                    metavar="T:REP",
                    help="join a fresh replica named REP at virtual time T "
                         "(repeatable), e.g. --scale-at 0.8:r4")
    ap.add_argument("--migrate-on-drain", action="store_true",
                    help="drained replicas expel queued/preempted/running "
                         "requests (KV swap blobs included) and the router "
                         "rehomes them to survivors")
    ap.add_argument("--shared-prefix-tier", action="store_true",
                    help="fleet-level content-addressed prefix page tier: "
                         "local prefix misses adopt pages a peer computed "
                         "instead of recomputing prefill (needs --paged)")
    ap.add_argument("--shed-policy", default="none",
                    choices=("none", "defer", "slo", "all"),
                    help="admission backpressure when every admitting "
                         "replica is over --shed-threshold")
    ap.add_argument("--shed-threshold", type=float, default=0.95,
                    help="replica pressure in [0, 1] above which "
                         "--shed-policy engages")
    ap.add_argument("--quantum", type=int, default=1,
                    help="decode tokens per scheduling round")
    ap.add_argument("--preempt", default=True,
                    action=argparse.BooleanOptionalAction)
    ap.add_argument("--slo-ttft", type=float, default=None)
    ap.add_argument("--slo-tpot", type=float, default=None)
    ap.add_argument("--metrics-out", default="",
                    help="--server only: write the telemetry registry "
                         "snapshot (canonical JSON) here after the drain")
    ap.add_argument("--trace-out", default="",
                    help="--server only: write a Perfetto/Chrome "
                         "trace.json of request/slot lifecycle spans "
                         "(virtual-clock time) here after the drain")
    ap.add_argument("--probes", action="store_true",
                    help="in-graph numerics probes (DESIGN.md §14): "
                         "saturation / accumulator-headroom / KV-error "
                         "counters threaded through the jitted decode")
    ap.add_argument("--numerics-out", default="",
                    help="write the numerics summary JSON here after the "
                         "run (needs --probes)")
    ap.add_argument("--seed", type=int, default=0,
                    help="traffic/workload PRNG seed")
    args = ap.parse_args()
    if args.paged and args.uniform:
        ap.error("--paged serves through the slot pool; drop --uniform")
    if args.spec_draft != "none" and args.uniform:
        ap.error("speculative decoding runs through serve(); drop --uniform")
    if args.spec_draft == "model" and not args.compress:
        ap.error("--spec-draft model drafts with the compressed params "
                 "through the lut backend; add --compress")
    if args.server and args.uniform:
        ap.error("--server schedules through the slot pool; drop --uniform")
    if args.server and args.spec_draft != "none":
        ap.error("the scheduler drives plain decode rounds; drop "
                 "--spec-draft for --server")
    if (args.metrics_out or args.trace_out) and not args.server:
        ap.error("--metrics-out/--trace-out report the scheduler drain; "
                 "add --server")
    if args.numerics_out and not args.probes:
        ap.error("--numerics-out reports the probe counters; add --probes")
    if ((args.replicas > 1 or args.drain_at or args.scale_at
         or args.migrate_on_drain or args.shared_prefix_tier
         or args.shed_policy != "none") and not args.server):
        ap.error("--replicas/--drain-at/--scale-at/--migrate-on-drain/"
                 "--shared-prefix-tier/--shed-policy drive the fleet "
                 "router; add --server")
    if args.replicas < 1:
        ap.error("--replicas wants at least 1")
    if args.shared_prefix_tier and not args.paged:
        ap.error("--shared-prefix-tier shares prefix PAGES; add --paged")
    if not 0.0 <= args.shed_threshold <= 1.0:
        ap.error("--shed-threshold wants a pressure fraction in [0, 1]")
    if args.probes and args.spec_draft != "none":
        ap.error("numerics probes instrument the plain decode loops; drop "
                 "--spec-draft for --probes")

    mesh = None
    if args.tp > 1:
        if args.paged and args.page_size % args.tp:
            ap.error(f"--page-size {args.page_size} must be a multiple of "
                     f"--tp {args.tp} (each shard owns an S-slice of every "
                     "page)")
        _ensure_devices(args.tp)
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(1, args.tp)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.compress:
        wq = cfg.quantized(n_weights=args.n_weights).wq
        params, qstate = cluster_params(params, wq, init_state(wq), wq.interval,
                                        jax.random.PRNGKey(1))
        cparams = to_codebook_params(params, wq, qstate)
        from repro.core.quantizer import codebook_indices
        idx_tree, _ = codebook_indices(params, wq, qstate)
        # end-to-end claim: weights AND serving state (dense float slab vs
        # the paged int8 cache sized for the actual tokens in flight)
        max_len = args.prompt_len + args.max_new + 8
        fpb = 4 if cfg.dtype == "float32" else 2
        kv_fp = kv_cache_bytes(cfg.n_layers, cfg.n_kv, cfg.hd,
                               args.max_batch * max_len, dtype_bytes=fpb)
        # page rounding is per request (each reserves whole pages), not on
        # the aggregate token count
        kv_packed = min(args.requests, args.max_batch) * kv_cache_bytes(
            cfg.n_layers, cfg.n_kv, cfg.hd,
            args.prompt_len + args.max_new,
            quant=True, page_size=args.page_size)
        rep = memory_report(idx_tree, wq.num_weights, max(cfg.act_levels, 32),
                            kv_fp_bytes=kv_fp, kv_packed_bytes=kv_packed)
        print("[memory]", rep.row())
        params = cparams
    elif args.backend != "dense":
        ap.error(f"--backend {args.backend} needs --compress (index-form "
                 "weights)")

    spec = None
    if args.spec_draft != "none":
        spec = SpecConfig(
            draft=args.spec_draft, k=args.spec_k,
            # the model draft is the paper's lower tier: the SAME index-form
            # params contracted through a coarse integer grid
            draft_params=params if args.spec_draft == "model" else None,
            draft_backend="lut", lut_levels=512)
    max_len = (args.prompt_len + args.max_new + 8
               + (args.spec_k if spec else 0))
    max_len += (-max_len) % args.tp        # the cache S axis shards over tp
    def mk_engine():
        return ServeEngine(model, params, max_len=max_len,
                           temperature=args.temperature, mesh=mesh,
                           backend=args.backend, max_batch=args.max_batch,
                           paged=args.paged, page_size=args.page_size,
                           kv_dtype=args.kv_dtype,
                           prefix_cache=args.prefix_cache,
                           top_k=args.top_k, top_p=args.top_p, spec=spec,
                           probes=args.probes)

    engine = mk_engine()
    if args.server:
        run_server(args, engine, cfg, mk_engine)
        return
    rng = np.random.default_rng(args.seed)
    prompts = [[int(t) for t in rng.integers(0, cfg.vocab, args.prompt_len)]
               for _ in range(args.requests)]

    # warm the compiles so the reported rate is steady-state — same batch
    # and max_new as the timed run (jit retraces on any shape change)
    warm = engine.generate if args.uniform else engine.serve
    warm(prompts, args.max_new)
    if args.paged:
        engine.pool.reset_stats()
    if spec is not None:
        engine.spec_stats.reset()
    if args.probes:
        engine.reset_probes()          # count only the timed run below

    t0 = time.time()
    if args.uniform:
        outs = engine.generate(prompts, max_new=args.max_new)
    else:
        outs = engine.serve(prompts, max_new=args.max_new)
    dt = time.time() - t0
    toks = args.requests * args.max_new
    mode = "uniform" if args.uniform else f"slots={args.max_batch}"
    if args.paged:
        mode += f", paged({args.page_size}t/{args.kv_dtype})"
    print(f"[serve] {toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s on "
          f"{jax.default_backend()}, backend={args.backend}, {mode}, "
          f"{dt / args.requests * 1e3:.1f} ms/request)")
    if args.paged:
        st = engine.pool.stats
        print(f"[kv] paged pool: peak "
              f"{st.peak_pages_in_use}/{engine.pool.usable_pages} pages "
              f"({engine.pool.bytes_per_page() * st.peak_pages_in_use / 1e6:.3f}MB"
              f" peak vs {engine.dense_cache_bytes() / 1e6:.3f}MB dense slab), "
              f"prefix hit rate {100 * st.hit_rate:.0f}%, "
              f"{st.cow_copies} CoW, {st.evictions} evictions"
              + (f", {st.truncated_pages} pages rolled back"
                 if spec else ""))
    if spec is not None:
        ss = engine.spec_stats
        print(f"[spec] {args.spec_draft} draft, k={args.spec_k}: "
              f"{ss.rounds} rounds, acceptance "
              f"{100 * ss.acceptance_rate:.0f}%, "
              f"{ss.tokens_per_round:.1f} tokens/round")
    if args.probes:
        report_numerics(engine, args.numerics_out)
    print("sample:", outs[0][:args.prompt_len], "->",
          outs[0][args.prompt_len:])


if __name__ == "__main__":
    main()
