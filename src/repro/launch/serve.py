"""Serving launcher: loads (or trains) a model, optionally compresses the
weights to codebook-index form (paper §4 / DESIGN.md §2), and serves a
request stream through the continuous-batching ServeEngine (DESIGN.md §3).

Knobs:
    --backend {dense,codebook,lut}   matmul path for index-form weights
    --max-batch N                    slot-pool width (continuous batching)
    --requests N                     queue length (> max-batch exercises
                                     join/leave slot reuse)
    --uniform                        use the single fixed-batch generate()
                                     instead of the slot-pool serve()

CPU smoke runs:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --compress --requests 8 --max-batch 4 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --compress --backend codebook --requests 4 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.core.quantizer import cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params
from repro.core.export import memory_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--backend", default="dense",
                    choices=("dense", "codebook", "lut"))
    ap.add_argument("--n-weights", type=int, default=1000)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--uniform", action="store_true",
                    help="fixed-batch generate() instead of the slot pool")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.compress:
        wq = cfg.quantized(n_weights=args.n_weights).wq
        params, qstate = cluster_params(params, wq, init_state(wq), wq.interval,
                                        jax.random.PRNGKey(1))
        cparams = to_codebook_params(params, wq, qstate)
        from repro.core.quantizer import codebook_indices
        idx_tree, _ = codebook_indices(params, wq, qstate)
        rep = memory_report(idx_tree, wq.num_weights, max(cfg.act_levels, 32))
        print("[memory]", rep.row())
        params = cparams
    elif args.backend != "dense":
        ap.error(f"--backend {args.backend} needs --compress (index-form "
                 "weights)")

    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.max_new + 8,
                         temperature=args.temperature,
                         backend=args.backend, max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(0, cfg.vocab, args.prompt_len)]
               for _ in range(args.requests)]

    # warm the compiles so the reported rate is steady-state — same batch
    # and max_new as the timed run (jit retraces on any shape change)
    warm = engine.generate if args.uniform else engine.serve
    warm(prompts, args.max_new)

    t0 = time.time()
    if args.uniform:
        outs = engine.generate(prompts, max_new=args.max_new)
    else:
        outs = engine.serve(prompts, max_new=args.max_new)
    dt = time.time() - t0
    toks = args.requests * args.max_new
    mode = "uniform" if args.uniform else f"slots={args.max_batch}"
    print(f"[serve] {toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s on "
          f"{jax.default_backend()}, backend={args.backend}, {mode}, "
          f"{dt / args.requests * 1e3:.1f} ms/request)")
    print("sample:", outs[0][:args.prompt_len], "->",
          outs[0][args.prompt_len:])


if __name__ == "__main__":
    main()
