import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any other import (jax locks the device count on first init).
"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell; record memory analysis, FLOPs/bytes, and the collective schedule.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single,multi

Per-cell JSON lands in ``dryrun_results/`` (``--out``); benchmarks/roofline
reads those files.  Because scan-over-layers HLO counts a loop body once,
each cell also lowers tiny *probe* configs (depth 1 and 2, unrolled) on the
same mesh to recover per-layer FLOP/byte/collective increments; the harness
reports  total = outside + depth × per_layer  (exact for homogeneous
stacks; the only uncorrected loops are the SSM/RWKV state-carry scans whose
bodies are <1% of layer FLOPs — see DESIGN.md §6).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs.base import SHAPES
from repro.configs.registry import ASSIGNED
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.hlo import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models.model_zoo import build
from repro.optim import OptConfig


def _abstract_compressed_params(model, wq):
    """ShapeDtypeStruct tree of the codebook-index weight representation
    (paper §4 deployment: intN index planes + |W| codebook)."""
    from repro.core.quantizer import QuantizerState
    from repro.serving.compress import to_codebook_params

    def make():
        params = model.init(jax.random.PRNGKey(0))
        state = QuantizerState(
            codebooks={"": jnp.zeros((wq.num_weights,), jnp.float32)},
            last_step=0)
        return to_codebook_params(params, wq, state)
    return jax.eval_shape(make)


_SERVE_TP_BUDGET = 3e9  # bytes/device of weights we allow data-replicated


def _serve_fsdp(cfg, mesh) -> bool:
    """Serving weight layout: TP-only (no per-layer gathers) whenever the
    params fit replicated over `data` — the §Perf(a)/(c) win; models beyond
    ~3 GB/device of TP-sharded weights (mistral-123b, grok-314b,
    qwen3-moe-30b) keep ZeRO-3 storage + per-layer gathers instead of
    blowing HBM."""
    import math
    model = build(cfg)
    total = sum(math.prod(x.shape) * x.dtype.itemsize
                for x in jax.tree.leaves(ST.abstract_params(model)))
    return total / mesh.shape["model"] > _SERVE_TP_BUDGET


def _lower_cell(cfg, shape_name: str, mesh, compressed: bool = False):
    """Lower + compile one cell; returns (compiled, seconds)."""
    sh = SHAPES[shape_name]
    if sh.kind != "train":
        cfg = cfg.replace(fsdp=_serve_fsdp(cfg, mesh))
    model = build(cfg)
    if compressed and sh.kind != "train":
        params_abs = _abstract_compressed_params(model, cfg.quantized().wq)
        from repro.distributed import sharding as SHD
        mcfg = None
        from repro.models import transformer as TT
        if cfg.n_experts:
            mcfg = TT.moe_cfg(cfg)
        pspecs = SHD.param_specs(params_abs, cfg, mcfg, mesh, fsdp=False)
    else:
        params_abs = ST.abstract_params(model)
        pspecs = ST.params_partition_specs(model, mesh)
    p_sh = ST.shardings_for(pspecs, mesh)
    b_sh = ST.shardings_for(ST.batch_specs(model, shape_name, mesh), mesh)
    batch_abs = model.input_specs(shape_name)

    t0 = time.time()
    if sh.kind == "train":
        ocfg = OptConfig(name="adamw", moments_dtype=cfg.moments_dtype)
        o_sh = ST.shardings_for(ST.opt_specs(model, ocfg, mesh), mesh)
        opt_abs = ST.abstract_opt_state(model, ocfg)
        step = ST.make_train_step(model, ocfg, mesh)
        lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1)).lower(
            params_abs, opt_abs, batch_abs)
    elif sh.kind == "prefill":
        c_sh = ST.shardings_for(
            ST.cache_partition_specs(model, shape_name, mesh), mesh)
        step = ST.make_prefill_step(model, mesh)
        lowered = jax.jit(step, in_shardings=(p_sh, b_sh),
                          out_shardings=(None, c_sh)).lower(
            params_abs, batch_abs)
    else:  # decode
        c_sh = ST.shardings_for(
            ST.cache_partition_specs(model, shape_name, mesh), mesh)
        cache_abs = model.cache_specs(shape_name)
        step = ST.make_decode_step(model, mesh)
        lowered = jax.jit(step, in_shardings=(p_sh, b_sh["tokens"], c_sh),
                          out_shardings=(None, c_sh),
                          donate_argnums=(2,)).lower(
            params_abs, batch_abs["tokens"], cache_abs)
    compiled = lowered.compile()
    return compiled, time.time() - t0


def _probe_cfgs(cfg):
    """Depth knobs for the scan-trip-count correction."""
    if cfg.family == "hybrid":
        se = cfg.shared_attn_every
        mk = lambda n: cfg.replace(n_layers=se * n, scan_unroll=True)
        return {"layers": (mk, cfg.n_layers // se)}
    if cfg.family == "audio":
        return {"layers": ((lambda n: cfg.replace(n_layers=n, enc_layers=1,
                                                  scan_unroll=True)),
                           cfg.n_layers),
                "enc": ((lambda n: cfg.replace(n_layers=1, enc_layers=n,
                                               scan_unroll=True)),
                        cfg.enc_layers)}
    return {"layers": ((lambda n: cfg.replace(n_layers=n, scan_unroll=True)),
                       cfg.n_layers)}


def _stats(compiled):
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return {
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "collectives_per_device": coll,
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }


def _metric3(s):
    return {"flops": s["flops_per_device"], "bytes": s["bytes_per_device"],
            "coll": s["collectives_per_device"].get("total", 0)}


def _corrected(cfg, shape_name, mesh, base_stats, compressed=False):
    """Probe shallow configs; return trip-count-corrected per-step totals.

    Cost model (every lax.scan body is counted once by cost_analysis):

        total = base(all depths 1) + Σ_k (depth_k − 1) · inc_k

    Probes run fully unrolled at microbatches=1: microbatching only *splits
    the token batch* (each microbatch handles B/m tokens), so a probe over
    the whole batch already measures the true per-step totals — multiplying
    by m would overcount everything token-proportional.  depth_k are the
    layer-stack depths (decoder layers; + encoder layers for audio).
    """
    probes = _probe_cfgs(cfg)

    def base_of(c):
        # moe_token_chunks=1: the chunk scan is yet another body-counted-once
        # loop; probes must run it flat (memory is taken from the full cell)
        c = c.replace(scan_unroll=True, microbatches=1, moe_token_chunks=1)
        if cfg.family == "audio":
            c = c.replace(enc_layers=1)
        return c.replace(n_layers=(cfg.shared_attn_every
                                   if cfg.family == "hybrid" else 1))

    base_cfg = base_of(cfg)
    s1 = _stats(_lower_cell(base_cfg, shape_name, mesh,
                            compressed=compressed)[0])
    f0 = _metric3(s1)

    incs = {}
    for name, (mk, depth) in probes.items():
        cfg2 = base_of(mk(2)) if name != "layers" else \
            mk(2).replace(scan_unroll=True, microbatches=1,
                          moe_token_chunks=1)
        if cfg.family == "audio":
            cfg2 = cfg.replace(scan_unroll=True, microbatches=1,
                               n_layers=2 if name == "layers" else 1,
                               enc_layers=2 if name == "enc" else 1)
        s2 = _stats(_lower_cell(cfg2, shape_name, mesh,
                                compressed=compressed)[0])
        f2 = _metric3(s2)
        incs[name] = {"depth": depth,
                      **{k: f2[k] - f0[k] for k in f0}}

    corrected = {}
    for k in f0:
        corrected[k] = f0[k] + sum((i["depth"] - 1) * i[k]
                                   for i in incs.values())
    corrected["per_layer"] = incs
    return corrected


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             probes: bool = True, compressed: bool = False):
    cfg = configs.get(arch)
    if compressed:
        cfg = cfg.quantized()
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}" + ("__q" if compressed else "")
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        print(f"[skip] {tag}")
        return json.load(open(path))
    if shape_name not in cfg.shapes():
        rec = {"cell": tag, "status": "skipped",
               "reason": ("no decoder" if not cfg.has_decoder else
                          "full-attention arch: long_500k documented-skip")}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[SKIP-doc] {tag}: {rec['reason']}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    print(f"[lower] {tag} mesh={dict(mesh.shape)}", flush=True)
    try:
        compiled, secs = _lower_cell(cfg, shape_name, mesh,
                                     compressed=compressed)
        rec = {"cell": tag, "arch": arch, "shape": shape_name,
               "mesh": mesh_name, "n_devices": mesh.size,
               "status": "ok", "compile_seconds": round(secs, 1),
               **_stats(compiled)}
        print(f"  memory_analysis: {compiled.memory_analysis()}", flush=True)
        ca = compiled.cost_analysis()
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}", flush=True)
        del compiled
        if probes:
            rec["corrected"] = _corrected(cfg, shape_name, mesh, rec,
                                          compressed=compressed)
    except Exception as e:  # a cell failure is a bug — record it loudly
        rec = {"cell": tag, "status": "error", "error": repr(e),
               "trace": traceback.format_exc()[-4000:]}
        print(f"[ERROR] {tag}: {e!r}", flush=True)
    os.makedirs(out_dir, exist_ok=True)
    json.dump(rec, open(path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="dryrun_results")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--compressed", action="store_true",
                    help="codebook-quantized variant (|A|=32,|W|=1000)")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch in ("all",) else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for m in meshes:
                rec = run_cell(arch, shape, m == "multi", args.out,
                               probes=not args.no_probes,
                               compressed=args.compressed)
                st = rec.get("status")
                n_ok += st == "ok"
                n_err += st == "error"
                n_skip += st == "skipped"
    print(f"\nDRY-RUN SUMMARY: ok={n_ok} skipped(doc)={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
