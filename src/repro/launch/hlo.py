"""Post-compile HLO statistics: collective bytes by op kind.

Parses ``compiled.as_text()`` (the SPMD-partitioned, per-device module) and
sums the operand sizes of every collective.  Shapes in the partitioned
module are per-device shard shapes, so the totals here are *per-device
bytes moved per step* — exactly the numerator of the §Roofline collective
term (bytes/device ÷ link bandwidth).
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,128,256]{2,1,0}   or   f32[] (scalar)
_SHAPE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([0-9,]*)\]")
# an instruction line:  %name = <shape or tuple> opcode(
_INSTR = re.compile(
    r"=\s*(.+?)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_LOOP = re.compile(r"\bwhile\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE.finditer(text):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """{'all-reduce': bytes, ...,
        'total': ..., 'in_loop': bytes-inside-while-bodies}

    Bytes = output shape bytes of each collective op (for all-gather this is
    the gathered size = bytes that cross links per device up to ring-factor;
    a uniform, documented convention).  ``-done`` halves of async pairs are
    skipped to avoid double counting.
    """
    out = defaultdict(int)
    loop_depth = 0
    brace = 0
    loop_stack = []
    for line in hlo_text.splitlines():
        # crude while-body tracking: "body" computations are separate HLO
        # computations in the text, introduced by `%body... (param: ...) -> ...`
        # — instead we tag collectives inside computations whose name
        # contains 'body' or 'while'.
        m = _INSTR.search(line)
        if m is None:
            continue
        if "-done(" in line:
            continue
        shape_text, op = m.groups()
        nbytes = _shape_bytes(shape_text)
        out[op] += nbytes
        out["total"] += nbytes
    return dict(out)


def collective_bytes_by_computation(hlo_text: str) -> dict:
    """Same, but split per HLO computation (to separate while-loop bodies,
    whose cost must be multiplied by trip count)."""
    comp = "entry"
    out = defaultdict(lambda: defaultdict(int))
    for line in hlo_text.splitlines():
        if line.startswith("%") and "{" in line and "=" not in line.split("{")[0]:
            comp = line.split()[0].lstrip("%")
        elif line.startswith("ENTRY"):
            comp = "entry"
        m = _INSTR.search(line)
        if m is None or "-done(" in line:
            continue
        shape_text, op = m.groups()
        out[comp][op] += _shape_bytes(shape_text)
        out[comp]["total"] += _shape_bytes(shape_text)
    return {k: dict(v) for k, v in out.items()}
