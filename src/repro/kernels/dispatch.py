"""Serving-time matmul backend switch: ``dense`` | ``codebook`` | ``lut``.

``models.layers.dense`` consults this module whenever its params are in
codebook-index form (``{'w_idx', 'codebook'}``, see serving/compress.py) and
routes the contraction accordingly (DESIGN.md §2–§3):

* ``dense``    — gather the codebook then a plain XLA dot (default; what
                 training and the seed serving path always did).
* ``codebook`` — ``kernels.codebook_matmul``: indices stay narrow in HBM,
                 dequantize-in-VMEM gather feeding the MXU.  The TPU
                 deployment artifact; compiled Pallas on TPU/GPU, interpret
                 fallback elsewhere (see ``kernels.ops``).
* ``lut``      — ``kernels.lut_matmul``: the paper's faithful §4 engine.
                 Activations are snapped to a uniform level grid, the
                 multiplication table M[a, w] = rint(a·w·2^s/Δa) is gathered
                 and accumulated in int32 — the contraction itself performs
                 no multiplications and no floating-point ops.

The backend is *trace-time* state: ``ServeEngine`` (and anything else) wraps
its jitted calls in ``use_backend(...)`` so the choice is baked into each
trace; already-compiled executables are unaffected by later switches.
Backend selection is process-global, not thread-local — concurrent tracing
under different backends is not supported.

**Tensor parallelism** (DESIGN.md §10): ``use_backend(..., mesh=...)``
additionally routes every index-form contraction through ``shard_map`` over
the mesh's ``model`` axis.  The *weights never rematerialize*: only the
narrow integer indices are sharded — column-parallel mats
(``kind='col'``: wq/wk/wv/w1/w3/lm_head) split the output axis, N/tp
indices per shard, no collective; row-parallel mats (``kind='row'``:
wo/w2) split the reduction axis, K/tp indices per shard, one psum of the
(…, N) output.  The codebook (and the lut backend's A×W table, built from
it) replicates — it is tiny by construction.  The ``lut`` row-parallel
psum happens on the **int32 accumulator** (exact: integer addition is
associative), so a TP-sharded lut contraction is bit-identical to the
single-device one; the scale chosen for the full fan-in stays safe for
every K/tp sub-reduction.  Layers whose sharded axis does not divide the
TP degree fall back to replicated compute inside an all-replicated
shard_map (correct, no savings).
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import probes

__all__ = ["BACKENDS", "LutSpec", "BackendSpec", "make_lut_spec",
           "use_backend", "matmul_backend", "matmul_mesh", "backend_matmul",
           "bind_backend", "build_lut_table", "attach_lut_tables",
           "kernel_config", "autotune_shapes", "matmul_call_counts",
           "reset_matmul_call_counts"]

BACKENDS = ("dense", "codebook", "lut")


@dataclasses.dataclass(frozen=True)
class LutSpec:
    """Static description of the §4 integer emulation grid.

    a_min/a_max: activation clip range covered by the level grid.  RMS-normed
                 transformer activations live well inside ±16; anything
                 outside saturates (same posture as the paper's bounded
                 activations).
    levels:      |A| — number of activation levels (grid resolution Δa).
    s:           fixed-point scale exponent, chosen by ``make_lut_spec`` so
                 ``fan_in · max|M|`` statically fits an int32 accumulator
                 (the paper's no-overflow guarantee, core/lut.choose_scale).
    """

    a_min: float
    a_max: float
    levels: int
    s: int

    @property
    def da(self) -> float:
        return (self.a_max - self.a_min) / (self.levels - 1)


def make_lut_spec(codebook, fan_in: int, *, levels: int = 4096,
                  a_range: tuple[float, float] = (-16.0, 16.0),
                  acc_bits: int = 32) -> LutSpec:
    """Pick the largest scale s with a static no-overflow guarantee.

    max|M| = max|a|·max|w|·2^s/Δa and we need fan_in·max|M| < 2^(acc_bits−1)
    — the same bound as ``core.lut.choose_scale`` with the activation grid
    standing in for the level set.
    """
    a_min, a_max = a_range
    da = (a_max - a_min) / (levels - 1)
    wmax = float(np.max(np.abs(np.asarray(codebook, np.float64))))
    amax = max(abs(a_min), abs(a_max))
    headroom = 2.0 ** (acc_bits - 1) - 1
    s = int(np.floor(np.log2(headroom * da / max(fan_in * wmax * amax, 1e-30))))
    if s < 1:
        raise ValueError(
            f"no int{acc_bits} scale fits fan_in={fan_in}, max|w|={wmax:.3g}, "
            f"grid ±{amax}: coarsen the grid or widen the accumulator")
    return LutSpec(a_min=a_min, a_max=a_max, levels=levels, s=s)


def build_lut_table(codebook, spec: LutSpec):
    """The §4 multiplication table M[a, w] = rint(a·w·2^s/Δa) as int32.

    ONE recipe shared by every consumer (engine-time precompute, the TP
    psum path, the trace-time fallback below) — parity across them depends
    on the rounding being identical.  Accepts a single (|W|,) codebook or
    a layer-stacked (L, |W|) one; the activation-grid axis is appended
    second-to-last either way → (|A|, |W|) or (L, |A|, |W|).
    """
    da, s = spec.da, spec.s
    avals = spec.a_min + jnp.arange(spec.levels, dtype=jnp.float32) * da
    scale = (2.0 ** s) / da
    prod = avals[:, None] * codebook.astype(jnp.float32)[..., None, :]
    return jnp.rint(prod * scale).astype(jnp.int32)


def attach_lut_tables(params, spec: LutSpec):
    """Precompute a ``lut_table`` leaf next to every routed index-form dict.

    The table is a pure function of (codebook, grid) but building it inside
    the per-layer ``lax.scan`` body cannot be hoisted by XLA (the scanned
    codebook leaf is a per-iteration slice) — so the lut backend used to
    re-rint the whole |A|×|W| table every layer, every step.  Attaching it
    as a param leaf turns that into a plain HBM operand: stacked (L, |W|)
    codebooks get a stacked (L, |A|, |W|) table the scan slices alongside
    the indices, and ``distributed.sharding.serve_param_specs`` replicates
    any non-w/w_idx leaf, so the table rides through TP untouched (the §10
    psum contract needs every shard to see the identical table).

    The embedding's index form is skipped: its lookup (and the tied
    lm-head) dequantize via the codebook directly, never through
    ``backend_matmul``.
    """
    def walk(node, parts):
        if not isinstance(node, dict):
            return node
        if "w_idx" in node and "codebook" in node \
                and "embed" not in parts and node["w_idx"].ndim >= 2:
            return {**node, "lut_table": build_lut_table(node["codebook"],
                                                         spec)}
        return {k: walk(v, parts + [k]) for k, v in node.items()}

    return walk(params, [])


def kernel_config(kernel: str, m: int, k: int, n: int, *, dtype: str,
                  table_shape: tuple, plat: str | None = None, **kw):
    """Launch config for one contraction site — see ``kernels.autotune``.

    ``plat`` defaults to the live platform class: 'tpu' (compiled Pallas)
    when Mosaic is available, 'xla' (fallback kernels) otherwise.
    """
    from repro.kernels import autotune, ops

    if plat is None:
        plat = "tpu" if ops.supports_compiled_pallas() else "xla"
    return autotune.kernel_config(kernel, m, k, n, dtype=dtype, plat=plat,
                                  table_shape=table_shape, **kw)


def autotune_shapes(shapes, **kw):
    """Batch-tune + persist the cache JSON — see ``kernels.autotune``."""
    from repro.kernels import autotune

    return autotune.autotune_shapes(shapes, **kw)


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """A (backend, lut grid, mesh) triple naming how ONE model's matmuls run.

    Speculative decoding traces TWO models inside one jitted step — e.g. a
    coarse-grid ``lut``-tier draft proposing tokens that a ``codebook``-tier
    target verifies (serving/spec.py).  The ambient backend state is scoped
    and re-entrant, so per-model overrides nest freely within a single
    trace:

        with target.scope():            # e.g. codebook
            ... trace the verify forward ...
            with draft.scope():         # e.g. lut, its own (coarser) grid
                ... trace the draft proposal loop ...

    Each scope applies only to the ops traced under it; the executable that
    comes out runs both models' contractions through their own kernels.
    The one-backend-per-jitted-function rule of ``bind_backend`` still
    holds at the *outer* level: a function whose trace mixes scopes must
    itself be jitted once per (target, draft) pairing.
    """

    name: str = "dense"
    lut_spec: LutSpec | None = None
    mesh: object = None            # None = single-device (draft models)

    def scope(self):
        return use_backend(self.name, self.lut_spec, self.mesh)


class _State:
    backend: str = "dense"
    lut_spec: LutSpec | None = None
    mesh: object = None


_STATE = _State()

# Trace-time dispatch counters: "{backend}.{route}" -> number of
# backend_matmul sites traced through that route (local | col | row |
# replicated).  Process-global like the backend state itself; the serving
# telemetry registry (serving/telemetry.py) reads these as deltas — this
# module must never import serving/.
MATMUL_CALLS: dict = {}


def _count_route(route: str) -> None:
    key = f"{_STATE.backend}.{route}"
    MATMUL_CALLS[key] = MATMUL_CALLS.get(key, 0) + 1


def matmul_call_counts() -> dict:
    """Snapshot of the trace-time route counters."""
    return dict(MATMUL_CALLS)


def reset_matmul_call_counts() -> None:
    MATMUL_CALLS.clear()


def matmul_backend() -> str:
    """The backend active for traces happening right now."""
    return _STATE.backend


def bind_backend(fn, name: str, lut_spec: LutSpec | None = None, mesh=None):
    """A *new* callable running ``fn`` under ``use_backend(name, ...)``.

    jax.jit keys its executable cache on function identity, NOT on this
    module's ambient backend — jitting the same function object under two
    backends would silently reuse the first trace.  Each ``bind_backend``
    call returns a distinct closure, so ``jax.jit(bind_backend(f, b))``
    gets its own cache per backend.  ``ServeEngine`` builds its jitted
    steps this way.
    """
    def bound(*args, **kwargs):
        with use_backend(name, lut_spec, mesh):
            return fn(*args, **kwargs)
    bound.__name__ = f"{getattr(fn, '__name__', 'fn')}[{name}]"
    return bound


@contextlib.contextmanager
def use_backend(name: str, lut_spec: LutSpec | None = None, mesh=None):
    """Route index-form ``dense`` layers through ``name`` while tracing.

    Trace-time state: enter this context around the *tracing* of a jitted
    function (or wrap the function with ``bind_backend`` so every trace is
    covered).  Never jit one function object under two different backends —
    see ``bind_backend``.  ``mesh`` additionally shard-maps every routed
    contraction over the mesh's ``model`` axis (see module docstring).
    """
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; expected one of {BACKENDS}")
    if name == "lut" and lut_spec is None:
        raise ValueError("backend 'lut' needs a LutSpec (make_lut_spec)")
    prev = _STATE.backend, _STATE.lut_spec, _STATE.mesh
    _STATE.backend, _STATE.lut_spec, _STATE.mesh = name, lut_spec, mesh
    try:
        yield
    finally:
        _STATE.backend, _STATE.lut_spec, _STATE.mesh = prev


def matmul_mesh():
    """The mesh index-form contractions are being sharded over (or None)."""
    return _STATE.mesh


def backend_matmul(x, w_idx, codebook, kind: str | None = None, table=None):
    """``x @ codebook[w_idx]`` through the active non-dense backend.

    x: (..., K) float; w_idx: (K, N) integer indices; codebook: (|W|,).
    kind: 'col' | 'row' | None — the layer's TP role per
    ``distributed.sharding.param_specs`` (only consulted when a mesh is
    active; None = replicated compute).  table: optional precomputed
    (|A|, |W|) int32 §4 table (``attach_lut_tables``) — the lut backend
    rebuilds it from the codebook when absent, which is correct but
    re-derives the table inside every layer of a scanned stack.
    Returns (..., N) in x.dtype.  Callers guarantee ``matmul_backend()``
    is not 'dense' (the plain gather+dot lives in models.layers.dense).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if _STATE.mesh is not None and "model" in _STATE.mesh.axis_names \
            and _STATE.mesh.shape["model"] > 1:
        y = _sharded_matmul(x2, w_idx, codebook, kind, _STATE.mesh, table)
    else:
        _count_route("local")
        y = _local_matmul(x2, w_idx, codebook, table)
    # Numerics taps sit here, on the full (pre-shard_map) activations and
    # the decoded output — a no-op unless a probes.layer frame is open.
    probes.tap_matmul(x2, y, _STATE.backend, _STATE.lut_spec)
    return y.reshape(*lead, -1).astype(x.dtype)


def _local_matmul(x2, w_idx, codebook, table=None):
    from repro.kernels import ops  # lazy: keep pallas off the import path

    if _STATE.backend == "codebook":
        return ops.codebook_matmul(x2, w_idx, codebook)
    if _STATE.backend == "lut":
        return _lut_matmul(x2, w_idx, codebook, _STATE.lut_spec, table)
    raise ValueError(f"backend_matmul called with {_STATE.backend!r}")


def _sharded_matmul(x2, w_idx, codebook, kind, mesh, table=None):
    """shard_map the contraction over `model` (Pallas kernels have no SPMD
    partitioning rule, so left to XLA they would replicate and all-gather
    their operands — this keeps only int indices moving, never weights).

    col:  x replicated, w_idx (K, N/tp) → local kernel, output N-sharded.
    row:  x (…, K/tp), w_idx (K/tp, N) → local kernel + one (…, N) psum
          (the lut backend psums the int32 accumulator — exact).
    else: all-replicated shard_map (every shard computes the full product).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compat import shard_map

    backend, spec = _STATE.backend, _STATE.lut_spec
    tp = mesh.shape["model"]
    K, N = w_idx.shape

    def kernel(xl, wl):
        from repro.kernels import ops

        if backend == "codebook":
            return ops.codebook_matmul(xl, wl, codebook)
        return _lut_matmul(xl, wl, codebook, spec, table)

    if kind == "col" and N % tp == 0:
        _count_route("col")
        f = shard_map(kernel, mesh=mesh,
                      in_specs=(P(None, None), P(None, "model")),
                      out_specs=P(None, "model"), check_vma=False)
        return f(x2, w_idx)

    if kind == "row" and K % tp == 0:
        _count_route("row")
        if backend == "lut":
            def body(xl, wl):
                # psum the int32 accumulator, decode the scale once after:
                # integer addition is associative, so the sharded reduction
                # is bit-identical to the single-device contraction (the
                # replicated table means every shard gathers identical
                # entries; the full-fan-in scale stays safe per K/tp slice)
                acc = jax.lax.psum(_lut_acc(xl, wl, codebook, spec, table),
                                   "model")
                return acc.astype(jnp.float32) * (spec.da / (2.0 ** spec.s))
        else:
            def body(xl, wl):
                return jax.lax.psum(kernel(xl, wl), "model")
        f = shard_map(body, mesh=mesh,
                      in_specs=(P(None, "model"), P("model", None)),
                      out_specs=P(None, None), check_vma=False)
        return f(x2, w_idx)

    # replicated fallback (axis does not divide tp, or unannotated site)
    _count_route("replicated")
    f = shard_map(kernel, mesh=mesh,
                  in_specs=(P(None, None), P(None, None)),
                  out_specs=P(None, None), check_vma=False)
    return f(x2, w_idx)


def _lut_acc(x2, w_idx, codebook, spec: LutSpec, table=None):
    """The §4 integer accumulator: snap activations to the level grid,
    gather M[a_idx·C + w_idx], sum in int32 (no decode).

    ``table`` is the precomputed constant of a real deployment
    (``attach_lut_tables`` hangs it off the params).  When absent it is
    rebuilt here from the codebook — same ``build_lut_table`` recipe, so
    the accumulators are bit-identical either way, but the rebuild sits
    inside the layer scan and costs |A|·|W| rints per layer per step.
    """
    from repro.kernels import ops

    da = spec.da
    a_idx = jnp.clip(jnp.round((x2.astype(jnp.float32) - spec.a_min) / da),
                     0, spec.levels - 1).astype(jnp.int32)
    if table is None:
        table = build_lut_table(codebook, spec)              # (|A|, |W|)
    return ops.lut_matmul(a_idx, w_idx, table)


def _lut_matmul(x2, w_idx, codebook, spec: LutSpec, table=None):
    """Faithful §4 contraction: int32 accumulate, decode once at the end."""
    acc = _lut_acc(x2, w_idx, codebook, spec, table)
    return acc.astype(jnp.float32) * (spec.da / (2.0 ** spec.s))
