"""Deterministic, cache-backed kernel autotuning (DESIGN.md §12).

Every routed contraction (``ops.codebook_matmul`` / ``ops.lut_matmul``)
asks this module for its launch config at trace time — block sizes and
unroll for the compiled Pallas kernels on TPU, chunking/variant for the
XLA fallbacks elsewhere.  Shapes are static under jit, so the lookup
happens once per traced shape and folds into the executable.

Selection is a *deterministic cost model* over the candidate space —
padded-tile memory traffic in integer bytes, largest-tile-first
tie-breaking — NOT wall-clock timing.  Two runs over the same shape set
therefore produce byte-identical tuning caches on any machine, which is
what makes the cache CI-replayable (tests/test_autotune.py pins this).
Measured tuning exists as an opt-in (``measure=True``): it times each
candidate on seeded inputs and overrides the model's pick, for operators
bringing the cache up on real hardware; CI never exercises it.

Cache format (``tuning_cache.json``, override via $REPRO_TUNING_CACHE):

    { "<kernel>|<plat>|m{M}k{K}n{N}|<dtype>|t{R}x{C}": {config...}, ... }

keyed on everything the choice depends on — kernel, platform class
(``tpu`` = compiled Pallas, ``xla`` = fallback), problem shape, activation
dtype, table/codebook shape (the table competes for VMEM).  Values are
flat JSON objects of ints/strings; the file is dumped with sorted keys so
it diffs cleanly and byte-compares across runs.
"""

from __future__ import annotations

import json
import os
import pathlib

__all__ = ["kernel_config", "autotune_shapes", "candidates", "model_cost",
           "cache_key", "load_cache", "save_cache", "default_cache_path",
           "clear_memory_cache"]

_VMEM_BUDGET = 12 * 1024 * 1024      # bytes of VMEM a kernel may plan for
_LANE = 128                          # TPU lane count: last-dim tile quantum
_SUBLANE = 8                         # f32 sublane quantum: second-minor tile


def default_cache_path() -> str:
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return env
    return str(pathlib.Path(__file__).with_name("tuning_cache.json"))


def load_cache(path: str | None = None) -> dict:
    p = pathlib.Path(path or default_cache_path())
    if not p.exists():
        return {}
    return json.loads(p.read_text())


def save_cache(cache: dict, path: str | None = None) -> str:
    """Canonical dump: sorted keys, fixed separators — byte-stable."""
    p = pathlib.Path(path or default_cache_path())
    p.write_text(json.dumps(cache, sort_keys=True, indent=1) + "\n")
    return str(p)


def cache_key(kernel: str, plat: str, m: int, k: int, n: int,
              dtype: str, table_shape: tuple) -> str:
    t = "x".join(str(int(d)) for d in table_shape)
    return f"{kernel}|{plat}|m{int(m)}k{int(k)}n{int(n)}|{dtype}|t{t}"


def _ceil_to(x: int, q: int) -> int:
    return -(-x // q) * q


def _tile_sizes(dim: int, quantum: int, cap: int) -> list:
    """Candidate tile sizes for one axis: quantum multiples covering the
    (rounded-up) dim, largest first so equal-cost ties pick the bigger
    tile (fewer grid steps, better MXU/VPU occupancy)."""
    full = _ceil_to(max(dim, 1), quantum)
    out = []
    t = quantum
    while t < min(full, cap):
        out.append(t)
        t *= 2
    out.append(min(full, cap))
    return sorted(set(out), reverse=True)


def candidates(kernel: str, plat: str, m: int, k: int, n: int,
               dtype: str, table_shape: tuple) -> list:
    """Enumerate valid launch configs, preferred-first.

    tpu: (bm, bn, bk) Pallas tiles — bn/bk are lane-dim multiples of 128,
         bm multiples of the f32 sublane (8); everything that must be
         VMEM-co-resident (3 live tiles, double-buffered streams, the
         whole table) has to fit the budget.  lut adds the K-step unroll.
    xla: lut — gather variant ('rows' | 'flat') × K-chunk size; codebook —
         a single fused gather+dot, nothing to tune.
    """
    if plat == "xla":
        if kernel == "lut":
            return [{"impl": "xla", "variant": v, "kc": kc}
                    for v in ("rows", "flat") for kc in (32, 64, 128)
                    ]
        return [{"impl": "xla"}]

    table_bytes = 4
    for d in table_shape:
        table_bytes *= int(d)
    in_bytes = 4 if kernel == "lut" else (2 if dtype == "bfloat16" else 4)
    out = []
    for bm in _tile_sizes(m, _SUBLANE, 256):
        for bn in _tile_sizes(n, _LANE, 512):
            for bk in _tile_sizes(k, _LANE, 512):
                # 2× on the streamed operands: double-buffered DMA windows
                vmem = (2 * (bm * bk + bk * bn) * in_bytes
                        + bm * bn * 4 + table_bytes)
                if kernel == "lut":
                    vmem += bm * bn * 4          # gathered unroll tile
                if vmem > _VMEM_BUDGET:
                    continue
                cfg = {"impl": "pallas", "bm": bm, "bn": bn, "bk": bk}
                if kernel == "lut":
                    cfg["unroll"] = 8
                out.append(cfg)
    return out


def model_cost(kernel: str, cfg: dict, m: int, k: int, n: int,
               dtype: str, table_shape: tuple) -> int:
    """Integer cost of one launch — deterministic across machines.

    Pallas: bytes DMA'd through VMEM over the whole padded grid (streamed
    input tiles per grid step + one output pass + the table once) — the
    memory-bound proxy; padding waste from oversized tiles on ragged dims
    is charged at full price, which is what steers ragged shapes toward
    smaller tiles.  XLA lut: XLA:CPU lowers gather to a scalar loop, so
    the element gathers dominate at ~1 cost unit per looked-up byte
    regardless of variant; 'rows' additionally pays its sequential
    row-copy traffic (so 'flat' wins on the model — 'rows' stays a
    candidate for measured tuning); per-scan-step overhead steers toward
    few chunks, a 4× spill charge on past-L2 intermediates steers large-M
    shapes back to cache-sized chunks.  Constants were fit to in-engine
    A/B timings on the serving shapes (DESIGN.md §12), not first
    principles — the committed tuning cache pins the hot shapes anyway.
    """
    table_bytes = 4
    for d in table_shape:
        table_bytes *= int(d)
    if cfg.get("impl") == "xla":
        if kernel != "lut":
            return 0
        kc = int(cfg["kc"])
        kp = _ceil_to(k, kc)
        ncols = int(table_shape[-1])
        gather = 4 * m * kp * n
        if cfg["variant"] == "rows":
            gather += m * kp * ncols             # sequential row copies
        steps = kp // kc
        inter = 8 * m * kc * (max(ncols, n) if cfg["variant"] == "rows"
                              else n)            # addresses + gathered vals
        spill = 4 * max(inter - (1 << 21), 0)    # past-L2 intermediates
        return gather + steps * 50_000 + spill
    bm, bn, bk = int(cfg["bm"]), int(cfg["bn"]), int(cfg["bk"])
    gm, gn, gk = -(-m // bm), -(-n // bn), -(-k // bk)
    in_bytes = 4 if kernel == "lut" else (2 if dtype == "bfloat16" else 4)
    stream = gm * gn * gk * (bm * bk + bk * bn) * in_bytes
    out_pass = gm * gn * bm * bn * 4
    return stream + out_pass + table_bytes


def _measure(kernel: str, cfg: dict, m: int, k: int, n: int,
             dtype: str, table_shape: tuple, seed: int) -> float:
    """Median wall-clock of one candidate on seeded inputs (opt-in path)."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(seed)
    if kernel == "lut":
        r, c = int(table_shape[0]), int(table_shape[1])
        a = jnp.asarray(rng.integers(0, r, (m, k)), jnp.int32)
        w = jnp.asarray(rng.integers(0, c, (k, n)), jnp.int32)
        t = jnp.asarray(rng.integers(-1000, 1000, (r, c)), jnp.int32)
        from repro.kernels.lut_matmul import lut_matmul_pallas, lut_matmul_xla
        if cfg.get("impl") == "xla":
            fn = lambda: lut_matmul_xla(a, w, t, kc=cfg["kc"],   # noqa: E731
                                        variant=cfg["variant"])
        else:
            fn = lambda: lut_matmul_pallas(                      # noqa: E731
                a, w, t, bm=cfg["bm"], bn=cfg["bn"], bk=cfg["bk"],
                unroll=cfg.get("unroll", 8), interpret=False)
    else:
        w_ = int(table_shape[-1])
        x = jnp.asarray(rng.standard_normal((m, k)),
                        jnp.bfloat16 if dtype == "bfloat16" else jnp.float32)
        wi = jnp.asarray(rng.integers(0, w_, (k, n)), jnp.int32)
        book = jnp.asarray(rng.standard_normal((w_,)), jnp.float32)
        from repro.kernels.codebook_matmul import (codebook_matmul_pallas,
                                                   codebook_matmul_xla)
        if cfg.get("impl") == "xla":
            fn = lambda: codebook_matmul_xla(x, wi, book)        # noqa: E731
        else:
            fn = lambda: codebook_matmul_pallas(                 # noqa: E731
                x, wi, book, bm=cfg["bm"], bn=cfg["bn"], bk=cfg["bk"],
                interpret=False)
    jax.block_until_ready(fn())                                  # compile
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


_MEM: dict = {}            # in-process cache, seeded lazily from the file
_MEM_LOADED = False

# How each kernel_config call resolved — a fixed vocabulary so snapshot
# schemas stay stable.  Process-global (like _MEM, which persists across
# engines); serving telemetry reads these as deltas from attach time.
TUNING_COUNTS = {"explicit_hit": 0, "memory_hit": 0, "model_select": 0,
                 "measured_select": 0}


def tuning_counts() -> dict:
    return dict(TUNING_COUNTS)


def reset_tuning_counts() -> None:
    for k in TUNING_COUNTS:
        TUNING_COUNTS[k] = 0


def clear_memory_cache():
    global _MEM_LOADED
    _MEM.clear()
    _MEM_LOADED = False


def kernel_config(kernel: str, m: int, k: int, n: int, *, dtype: str,
                  plat: str, table_shape: tuple, cache: dict | None = None,
                  measure: bool = False, seed: int = 0) -> dict:
    """The launch config for one (kernel, platform, shape, dtype) site.

    Resolution order: explicit ``cache`` dict → in-process cache (seeded
    from the JSON file on first miss) → cost-model selection (persisted to
    the in-process cache; ``autotune_shapes`` writes it to disk).
    """
    global _MEM_LOADED
    key = cache_key(kernel, plat, m, k, n, dtype, table_shape)
    if cache is not None and key in cache:
        TUNING_COUNTS["explicit_hit"] += 1
        return cache[key]
    if not _MEM_LOADED:
        _MEM.update(load_cache())
        _MEM_LOADED = True
    if cache is None and key in _MEM:
        TUNING_COUNTS["memory_hit"] += 1
        return _MEM[key]
    cands = candidates(kernel, plat, m, k, n, dtype, table_shape)
    if measure:
        TUNING_COUNTS["measured_select"] += 1
        best = min(cands, key=lambda c: _measure(kernel, c, m, k, n, dtype,
                                                 table_shape, seed))
    else:
        TUNING_COUNTS["model_select"] += 1
        # min() is stable: equal-cost ties resolve to the earlier
        # (larger-tile / preferred-variant) candidate — deterministically
        best = min(cands, key=lambda c: model_cost(kernel, c, m, k, n,
                                                   dtype, table_shape))
    (_MEM if cache is None else cache)[key] = best
    return best


def autotune_shapes(shapes, *, path: str | None = None, measure: bool = False,
                    seed: int = 0) -> dict:
    """Tune a shape set and persist the cache JSON; returns the cache.

    ``shapes``: iterable of dicts with keys kernel/plat/m/k/n/dtype/
    table_shape (missing dtype defaults to float32).  Starts from the
    existing file so repeated runs are cumulative and idempotent.
    """
    cache = load_cache(path)
    for s in shapes:
        kernel_config(s["kernel"], s["m"], s["k"], s["n"],
                      dtype=s.get("dtype", "float32"), plat=s["plat"],
                      table_shape=tuple(s["table_shape"]), cache=cache,
                      measure=measure, seed=seed)
    save_cache(cache, path)
    return cache
