"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth the kernels are asserted against
(interpret=True on CPU; real TPU elsewhere).  They are deliberately naive —
clarity over speed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "codebook_matmul_ref",
    "lut_matmul_ref",
    "act_quant_ref",
    "kmeans_assign_ref",
]


def codebook_matmul_ref(x: jnp.ndarray, w_idx: jnp.ndarray,
                        codebook: jnp.ndarray) -> jnp.ndarray:
    """out = x @ codebook[w_idx]  — dequantize-then-matmul ground truth.

    x: (M, K) float; w_idx: (K, N) int; codebook: (W,) float. out: (M, N) f32.
    """
    w = codebook[w_idx.astype(jnp.int32)].astype(x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def lut_matmul_ref(a_idx: jnp.ndarray, w_idx: jnp.ndarray,
                   table: jnp.ndarray) -> jnp.ndarray:
    """acc[m, n] = Σ_k table[a_idx[m, k], w_idx[k, n]]  (paper §4 engine).

    a_idx: (M, K) int32; w_idx: (K, N) int32; table: (R, C) int32.
    """
    flat = table.reshape(-1)
    n_cols = table.shape[1]
    gathered = flat[a_idx[:, :, None] * n_cols + w_idx[None, :, :]]
    return jnp.sum(gathered, axis=1)


def act_quant_ref(x: jnp.ndarray, kind: str, levels: int) -> jnp.ndarray:
    """Quantized activation values (forward semantics only)."""
    from repro.core.activations import ActQuantConfig, act_apply
    return act_apply(ActQuantConfig(kind, levels), x)


def kmeans_assign_ref(values: jnp.ndarray, centers: jnp.ndarray):
    """(assignment, per-center sum, per-center count) for sorted centers."""
    boundaries = (centers[:-1] + centers[1:]) / 2.0
    idx = jnp.searchsorted(boundaries, values, side="right").astype(jnp.int32)
    k = centers.shape[0]
    sums = jax.ops.segment_sum(values.astype(jnp.float32), idx, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones_like(values, jnp.float32), idx,
                                 num_segments=k)
    return idx, sums, counts
