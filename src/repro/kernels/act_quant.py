"""Fused quantized-activation kernel (paper §2.1 forward semantics).

Computes ``q(f(x))`` — the underlying bounded nonlinearity followed by
round-to-level in output space — in one VMEM pass, optionally also emitting
the int32 level index (the row index for the §4 LUT engine).  Elementwise,
so the only tiling concern is lane alignment; blocks default to (256, 256).

The backward pass (underlying-function derivative) is attached in ``ops.py``
via ``jax.custom_vjp`` — the kernel itself is forward-only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["act_quant_kernel", "act_quant_pallas"]


def _base(kind: str, x):
    if kind == "tanh":
        return jnp.tanh(x)
    if kind == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if kind == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-x))
    if kind == "rtanh":
        return jnp.maximum(jnp.tanh(x), 0.0)
    raise ValueError(kind)


def act_quant_kernel(x_ref, y_ref, idx_ref, *, kind: str, levels: int,
                     lo: float, step: float):
    y = _base(kind, x_ref[...].astype(jnp.float32))
    q = jnp.round((y - lo) / step)
    q = jnp.clip(q, 0.0, levels - 1)
    y_ref[...] = (lo + q * step).astype(y_ref.dtype)
    idx_ref[...] = q.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("kind", "levels", "bm", "bn", "interpret"))
def act_quant_pallas(x: jnp.ndarray, *, kind: str, levels: int,
                     bm: int = 256, bn: int = 256,
                     interpret: bool = True):
    """Returns (quantized values, level indices); forward-only semantics.

    x is flattened to 2-D, padded to block multiples, and restored.
    """
    from repro.core.activations import ACT_RANGES
    lo, hi = ACT_RANGES[kind]
    step = (hi - lo) / (levels - 1)

    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = bn
    rows = -(-n // cols)
    pad = rows * cols - n
    x2 = jnp.pad(flat, (0, pad)).reshape(rows, cols)
    rp = (-rows) % bm
    if rp:
        x2 = jnp.pad(x2, ((0, rp), (0, 0)))

    grid = (x2.shape[0] // bm, x2.shape[1] // bn)
    y2, idx2 = pl.pallas_call(
        functools.partial(act_quant_kernel, kind=kind, levels=levels,
                          lo=lo, step=step),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                   pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct(x2.shape, x.dtype),
                   jax.ShapeDtypeStruct(x2.shape, jnp.int32)],
        interpret=interpret,
    )(x2)
    y = y2.reshape(-1)[:n].reshape(shape)
    idx = idx2.reshape(-1)[:n].reshape(shape)
    return y, idx
