"""Faithful §4 integer engine as a tiled Pallas kernel (DESIGN.md §12).

acc[m, n] = Σ_k  M[a_idx[m, k], w_idx[k, n]]

Both operands are *indices*; the multiplication table M is VMEM-resident
(flattened for a single-gather address computation ``a·C + w``).  The grid
is ``(⌈M/bm⌉, ⌈N/bn⌉, ⌈K/bk⌉)`` with K innermost, so each (bm, bn) int32
accumulator tile stays resident in VMEM across the whole K sweep and the
table — whose BlockSpec index map is constant — is DMA'd exactly once and
then revisited from fast memory by every grid step (Pallas only re-fetches
a block when its index map moves; with the K dimension marked ``arbitrary``
the other operand streams are double-buffered behind the gather work).

Ragged shapes are handled by *explicit masking*, not implicit padding:

* K tail: a per-element ``k < K`` mask zeroes the padded contributions
  (the old wrapper padded with (row 0, col 0) pairs and subtracted
  ``pad·table[0,0]`` afterwards — correct only while the pad indices were
  actually zero-filled).
* M/N edges: loads beyond the array edge are undefined on TPU, so every
  gather address is clamped into the table before the lookup; the out-of-
  range rows/columns of the output tile are dropped by Pallas' masked
  edge-block stores.

The K loop walks ``unroll`` steps per ``fori_loop`` iteration so the
gathered intermediate is ``unroll`` (bm, bn) tiles rather than a
(bm, bk, bn) cube — VMEM stays bounded by 3 tiles + the table.

On a real TPU this runs on the VPU (gathers + int adds; the MXU is idle) —
it is the *faithful artifact* proving the multiply-free dataflow, not the
deployment path (that is ``codebook_matmul``, DESIGN.md §2).  Off-TPU the
serving path takes ``lut_matmul_xla`` below — the same gather-accumulate
contraction expressed as XLA ops (bit-identical: integer addition is
associative, so any accumulation order gives the same int32 sums) — because
interpret-mode Pallas re-enters the grid per block at HLO level, which is
orders of magnitude slower than one fused XLA gather.  Parity between the
two (and the jnp oracle in ``kernels.ref``) is exact and property-tested.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lut_matmul_kernel", "lut_matmul_pallas", "lut_matmul_xla"]


def _canonical_idx(idx, n: int):
    """int32 ids in [0, n) — narrow dtypes store ids ≥ 2^(bits-1) as
    negatives (two's complement); the flat address arithmetic must not."""
    idx = idx.astype(jnp.int32)
    return jnp.where(idx < 0, idx + n, idx)


def lut_matmul_kernel(a_ref, w_ref, table_ref, out_ref, *,
                      bk: int, k_total: int, unroll: int):
    """One (bm, bn) int32 accumulator tile, revisited across the K grid."""
    kg = pl.program_id(2)

    @pl.when(kg == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    flat = table_ref[0, :]                          # (R*C,) int32, resident
    a_blk = a_ref[...]                              # (bm, bk) int32, pre-·C
    w_blk = w_ref[...]                              # (bk, bn) int32
    size = flat.shape[0]
    base = kg * bk

    def step(acc, kk):
        # clamp: edge-block loads are undefined on TPU; any address they
        # produce is pulled into the table, and the mask / masked store
        # guarantees the value never lands in a live accumulator cell
        addr = jnp.clip(a_blk[:, kk][:, None] + w_blk[kk, :][None, :],
                        0, size - 1)                # (bm, bn)
        g = jnp.take(flat, addr, axis=0, mode="clip")
        return acc + jnp.where(base + kk < k_total, g, 0)

    def body(i, acc):
        for u in range(unroll):                     # trace-time unroll
            acc = step(acc, i * unroll + u)
        return acc

    acc = jax.lax.fori_loop(0, bk // unroll, body, jnp.zeros_like(out_ref))
    out_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "unroll", "interpret"))
def lut_matmul_pallas(a_idx: jnp.ndarray, w_idx: jnp.ndarray,
                      table: jnp.ndarray, *,
                      bm: int = 128, bn: int = 128, bk: int = 128,
                      unroll: int = 8, interpret: bool = True) -> jnp.ndarray:
    """a_idx: (M, K) int rows of the table; w_idx: (K, N) int columns;
    table: (R, C) int32.  Returns (M, N) int32 accumulators.

    Dims need not be multiples of the block sizes — edge blocks are masked
    inside the kernel (module docstring).  The row index is pre-multiplied
    by C outside the kernel (one integer multiply per *index*, amortised —
    the per-MAC path stays multiply-free; on-device this constant-stride
    scaling is an address computation).
    """
    m, k = a_idx.shape
    k2, n = w_idx.shape
    assert k == k2, (a_idx.shape, w_idx.shape)
    rows, n_cols = table.shape
    a_scaled = _canonical_idx(a_idx, rows) * n_cols
    w_can = _canonical_idx(w_idx, n_cols)
    flat = table.reshape(1, -1).astype(jnp.int32)
    while bk % unroll:
        unroll //= 2

    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    kernel = functools.partial(lut_matmul_kernel, bk=bk, k_total=k,
                               unroll=max(unroll, 1))
    kwargs = {}
    if not interpret:       # TPU: m,n parallel; K revisits the accumulator
        from jax.experimental.pallas import tpu as pltpu
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, flat.shape[1]), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
        **kwargs,
    )(a_scaled, w_can, flat)
    return out


@functools.partial(jax.jit, static_argnames=("kc", "variant"))
def lut_matmul_xla(a_idx: jnp.ndarray, w_idx: jnp.ndarray,
                   table: jnp.ndarray, *, kc: int = 64,
                   variant: str = "rows") -> jnp.ndarray:
    """The identical contraction as fused XLA gathers (off-TPU fast path).

    variant 'rows' gathers each (m, k) pair's table *row* first — (M, K, C)
    sequential row copies that stay L1-resident for the inner (m, k, n)
    lookup — then indexes along C with ``w_idx``; 'flat' computes the
    ``a·C + w`` flat address directly (fewer intermediates, random access
    into the full R·C table).  ``kc`` chunks the K axis through a
    ``lax.scan`` so the (M, kc, N) gathered intermediate is cache-sized
    instead of materialising the full (M, K, N) cube.  All variants produce
    bit-identical int32 accumulators (integer addition is associative).
    """
    m, k = a_idx.shape
    n = w_idx.shape[1]
    rows, n_cols = table.shape
    a_can = _canonical_idx(a_idx, rows)
    w_can = _canonical_idx(w_idx, n_cols)
    table = table.astype(jnp.int32)
    kc = min(kc, k)

    def chunk_sum(ab, wb, kmask):
        """Masked Σ over one K chunk; kmask zeroes the ragged tail
        explicitly (no pad-and-correct)."""
        if variant == "flat":
            addr = ab[:, :, None] * n_cols + wb[None, :, :]
            g = jnp.take(table.reshape(-1), addr, axis=0, mode="clip")
        else:
            rowvals = jnp.take(table, ab, axis=0, mode="clip")  # (M, kc, C)
            idx = jnp.broadcast_to(wb[None], (ab.shape[0],) + wb.shape)
            g = jnp.take_along_axis(rowvals, idx, axis=2, mode="clip")
        return jnp.sum(jnp.where(kmask[None, :, None], g, 0), axis=1)

    pad = (-k) % kc
    if pad:
        a_can = jnp.pad(a_can, ((0, 0), (0, pad)))
        w_can = jnp.pad(w_can, ((0, pad), (0, 0)))
    kt = k + pad
    if kt == kc:
        acc = chunk_sum(a_can, w_can, jnp.arange(kc) < k)
    else:
        def body(acc, k0):
            ab = jax.lax.dynamic_slice_in_dim(a_can, k0, kc, 1)
            wb = jax.lax.dynamic_slice_in_dim(w_can, k0, kc, 0)
            return acc + chunk_sum(ab, wb, k0 + jnp.arange(kc) < k), None
        acc, _ = jax.lax.scan(body, jnp.zeros((m, n), jnp.int32),
                              jnp.arange(0, kt, kc))
    return acc
