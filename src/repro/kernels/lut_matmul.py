"""Faithful §4 integer engine as a Pallas kernel.

acc[m, n] = Σ_k  M[a_idx[m, k], w_idx[k, n]]

Both operands are *indices*; the multiplication table M is VMEM-resident
(flattened for a single-gather address computation ``a·C + w``).  The inner
loop walks the K block one step at a time so the gathered intermediate is a
(bm, bn) tile rather than a (bm, bk, bn) cube — VMEM stays bounded by
3 tiles + the table.

On a real TPU this runs on the VPU (gathers + int adds; the MXU is idle) —
it is the *faithful artifact* proving the multiply-free dataflow, not the
deployment path (that is ``codebook_matmul``, DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lut_matmul_kernel", "lut_matmul_pallas"]


def lut_matmul_kernel(a_ref, w_ref, table_ref, out_ref, *, bk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    flat = table_ref[0, :]                          # (R*C,) int32
    a_blk = a_ref[...]                              # (bm, bk) int32
    w_blk = w_ref[...]                              # (bk, bn) int32

    def body(kk, acc):
        addr = a_blk[:, kk][:, None] + w_blk[kk, :][None, :]  # (bm, bn)
        return acc + jnp.take(flat, addr, axis=0)

    acc = jax.lax.fori_loop(0, bk, body, jnp.zeros_like(out_ref))
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_matmul_pallas(a_idx: jnp.ndarray, w_idx: jnp.ndarray,
                      table: jnp.ndarray, *,
                      bm: int = 128, bn: int = 128, bk: int = 128,
                      interpret: bool = True) -> jnp.ndarray:
    """a_idx: (M, K) int32 rows of the table; w_idx: (K, N) int32 columns;
    table: (R, C) int32.  Returns (M, N) int32 accumulators.

    The row index is pre-multiplied by C outside the kernel (one integer
    multiply per *index*, amortised — the per-MAC path stays multiply-free;
    on-device this constant-stride scaling is an address computation).
    K is padded with (row 0, col 0) pairs and corrected by −pad·table[0,0].
    """
    m, k = a_idx.shape
    k2, n = w_idx.shape
    assert k == k2
    n_cols = table.shape[1]
    a_scaled = a_idx.astype(jnp.int32) * n_cols
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        a_scaled = jnp.pad(a_scaled, ((0, mp), (0, kp)))
    if kp or np_:
        w_idx = jnp.pad(w_idx.astype(jnp.int32), ((0, kp), (0, np_)))
    flat = table.reshape(1, -1).astype(jnp.int32)

    grid = (a_scaled.shape[0] // bm, w_idx.shape[1] // bn,
            a_scaled.shape[1] // bk)
    out = pl.pallas_call(
        functools.partial(lut_matmul_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, flat.shape[1]), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a_scaled.shape[0], w_idx.shape[1]),
                                       jnp.int32),
        interpret=interpret,
    )(a_scaled, w_idx, flat)
    out = out[:m, :n]
    if kp:  # remove the padded (row 0, col 0) contributions
        out = out - kp * table[0, 0].astype(jnp.int32)
    return out
