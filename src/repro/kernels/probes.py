"""In-graph numerics probes: the functional core (DESIGN.md §14).

The paper's claim is that discretization loses nothing — this module is
how the serving stack *measures* that at runtime instead of asserting it.
Every silent ``clip`` in the kernels (activation snapping to the §4 level
grid, int8 KV rounding, page-table canonicalization) gets a counter, and
the counters ride the jitted forward as ordinary fixed-shape arrays:

* **State** is a flat dict of (L,)-per-layer and scalar float32 counters
  (``init_state``).  It threads through ``lax.scan``/``lax.while_loop``
  carries like any other cache plane — no host sync, no ``io_callback``.
  An *empty* dict is the off state: it contributes zero pytree leaves, so
  the traced program is bit-identical to an uninstrumented one.
* **Recording** is trace-time ambient: a scan body opens a collector
  frame (``layer(state, l)``), the tap helpers called from arbitrarily
  deep code (``kernels.dispatch``, ``models.attention.quantize_kv``,
  ``models.layers.ffn_act``) append their values to the innermost frame,
  and the frame merges them into the carried state at layer ``l`` on
  exit.  With no frame open every tap is a no-arg early return — XLA
  never sees the instrumentation.
* **Nested-trace guard**: a frame remembers the JAX trace it was opened
  under and taps fired from a *different* trace (an inner ``lax.scan``
  such as flash-attention's KV streaming, a ``shard_map`` body such as
  the MoE dispatch or the TP attention paths) silently no-op — recording
  across trace boundaries would leak tracers.  Shard-mapped sites are
  instead covered from outside the ``shard_map`` (see
  ``dispatch.backend_matmul``) or documented as uncovered.

Counter semantics (all float32; sums are exact below 2^24 events — the
precision caveat of long-horizon totals is documented in DESIGN.md §14):

    act_sat / act_total  (L,)  elements outside the activation grid /
                               elements seen (lut a_min..a_max snapping +
                               the relu6 act-quant rails)
    acc_max              (L,)  high-water max |int32 accumulator| of the
                               lut contraction, derived from the decoded
                               output (|y|·2^s/Δa — exact to f32)
    kv_err_max/_sum/_cnt (L,)  int8 KV round-trip |dequant − orig|
    matmul_calls         (L,)  routed backend_matmul sites traced
    page_oob             ()    page-table ids outside [0, n_pages)
    tokens               ()    token positions processed

This module must stay importable from ``models/`` and ``kernels/`` —
it depends on jax only, never on ``serving/`` (the serving-side summary,
static index audit, and drift sentinels live in ``serving.probes``).
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

__all__ = ["init_state", "layer", "bump", "active", "record",
           "tap_matmul", "tap_kv", "tap_act", "PER_LAYER", "MAXES",
           "SCALARS"]

PER_LAYER = ("act_sat", "act_total", "acc_max", "kv_err_max", "kv_err_sum",
             "kv_err_cnt", "matmul_calls")
MAXES = ("acc_max", "kv_err_max")
SCALARS = ("page_oob", "tokens")


def init_state(n_layers: int) -> dict:
    """Fresh all-zero probe state: (L,) per-layer counters + scalars.
    Each key gets its OWN buffer — the state is threaded through donating
    jits, and aliased leaves would be donated twice."""
    st = {k: jnp.zeros((n_layers,), jnp.float32) for k in PER_LAYER}
    st.update({k: jnp.zeros((), jnp.float32) for k in SCALARS})
    return st


# --- ambient collector frames ------------------------------------------------

class _Frame:
    __slots__ = ("token", "recs")

    def __init__(self, token):
        self.token = token
        self.recs: dict[str, list] = {}


_FRAMES: list[_Frame] = []


def _cur_trace():
    """Identity of the innermost JAX trace being built right now.  Used
    to fence recording to the frame's own trace; on a JAX without the
    API the guard degrades to always-match (taps under nested traces
    would then raise a leak error instead of silently skipping)."""
    try:
        return jax.core.trace_ctx.trace
    except AttributeError:      # pragma: no cover - jax version drift
        return None


def active() -> bool:
    """True when a collector frame is open for the *current* trace —
    the cheap gate every tap checks before computing anything."""
    return bool(_FRAMES) and _FRAMES[-1].token is _cur_trace()


def record(name: str, value) -> None:
    """Append one value to the innermost frame (no-op when inactive)."""
    if not active():
        return
    _FRAMES[-1].recs.setdefault(name, []).append(value)


def _merge(state: dict, recs: dict, l) -> dict:
    """Fold a frame's recordings into the carried state at layer ``l``."""
    out = dict(state)
    for name, vals in recs.items():
        cur = out.get(name)
        if cur is None or cur.ndim != 1:
            continue
        vs = [jnp.asarray(v, jnp.float32) for v in vals]
        acc = vs[0]
        if name in MAXES:
            for v in vs[1:]:
                acc = jnp.maximum(acc, v)
            out[name] = cur.at[l].max(acc)
        else:
            for v in vs[1:]:
                acc = acc + v
            out[name] = cur.at[l].add(acc)
    return out


class _Box:
    """Mutable result slot: ``with layer(ps, l) as pb: ...`` leaves the
    merged state in ``pb.state`` after the block exits."""

    __slots__ = ("state",)

    def __init__(self, state):
        self.state = state


@contextlib.contextmanager
def layer(state: dict, l):
    """Collector frame for one scanned layer body.  ``state`` empty →
    inert (no frame, no ops); otherwise taps fired under this frame are
    merged into ``state`` at index ``l`` when the block exits."""
    box = _Box(state)
    if not state:
        yield box
        return
    fr = _Frame(_cur_trace())
    _FRAMES.append(fr)
    try:
        yield box
    finally:
        _FRAMES.pop()
    box.state = _merge(state, fr.recs, l)


def bump(state: dict, name: str, v) -> dict:
    """Direct scalar-counter update (no frame needed) — for quantities
    available at the top of a traced function (page tables, token
    counts).  No-op on the empty state."""
    if not state or name not in state:
        return state
    return {**state, name: state[name] + jnp.asarray(v, jnp.float32)}


# --- tap helpers (call sites in dispatch / attention / layers) ---------------

def tap_matmul(x2, y, backend: str, spec) -> None:
    """One routed backend_matmul: call count, lut grid saturation on the
    *full* (pre-shard_map) activations, and the int32 accumulator
    high-water decoded from the output (|acc| = |y|·2^s/Δa — y is the
    accumulator times a power-of-two-scaled constant, so the round-trip
    is exact up to f32 resolution of the accumulator itself)."""
    if not active():
        return
    record("matmul_calls", 1.0)
    if backend == "lut" and spec is not None:
        xf = x2.astype(jnp.float32)
        record("act_sat", jnp.sum((xf < spec.a_min)
                                  | (xf > spec.a_max)).astype(jnp.float32))
        record("act_total", float(x2.size))
        scale = (2.0 ** spec.s) / spec.da
        record("acc_max",
               jnp.round(jnp.max(jnp.abs(y.astype(jnp.float32))) * scale))


def tap_kv(t, q, scale) -> None:
    """int8 KV round-trip error at one quantize_kv call site: the error
    the *reader* actually sees (dequantized through the stored bf16
    scale), max + sum + count per layer."""
    if not active():
        return
    deq = q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
    err = jnp.abs(deq - t.astype(jnp.float32))
    record("kv_err_max", jnp.max(err))
    record("kv_err_sum", jnp.sum(err))
    record("kv_err_cnt", float(err.size))


def tap_act(x, lo: float, hi: float) -> None:
    """act_quant saturation: pre-activation elements outside the bounded
    kind's output rails (relu6: [0, 6]) — the inputs the quantized
    nonlinearity pins to an endpoint level."""
    if not active():
        return
    xf = x.astype(jnp.float32)
    record("act_sat", jnp.sum((xf < lo) | (xf > hi)).astype(jnp.float32))
    record("act_total", float(x.size))
