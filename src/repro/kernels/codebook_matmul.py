"""TPU-native codebook matmul: ``out = x @ codebook[w_idx]``.

This is the paper's §4 insight re-expressed for the TPU memory hierarchy:
weights live in HBM as *narrow integer indices* (int8 for |W|≤256, int16 up
to 65536) while the |W|-entry f32/bf16 codebook is tiny and VMEM-resident.
Each grid step:

  HBM → VMEM   x tile (bm×bk, bf16/f32) and w_idx tile (bk×bn, int8/16)
  VMEM         gather: w = codebook[w_idx]   (VPU)
  MXU          acc += x_tile @ w_tile        (f32 accumulation)

HBM weight traffic drops 2–4× vs bf16 (4–8× vs f32), which is the roofline
win for memory-bound decode shapes.  The multiply itself is free on the MXU —
the *no-multiply* property of the paper does not transfer to TPU, the
*no-weight-memory* property does (DESIGN.md §2).

Grid is (M/bm, N/bn, K/bk) with K innermost so the f32 accumulator tile
stays resident in VMEM across the K sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["codebook_matmul_kernel", "codebook_matmul_pallas"]


def codebook_matmul_kernel(x_ref, idx_ref, book_ref, out_ref):
    """One (bm, bn) output tile; revisited across the K grid dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...].astype(jnp.int32)           # (bk, bn)
    book = book_ref[0, :]                          # (W,) — whole codebook
    w = jnp.take(book, idx, axis=0)                # dequantize in VMEM
    out_ref[...] += jnp.dot(x_ref[...], w.astype(x_ref.dtype),
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def codebook_matmul_pallas(x: jnp.ndarray, w_idx: jnp.ndarray,
                           codebook: jnp.ndarray, *,
                           bm: int = 128, bn: int = 128, bk: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """x: (M, K) float; w_idx: (K, N) int8/int16/int32; codebook: (W,).

    Dims need not be multiples of the block sizes — inputs are zero/0-index
    padded (zero x rows null out garbage gathers) and the result is sliced.
    """
    m, k = x.shape
    k2, n = w_idx.shape
    assert k == k2, (x.shape, w_idx.shape)
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        x = jnp.pad(x, ((0, mp), (0, kp)))
    if kp or np_:
        w_idx = jnp.pad(w_idx, ((0, kp), (0, np_)))
    book2d = codebook.reshape(1, -1).astype(jnp.float32)

    grid = (x.shape[0] // bm, w_idx.shape[1] // bn, x.shape[1] // bk)
    out = pl.pallas_call(
        codebook_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, book2d.shape[1]), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w_idx.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(x, w_idx, book2d)
    return out[:m, :n]
