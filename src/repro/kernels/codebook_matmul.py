"""TPU-native codebook matmul: ``out = x @ codebook[w_idx]`` (DESIGN.md §12).

This is the paper's §4 insight re-expressed for the TPU memory hierarchy:
weights live in HBM as *narrow integer indices* (int8 for |W|≤256, int16 up
to 65536) while the |W|-entry f32/bf16 codebook is tiny and VMEM-resident.
Each grid step:

  HBM → VMEM   x tile (bm×bk, bf16/f32) and w_idx tile (bk×bn, int8/16)
  VMEM         gather: w = codebook[w_idx]   (VPU)
  MXU          acc += x_tile @ w_tile        (f32 accumulation)

HBM weight traffic drops 2–4× vs bf16 (4–8× vs f32), which is the roofline
win for memory-bound decode shapes.  The multiply itself is free on the MXU —
the *no-multiply* property of the paper does not transfer to TPU, the
*no-weight-memory* property does (DESIGN.md §2).

Grid is ``(⌈M/bm⌉, ⌈N/bn⌉, ⌈K/bk⌉)`` with K innermost so the f32
accumulator tile stays VMEM-resident across the K sweep; the codebook's
BlockSpec index map is constant, so it is DMA'd once and revisited from
VMEM by every grid step while the x / w_idx streams double-buffer behind
the MXU (K marked ``arbitrary``, m/n ``parallel``).

Ragged shapes use *explicit masking*, not implicit padding: the K tail of
both operands is zeroed inside the kernel (0·0 contributes nothing to the
accumulator — and masking both sides means a TPU edge block's undefined
values can never surface as NaN·0), gather indices are clamped into the
codebook, and M/N edge tiles are trimmed by Pallas' masked edge stores.

Off-TPU the serving path takes ``codebook_matmul_xla`` — the same
dequantize-in-registers gather feeding one fused XLA dot (CPU has no
separate fast-memory tier for the codebook to exploit, so the Pallas block
walk only adds overhead there).  Parity against the Pallas kernel and the
``kernels.ref`` oracle is property-tested to f32 reduction-order tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["codebook_matmul_kernel", "codebook_matmul_pallas",
           "codebook_matmul_xla"]


def _canonical_idx(idx, n: int):
    """int32 ids in [0, n) — narrow dtypes store ids ≥ 2^(bits-1) as
    negatives (two's complement)."""
    idx = idx.astype(jnp.int32)
    return jnp.where(idx < 0, idx + n, idx)


def codebook_matmul_kernel(x_ref, idx_ref, book_ref, out_ref, *,
                           bk: int, k_total: int):
    """One (bm, bn) f32 accumulator tile; revisited across the K grid."""
    kg = pl.program_id(2)

    @pl.when(kg == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...].astype(jnp.int32)           # (bk, bn)
    book = book_ref[0, :]                          # (|W|,) — VMEM-resident
    w = jnp.take(book, jnp.clip(idx, 0, book.shape[0] - 1), axis=0,
                 mode="clip")                      # dequantize in VMEM
    # explicit ragged-K masks on BOTH operands: an edge block's undefined
    # lanes (TPU) might be NaN, and NaN·0 would poison the accumulator
    kw = jax.lax.broadcasted_iota(jnp.int32, w.shape, 0) + kg * bk
    w = jnp.where(kw < k_total, w, 0.0)
    x = x_ref[...]
    kx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + kg * bk
    x = jnp.where(kx < k_total, x, jnp.zeros_like(x))
    out_ref[...] += jnp.dot(x, w.astype(x.dtype),
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def codebook_matmul_pallas(x: jnp.ndarray, w_idx: jnp.ndarray,
                           codebook: jnp.ndarray, *,
                           bm: int = 128, bn: int = 128, bk: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """x: (M, K) float; w_idx: (K, N) int8/int16/int32; codebook: (W,).

    Dims need not be multiples of the block sizes — edge blocks are masked
    inside the kernel (module docstring), never padded by the wrapper.
    """
    m, k = x.shape
    k2, n = w_idx.shape
    assert k == k2, (x.shape, w_idx.shape)
    w_can = _canonical_idx(w_idx, codebook.shape[-1])
    book2d = codebook.reshape(1, -1).astype(jnp.float32)

    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    kwargs = {}
    if not interpret:       # TPU: m,n parallel; K revisits the accumulator
        from jax.experimental.pallas import tpu as pltpu
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    out = pl.pallas_call(
        functools.partial(codebook_matmul_kernel, bk=bk, k_total=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, book2d.shape[1]), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(x, w_can, book2d)
    return out


@jax.jit
def codebook_matmul_xla(x: jnp.ndarray, w_idx: jnp.ndarray,
                        codebook: jnp.ndarray) -> jnp.ndarray:
    """The same contraction as one fused XLA gather + dot (off-TPU path).

    The |W|-entry codebook gather is L1-resident on any CPU; XLA fuses it
    into the dot's packing pass, so this runs at dense-matmul speed while
    HBM/DRAM still only ever holds the narrow indices.
    """
    w_can = _canonical_idx(w_idx, codebook.shape[-1])
    w = jnp.take(codebook.astype(jnp.float32), w_can, axis=0,
                 mode="clip").astype(x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)
