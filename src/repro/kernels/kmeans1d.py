"""Streaming 1-D k-means assignment + partial centroid sums (paper §2.2).

The periodic clustering event must assign up to ~10⁸ weights to |W| sorted
centers and compute per-center sums/counts — a pure HBM-bandwidth-bound
streaming reduction, ideal for a fused kernel: the (|W|−1) boundaries stay
in VMEM while weight blocks stream through once, emitting partial sums that
the host (or a follow-up reduce) combines into new centroids.

Assignment uses chunked broadcast-compare (rank = Σ 1[v > boundary]) — a
`searchsorted` without data-dependent control flow, VPU-friendly.  Partial
sums use a one-hot-mask matmul over center chunks (MXU-friendly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["kmeans_assign_kernel", "kmeans_assign_pallas"]

_CHUNK = 128  # boundary/center chunk width (lane-aligned)


def kmeans_assign_kernel(v_ref, b_ref, idx_ref, sums_ref, counts_ref, *,
                         k: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    v = v_ref[0, :].astype(jnp.float32)             # (bv,)
    bounds = b_ref[0, :]                            # (kb,) padded with +inf

    # rank of each value among boundaries = assignment index
    idx = jnp.zeros_like(v, dtype=jnp.int32)
    n_chunks = bounds.shape[0] // _CHUNK
    for c in range(n_chunks):                       # static unroll
        chunk = jax.lax.dynamic_slice_in_dim(bounds, c * _CHUNK, _CHUNK)
        idx += jnp.sum(v[:, None] >= chunk[None, :], axis=1).astype(jnp.int32)
    idx_ref[0, :] = idx

    # partial sums/counts via one-hot matmuls over center chunks
    kc = sums_ref.shape[1] // _CHUNK
    for c in range(kc):                             # static unroll
        ids = c * _CHUNK + jax.lax.broadcasted_iota(jnp.int32, (1, _CHUNK), 1)
        mask = (idx[:, None] == ids).astype(jnp.float32)       # (bv, 128)
        sums_ref[0, c * _CHUNK:(c + 1) * _CHUNK] += v @ mask
        counts_ref[0, c * _CHUNK:(c + 1) * _CHUNK] += jnp.sum(mask, axis=0)


@functools.partial(jax.jit, static_argnames=("bv", "interpret"))
def kmeans_assign_pallas(values: jnp.ndarray, centers: jnp.ndarray, *,
                         bv: int = 4096, interpret: bool = True):
    """values: (n,) float; centers: (k,) sorted.  Returns (idx, sums, counts).

    Padding: values padded with +inf (assigned to the last center) and the
    pad contribution removed from sums/counts afterwards; boundaries padded
    with +inf to a 128 multiple (never exceeded by real values).
    """
    v = values.reshape(-1).astype(jnp.float32)
    n = v.shape[0]
    k = centers.shape[0]
    bounds = (centers[:-1] + centers[1:]) / 2.0
    # strictly more boundary slots than real boundaries, so at least one BIG
    # pad boundary exists and padded values rank past every real center
    kb = (bounds.shape[0] // _CHUNK + 1) * _CHUNK
    # boundary padding BIG and value padding 2·BIG: padded values rank past
    # every real center (idx ≥ kb ≥ k) so they fall outside all sum chunks —
    # exact exclusion with no correction arithmetic (finite, so the masked
    # matmul never produces inf·0).  Assumes |values| < BIG.
    BIG = jnp.float32(1e30)
    bounds = jnp.pad(bounds.astype(jnp.float32),
                     (0, kb - bounds.shape[0]), constant_values=BIG)
    kk = -(-k // _CHUNK) * _CHUNK

    pad = (-n) % bv
    vp = jnp.concatenate([v, jnp.broadcast_to(2 * BIG, (pad,))]) if pad else v
    grid = (vp.shape[0] // bv,)
    idx, sums, counts = pl.pallas_call(
        functools.partial(kmeans_assign_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bv), lambda i: (0, i)),
                  pl.BlockSpec((1, kb), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, bv), lambda i: (0, i)),
                   pl.BlockSpec((1, kk), lambda i: (0, 0)),
                   pl.BlockSpec((1, kk), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, vp.shape[0]), jnp.int32),
                   jax.ShapeDtypeStruct((1, kk), jnp.float32),
                   jax.ShapeDtypeStruct((1, kk), jnp.float32)],
        interpret=interpret,
    )(vp.reshape(1, -1), bounds.reshape(1, -1))
    idx = idx[0, :n]
    sums, counts = sums[0, :k], counts[0, :k]
    return idx, sums, counts
