"""Public ops for the kernels package: jit'd wrappers + gradients.

The matmul ops are *routed* per platform (DESIGN.md §12): on TPU they run
the compiled tiled Pallas kernels with block sizes from the autotune cache
(``kernels.autotune``); everywhere else they take the semantically
identical XLA fallbacks (``*_xla``) — interpret-mode Pallas re-enters the
grid per block at HLO level and is orders of magnitude slower, so it is a
*testing* vehicle (tests/test_kernels.py runs it for block-walk parity),
never a serving path.

* ``codebook_matmul(x, w_idx, codebook)`` — differentiable w.r.t. x and the
  codebook (d codebook = scatter-add of x^T·g over indices), NOT w.r.t. the
  integer indices.  This is exactly the gradient structure the paper's
  training uses between clustering events (weights move freely in float;
  here the codebook is the float degree of freedom).
* ``lut_matmul(a_idx, w_idx, table)`` — integer-only, no gradient; the
  Pallas and XLA routes produce bit-identical int32 accumulators (integer
  addition is associative), so routing never shows up in goldens.
* ``act_quant(x, kind, levels)`` — paper §2.1 backward: derivative of the
  *underlying* function, ignoring quantization.
* ``kmeans_assign(values, centers)`` — no gradient (clustering is a
  training-loop event, not part of the differentiated graph).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import act_quant as _aq
from repro.kernels import codebook_matmul as _cm
from repro.kernels import kmeans1d as _km
from repro.kernels import lut_matmul as _lm
from repro.kernels import page_gather as _pg

__all__ = ["codebook_matmul", "lut_matmul", "act_quant", "kmeans_assign",
           "gather_pages", "on_tpu", "supports_compiled_pallas",
           "route_counts", "reset_route_counts"]

# Trace-time platform-route counters: "{kernel}.{impl}" -> times that impl
# was selected while tracing (pallas | pallas_interpret | xla).  Read as
# deltas by serving/telemetry.py; kernels/ never imports serving/.
ROUTE_CALLS: dict = {}


def _count_route(kernel: str, impl: str) -> None:
    key = f"{kernel}.{impl}"
    ROUTE_CALLS[key] = ROUTE_CALLS.get(key, 0) + 1


def route_counts() -> dict:
    return dict(ROUTE_CALLS)


def reset_route_counts() -> None:
    ROUTE_CALLS.clear()


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def supports_compiled_pallas() -> bool:
    """True when the platform can run these kernels compiled (Mosaic).

    The kernels are written against the TPU memory hierarchy (VMEM-resident
    tables, MXU accumulation); everywhere else they execute in Pallas
    interpret mode — same numerics, HLO-level speed — so the serving
    backends stay usable on CPU dev boxes and in CI.
    """
    return on_tpu()


def _interp() -> bool:
    return not supports_compiled_pallas()


def _tuned(kernel: str, m: int, k: int, n: int, dtype, table_shape):
    from repro.kernels import autotune

    plat = "tpu" if supports_compiled_pallas() else "xla"
    return autotune.kernel_config(kernel, int(m), int(k), int(n),
                                  dtype=jnp.dtype(dtype).name, plat=plat,
                                  table_shape=tuple(int(d)
                                                    for d in table_shape))


# --- codebook matmul ---------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=())
def codebook_matmul(x, w_idx, codebook):
    m, k = x.shape
    n = w_idx.shape[1]
    cfg = _tuned("codebook", m, k, n, x.dtype, codebook.shape)
    if cfg.get("impl") == "xla":
        _count_route("codebook", "xla")
        return _cm.codebook_matmul_xla(x, w_idx, codebook)
    _count_route("codebook", "pallas_interpret" if _interp() else "pallas")
    return _cm.codebook_matmul_pallas(x, w_idx, codebook, bm=cfg["bm"],
                                      bn=cfg["bn"], bk=cfg["bk"],
                                      interpret=_interp())


def _cm_fwd(x, w_idx, codebook):
    return codebook_matmul(x, w_idx, codebook), (x, w_idx, codebook)


def _cm_bwd(res, g):
    x, w_idx, codebook = res
    w = codebook[w_idx.astype(jnp.int32)].astype(g.dtype)        # (K, N)
    dx = jnp.dot(g, w.T).astype(x.dtype)
    # d codebook: scatter-add (x^T g) over the index map
    xtg = jnp.dot(x.astype(jnp.float32).T, g)                    # (K, N)
    dbook = jax.ops.segment_sum(xtg.reshape(-1),
                                w_idx.astype(jnp.int32).reshape(-1),
                                num_segments=codebook.shape[0])
    return dx, None, dbook.astype(codebook.dtype)


codebook_matmul.defvjp(_cm_fwd, _cm_bwd)


# --- faithful integer engine -------------------------------------------------

def lut_matmul(a_idx, w_idx, table):
    """Integer accumulators of the §4 engine (no gradient, by construction).

    Pallas (TPU) and XLA (elsewhere) routes are bit-identical — integer
    addition is associative, so accumulation order cannot matter.
    """
    m, k = a_idx.shape
    n = w_idx.shape[1]
    cfg = _tuned("lut", m, k, n, a_idx.dtype, table.shape)
    if cfg.get("impl") == "xla":
        _count_route("lut", "xla")
        return _lm.lut_matmul_xla(a_idx, w_idx, table, kc=cfg["kc"],
                                  variant=cfg["variant"])
    _count_route("lut", "pallas_interpret" if _interp() else "pallas")
    return _lm.lut_matmul_pallas(a_idx, w_idx, table, bm=cfg["bm"],
                                 bn=cfg["bn"], bk=cfg["bk"],
                                 unroll=cfg.get("unroll", 8),
                                 interpret=_interp())


# --- paged KV cache: page-table gather ---------------------------------------

def gather_pages(pool, page_table):
    """out[b, p] = pool[page_table[b, p]] — the paged-decode gather.

    pool: (n_pages, page, *rest); page_table: (B, P) int32.  Returns
    (B, P, page, *rest).  On TPU this is the compiled Pallas scalar-prefetch
    kernel (one DMA per page, no index expansion); elsewhere the identical
    gather is left to XLA — ``jnp.take`` fuses on CPU whereas interpret-mode
    Pallas would re-enter Python inside every decode step.  No gradient
    (serving-only, like ``lut_matmul``).
    """
    if supports_compiled_pallas():
        _count_route("page_gather", "pallas")
        return _pg.page_gather_pallas(pool, page_table, interpret=False)
    _count_route("page_gather", "xla")
    # mode='clip' matches the Pallas kernel's explicit page-id clamp (the
    # jnp.take default is 'fill', which would turn an OOB id into NaN/INT_MIN
    # rather than the bounded-garbage contract both paths promise)
    return jnp.take(pool, page_table.astype(jnp.int32), axis=0, mode="clip")


# --- fused activation quantization ------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def act_quant(x, kind: str, levels: int):
    y, _ = _aq.act_quant_pallas(x, kind=kind, levels=levels,
                                interpret=_interp())
    return y


def _aq_fwd(x, kind, levels):
    return act_quant(x, kind, levels), x


def _aq_bwd(kind, levels, x, g):
    # derivative of the underlying (un-quantized) nonlinearity — paper §2.1
    if kind == "tanh":
        d = 1.0 - jnp.tanh(x) ** 2
    elif kind == "relu6":
        d = ((x > 0.0) & (x < 6.0)).astype(g.dtype)
    elif kind == "sigmoid":
        s = jax.nn.sigmoid(x)
        d = s * (1.0 - s)
    elif kind == "rtanh":
        d = jnp.where(x > 0.0, 1.0 - jnp.tanh(x) ** 2, 0.0)
    else:
        raise ValueError(kind)
    return ((g * d).astype(x.dtype),)


act_quant.defvjp(_aq_fwd, _aq_bwd)


def act_quant_index(x, kind: str, levels: int):
    """Level indices only (int32; no gradient path)."""
    _, idx = _aq.act_quant_pallas(x, kind=kind, levels=levels,
                                  interpret=_interp())
    return idx


# --- k-means streaming assignment -------------------------------------------

def kmeans_assign(values, centers):
    """(assignment idx, per-center sums, per-center counts)."""
    return _km.kmeans_assign_pallas(values, centers, interpret=_interp())
