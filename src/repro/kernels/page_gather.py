"""Page-table gather for the paged KV cache (DESIGN.md §8).

Decode's hot-loop memory op: assemble each slot's logical KV sequence from
the physical page pool,

    out[b, p] = pool[page_table[b, p]]        pool: (n_pages, page, ...)

The page table is a *scalar-prefetch* operand (``PrefetchScalarGridSpec``):
it is resident in SMEM before the kernel body runs, so the (b, p) grid
step's BlockSpec index map can read ``pt[b, p]`` and DMA exactly one
physical page HBM→VMEM — no gather instruction, no materialised index
expansion.  With int8 pages the HBM traffic per step is
``tokens_in_flight · KV · hd`` bytes, the paged-cache equivalent of the
codebook kernel's narrow-weight win (DESIGN.md §2).

Trailing pool dims are free-form: the same kernel moves K/V pages
``(page, KV, hd)`` and their per-token-per-head scale pages ``(page, KV)``.

Off-TPU the serving path uses the XLA fallback in ``kernels.ops``
(``jnp.take`` fuses fine on CPU; interpret-mode Pallas would be a
python-level inner loop per decode step).  This kernel is the TPU artifact
and is parity-checked against the fallback in interpret mode by the tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["page_gather_kernel", "page_gather_pallas"]


def page_gather_kernel(pt_ref, pool_ref, out_ref):
    """Copy one physical page into its (b, p) slot of the gathered output.

    The page *selection* already happened in the BlockSpec index map (which
    read ``pt_ref`` — SMEM-resident via scalar prefetch); the body is a pure
    VMEM page move.
    """
    del pt_ref
    out_ref[...] = pool_ref[...][None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather_pallas(pool: jnp.ndarray, page_table: jnp.ndarray, *,
                       interpret: bool = True) -> jnp.ndarray:
    """pool: (n_pages, page, *rest); page_table: (B, P) int32.

    Returns (B, P, page, *rest) in pool.dtype — slot b's logical sequence is
    ``out[b].reshape(P * page, *rest)``.  The allocator guarantees live ids
    < n_pages (page 0 is the shared trash page, see serving/kvcache.py),
    but an out-of-range id reaching the index map would DMA from past the
    pool — undefined on TPU, not an exception — so ids are clamped into
    the pool *explicitly* here (a bad id degrades to reading the last
    page, same bounded-garbage contract as the trash page; the masked
    attention window means it never reaches live scores).
    """
    B, P = page_table.shape
    n_pages = pool.shape[0]
    page_shape = pool.shape[1:]
    zeros = (0,) * len(page_shape)
    pt = jnp.clip(page_table.astype(jnp.int32), 0, n_pages - 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[pl.BlockSpec((1,) + page_shape,
                               lambda b, p, pt: (pt[b, p],) + zeros)],
        out_specs=pl.BlockSpec((1, 1) + page_shape,
                               lambda b, p, pt: (b, p) + zeros),
    )
    return pl.pallas_call(
        page_gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P) + page_shape, pool.dtype),
        interpret=interpret,
    )(pt, pool)
