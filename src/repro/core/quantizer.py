"""Periodic adaptive weight clustering over parameter pytrees (paper §2.2).

The paper's procedure: every ``interval`` (=1000) training steps, cluster
*all* network weights and biases to ``|W|`` unique values and snap each
weight to its centroid; training then continues unmodified.  This module
implements that as a pure function over a parameter pytree plus a small
``QuantizerState`` so it drops into any training loop:

    wq = WeightQuantConfig(num_weights=1000, method="laplacian_l1")
    state = init_state(wq)
    ...
    if wq.due(step):
        params, state = cluster_params(params, wq, state, step, key)

Scopes: ``global`` (one codebook for the whole network — the paper's default,
enabling a single A×W multiplication table) or ``per_layer`` (paper §5 future
work bullet 1 — one codebook per parameter tensor).

|W| annealing (paper §5 future work bullet 2): start at ``anneal_from`` and
decay geometrically to ``num_weights`` over ``anneal_steps``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering

__all__ = [
    "WeightQuantConfig",
    "QuantizerState",
    "init_state",
    "cluster_params",
    "codebook_indices",
    "num_weights_at",
    "param_filter",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class WeightQuantConfig:
    """Weight-clustering configuration.

    num_weights: |W| — number of unique weight values (0 disables).
    method:      'kmeans' | 'laplacian_l1' | 'uniform'.
    scope:       'global' (single codebook, paper default) | 'per_layer'.
    interval:    clustering cadence in steps (paper: 1000).
    subsample:   fraction of weights fed to k-means (paper §3.3: 0.02 for
                 AlexNet); 1.0 = use everything. Ignored by laplacian_l1,
                 which only needs mean/max statistics.
    kmeans_iters: Lloyd iterations per clustering event.
    anneal_from / anneal_steps: optional |W| annealing (§5 future work).
    exclude:     regex over param paths ('layer/w') exempt from clustering
                 (default none — the paper clusters everything incl. biases).
    """

    num_weights: int = 0
    method: str = "laplacian_l1"
    scope: str = "global"
    interval: int = 1000
    subsample: float = 1.0
    kmeans_iters: int = 25
    anneal_from: int = 0
    anneal_steps: int = 0
    exclude: str = ""

    def __post_init__(self):
        if self.num_weights and self.num_weights < 2:
            raise ValueError("num_weights must be >= 2 (or 0 to disable)")
        if self.method not in ("kmeans", "laplacian_l1", "uniform"):
            raise ValueError(f"unknown clustering method {self.method!r}")
        if self.scope not in ("global", "per_layer"):
            raise ValueError(f"unknown scope {self.scope!r}")

    @property
    def enabled(self) -> bool:
        return self.num_weights > 0

    def due(self, step: int) -> bool:
        """True on steps where the clustering event fires."""
        return self.enabled and step > 0 and step % self.interval == 0


@dataclasses.dataclass
class QuantizerState:
    """Codebook(s) from the most recent clustering event.

    codebooks: {path: centers} for per_layer scope, {'': centers} for global.
               Empty until the first clustering event.
    last_step: step of the most recent event (-1 = never).
    """

    codebooks: dict
    last_step: int = -1


def init_state(cfg: WeightQuantConfig) -> QuantizerState:
    del cfg
    return QuantizerState(codebooks={}, last_step=-1)


def num_weights_at(cfg: WeightQuantConfig, step: int) -> int:
    """|W| schedule: geometric decay anneal_from -> num_weights."""
    if not cfg.anneal_from or cfg.anneal_from <= cfg.num_weights:
        return cfg.num_weights
    if step >= cfg.anneal_steps:
        return cfg.num_weights
    frac = step / max(cfg.anneal_steps, 1)
    w = cfg.anneal_from * (cfg.num_weights / cfg.anneal_from) ** frac
    return max(cfg.num_weights, int(round(w)))


def param_filter(cfg: WeightQuantConfig):
    """Predicate(path) -> bool: True if this tensor is clustered."""
    if not cfg.exclude:
        return lambda path: True
    pat = re.compile(cfg.exclude)
    return lambda path: not pat.search(path)


def _flat_paths(params: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in leaves]
    values = [v for _, v in leaves]
    return paths, values, treedef


def _centers(values: jnp.ndarray, cfg: WeightQuantConfig, k: int,
             key: jax.Array) -> jnp.ndarray:
    if cfg.method == "laplacian_l1":
        return clustering.laplacian_l1_centers(values, k)
    if cfg.method == "uniform":
        return clustering.uniform_centers(values, k)
    v = values
    if cfg.subsample < 1.0 and v.size > 1_000_000:
        v = clustering.subsample(v.reshape(-1), cfg.subsample, key)
    return clustering.kmeans1d(v, k, iters=cfg.kmeans_iters)


def cluster_params(params: PyTree, cfg: WeightQuantConfig,
                   state: QuantizerState, step: int,
                   key: jax.Array) -> tuple[PyTree, QuantizerState]:
    """One clustering event: snap every (included) weight to its centroid.

    Pure function; callers decide cadence via ``cfg.due(step)``.
    """
    if not cfg.enabled:
        return params, state
    k = num_weights_at(cfg, step)
    keep = param_filter(cfg)
    paths, values, treedef = _flat_paths(params)

    if cfg.scope == "global":
        included = [v.reshape(-1).astype(jnp.float32)
                    for p, v in zip(paths, values) if keep(p)]
        flat = jnp.concatenate(included) if included else jnp.zeros((1,), jnp.float32)
        centers = _centers(flat, cfg, k, key)
        new_values = [
            clustering.quantize_to_centers(v, centers) if keep(p) else v
            for p, v in zip(paths, values)
        ]
        books = {"": centers}
    else:
        books, new_values = {}, []
        for i, (p, v) in enumerate(zip(paths, values)):
            if keep(p) and v.size >= 2:
                c = _centers(v.reshape(-1), cfg, min(k, v.size),
                             jax.random.fold_in(key, i))
                books[p] = c
                new_values.append(clustering.quantize_to_centers(v, c))
            else:
                new_values.append(v)

    new_params = jax.tree_util.tree_unflatten(treedef, new_values)
    return new_params, QuantizerState(codebooks=books, last_step=step)


def codebook_indices(params: PyTree, cfg: WeightQuantConfig,
                     state: QuantizerState) -> tuple[PyTree, dict]:
    """Index representation of a clustered network (paper §4 deployment).

    Returns (pytree of int32 index arrays mirroring params, codebooks dict).
    Each index selects into the relevant codebook; this is the form whose
    memory footprint the §4 analysis (and our TPU codebook kernels) exploit.
    Raises if clustering never ran.
    """
    if not state.codebooks:
        raise ValueError("no codebook yet — run cluster_params first")
    keep = param_filter(cfg)
    paths, values, treedef = _flat_paths(params)
    idx_leaves = []
    for p, v in zip(paths, values):
        if cfg.scope == "global":
            book = state.codebooks[""]
        else:
            book = state.codebooks.get(p)
        if book is None or not keep(p):
            idx_leaves.append(v)  # unclustered tensor kept verbatim
            continue
        idx_leaves.append(clustering.assign_to_centers(v.astype(jnp.float32), book)
                          .reshape(v.shape))
    return jax.tree_util.tree_unflatten(treedef, idx_leaves), dict(state.codebooks)
