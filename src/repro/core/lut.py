"""The paper's §4 inference engine: multiplication table + activation table.

Construction (paper Figs. 8-9):

* ``mult table``  M[a, w] = round(a_val · w_val · 2^s / Δx), int — one row per
  activation level **plus one bias row** (activation ≡ 1.0), one column per
  codebook weight **plus one identity column** (w ≡ 1.0, used to decode the
  final layer's output, "looking into the column for w=1").
* accumulate looked-up entries in an integer register; the sum equals the
  pre-activation x scaled by 2^s/Δx (to table rounding).
* ``acc >> s`` (arithmetic shift ≡ floor(x/Δx)) + ``zero_offset`` indexes the
  **activation table**, which maps each Δx-wide input bin directly to the next
  layer's activation-level row index — no boundary scan, no non-linearity.

Boundary snapping: for non-uniform input-space boundaries (tanhD etc.) the
bin edges are snapped to multiples of Δx; more table entries ⇒ smaller Δx ⇒
less snapping error (paper's 12-entry example for tanhD(6), Δx=0.218).
For ReLU6 the boundaries are already uniform, Δx = 6/(|A|−1), and the table
is an identity map (paper footnote 7).

Overflow is excluded statically: ``choose_scale`` picks the largest ``s``
such that ``fan_in · max|M|`` fits the accumulator width, and verifies the
accumulated *rounding* error stays ≪ one bin.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.activations import ActQuantConfig, act_input_boundaries

__all__ = ["LutConfig", "LutTables", "build_tables", "choose_scale"]


@dataclasses.dataclass(frozen=True)
class LutConfig:
    """act:        the activation-quantization config (gives |A| and ranges).
    table_entries: activation-table length T (≥ |A|); more entries = finer Δx.
                   Ignored for relu6 (identity table, Δx fixed by the level grid).
    acc_bits:      accumulator width (32 or 64).
    s_bits:        fixed-point scale exponent; None = choose automatically.
    x_pad:         fractional padding beyond the extreme boundary covered by
                   the table (inputs outside saturate to the end bins).
    """

    act: ActQuantConfig
    table_entries: int = 0
    acc_bits: int = 32
    s_bits: int | None = None
    x_pad: float = 0.25


@dataclasses.dataclass(frozen=True)
class LutTables:
    """The deployable artifact (all integers except the codebook metadata)."""

    mult: np.ndarray        # (|A|+1, |W|+1) int — rows: levels + bias; cols: weights + w≡1
    act_table: np.ndarray   # (T,) int32 — input bin -> activation level index
    levels: np.ndarray      # (|A|,) f32 — level values (for decode/inspection only)
    codebook: np.ndarray    # (|W|,) f32 — weight values (metadata; not used at inference)
    s: int                  # scale exponent
    dx: float               # activation-input sampling interval
    zero_offset: int        # index of the bin containing x = 0
    bias_row: int           # = |A| (row encoding activation ≡ 1.0)
    identity_col: int       # = |W| (column encoding w ≡ 1.0)
    acc_dtype: np.dtype     # accumulator dtype

    @property
    def n_levels(self) -> int:
        return int(self.levels.shape[0])

    @property
    def n_weights(self) -> int:
        return int(self.codebook.shape[0])

    def decode(self, acc: np.ndarray) -> np.ndarray:
        """Float value of a final-layer accumulator (the single boundary-
        crossing scale; inference itself never computes this)."""
        return np.asarray(acc, np.float64) * self.dx / (2.0 ** self.s)


def choose_scale(codebook: np.ndarray, levels_max: float, dx: float,
                 fan_in: int, acc_bits: int = 32,
                 err_bins_tol: float = 0.5) -> int:
    """Largest s with a static no-overflow guarantee (paper §4 last ¶).

    max|entry| = max(|w|·max(|a|,1)) · 2^s / Δx   (bias row uses a=1)
    need   fan_in · max|entry| < 2^(acc_bits−1)               (hard bound)
    and    RMS accumulated rounding error ≈ 0.29·√fan_in / 2^s
           < err_bins_tol bins   (independent ±0.5 roundings; the
           worst-case bound fan_in/2^{s+1} is unreachable in practice and
           would force 64-bit accumulators beyond fan-in ≈ 2·tol·2^{s_over}).
    """
    wmax = float(np.max(np.abs(codebook))) if codebook.size else 1.0
    wmax = max(wmax, 1.0)             # identity column encodes w ≡ 1.0
    amax = max(abs(levels_max), 1.0)  # bias row multiplies by 1.0
    headroom = 2.0 ** (acc_bits - 1) - 1
    # fan_in * wmax * amax * 2^s / dx  <  headroom
    s_over = int(np.floor(np.log2(headroom * dx / max(fan_in * wmax * amax, 1e-30))))
    s_err = int(np.ceil(np.log2(max(0.29 * fan_in ** 0.5 / err_bins_tol, 1.0))))
    if s_over < s_err:
        raise ValueError(
            f"no s satisfies both overflow (s<={s_over}) and rounding "
            f"(s>={s_err}) for fan_in={fan_in}, acc_bits={acc_bits}; "
            f"use acc_bits=64 or a larger dx")
    return s_over


def build_tables(codebook: np.ndarray, cfg: LutConfig,
                 fan_in: int) -> LutTables:
    """Build the §4 tables for one (codebook, activation, fan-in) triple."""
    act = cfg.act
    if not act.enabled:
        raise ValueError("LUT inference requires quantized activations")
    codebook = np.sort(np.asarray(codebook, np.float64).reshape(-1))
    lo, hi = act.out_range
    levels = np.linspace(lo, hi, act.levels)

    # --- activation table: input bin -> level index -------------------------
    if act.kind == "relu6":
        # Uniform boundaries (footnote 7): Δx = step, table = identity over
        # the bins whose centers are the levels; still materialised so the
        # engine is uniform across activation kinds.
        dx = act.step
        bounds = act_input_boundaries(act)          # at midpoints: (j-.5)*dx
        x_min, x_max = 0.0 - dx, 6.0 + dx
    else:
        bounds = act_input_boundaries(act)          # non-uniform (e.g. arctanh)
        span = max(abs(bounds[0]), abs(bounds[-1]))
        x_min = -span * (1.0 + cfg.x_pad)
        x_max = +span * (1.0 + cfg.x_pad)
        t = cfg.table_entries or 4 * act.levels
        dx = (x_max - x_min) / t

    zero_offset = int(np.ceil(-x_min / dx))          # bin index of x = 0
    n_bins = int(np.ceil(x_max / dx)) + zero_offset + 1
    # entry for bin b covers x ∈ [(b − zero_offset)·Δx, (b+1 − zero_offset)·Δx)
    bin_left = (np.arange(n_bins) - zero_offset) * dx
    bin_center = bin_left + dx / 2.0
    # level index whose (snapped) bin contains this center:
    act_table = np.searchsorted(bounds, bin_center, side="right").astype(np.int32)
    act_table = np.clip(act_table, 0, act.levels - 1)

    # --- scale + multiplication table ---------------------------------------
    s = cfg.s_bits if cfg.s_bits is not None else choose_scale(
        codebook, max(abs(lo), abs(hi)), dx, fan_in, cfg.acc_bits)
    scale = (2.0 ** s) / dx
    rows = np.concatenate([levels, [1.0]])          # + bias row (a ≡ 1)
    cols = np.concatenate([codebook, [1.0]])        # + identity column (w ≡ 1)
    mult = np.rint(np.outer(rows, cols) * scale)
    acc_dtype = np.dtype(np.int32 if cfg.acc_bits == 32 else np.int64)
    max_entry = np.max(np.abs(mult))
    if fan_in * max_entry >= 2.0 ** (cfg.acc_bits - 1):
        raise ValueError("overflow guarantee violated — lower s or widen acc")
    mult = mult.astype(acc_dtype)

    return LutTables(mult=mult, act_table=act_table,
                     levels=levels.astype(np.float32),
                     codebook=codebook.astype(np.float32),
                     s=s, dx=float(dx), zero_offset=zero_offset,
                     bias_row=act.levels, identity_col=int(codebook.shape[0]),
                     acc_dtype=acc_dtype)
