"""Quantized activation functions (paper §2.1, Fig. 1).

Forward: the underlying bounded nonlinearity's output is quantized to ``L``
levels equally spaced in *output* space (endpoints included, matching the
paper's ReLU6 construction where ``dx = 6/(|A|-1)`` and level 0 is exactly 0).
Backward: the quantization is ignored and the derivative of the *underlying*
function is used (paper: "we proceed by ignoring the quantization and instead
compute the derivatives of the underlying function").

Implemented with the ``y + stop_gradient(q(y) - y)`` trick, which yields the
exact underlying-function gradient while emitting exactly-quantized values.

Because the levels are equally spaced in output space, the implied *input*
space bin boundaries sit at ``f^{-1}(midpoint of adjacent levels)`` — densest
where the underlying derivative is largest, the property Fig. 1 highlights.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ActQuantConfig",
    "act_apply",
    "act_index",
    "act_levels",
    "act_input_boundaries",
    "quantize_input",
    "ACT_RANGES",
]

# Output ranges of the supported bounded nonlinearities.
ACT_RANGES = {
    "tanh": (-1.0, 1.0),
    "relu6": (0.0, 6.0),
    "sigmoid": (0.0, 1.0),
    "rtanh": (0.0, 1.0),  # rectified tanh: max(0, tanh(x))
}


def _base_fn(kind: str):
    if kind == "tanh":
        return jnp.tanh
    if kind == "relu6":
        return lambda x: jnp.clip(x, 0.0, 6.0)
    if kind == "sigmoid":
        return jax.nn.sigmoid
    if kind == "rtanh":
        return lambda x: jnp.maximum(jnp.tanh(x), 0.0)
    if kind in ("relu", "none", "identity"):
        # Unbounded / linear: quantization unsupported (paper switches AlexNet
        # from ReLU to ReLU6 precisely to get a bounded range).
        return (jax.nn.relu if kind == "relu" else (lambda x: x))
    if kind == "silu":
        return jax.nn.silu
    if kind == "gelu":
        return jax.nn.gelu
    raise ValueError(f"unknown activation kind: {kind}")


@dataclasses.dataclass(frozen=True)
class ActQuantConfig:
    """Activation-quantization configuration.

    kind:   underlying nonlinearity ('tanh', 'relu6', 'sigmoid', 'rtanh';
            'relu'/'silu'/'gelu'/'none' are allowed only with levels == 0).
    levels: |A|; 0 disables quantization (continuous baseline).
    """

    kind: str = "tanh"
    levels: int = 0

    def __post_init__(self):
        if self.levels:
            if self.kind not in ACT_RANGES:
                raise ValueError(
                    f"activation '{self.kind}' is unbounded; cannot quantize "
                    f"(paper §3.3 switches ReLU->ReLU6 for this reason)")
            if self.levels < 2:
                raise ValueError("levels must be >= 2 (or 0 to disable)")

    @property
    def enabled(self) -> bool:
        return self.levels > 0

    @property
    def out_range(self):
        return ACT_RANGES[self.kind]

    @property
    def step(self) -> float:
        lo, hi = self.out_range
        return (hi - lo) / (self.levels - 1)


def act_levels(cfg: ActQuantConfig) -> jnp.ndarray:
    """The |A| quantized output values a_0 .. a_{L-1} (float32)."""
    if not cfg.enabled:
        raise ValueError("continuous activation has no discrete levels")
    lo, hi = cfg.out_range
    return jnp.linspace(lo, hi, cfg.levels, dtype=jnp.float32)


def _quantize_output(cfg: ActQuantConfig, y: jnp.ndarray) -> jnp.ndarray:
    lo, _ = cfg.out_range
    step = cfg.step
    q = jnp.round((y - lo) / step)
    q = jnp.clip(q, 0, cfg.levels - 1)
    return (lo + q * step).astype(y.dtype)


def act_apply(cfg: ActQuantConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Quantized activation with underlying-derivative backward pass."""
    y = _base_fn(cfg.kind)(x)
    if not cfg.enabled:
        return y
    return y + jax.lax.stop_gradient(_quantize_output(cfg, y) - y)


def act_index(cfg: ActQuantConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Level index j in [0, |A|) of the quantized activation (no gradient).

    This is the row index fed to the next layer's multiplication table in the
    LUT inference engine (paper Fig. 8/9).
    """
    if not cfg.enabled:
        raise ValueError("continuous activation has no level index")
    y = _base_fn(cfg.kind)(x)
    lo, _ = cfg.out_range
    q = jnp.round((y - lo) / cfg.step)
    return jnp.clip(q, 0, cfg.levels - 1).astype(jnp.int32)


def act_input_boundaries(cfg: ActQuantConfig) -> np.ndarray:
    """Input-space thresholds b_1..b_{L-1} between adjacent output levels.

    Crossing b_j moves the emitted level from a_{j-1} to a_j.  Computed as
    f^{-1}((a_{j-1}+a_j)/2).  Used to build the §4 activation index table and
    for tests; saturating regions are handled by clipping in `act_index`.
    """
    if not cfg.enabled:
        raise ValueError("continuous activation has no boundaries")
    lo, hi = cfg.out_range
    levels = np.linspace(lo, hi, cfg.levels)
    mids = (levels[:-1] + levels[1:]) / 2.0
    eps = 1e-9
    if cfg.kind == "tanh":
        return np.arctanh(np.clip(mids, -1 + eps, 1 - eps))
    if cfg.kind == "relu6":
        return mids  # identity in the non-saturating region
    if cfg.kind == "sigmoid":
        m = np.clip(mids, eps, 1 - eps)
        return np.log(m / (1 - m))
    if cfg.kind == "rtanh":
        m = np.clip(mids, eps, 1 - eps)
        return np.arctanh(m)
    raise ValueError(cfg.kind)


def quantize_input(x: jnp.ndarray, levels: int, lo: float, hi: float) -> jnp.ndarray:
    """Quantize network inputs to `levels` uniform values in [lo, hi].

    Used for the paper's Table-1 "quantized inputs" columns, where network
    inputs are quantized to the same number of levels as activations.
    Straight-through gradient (identity within range).
    """
    step = (hi - lo) / (levels - 1)
    q = jnp.clip(jnp.round((x - lo) / step), 0, levels - 1) * step + lo
    return x + jax.lax.stop_gradient(q.astype(x.dtype) - x)
