"""Deployment export: index packing, entropy coding, memory accounting (§4).

The paper's memory claim: a clustered network stores per weight only a
⌈log2|W|⌉-bit index (10 bits at |W|=1000) instead of a 32-bit float — >69%
savings — and entropy-coding the indices (near-Laplacian occupancy) gets the
average below 7 bits — >78% savings.  The A×W multiplication table
(32×1000 entries) is amortised across the whole network.

This module computes those numbers for real trained networks and produces
the packed artifact: bit-packed index planes + codebook + LUT tables.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np

__all__ = [
    "bits_per_index",
    "pack_indices",
    "unpack_indices",
    "entropy_bits",
    "kv_cache_bytes",
    "MemoryReport",
    "memory_report",
]

PyTree = Any


def bits_per_index(n_values: int) -> int:
    return max(1, math.ceil(math.log2(max(n_values, 2))))


def pack_indices(idx: np.ndarray, bits: int) -> np.ndarray:
    """Bit-pack non-negative ints (< 2^bits) into a uint8 stream (LSB-first)."""
    idx = np.asarray(idx, np.uint64).reshape(-1)
    if idx.size and int(idx.max()) >= (1 << bits):
        raise ValueError("index exceeds bit width")
    total_bits = idx.size * bits
    out = np.zeros((total_bits + 7) // 8, np.uint8)
    bitpos = np.arange(idx.size, dtype=np.uint64) * np.uint64(bits)
    for b in range(bits):
        src = ((idx >> np.uint64(b)) & np.uint64(1)).astype(np.uint8)
        pos = bitpos + np.uint64(b)
        np.bitwise_or.at(out, (pos // 8).astype(np.int64),
                         src << (pos % 8).astype(np.uint8))
    return out


def unpack_indices(packed: np.ndarray, bits: int, count: int) -> np.ndarray:
    """Inverse of pack_indices."""
    packed = np.asarray(packed, np.uint8)
    out = np.zeros(count, np.uint64)
    bitpos = np.arange(count, dtype=np.uint64) * np.uint64(bits)
    for b in range(bits):
        pos = bitpos + np.uint64(b)
        bit = (packed[(pos // 8).astype(np.int64)] >>
               (pos % 8).astype(np.uint8)) & 1
        out |= bit.astype(np.uint64) << np.uint64(b)
    return out.astype(np.int64)


def entropy_bits(idx: np.ndarray, n_values: int) -> float:
    """Shannon entropy (bits/index) of the marginal index distribution — the
    paper's "simplest (non-adaptive, marginal-only) entropy coding" bound."""
    counts = np.bincount(np.asarray(idx).reshape(-1), minlength=n_values)
    p = counts[counts > 0] / counts.sum()
    return float(-(p * np.log2(p)).sum())


def kv_cache_bytes(n_layers: int, n_kv: int, head_dim: int, tokens: int,
                   *, dtype_bytes: int = 2, quant: bool = False,
                   page_size: int = 0) -> int:
    """Serving-state bytes for ``tokens`` cached tokens (K + V, all layers).

    ``quant``: int8 pages + per-token-per-head bf16 scales (the paged
    cache's quantize-what-you-store representation); else plain floats of
    ``dtype_bytes``.  ``page_size > 0`` rounds tokens up to whole pages —
    the paged pool's allocation granularity (the dense slab instead
    allocates ``max_batch × max_len`` regardless of tokens in flight; pass
    that product as ``tokens`` with ``page_size=0`` to size it).
    """
    if page_size:
        tokens = math.ceil(tokens / page_size) * page_size
    per_tok_head = (2 * head_dim * (1 if quant else dtype_bytes)
                    + (4 if quant else 0))          # k+v (+ 2 bf16 scales)
    return n_layers * n_kv * per_tok_head * tokens


@dataclasses.dataclass(frozen=True)
class MemoryReport:
    n_params: int
    n_weights: int          # |W|
    n_levels: int           # |A|
    fp32_bytes: int
    bf16_bytes: int
    index_bits: int
    packed_bytes: int       # indices bit-packed + codebook + LUT tables
    entropy_bits_per_w: float
    entropy_bytes: int      # entropy-coded indices + codebook + LUT tables
    table_bytes: int        # A×W mult table + activation table
    kv_fp_bytes: int = 0      # serving state: dense float KV slab
    kv_packed_bytes: int = 0  # serving state: paged int8 cache in use
    lut_table_bytes: int = 0  # actual attached lut_table leaves (if any)

    @property
    def savings_vs_fp32(self) -> float:
        return 1.0 - self.packed_bytes / self.fp32_bytes

    @property
    def entropy_savings_vs_fp32(self) -> float:
        return 1.0 - self.entropy_bytes / self.fp32_bytes

    @property
    def savings_vs_bf16(self) -> float:
        return 1.0 - self.packed_bytes / self.bf16_bytes

    @property
    def deployed_fp_bytes(self) -> int:
        """End-to-end float deployment: fp32 weights + float KV slab."""
        return self.fp32_bytes + self.kv_fp_bytes

    @property
    def deployed_packed_bytes(self) -> int:
        """End-to-end packed deployment: indices+tables + paged int8 KV."""
        return self.packed_bytes + self.kv_packed_bytes

    @property
    def deployed_savings(self) -> float:
        """The paper's "less than one third" claim measured end-to-end —
        weights AND serving state, not weights alone."""
        if not self.deployed_fp_bytes:
            return 0.0
        return 1.0 - self.deployed_packed_bytes / self.deployed_fp_bytes

    def row(self) -> str:
        s = (f"params={self.n_params} |W|={self.n_weights} |A|={self.n_levels} "
             f"fp32={self.fp32_bytes/1e6:.2f}MB packed={self.packed_bytes/1e6:.2f}MB "
             f"({100*self.savings_vs_fp32:.1f}% saved) "
             f"entropy={self.entropy_bytes/1e6:.2f}MB "
             f"({100*self.entropy_savings_vs_fp32:.1f}% saved, "
             f"{self.entropy_bits_per_w:.2f} bits/w)")
        if self.kv_fp_bytes:
            s += (f" | deployed(w+kv)={self.deployed_fp_bytes/1e6:.2f}MB"
                  f"->{self.deployed_packed_bytes/1e6:.2f}MB "
                  f"({100*self.deployed_savings:.1f}% saved)")
        return s


def memory_report(index_tree: PyTree, n_weights: int, n_levels: int,
                  table_entries: int = 0,
                  acc_bytes: int = 4,
                  kv_fp_bytes: int = 0,
                  kv_packed_bytes: int = 0) -> MemoryReport:
    """§4 memory accounting for a clustered network in index form.

    ``kv_fp_bytes`` / ``kv_packed_bytes`` (optional, via ``kv_cache_bytes``)
    fold serving state into the claim: a deployed LM ships its KV cache
    alongside its weights, so the "less than one third" comparison is
    (fp32 weights + float slab) vs (packed indices + paged int8 cache).

    The walk is path-aware: ``lut_table`` leaves (the precomputed §4
    A×W tables ``dispatch.attach_lut_tables`` hangs next to each routed
    index dict) are int32 but are *tables*, not per-weight indices —
    they are counted by their actual bytes into the table accounting
    instead of inflating ``n_params``/entropy.  Without attached tables
    the analytic (|A|+1)×(|W|+1) mult-table size is used as before.
    """
    idx_leaves, tables = [], []
    for path, leaf in jax.tree_util.tree_leaves_with_path(index_tree):
        a = np.asarray(leaf)
        if any(getattr(k, "key", getattr(k, "name", None)) == "lut_table"
               for k in path):
            tables.append(a)
        elif np.issubdtype(a.dtype, np.integer):
            idx_leaves.append(a)
    flat = (np.concatenate([x.reshape(-1) for x in idx_leaves])
            if idx_leaves else np.zeros(0, np.int64))
    n = int(flat.size)
    bits = bits_per_index(n_weights)
    lut_table_bytes = sum(int(a.nbytes) for a in tables)
    # mult table: actual attached lut_table leaves when present, else the
    # analytic (|A|+1)×(|W|+1) ints; + activation table + f32 codebook
    mult_bytes = lut_table_bytes or (n_levels + 1) * (n_weights + 1) * acc_bytes
    t_entries = table_entries or 4 * n_levels
    table_bytes = mult_bytes + t_entries * 4 + n_weights * 4
    ent = entropy_bits(flat, n_weights) if n else 0.0
    return MemoryReport(
        n_params=n, n_weights=n_weights, n_levels=n_levels,
        fp32_bytes=4 * n, bf16_bytes=2 * n,
        index_bits=bits,
        packed_bytes=(n * bits + 7) // 8 + table_bytes,
        entropy_bits_per_w=ent,
        entropy_bytes=int(math.ceil(n * ent / 8)) + table_bytes,
        table_bytes=table_bytes,
        kv_fp_bytes=kv_fp_bytes,
        kv_packed_bytes=kv_packed_bytes,
        lut_table_bytes=lut_table_bytes)
