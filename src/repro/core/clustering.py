"""Weight clustering (paper §2.2, Figs. 3-5).

Three ways to pick the |W| cluster centers:

* ``kmeans1d``     — jitted 1-D Lloyd k-means (the paper's default; "all of the
                     clustering approaches we tried gave similar results").
* ``laplacian_l1`` — the paper's closed-form model-based quantizer: centers at
                     ``a ± b·L_i`` with ``L_i = L_{i-1} + Δ_i``,
                     ``Δ_i = −ln(1 − 2·exp(L_{i-1})/N)``, ``L_0 = 0``.
                     The recursion telescopes:  ``exp(−L_i) = 1 − 2i/N``  —
                     i.e. the tail mass drops linearly (paper Fig. 5, linear
                     occupancy), so we implement the stable closed form
                     ``L_i = −ln(1 − 2i/N)``.
* ``uniform``      — equally-spaced levels between min and max (the Lin et
                     al. 2015 baseline the paper argues against).

Everything here is pure-functional and jittable; the periodic-clustering
trainer hook lives in ``repro.core.quantizer``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "kmeans1d",
    "laplacian_l1_levels",
    "laplacian_l1_centers",
    "uniform_centers",
    "assign_to_centers",
    "quantize_to_centers",
    "subsample",
]


# ---------------------------------------------------------------------------
# assignment / replacement
# ---------------------------------------------------------------------------

def assign_to_centers(values: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """Nearest-center index for each value.  ``centers`` must be sorted.

    Uses the midpoint-boundary trick: in 1-D, nearest-center regions are the
    intervals between adjacent-center midpoints, so a ``searchsorted`` over
    the |W|−1 midpoints gives the argmin without an O(n·|W|) distance matrix.
    """
    boundaries = (centers[:-1] + centers[1:]) / 2.0
    return jnp.searchsorted(boundaries, values, side="right").astype(jnp.int32)


def quantize_to_centers(values: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """Replace each value with its assigned (sorted) center's value."""
    return centers[assign_to_centers(values, centers)].astype(values.dtype)


def subsample(values: jnp.ndarray, fraction: float, key: jax.Array) -> jnp.ndarray:
    """Random subsample (paper §3.3: 2% of AlexNet's weights for k-means)."""
    n = values.shape[0]
    m = max(1, int(n * fraction))
    idx = jax.random.randint(key, (m,), 0, n)  # with replacement; fine for stats
    return values[idx]


# ---------------------------------------------------------------------------
# k-means (1-D Lloyd, jitted, fixed iteration count)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans1d(values: jnp.ndarray, k: int, iters: int = 50,
             key: jax.Array | None = None) -> jnp.ndarray:
    """1-D k-means over ``values`` (flattened). Returns sorted centers (f32).

    Lloyd from TWO deterministic inits — data quantiles (equal-mass bins,
    best for heavy-tailed data at small k) and a uniform min..max grid
    (better basin at large k) — keeping whichever converges to lower MSE.
    Single-init 1-D Lloyd is notoriously slow out of a bad basin; the dual
    start fixes that at 2× a cost paid once per 1000 steps.
    Empty clusters keep their previous center.
    """
    v = values.reshape(-1).astype(jnp.float32)
    qs = (jnp.arange(k, dtype=jnp.float32) + 0.5) / k
    init_q = jnp.quantile(v, qs)
    lo, hi = jnp.min(v), jnp.max(v)
    init_u = lo + (hi - lo) * qs

    def lloyd(centers):
        def body(centers, _):
            idx = assign_to_centers(v, centers)
            sums = jax.ops.segment_sum(v, idx, num_segments=k)
            counts = jax.ops.segment_sum(jnp.ones_like(v), idx,
                                         num_segments=k)
            new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0),
                            centers)
            return jnp.sort(new), None

        centers, _ = jax.lax.scan(body, centers, None, length=iters)
        mse = jnp.mean((centers[assign_to_centers(v, centers)] - v) ** 2)
        return centers, mse

    cands, mses = jax.vmap(lloyd)(jnp.stack([init_q, init_u]))
    return cands[jnp.argmin(mses)]


# ---------------------------------------------------------------------------
# Laplacian L1 closed form (paper §2.2)
# ---------------------------------------------------------------------------

def laplacian_l1_levels(n_centers: int) -> np.ndarray:
    """Normalized positive levels L_0..L_m for the L1-optimal Laplacian grid.

    Odd N:  centers at {0, ±L_1 .. ±L_m}, m=(N−1)/2, with exp(−L_i)=1−2i/N.
    Even N: centers at {±L_1 .. ±L_m}, m=N/2, with exp(−L_i)=1−(2i−1)/N
            (same linear-tail-mass construction, no zero center).
    Returned array is the positive half including L_0=0 for odd N.
    """
    if n_centers < 1:
        raise ValueError("need at least one center")
    n = n_centers
    if n % 2 == 1:
        i = np.arange(0, (n - 1) // 2 + 1, dtype=np.float64)
        tail = 1.0 - 2.0 * i / n
    else:
        i = np.arange(1, n // 2 + 1, dtype=np.float64)
        tail = 1.0 - (2.0 * i - 1.0) / n
    return -np.log(np.maximum(tail, 1e-300))


def laplacian_l1_centers(values: jnp.ndarray, n_centers: int,
                         nudge: bool = True) -> jnp.ndarray:
    """Closed-form centers ``a ± b·L_i`` fitted to ``values`` (paper §2.2).

    ``a`` is the mean; ``b`` starts at ``W_max / L_max`` (so the extreme level
    sits at the largest observed amplitude), then is "nudged" per the paper:

    * early training (``W_max < 0.5``): move the extreme level *outward* by
      ``b·Δ_max / (2·(1−W_max))`` — weights are still too tightly packed
      around the mean for a fair Laplacian sample;
    * late training (``W_max > 1.25``): move it slightly *inward* by
      ``b·Δ_max/4`` — retains the regularising pull-back of extreme weights.

    Jittable (n_centers static through the numpy level grid).
    """
    v = values.reshape(-1).astype(jnp.float32)
    levels = jnp.asarray(laplacian_l1_levels(n_centers), dtype=jnp.float32)
    l_max = float(levels[-1])
    # Δ_max = L_m − L_{m−1}: spacing of the outermost pair.
    d_max = float(levels[-1] - levels[-2]) if levels.shape[0] > 1 else 1.0

    a = jnp.mean(v)
    w_max = jnp.max(jnp.abs(v - a))
    w_max = jnp.maximum(w_max, 1e-12)
    b = w_max / l_max
    if nudge:
        # outward nudge: extreme level b·L_max grows by b·Δ_max/(2(1−W_max))
        out = b * (1.0 + d_max / (2.0 * jnp.maximum(1.0 - w_max, 1e-6) * l_max))
        # inward nudge: extreme level shrinks by b·Δ_max/4
        inw = b * (1.0 - d_max / (4.0 * l_max))
        b = jnp.where(w_max < 0.5, out, jnp.where(w_max > 1.25, inw, b))

    pos = a + b * levels
    if n_centers % 2 == 1:
        neg = a - b * levels[1:]
    else:
        neg = a - b * levels
    return jnp.sort(jnp.concatenate([neg, pos]))


def uniform_centers(values: jnp.ndarray, n_centers: int) -> jnp.ndarray:
    """Equally-spaced centers between min and max (Lin et al. baseline)."""
    v = values.reshape(-1).astype(jnp.float32)
    lo, hi = jnp.min(v), jnp.max(v)
    t = jnp.linspace(0.0, 1.0, n_centers, dtype=jnp.float32)
    return lo + t * (hi - lo)
