"""Core of the paper's contribution:

activations — §2.1 quantized nonlinearities (tanhD etc., underlying-derivative backward)
clustering  — §2.2 k-means / closed-form Laplacian-L1 / uniform weight clustering
quantizer   — periodic-clustering hook over parameter pytrees (+ |W| anneal, scopes)
lut         — §4 multiplication table + activation index table construction
fixedpoint  — §4 integer-only inference engine (lookups + adds + bit-shift)
export      — index packing, entropy coding, memory accounting
"""

from repro.core.activations import ActQuantConfig, act_apply, act_index, act_levels
from repro.core.quantizer import WeightQuantConfig, QuantizerState, cluster_params, init_state
from repro.core.lut import LutConfig, LutTables, build_tables
