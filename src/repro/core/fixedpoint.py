"""Integer-only inference (paper §4, Fig. 9) — the faithful engine.

Everything at inference time is: table lookups, integer adds, one arithmetic
bit-shift per unit.  No multiplies, no floats, no non-linearity evaluation.

    acc[n]  = Σ_k  M[a_idx[k], w_idx[k, n]]  (+ M[bias_row, b_idx[n]])
    bin     = (acc >> s) + zero_offset        # arithmetic shift ≡ floor(x/Δx)
    a_idx'  = act_table[clip(bin)]            # next layer's row indices

The final (linear) layer stops at ``acc``; its float meaning is
``acc · Δx / 2^s`` (``LutTables.decode``), or equivalently one lookup into
the w≡1 identity column — computed only by callers that need float outputs
(tests/metrics), never by the engine itself.

All functions are jnp + jittable so they double as the oracle for
``kernels/lut_matmul`` and run under ``jax.jit`` for the CPU benchmarks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.activations import ActQuantConfig
from repro.core.lut import LutTables

__all__ = [
    "input_to_indices",
    "int_linear",
    "acc_to_act_index",
    "int_mlp_forward",
]


def _jt(tables: LutTables):
    """Device copies of the integer tables."""
    if tables.acc_dtype == np.dtype(np.int64) and not jax.config.jax_enable_x64:
        raise ValueError("acc_bits=64 tables need jax_enable_x64; either "
                         "enable it or build with acc_bits=32")
    dt = jnp.int32 if tables.acc_dtype == np.dtype(np.int32) else jnp.int64
    return jnp.asarray(tables.mult, dt), jnp.asarray(tables.act_table, jnp.int32)


def input_to_indices(x: jnp.ndarray, cfg: ActQuantConfig) -> jnp.ndarray:
    """Quantize network *inputs* to activation-level indices (Table 1,
    "quantized inputs" columns: inputs share the activation level grid)."""
    lo, hi = cfg.out_range
    q = jnp.round((jnp.clip(x, lo, hi) - lo) / cfg.step)
    return q.astype(jnp.int32)


def int_linear(a_idx: jnp.ndarray, w_idx: jnp.ndarray,
               b_idx: jnp.ndarray | None, tables: LutTables,
               k_chunk: int = 512) -> jnp.ndarray:
    """acc[..., n] = Σ_k M[a_idx[..., k], w_idx[k, n]] (+ bias row lookup).

    a_idx: (..., K) int32 activation-level indices (bias row allowed).
    w_idx: (K, N) int32 codebook indices.
    b_idx: (N,) int32 codebook indices of the biases, or None.
    Gathers are chunked over K to bound the (..., k_chunk, N) intermediate.
    """
    mult, _ = _jt(tables)
    n_cols = tables.mult.shape[1]
    flat = mult.reshape(-1)
    K = a_idx.shape[-1]
    batch = a_idx.shape[:-1]
    acc = jnp.zeros(batch + (w_idx.shape[1],), dtype=flat.dtype)

    # pad K to a multiple of k_chunk with (bias_row, identity_col) pairs whose
    # contribution we subtract afterwards — keeps the scan shape static.
    pad = (-K) % k_chunk
    if pad:
        a_pad = jnp.full(batch + (pad,), tables.bias_row, jnp.int32)
        w_pad = jnp.full((pad, w_idx.shape[1]), tables.identity_col, jnp.int32)
        a_idx = jnp.concatenate([a_idx, a_pad], axis=-1)
        w_idx = jnp.concatenate([w_idx, w_pad], axis=0)
    n_chunks = a_idx.shape[-1] // k_chunk

    def body(acc, c):
        a = jax.lax.dynamic_slice_in_dim(a_idx, c * k_chunk, k_chunk, -1)
        w = jax.lax.dynamic_slice_in_dim(w_idx, c * k_chunk, k_chunk, 0)
        gathered = flat[a[..., :, None] * n_cols + w]      # (..., k_chunk, N)
        return acc + jnp.sum(gathered, axis=-2), None

    acc, _ = jax.lax.scan(body, acc, jnp.arange(n_chunks))
    if pad:
        acc = acc - pad * mult[tables.bias_row, tables.identity_col]
    if b_idx is not None:
        acc = acc + mult[tables.bias_row, b_idx]
    return acc


def acc_to_act_index(acc: jnp.ndarray, tables: LutTables) -> jnp.ndarray:
    """Bit-shift + activation-table lookup (Fig. 9): accumulator -> next
    layer's activation-level row index."""
    _, act_table = _jt(tables)
    bins = jax.lax.shift_right_arithmetic(acc, jnp.asarray(tables.s, acc.dtype))
    bins = jnp.clip(bins.astype(jnp.int32) + tables.zero_offset,
                    0, tables.act_table.shape[0] - 1)
    return act_table[bins]


def int_mlp_forward(layers, x_idx: jnp.ndarray, tables: LutTables,
                    final_linear: bool = True):
    """Run a whole MLP with the integer engine.

    layers: sequence of (w_idx (K,N) int32, b_idx (N,) int32 | None).
    x_idx:  (..., K0) activation-level indices of the (quantized) inputs.
    Returns the final layer's raw integer accumulators if final_linear
    (regression / logits), else the final activation indices.
    """
    a = x_idx
    for li, (w_idx, b_idx) in enumerate(layers):
        acc = int_linear(a, w_idx, b_idx, tables)
        last = li == len(layers) - 1
        if last and final_linear:
            return acc
        a = acc_to_act_index(acc, tables)
    return a
