"""Model bundle: uniform handle over every architecture.

``build(cfg)`` returns a ``Model`` whose members close over the config:

    model.init(key)                      -> params
    model.loss(params, batch, mesh)      -> (loss, metrics)
    model.forward(params, batch, mesh)   -> logits
    model.prefill(params, batch, mesh)   -> (logits, cache)
    model.decode(params, tokens, cache, mesh) -> (logits, cache)
    model.init_cache(batch, max_len)     -> cache
    model.input_specs(shape_name, ...)   -> ShapeDtypeStruct batch (dry-run)

Serving contracts (DESIGN.md §3): ``prefill`` accepts an optional
``batch['lengths']`` (B,) vector marking right-padded prompts — logits come
back at each row's last real position and ``cache['pos']`` as a (B,)
vector; ``decode`` then treats a vector ``cache['pos']`` as per-slot
positions (the ServeEngine's continuous batching).  KV-cache families
only.  Matmul routing for codebook-index params (dense | codebook | lut)
is ambient trace-time state — see ``kernels.dispatch``; the params, not
this handle, carry the representation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def init(self, key):
        return T.init_params(key, self.cfg)

    def loss(self, params, batch, mesh=None):
        return T.loss_fn(params, self.cfg, batch, mesh)

    def forward(self, params, batch, mesh=None):
        return T.forward(params, self.cfg, batch, mesh)

    def prefill(self, params, batch, mesh=None):
        return T.prefill(params, self.cfg, batch, mesh)

    def decode(self, params, tokens, cache, mesh=None):
        return T.decode_step(params, self.cfg, tokens, cache, mesh)

    def verify(self, params, tokens, cache, mesh=None):
        """Score K1 tokens per slot in ONE forward (speculative verify,
        DESIGN.md §9): logits at every position, K/V written at
        pos..pos+K1−1, ``cache['pos']`` left for the caller to advance by
        the accepted count.  Dispatches on ``page_table`` like decode."""
        return T.verify_step(params, self.cfg, tokens, cache, mesh)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return T.init_cache(self.cfg, batch, max_len, dtype)

    # --- paged KV cache (DESIGN.md §8) ---------------------------------------
    def init_paged_cache(self, n_pages: int, page_size: int,
                         dtype=jnp.bfloat16):
        """Page-pool cache pytree; dtype=int8 → quantized pages + scales."""
        return T.init_paged_cache(self.cfg, n_pages, page_size, dtype)

    def prefill_chunk(self, params, batch, cache, mesh=None):
        """One page-sized chunk of one request's prompt (chunked prefill)."""
        return T.prefill_chunk(params, self.cfg, batch, cache, mesh)

    # --- dry-run stand-ins ----------------------------------------------------
    def input_specs(self, shape_name: str) -> dict:
        """ShapeDtypeStruct batch for a shape cell (no allocation).

        For train/prefill this is the token batch (+ stubbed modality
        embeddings); for decode it is the (B, 1) token step — the cache spec
        comes from ``cache_specs``.
        """
        cfg = self.cfg
        sh = SHAPES[shape_name]
        B, S = sh.global_batch, sh.seq_len
        f32 = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        if sh.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.family == "vlm":
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f32)
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_len,
                                                    cfg.d_model), f32)
        return batch

    def cache_specs(self, shape_name: str) -> Any:
        """ShapeDtypeStruct pytree of the decode cache for a shape cell."""
        sh = SHAPES[shape_name]
        cache = jax.eval_shape(
            lambda: T.init_cache(self.cfg, sh.global_batch, sh.seq_len,
                                 jnp.bfloat16))
        return cache


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "paper":
        raise ValueError("paper-family nets are built via repro.models."
                         "papernets (see benchmarks/)")
    return Model(cfg)
