"""Mixture-of-Experts with explicit shard_map dispatch.

Two sharding regimes over the `model` mesh axis, chosen by divisibility:

* **EP** (n_experts % model_size == 0, e.g. qwen3-moe 128e over 16):
  experts sharded over `model`; activations are *replicated* across `model`
  (they arrive that way from the attention block), so each shard routes all
  of its local tokens, keeps only the slice destined for its experts, and
  the final psum over `model` combines expert outputs.  No all-to-all is
  needed — replication over the TP axis plays the role of the dispatch
  collective, which is the natural choice when TP is already present.
* **TP-within-expert** (n_experts < model_size, e.g. grok-1 8e over 16):
  every expert's d_ff is sharded over `model` (column/row parallel pair),
  all shards process all experts, psum at the end.

Token→slot dispatch is sort-based and *device-local* (the reason for
shard_map rather than relying on XLA to partition a global sort): stable
argsort by expert id, rank-within-run as capacity slot, scatter to an
(E_local, C, d) buffer, grouped einsum, gather back, weighted combine.
Tokens over capacity are dropped (standard GShard semantics, capacity
factor configurable).

Weights may additionally be FSDP-sharded over `data` (ZeRO-3); the block
all-gathers them on entry (`fsdp=True`).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, ffn_act

__all__ = ["MoEConfig", "moe_init", "moe_apply", "moe_param_specs"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act_kind: str = "silu"
    act_levels: int = 0
    model_axis: str = "model"
    dp_axes: tuple = ("data",)
    fsdp: bool = True         # expert weights gathered over dp_axes[?]
    token_chunks: int = 1     # dispatch in sequential token chunks (memory)

    def ep_size(self, model_size: int) -> int:
        return model_size if self.n_experts % model_size == 0 else 1


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    std = d ** -0.5
    return {
        "router": {"w": (jax.random.normal(ks[0], (d, E)) * std).astype(jnp.float32)},
        "w1": (jax.random.normal(ks[1], (E, d, f)) * std).astype(dtype),
        "w3": (jax.random.normal(ks[2], (E, d, f)) * std).astype(dtype),
        "w2": (jax.random.normal(ks[3], (E, f, d)) * (f ** -0.5)).astype(dtype),
    }


def moe_param_specs(cfg: MoEConfig, model_size: int, fsdp_axis: str = "data"):
    """PartitionSpecs for the expert weights (without the scan/layer dim)."""
    fa = fsdp_axis if cfg.fsdp else None
    if cfg.ep_size(model_size) > 1:   # EP: experts over model, FSDP over d
        return {"router": {"w": P(None, None)},
                "w1": P(cfg.model_axis, fa, None),
                "w3": P(cfg.model_axis, fa, None),
                "w2": P(cfg.model_axis, None, fa)}
    return {"router": {"w": P(None, None)},   # TP: d_ff over model
            "w1": P(None, fa, cfg.model_axis),
            "w3": P(None, fa, cfg.model_axis),
            "w2": P(None, cfg.model_axis, fa)}


def _dispatch_local(x_flat, ids, gates, e0, n_local, capacity):
    """Sort-based local dispatch. x_flat: (T, d); ids/gates: (T, k).

    Returns (buffer (n_local, C, d), slot (T*k,), keep (T*k,)).
    """
    T, k = ids.shape
    d = x_flat.shape[-1]
    flat_e = ids.reshape(-1)
    local = (flat_e >= e0) & (flat_e < e0 + n_local)
    le = jnp.where(local, flat_e - e0, n_local)          # n_local = drop bucket
    order = jnp.argsort(le, stable=True)
    le_s = le[order]
    # rank within each expert's run of the sorted array
    first = jnp.searchsorted(le_s, le_s, side="left")
    rank = jnp.arange(T * k) - first
    keep_s = (rank < capacity) & (le_s < n_local)
    slot_s = jnp.where(keep_s, le_s * capacity + rank, n_local * capacity)
    tok_s = order // k
    buf = jnp.zeros((n_local * capacity + 1, d), x_flat.dtype)
    buf = buf.at[slot_s].set(x_flat[tok_s], mode="drop")
    # un-sort slot/keep back to (T*k,) order
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * k))
    return buf[:-1].reshape(n_local, capacity, d), slot_s[inv], keep_s[inv]


def _moe_chunk(x_flat, router_w, w1, w3, w2, cfg: MoEConfig, e0, n_local):
    """Route + compute one flat token chunk: (Tc, d) -> (Tc, d)."""
    Tc, d = x_flat.shape
    logits = x_flat.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(Tc * cfg.top_k * cfg.capacity_factor
                          / cfg.n_experts))
    buf, slot, keep = _dispatch_local(x_flat, ids, gates, e0, n_local,
                                      capacity)

    h = ffn_act(jnp.einsum("ecd,edf->ecf", buf, w1), cfg.act_kind,
                cfg.act_levels) * jnp.einsum("ecd,edf->ecf", buf, w3)
    out = jnp.einsum("ecf,efd->ecd", h, w2)              # (n_local, C, d)

    out_flat = jnp.concatenate(
        [out.reshape(n_local * capacity, d),
         jnp.zeros((1, d), out.dtype)], axis=0)
    y = out_flat[slot]                                   # (Tc*k, d)
    w = (gates.reshape(-1) * keep).astype(y.dtype)
    tok = jnp.arange(Tc * cfg.top_k) // cfg.top_k
    return jax.ops.segment_sum(y * w[:, None], tok, num_segments=Tc)


def _moe_math(x, router_w, w1, w3, w2, cfg: MoEConfig, e0, n_local, ep):
    """Core routed computation on local tokens x: (B_l, L, d).

    With ``token_chunks > 1`` the dispatch/compute/combine runs over
    sequential token chunks (lax.scan): peak dispatch-buffer and gather
    memory shrink by the chunk count at identical FLOPs (capacity becomes
    per-chunk — slightly stricter balance requirement, recorded in DESIGN).
    """
    del ep
    Bl, L, d = x.shape
    T = Bl * L
    x_flat = x.reshape(T, d)
    nc = cfg.token_chunks if cfg.token_chunks > 1 and T % cfg.token_chunks == 0 \
        else 1
    if nc == 1:
        y = _moe_chunk(x_flat, router_w, w1, w3, w2, cfg, e0, n_local)
        return y.reshape(Bl, L, d), None

    def body(_, xc):
        return None, _moe_chunk(xc, router_w, w1, w3, w2, cfg, e0, n_local)

    _, ys = jax.lax.scan(body, None, x_flat.reshape(nc, T // nc, d))
    return ys.reshape(Bl, L, d), None


def moe_apply(p, x, cfg: MoEConfig, mesh=None):
    """Routed FFN.  x: (B, L, d) → (B, L, d).

    mesh None → single-device math (tests/smoke).  With a mesh, runs under
    shard_map with the EP/TP regime picked from the mesh's model-axis size.
    """
    if mesh is None:
        y, _ = _moe_math(x, p["router"]["w"], p["w1"].astype(x.dtype),
                         p["w3"].astype(x.dtype), p["w2"].astype(x.dtype),
                         cfg, 0, cfg.n_experts, ep=False)
        return y

    msize = mesh.shape[cfg.model_axis]
    ep = cfg.ep_size(msize) > 1
    specs = moe_param_specs(cfg, msize)
    dp = cfg.dp_axes

    def fn(x_l, wr, w1, w3, w2):
        if cfg.fsdp:
            w1 = jax.lax.all_gather(w1, "data", axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, "data", axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, "data", axis=2, tiled=True)
        if ep:
            m = jax.lax.axis_index(cfg.model_axis)
            n_local = cfg.n_experts // msize
            e0 = m * n_local
        else:
            n_local, e0 = cfg.n_experts, 0
        y, _ = _moe_math(x_l, wr, w1.astype(x_l.dtype), w3.astype(x_l.dtype),
                         w2.astype(x_l.dtype), cfg, e0, n_local, ep)
        return jax.lax.psum(y, cfg.model_axis)

    from repro.distributed.compat import shard_map
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp, None, None), specs["router"]["w"], specs["w1"],
                  specs["w3"], specs["w2"]),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(x, p["router"]["w"], p["w1"], p["w3"], p["w2"])
