"""Attention: GQA + RoPE / M-RoPE / qk-norm, flash-chunked softmax, KV cache.

Shapes convention: activations (B, L, D); heads live in the projection dims.
``flash_attention`` streams KV blocks with an online softmax (lax.scan), so
peak memory is O(L·block) instead of O(L²) — required for the 32k-prefill
dry-run cells and a §Perf lever everywhere else.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.kernels import probes
from repro.models.layers import dense, dense_init, rms_norm, rms_norm_init

__all__ = ["AttnConfig", "attn_init", "attn_apply", "init_kv_cache",
           "rope", "flash_attention", "chunk_attention", "attn_decode_paged",
           "attn_prefill_chunk", "attn_verify_cached", "attn_verify_paged",
           "quantize_kv", "dequantize_kv"]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int = 0                # 0 → d_model // n_heads
    qk_norm: bool = False            # qwen3 family
    rope_theta: float = 1e4
    rope_sections: tuple = ()        # M-RoPE (qwen2-vl): head_dim split
    window: int = 0                  # sliding-window size; 0 = full
    causal: bool = True              # False for encoder self-attn
    kv_block: int = 1024             # flash KV chunk

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def groups(self) -> int:
        return self.n_heads // self.n_kv


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32, cross: bool = False):
    ks = jax.random.split(key, 4)
    hd = cfg.hd
    p = {"wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
         "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv * hd, dtype),
         "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv * hd, dtype),
         "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype)}
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(hd, dtype)
        p["k_norm"] = rms_norm_init(hd, dtype)
    del cross
    return p


# --- rotary ------------------------------------------------------------------

def _rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def rope(x, pos, theta: float = 1e4, sections: tuple = ()):
    """Rotary embedding.  x: (B, L, H, hd); pos: (B, L) or (3, B, L) (M-RoPE).

    M-RoPE (qwen2-vl): the head_dim frequency bands are split into
    ``sections`` (e.g. 16/24/24 of hd/2) driven by (temporal, h, w) position
    streams; with a single position stream all sections use it (text mode —
    equivalent to standard RoPE, which is the paper-accurate text behaviour).
    """
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                        # (hd/2,)
    if pos.ndim == 2:
        pos3 = jnp.broadcast_to(pos[None], (3,) + pos.shape)
    else:
        pos3 = pos
    if sections:
        sec_id = jnp.repeat(jnp.arange(len(sections)),
                            jnp.asarray(sections), total_repeat_length=hd // 2)
        sec_id = jnp.minimum(sec_id, 2)
    else:
        sec_id = jnp.zeros((hd // 2,), jnp.int32)
    # angle[b, l, f] = pos3[sec_id[f], b, l] * freqs[f]
    p_sel = jnp.take(pos3, sec_id, axis=0)                # (hd/2, B, L)
    ang = jnp.einsum("fbl,f->blf", p_sel.astype(jnp.float32), freqs)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.stack([xf1 * cos - xf2 * sin, xf1 * sin + xf2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# --- decode attention (Lq == 1): one full einsum over the cache -------------
#
# With the KV cache sequence-sharded over `model` (flash-decode layout),
# this is the form XLA parallelizes correctly: per-shard partial scores,
# softmax max/sum all-reduce of (B, H, 1) scalars, partial PV + all-reduce.
# A lax.scan over KV blocks here would instead force a cache gather.

def _put_rows(dst, new, rows):
    """Per-row cache write: dst (B, S, ...), new (B, 1, ...), rows (B,).

    Each batch row (= serving slot) writes its token at its own sequence
    offset — the vmapped dynamic_update_slice XLA lowers to a scatter the
    while-loop buffer assignment still aliases in place."""
    def put(d, n, i):
        return jax.lax.dynamic_update_slice(d, n.astype(d.dtype),
                                            (i,) + (0,) * (d.ndim - 1))
    return jax.vmap(put)(dst, new, rows)


def _per_row(val, B):
    """Scalar decode bookkeeping broadcasts as-is; a (B,) vector (per-slot
    positions, continuous batching) reshapes to broadcast over the score's
    trailing KV-sequence axis."""
    v = jnp.asarray(val)
    return v.reshape(B, 1, 1, 1, 1) if v.ndim == 1 else v


def decode_attention(q, k, v, kv_len, exclude=None, extra_kv=None):
    """q: (B,1,KV,G,hd); k/v: (B,S,KV,hd) cache (may be *stale*: the current
    token's K/V are passed via ``extra_kv`` so the cache carry can be read
    before it is written — the ordering XLA needs to alias the update in
    place).  ``exclude``: ring slot being evicted this step (masked).
    ``kv_len``/``exclude`` may be scalars (uniform batch) or (B,) vectors
    (per-slot cache positions, see ServeEngine continuous batching)."""
    B, Lq, KV, G, hd = q.shape
    Lk = k.shape[1]
    qf = q.astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    idx = jnp.arange(Lk)[None, None, None, None, :]
    mask = idx < _per_row(kv_len, B)
    if exclude is not None:
        mask = mask & (idx != _per_row(exclude, B))
    s = jnp.where(mask, s, NEG_INF)
    if extra_kv is not None:
        k_new, v_new = extra_kv                       # (B, 1, KV, hd)
        s_new = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_new.astype(jnp.float32))
        s = jnp.concatenate([s, s_new], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    if extra_kv is not None:
        out = jnp.einsum("bkgqs,bskd->bqkgd", p[..., :Lk],
                         v.astype(jnp.float32))
        out = out + jnp.einsum("bkgqs,bskd->bqkgd", p[..., Lk:],
                               extra_kv[1].astype(jnp.float32))
    else:
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# --- paged KV cache (DESIGN.md §8) -------------------------------------------
#
# The cache is a global pool of fixed-size pages (L, n_pages, page, KV, hd);
# a slot's logical sequence is its page-table row gathered in order.  Pages
# store int8 values + per-token-per-head scales (quantize_kv) or plain
# floats.  Logical position s of slot b lives at
# pool[page_table[b, s // page], s % page] — gathers therefore reassemble a
# sequence whose index IS the logical position, so the masks of
# decode_attention/chunk_attention apply unchanged.

def _gather_paged_kv(k_pool, v_pool, page_table, layer, scales=None):
    """Assemble (B, P·page, KV, hd) float K/V for one layer's pool slice.

    k_pool/v_pool: (L, n_pages, page, KV, hd); page_table: (B, P);
    scales: optional (ks, vs) each (L, n_pages, page, KV).  Gathers route
    through ``kernels.ops.gather_pages`` (compiled Pallas on TPU).
    """
    from repro.kernels import ops

    B, P = page_table.shape
    page, KV, hd = k_pool.shape[2:]
    k_pl = jax.lax.dynamic_index_in_dim(k_pool, layer, 0, keepdims=False)
    v_pl = jax.lax.dynamic_index_in_dim(v_pool, layer, 0, keepdims=False)
    k_l = ops.gather_pages(k_pl, page_table).reshape(B, P * page, KV, hd)
    v_l = ops.gather_pages(v_pl, page_table).reshape(B, P * page, KV, hd)
    if scales is not None:
        ks_all, vs_all = scales
        ks_pl = jax.lax.dynamic_index_in_dim(ks_all, layer, 0, keepdims=False)
        vs_pl = jax.lax.dynamic_index_in_dim(vs_all, layer, 0, keepdims=False)
        ks = ops.gather_pages(ks_pl, page_table).reshape(B, P * page, KV)
        vs = ops.gather_pages(vs_pl, page_table).reshape(B, P * page, KV)
        k_l = dequantize_kv(k_l, ks)
        v_l = dequantize_kv(v_l, vs)
    return k_l, v_l


def chunk_attention(q, k_past, v_past, past_len, k_new, v_new):
    """Chunked-prefill attention: full attention to the valid past, causal
    within the chunk.

    q: (B, C, KV, G, hd) — one page-sized chunk of queries at absolute
    positions past_len..past_len+C−1.  k_past/v_past: (B, S, KV, hd)
    gathered pages, valid prefix ``past_len`` (scalar or (B,)).  k_new/v_new:
    (B, C, KV, hd) — the chunk's own K/V (not yet written to the pool; same
    read-before-write posture as decode_attention's ``extra_kv``).
    """
    B, C, KV, G, hd = q.shape
    S = k_past.shape[1]
    qf = q.astype(jnp.float32) * hd ** -0.5
    s_past = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_past.astype(jnp.float32))
    idx = jnp.arange(S)[None, None, None, None, :]
    s_past = jnp.where(idx < _per_row(past_len, B), s_past, NEG_INF)
    s_new = jnp.einsum("bqkgd,bckd->bkgqc", qf, k_new.astype(jnp.float32))
    causal = jnp.arange(C)[:, None] >= jnp.arange(C)[None, :]     # q i ≥ k j
    s_new = jnp.where(causal[None, None, None], s_new, NEG_INF)
    p = jax.nn.softmax(jnp.concatenate([s_past, s_new], axis=-1), axis=-1)
    out = (jnp.einsum("bkgqs,bskd->bqkgd", p[..., :S],
                      v_past.astype(jnp.float32))
           + jnp.einsum("bkgqc,bckd->bqkgd", p[..., S:],
                        v_new.astype(jnp.float32)))
    return out.astype(q.dtype)


def attn_decode_paged(p, x, cfg: AttnConfig, *, pos, page_table, write_pid,
                      write_off, valid_len, k_pool, v_pool, layer,
                      scales=None, mesh=None, dp=None):
    """Paged decode step: gather pages, attend, scatter the token's K/V into
    the tail page.

    page_table: (B, P) physical page ids per slot; write_pid/write_off: (B,)
    physical page + in-page offset receiving this token (the engine routes
    retired slots to the trash page 0).  valid_len: (B,) attendable logical
    prefix (= per-slot ``pos``; the fresh token enters via ``extra_kv``, so
    the possibly-stale tail entry is masked out by ``idx < valid_len``).
    scales present ⇒ int8 pages (quantize-what-you-store, DESIGN.md §4).
    With a mesh, each `model` shard owns an S-slice of every page and the
    softmax joins through two psums (``_paged_flash_shardmap``).
    Returns (out, k_pool, v_pool, new_scales).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg, pos=pos)
    if mesh is not None and k_pool.shape[2] % mesh.shape["model"] == 0:
        num, denom, m_glob, k_pool, v_pool, new_scales = _paged_flash_shardmap(
            q, k, v, k_pool, v_pool, scales, layer, page_table,
            write_pid[:, None], write_off[:, None], valid_len, mesh,
            dp or ("data",))
        out = _join_fresh(q, k, v, num, denom, m_glob)
        out = dense(p["wo"], out.reshape(B, 1, cfg.n_kv * cfg.groups
                                         * cfg.hd), kind="row")
        return out, k_pool, v_pool, new_scales
    k_l, v_l = _gather_paged_kv(k_pool, v_pool, page_table, layer, scales)
    out = decode_attention(q, k_l, v_l, valid_len, extra_kv=(k, v))
    if scales is not None:
        ks_all, vs_all = scales
        kq, ksc = quantize_kv(k)
        vq, vsc = quantize_kv(v)
        ks_all = ks_all.at[layer, write_pid, write_off].set(
            ksc[:, 0].astype(ks_all.dtype))
        vs_all = vs_all.at[layer, write_pid, write_off].set(
            vsc[:, 0].astype(vs_all.dtype))
        k, v, new_scales = kq, vq, (ks_all, vs_all)
    else:
        new_scales = None
    k_pool = k_pool.at[layer, write_pid, write_off].set(
        k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[layer, write_pid, write_off].set(
        v[:, 0].astype(v_pool.dtype))
    out = dense(p["wo"], out.reshape(B, 1, cfg.n_kv * cfg.groups * cfg.hd),
                kind="row")
    return out, k_pool, v_pool, new_scales


def attn_prefill_chunk(p, x, cfg: AttnConfig, *, pos, page_table, write_pid,
                       past_len, k_pool, v_pool, layer, scales=None,
                       mesh=None, dp=None):
    """One page-sized prefill chunk (batch of one) against the paged cache.

    x: (1, C, D) with C == page size; ``past_len`` (scalar) tokens already
    live in the pages of ``page_table`` (1, P).  The chunk's K/V are written
    as ONE page at physical id ``write_pid`` (page-aligned chunking makes
    the store a single dynamic_update_slice; ``write_pid`` 0 targets the
    trash page — used when the chunk's page is a shared prefix-cache hit
    recomputed only for its logits).  Returns (out, k_pool, v_pool, scales).
    """
    B, C, _ = x.shape
    if B != 1:
        # the page store below writes k[:, None] at (layer, pid, 0, 0, 0):
        # a leading batch dim would silently span the LAYER axis
        raise ValueError(f"attn_prefill_chunk is batch-of-one (got B={B}); "
                         "prompts stream through chunks one request at a "
                         "time")
    q, k, v = _project_qkv(p, x, cfg, pos=pos)
    if mesh is not None and k_pool.shape[2] % mesh.shape["model"] == 0:
        # the chunk is one full page: per-token write targets are the same
        # physical page at offsets 0..C−1, so each shard keeps exactly its
        # page slice (write_pid 0 = shared prefix-cache hit → trash)
        pid_t = jnp.broadcast_to(jnp.asarray(write_pid, jnp.int32), (B, C))
        off_t = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[None],
                                 (B, C))
        num, denom, m_glob, k_pool, v_pool, new_scales = _paged_flash_shardmap(
            q, k, v, k_pool, v_pool, scales, layer, page_table, pid_t,
            off_t, past_len, mesh, dp or ("data",))
        out = _join_fresh(q, k, v, num, denom, m_glob)
        out = dense(p["wo"], out.reshape(B, C, cfg.n_kv * cfg.groups
                                         * cfg.hd), kind="row")
        return out, k_pool, v_pool, new_scales
    k_l, v_l = _gather_paged_kv(k_pool, v_pool, page_table, layer, scales)
    out = chunk_attention(q, k_l, v_l, past_len, k, v)
    zero = jnp.zeros((), jnp.int32)
    if scales is not None:
        ks_all, vs_all = scales
        kq, ksc = quantize_kv(k)
        vq, vsc = quantize_kv(v)
        ks_all = jax.lax.dynamic_update_slice(
            ks_all, ksc[:, None].astype(ks_all.dtype),
            (layer, write_pid, zero, zero))
        vs_all = jax.lax.dynamic_update_slice(
            vs_all, vsc[:, None].astype(vs_all.dtype),
            (layer, write_pid, zero, zero))
        k, v, new_scales = kq, vq, (ks_all, vs_all)
    else:
        new_scales = None
    k_pool = jax.lax.dynamic_update_slice(
        k_pool, k[:, None].astype(k_pool.dtype),
        (layer, write_pid, zero, zero, zero))
    v_pool = jax.lax.dynamic_update_slice(
        v_pool, v[:, None].astype(v_pool.dtype),
        (layer, write_pid, zero, zero, zero))
    out = dense(p["wo"], out.reshape(B, C, cfg.n_kv * cfg.groups * cfg.hd),
                kind="row")
    return out, k_pool, v_pool, new_scales


# --- speculative verify (DESIGN.md §9) ---------------------------------------
#
# One batched forward scores the pending token plus K draft proposals per
# slot.  The attention math is chunk_attention's: full attention to the valid
# cached prefix (per-row ``valid_len``), causal among the K1 fresh tokens,
# whose K/V enter via ``k_new``/``v_new`` before being written back — the
# same read-before-write posture that lets XLA alias the cache in place.
# Rejection needs NO cache surgery here: rejected tokens' K/V remain as
# stale rows above the engine's rolled-back per-slot ``pos`` and every later
# step's valid-length mask fences them until they are overwritten.

def attn_verify_cached(p, x, cfg: AttnConfig, *, pos, insert_at, valid_len,
                       k_all, v_all, layer, scales=None, mesh=None, dp=None):
    """Multi-token verify against the stacked (L, B, S, KV, hd) cache.

    x: (B, K1, D) — per slot, the pending last token plus K draft proposals;
    pos: (B, K1) absolute RoPE positions; insert_at: (B,) first cache row
    written (K1 rows land contiguously, clamped to the cache end so retired
    slots lockstep-verify harmlessly into their own tail); valid_len: (B,)
    attendable cached prefix (== the engine's per-slot ``pos``).
    scales: (ks_all, vs_all) when the cache is int8-quantized.
    With a mesh, the cached prefix runs through the S-sharded flash join
    (DESIGN.md §10) and the K1 fresh causal rows fold in replicated.
    Returns (out (B, K1, D), k_all, v_all, new_scales).
    """
    B, K1, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, pos=pos)
    if mesh is not None and k_all.shape[2] % mesh.shape["model"] == 0:
        S = k_all.shape[2]
        rows = jnp.clip(insert_at, 0, S - K1)
        num, denom, m_glob, k_all, v_all, new_scales = _decode_cached_shardmap(
            q, k, v, k_all, v_all, scales, layer, rows, valid_len, None,
            mesh, dp or ("data",))
        out = _join_fresh(q, k, v, num, denom, m_glob)
        out = dense(p["wo"], out.reshape(B, K1, cfg.n_kv * cfg.groups
                                         * cfg.hd), kind="row")
        return out, k_all, v_all, new_scales
    k_raw = jax.lax.dynamic_index_in_dim(k_all, layer, 0, keepdims=False)
    v_raw = jax.lax.dynamic_index_in_dim(v_all, layer, 0, keepdims=False)
    k_l, v_l = k_raw, v_raw
    if scales is not None:
        ks_all, vs_all = scales
        ks_l = jax.lax.dynamic_index_in_dim(ks_all, layer, 0, keepdims=False)
        vs_l = jax.lax.dynamic_index_in_dim(vs_all, layer, 0, keepdims=False)
        k_l = dequantize_kv(k_raw, ks_l)
        v_l = dequantize_kv(v_raw, vs_l)
    out = chunk_attention(q, k_l, v_l, valid_len, k, v)
    S = k_all.shape[2]
    rows = jnp.clip(insert_at, 0, S - K1)
    if scales is not None:
        kq, ksc = quantize_kv(k)
        vq, vsc = quantize_kv(v)
        ks_all = jax.lax.dynamic_update_index_in_dim(
            ks_all, _put_rows(ks_l, ksc, rows).astype(ks_all.dtype), layer, 0)
        vs_all = jax.lax.dynamic_update_index_in_dim(
            vs_all, _put_rows(vs_l, vsc, rows).astype(vs_all.dtype), layer, 0)
        k, v, new_scales = kq, vq, (ks_all, vs_all)
    else:
        new_scales = None
    k_all = jax.lax.dynamic_update_index_in_dim(
        k_all, _put_rows(k_raw, k, rows).astype(k_all.dtype), layer, 0)
    v_all = jax.lax.dynamic_update_index_in_dim(
        v_all, _put_rows(v_raw, v, rows).astype(v_all.dtype), layer, 0)
    out = dense(p["wo"], out.reshape(B, K1, cfg.n_kv * cfg.groups * cfg.hd),
                kind="row")
    return out, k_all, v_all, new_scales


def attn_verify_paged(p, x, cfg: AttnConfig, *, pos, page_table, write_pid,
                      write_off, valid_len, k_pool, v_pool, layer,
                      scales=None, mesh=None, dp=None):
    """Multi-token verify against gathered pages (the paged twin of
    ``attn_verify_cached``).

    write_pid/write_off: (B, K1) per-token physical page + in-page offset —
    the K1 speculative tokens may straddle a page boundary, so each is
    scattered individually; the engine routes positions beyond a slot's
    live page span (speculative overshoot past the admission reservation)
    and retired slots to the trash page 0.  valid_len: (B,) attendable
    logical prefix.  Returns (out, k_pool, v_pool, new_scales).
    """
    B, K1, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, pos=pos)
    if mesh is not None and k_pool.shape[2] % mesh.shape["model"] == 0:
        num, denom, m_glob, k_pool, v_pool, new_scales = _paged_flash_shardmap(
            q, k, v, k_pool, v_pool, scales, layer, page_table, write_pid,
            write_off, valid_len, mesh, dp or ("data",))
        out = _join_fresh(q, k, v, num, denom, m_glob)
        out = dense(p["wo"], out.reshape(B, K1, cfg.n_kv * cfg.groups
                                         * cfg.hd), kind="row")
        return out, k_pool, v_pool, new_scales
    k_l, v_l = _gather_paged_kv(k_pool, v_pool, page_table, layer, scales)
    out = chunk_attention(q, k_l, v_l, valid_len, k, v)
    if scales is not None:
        ks_all, vs_all = scales
        kq, ksc = quantize_kv(k)
        vq, vsc = quantize_kv(v)
        ks_all = ks_all.at[layer, write_pid, write_off].set(
            ksc.astype(ks_all.dtype))
        vs_all = vs_all.at[layer, write_pid, write_off].set(
            vsc.astype(vs_all.dtype))
        k, v, new_scales = kq, vq, (ks_all, vs_all)
    else:
        new_scales = None
    k_pool = k_pool.at[layer, write_pid, write_off].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[layer, write_pid, write_off].set(v.astype(v_pool.dtype))
    out = dense(p["wo"], out.reshape(B, K1, cfg.n_kv * cfg.groups * cfg.hd),
                kind="row")
    return out, k_pool, v_pool, new_scales


# --- flash-chunked attention -------------------------------------------------
#
# Forward: online softmax over KV blocks (lax.scan).  Backward: a REAL flash
# backward via custom_vjp — naive autodiff through the forward scan would
# save every block's probability tensor (≈ the full L×L attention matrix in
# f32; tens of GB/device at 4k×remat and fatal at 32k).  We save only
# (q, k, v, out, m, denom) and re-derive per-block probabilities inside the
# backward scan:  dS = P ⊙ (dOut·Vᵀ − δ),  δ_i = Σ_d dOut_id·Out_id.

def flash_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None,
                    window: int = 0, kv_block: int = 1024):
    """Online-softmax attention over KV blocks.

    q: (B, Lq, KV, G, hd)   grouped query heads
    k, v: (B, Lk, KV, hd)
    q_offset: absolute position of q[0] (decode/prefill continuation).
    kv_len: number of valid KV entries (traced ok); None = Lk.
    Returns (B, Lq, KV, G, hd) in q.dtype.
    """
    if kv_len is None and isinstance(q_offset, int):
        # static masking pattern -> memory-safe custom-vjp path
        return _flash_cvjp(q, k, v, causal, q_offset, window, kv_block)
    out, _, _ = _flash_fwd_scan(q, k, v, causal, q_offset, kv_len, window,
                                kv_block)
    return out


def _mask_for(bi, blk, Lk, qpos, valid_len, causal, window):
    kpos = bi * blk + jnp.arange(blk)
    mask = kpos[None, :] < valid_len
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    return mask


def _flash_fwd_scan(q, k, v, causal, q_offset, kv_len, window, kv_block):
    B, Lq, KV, G, hd = q.shape
    Lk = k.shape[1]
    blk = min(kv_block, Lk)
    n_blk = -(-Lk // blk)
    pad = n_blk * blk - Lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blk, blk, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blk, blk, KV, hd).transpose(1, 0, 2, 3, 4)

    scale = hd ** -0.5
    qpos = q_offset + jnp.arange(Lq)
    valid_len = Lk if kv_len is None else kv_len

    def body(carry, blk_in):
        acc, m, denom, bi = carry
        kblk, vblk = blk_in                                   # (B, blk, KV, hd)
        # storage-dtype operands, f32 MXU accumulation: no full-sequence
        # f32 copies of q are materialised
        s = jnp.einsum("bqkgd,bckd->bkgqc", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = _mask_for(bi, blk, Lk, qpos, valid_len, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        denom = denom * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, denom, bi + 1), None

    acc0 = jnp.zeros((B, KV, G, Lq, hd), jnp.float32)
    m0 = jnp.full((B, KV, G, Lq), NEG_INF)
    d0 = jnp.zeros((B, KV, G, Lq))
    (acc, m, denom, _), _ = jax.lax.scan(body, (acc0, m0, d0, 0), (kb, vb))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).astype(q.dtype)       # (B, Lq, KV, G, hd)
    return out, m, denom


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_cvjp(q, k, v, causal, q_offset, window, kv_block):
    out, _, _ = _flash_fwd_scan(q, k, v, causal, q_offset, None, window,
                                kv_block)
    return out


def _flash_cvjp_fwd(q, k, v, causal, q_offset, window, kv_block):
    out, m, denom = _flash_fwd_scan(q, k, v, causal, q_offset, None, window,
                                    kv_block)
    return out, (q, k, v, out, m, denom)


def _flash_cvjp_bwd(causal, q_offset, window, kv_block, res, g):
    q, k, v, out, m, denom = res
    B, Lq, KV, G, hd = q.shape
    Lk = k.shape[1]
    blk = min(kv_block, Lk)
    n_blk = -(-Lk // blk)
    pad = n_blk * blk - Lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, n_blk, blk, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blk, blk, KV, hd).transpose(1, 0, 2, 3, 4)

    scale = hd ** -0.5
    # keep q/g/out in their storage dtype; upcast per-block inside the scan
    # (full-sequence f32 copies here were ~2.5 GB/device at mistral dims)
    gT = g.transpose(0, 2, 3, 1, 4)                           # (B,KV,G,Lq,hd)
    oT = out.transpose(0, 2, 3, 1, 4)
    delta = jnp.einsum("bkgqd,bkgqd->bkgq", gT.astype(jnp.float32),
                       oT.astype(jnp.float32))                # (B,KV,G,Lq)
    denom = jnp.maximum(denom, 1e-30)
    m = jnp.where(m <= NEG_INF / 2, 0.0, m)   # fully-masked rows: p stays 0
    qpos = q_offset + jnp.arange(Lq)

    def body(dq, blk_in):
        kblk, vblk, bi = blk_in
        s = jnp.einsum("bqkgd,bckd->bkgqc", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = _mask_for(bi, blk, Lk, qpos, Lk, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / denom[..., None]      # (B,KV,G,Lq,c)
        pc = p.astype(g.dtype)
        dv = jnp.einsum("bkgqc,bkgqd->bckd", pc, gT,
                        preferred_element_type=jnp.float32)   # (B,c,KV,hd)
        ds = p * (jnp.einsum("bkgqd,bckd->bkgqc", gT, vblk,
                             preferred_element_type=jnp.float32)
                  - delta[..., None])
        dsc = ds.astype(q.dtype)
        dk = jnp.einsum("bkgqc,bqkgd->bckd", dsc, q,
                        preferred_element_type=jnp.float32) * scale
        dq = dq + jnp.einsum("bkgqc,bckd->bqkgd", dsc, kblk,
                             preferred_element_type=jnp.float32) * scale
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Lq, KV, G, hd), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(
        body, dq0, (kb, vb, jnp.arange(n_blk)))
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(B, n_blk * blk, KV, hd)
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(B, n_blk * blk, KV, hd)
    if pad:
        dk, dv = dk[:, :Lk], dv[:, :Lk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_cvjp.defvjp(_flash_cvjp_fwd, _flash_cvjp_bwd)


# --- full module -------------------------------------------------------------

def init_kv_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.n_kv, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _project_qkv(p, x, cfg: AttnConfig, kv_src=None, pos=None,
                 rope_ok=True):
    B, L, _ = x.shape
    hd, KV, G = cfg.hd, cfg.n_kv, cfg.groups
    kv_src = x if kv_src is None else kv_src
    Lk = kv_src.shape[1]
    q = dense(p["wq"], x, kind="col").reshape(B, L, KV, G, hd)
    k = dense(p["wk"], kv_src, kind="col").reshape(B, Lk, KV, hd)
    v = dense(p["wv"], kv_src, kind="col").reshape(B, Lk, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    if rope_ok:
        q = rope(q.reshape(B, L, KV * G, hd), pos, cfg.rope_theta,
                 cfg.rope_sections).reshape(B, L, KV, G, hd)
        k = rope(k, pos, cfg.rope_theta, cfg.rope_sections)
    return q, k, v


KV_QMAX = 127.0


def quantize_kv(t):
    """(B, L, KV, hd) float -> (int8 values, per-(B,L,KV) bf16 scales).

    Symmetric per-token-per-head max-abs quantization — the serving-side KV
    cache representation (halves cache HBM vs bf16; the same quantize-what-
    you-store posture as the paper's §4 weight indices)."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / KV_QMAX
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -KV_QMAX, KV_QMAX)
    qi, sc = q.astype(jnp.int8), scale[..., 0].astype(jnp.bfloat16)
    # Round-trip error probe against the *stored* (int8, bf16-scale) pair —
    # inert unless a probes.layer frame is open in the current trace (the
    # shard_map TP call sites are auto-fenced by the trace-token guard).
    probes.tap_kv(t, qi, sc)
    return qi, sc


def dequantize_kv(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def _pay_dtype(dt):
    """Collective payload dtype: bf16 caches ship bf16 partial outputs
    (TPU posture — ~3 significant digits, inside the int8-KV noise floor);
    f32 caches ship f32 so the TP engine stays at the single-device noise
    floor (the tp-parity rig asserts token-for-token equality)."""
    return jnp.float32 if dt == jnp.float32 else jnp.bfloat16


def _join_fresh(q, k_new, v_new, num, denom, m_glob):
    """Fold the Lq fresh tokens (causal among themselves, all-visible to
    later ones) into the partial softmax statistics of the sharded past,
    then normalise — the online twin of chunk_attention's concat-softmax.

    q: (B, Lq, KV, G, hd); k_new/v_new: (B, Lq, KV, hd);
    num: (B, KV, G, Lq, hd); denom/m_glob: (B, KV, G, Lq).
    Returns (B, Lq, KV, G, hd) in q.dtype.
    """
    B, Lq, KV, G, hd = q.shape
    qf = q.astype(jnp.float32) * hd ** -0.5
    s_new = jnp.einsum("bqkgd,bckd->bkgqc", qf, k_new.astype(jnp.float32))
    causal = jnp.arange(Lq)[:, None] >= jnp.arange(Lq)[None, :]
    s_new = jnp.where(causal[None, None, None], s_new, NEG_INF)
    m2 = jnp.maximum(m_glob, jnp.max(s_new, axis=-1))
    corr = jnp.exp(m_glob - m2)
    e_new = jnp.exp(s_new - m2[..., None])
    num = num * corr[..., None] + jnp.einsum(
        "bkgqc,bckd->bkgqd", e_new, v_new.astype(jnp.float32))
    denom = denom * corr + jnp.sum(e_new, axis=-1)
    out = num / jnp.maximum(denom[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


def _decode_cached_shardmap(q, k, v, k_all, v_all, scales, layer, ins, vlen,
                            excl, mesh, dp):
    """Explicit flash attention over the `model`-sharded cache sequence dim.

    The XLA-auto path all-gathers each layer's (B, S, KV, hd) cache slice
    inside the decode loop (SPMD cannot re-shard the traced-index
    dynamic-update/read efficiently — confirmed in the dry-run HLO, ~1 GB
    f32 per layer).  Here every shard: reads its LOCAL S-slice, computes
    partial scores, joins softmax statistics with two tiny psums
    ((B,H,Lq) max/denominator and the (B,H,Lq,hd) partial output), and
    writes the Lq fresh tokens' K/V only on their owning shards.
    Collective bytes per layer drop from O(B·S·KV·hd) to O(B·H·hd·Lq).

    Generalised from the PR-1 decode-only form (DESIGN.md §10): Lq >= 1
    fresh rows per slot (speculative verify), ``ins``/``vlen`` scalars OR
    per-row (B,) vectors (the ServeEngine's continuous batching), and an
    optional per-row ``excl`` mask (decode's stale/ring insert row).  The
    Lq rows land contiguously at ins[b]..ins[b]+Lq−1 and must fit one
    shard's slice count (Lq <= S/tp — the engine validates); non-owned
    rows rewrite their current value (in-place friendly, collision-free
    because consecutive rows map injectively under mod-S_loc).

    Returns (num, denom, m_glob, k_all, v_all, scales) — the caller folds
    in the fresh tokens' causal softmax terms (``_join_fresh``).
    """
    B, Lq = q.shape[0], q.shape[1]
    b_ax = dp if B % _dp_size(mesh, dp) == 0 else None
    qspec = P(b_ax, None, None, None, None)
    cspec = P(None, b_ax, "model", None, None)
    sspec = P(None, b_ax, "model", None)
    have_sc = scales is not None
    have_ex = excl is not None
    hd = q.shape[-1]
    ins_v = jnp.broadcast_to(jnp.asarray(ins, jnp.int32), (B,))
    vlen_v = jnp.broadcast_to(jnp.asarray(vlen, jnp.int32), (B,))
    ex_v = (jnp.broadcast_to(jnp.asarray(excl, jnp.int32), (B,))
            if have_ex else jnp.zeros((B,), jnp.int32))

    def f(q, k, v, k_all, v_all, ks, vs, layer, ins, vlen, ex):
        m_id = jax.lax.axis_index("model")
        S_loc = k_all.shape[2]
        start = m_id * S_loc
        k_raw = jax.lax.dynamic_index_in_dim(k_all, layer, 0, keepdims=False)
        v_raw = jax.lax.dynamic_index_in_dim(v_all, layer, 0, keepdims=False)
        k_l, v_l = k_raw, v_raw
        if have_sc:
            # dequantize to bf16, not f32: halves the materialised copies
            k_l = dequantize_kv(k_raw, jax.lax.dynamic_index_in_dim(
                ks, layer, 0, keepdims=False)).astype(jnp.bfloat16)
            v_l = dequantize_kv(v_raw, jax.lax.dynamic_index_in_dim(
                vs, layer, 0, keepdims=False)).astype(jnp.bfloat16)
        # scores: operands stay in cache dtype; accumulate f32 on the MXU —
        # avoids materialising f32 copies of the K/V slices (2× HBM)
        qf = (q.astype(jnp.float32) * hd ** -0.5).astype(k_l.dtype)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_l,
                       preferred_element_type=jnp.float32)
        gidx = start + jnp.arange(S_loc)
        mask = gidx[None, :] < vlen[:, None]
        if have_ex:
            mask = mask & (gidx[None, :] != ex[:, None])
        s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)
        m_glob = jnp.maximum(jax.lax.pmax(m_loc, "model"), NEG_INF / 10)
        p = jnp.exp(s - m_glob[..., None])
        denom = jax.lax.psum(jnp.sum(p, axis=-1), "model")
        pay = _pay_dtype(v_l.dtype)
        num = jax.lax.psum(
            jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_l.dtype), v_l,
                       preferred_element_type=jnp.float32)
            .astype(pay), "model").astype(jnp.float32)
        # write the Lq fresh rows on their owning shards only (same-value
        # rewrite elsewhere keeps the store unconditional => in-place
        # friendly; mod-S_loc keeps a slot's row targets collision-free)
        rows = ins[:, None] + jnp.arange(Lq)[None, :]          # (B, Lq)
        owner = (rows >= start) & (rows < start + S_loc)
        loc = (rows - start) % S_loc
        bidx = jnp.arange(B)[:, None]

        def put(sl, new):
            cur = sl[bidx, loc]
            ow = owner.reshape(owner.shape + (1,) * (cur.ndim - 2))
            upd = jnp.where(ow, new.astype(sl.dtype), cur)
            return sl.at[bidx, loc].set(upd)

        if have_sc:
            kq, ksc = quantize_kv(k)
            vq, vsc = quantize_kv(v)
            ks = jax.lax.dynamic_update_index_in_dim(
                ks, put(jax.lax.dynamic_index_in_dim(ks, layer, 0,
                                                     keepdims=False), ksc),
                layer, 0)
            vs = jax.lax.dynamic_update_index_in_dim(
                vs, put(jax.lax.dynamic_index_in_dim(vs, layer, 0,
                                                     keepdims=False), vsc),
                layer, 0)
            k, v = kq, vq
        k_all = jax.lax.dynamic_update_index_in_dim(
            k_all, put(k_raw, k), layer, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(
            v_all, put(v_raw, v), layer, 0)
        return num, denom, m_glob, k_all, v_all, ks, vs

    from repro.distributed.compat import shard_map
    ks, vs = scales if have_sc else (jnp.zeros((), jnp.int8),) * 2
    num, denom, m_glob, k_all, v_all, ks, vs = shard_map(
        f, mesh=mesh,
        in_specs=(qspec, P(b_ax, None, None, None), P(b_ax, None, None, None),
                  cspec, cspec,
                  sspec if have_sc else P(),
                  sspec if have_sc else P(),
                  P(), P(b_ax), P(b_ax), P(b_ax)),
        out_specs=(P(b_ax, None, None, None, None),
                   P(b_ax, None, None, None),
                   P(b_ax, None, None, None),
                   cspec, cspec,
                   sspec if have_sc else P(),
                   sspec if have_sc else P()),
        check_vma=False,
    )(q, k, v, k_all, v_all, ks, vs, layer, ins_v, vlen_v, ex_v)
    new_scales = (ks, vs) if have_sc else None
    return num, denom, m_glob, k_all, v_all, new_scales


def _paged_flash_shardmap(q, k_new, v_new, k_pool, v_pool, scales, layer,
                          page_table, write_pid, write_off, vlen, mesh, dp):
    """The paged twin of ``_decode_cached_shardmap`` (DESIGN.md §10): each
    `model` shard owns an S-slice of EVERY page — the pool's in-page token
    axis is sharded, so the page *table* stays one (replicated) row per
    slot and every shard makes identical allocation decisions by
    construction.

    Each shard gathers its local slice of the slot's pages, masks by the
    GLOBAL token position (local index t of page p maps to
    ``p·page + shard·page_loc + t%page_loc``), joins softmax statistics
    with the same two psums, and scatters the Lq fresh tokens it owns
    (``write_off`` decides the owner; non-owned tokens are routed to the
    shard's trash page 0 so trash-bound and owned writes can never collide
    on a live location).

    q: (B, Lq, KV, G, hd); k_new/v_new: (B, Lq, KV, hd); write_pid/
    write_off: (B, Lq) per-token physical page + GLOBAL in-page offset;
    vlen: (B,) attendable logical prefix.  Returns (num, denom, m_glob,
    k_pool, v_pool, scales) for ``_join_fresh``.
    """
    B, Lq = q.shape[0], q.shape[1]
    b_ax = dp if B % _dp_size(mesh, dp) == 0 else None
    page = k_pool.shape[2]                      # global tokens per page
    have_sc = scales is not None
    hd = q.shape[-1]
    pspec = P(None, None, "model", None, None)
    sspec = P(None, None, "model", None)
    vlen_v = jnp.broadcast_to(jnp.asarray(vlen, jnp.int32), (B,))

    def f(q, k, v, k_pool, v_pool, ks, vs, layer, pt, pid, off, vlen):
        m_id = jax.lax.axis_index("model")
        page_loc = k_pool.shape[2]              # = page // tp
        k_l, v_l = _gather_paged_kv(k_pool, v_pool, pt, layer,
                                    (ks, vs) if have_sc else None)
        # int8 pages dequantize to f32 (exactly the local paged path's
        # numerics — the tp rig asserts token parity against it); bf16
        # pools stay bf16 and ship bf16 payloads
        if k_l.dtype not in (jnp.float32, jnp.bfloat16):
            k_l, v_l = k_l.astype(jnp.float32), v_l.astype(jnp.float32)
        qf = (q.astype(jnp.float32) * hd ** -0.5).astype(k_l.dtype)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_l,
                       preferred_element_type=jnp.float32)
        t = jnp.arange(k_l.shape[1])
        gpos = (t // page_loc) * page + m_id * page_loc + t % page_loc
        mask = gpos[None, :] < vlen[:, None]
        s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)
        m_glob = jnp.maximum(jax.lax.pmax(m_loc, "model"), NEG_INF / 10)
        pr = jnp.exp(s - m_glob[..., None])
        denom = jax.lax.psum(jnp.sum(pr, axis=-1), "model")
        pay = _pay_dtype(v_l.dtype)
        num = jax.lax.psum(
            jnp.einsum("bkgqs,bskd->bkgqd", pr.astype(v_l.dtype), v_l,
                       preferred_element_type=jnp.float32)
            .astype(pay), "model").astype(jnp.float32)
        # scatter the fresh tokens this shard owns; everyone else's land in
        # the local trash page (page 0 is never allocated — DESIGN.md §8)
        owner = (off // page_loc) == m_id
        pid_w = jnp.where(owner, pid, 0)
        off_w = jnp.where(owner, off % page_loc, 0)
        if have_sc:
            kq, ksc = quantize_kv(k)
            vq, vsc = quantize_kv(v)
            ks = ks.at[layer, pid_w, off_w].set(ksc.astype(ks.dtype))
            vs = vs.at[layer, pid_w, off_w].set(vsc.astype(vs.dtype))
            k, v = kq, vq
        k_pool = k_pool.at[layer, pid_w, off_w].set(k.astype(k_pool.dtype))
        v_pool = v_pool.at[layer, pid_w, off_w].set(v.astype(v_pool.dtype))
        return num, denom, m_glob, k_pool, v_pool, ks, vs

    from repro.distributed.compat import shard_map
    ks, vs = scales if have_sc else (jnp.zeros((), jnp.int8),) * 2
    num, denom, m_glob, k_pool, v_pool, ks, vs = shard_map(
        f, mesh=mesh,
        in_specs=(P(b_ax, None, None, None, None),
                  P(b_ax, None, None, None), P(b_ax, None, None, None),
                  pspec, pspec,
                  sspec if have_sc else P(),
                  sspec if have_sc else P(),
                  P(), P(b_ax, None), P(b_ax, None), P(b_ax, None),
                  P(b_ax)),
        out_specs=(P(b_ax, None, None, None, None),
                   P(b_ax, None, None, None),
                   P(b_ax, None, None, None),
                   pspec, pspec,
                   sspec if have_sc else P(),
                   sspec if have_sc else P()),
        check_vma=False,
    )(q, k_new, v_new, k_pool, v_pool, ks, vs, layer, page_table,
      write_pid, write_off, vlen_v)
    new_scales = (ks, vs) if have_sc else None
    return num, denom, m_glob, k_pool, v_pool, new_scales


def _dp_size(mesh, dp):
    n = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        n *= mesh.shape[a]
    return n


def attn_decode_cached(p, x, cfg: AttnConfig, *, pos, insert_at, valid_len,
                       k_all, v_all, layer, scales=None, mesh=None, dp=None):
    """Decode step against a *stacked* (L, B, S, KV, hd) cache carried
    through the layer scan — the new token's K/V are dynamic-update-sliced
    into the carry (aliased in place by XLA's while-loop buffer assignment,
    so the cache is never double-buffered), then the layer's slice is read
    back for the attention einsum.

    insert_at: ring/linear write position; valid_len: attendable prefix.
    Both accept either scalars (uniform batch — training smoke tests, the
    dry-run decode cells) or (B,) vectors (per-slot cache positions — the
    ServeEngine's continuous batching, where every batch row is a slot at
    its own sequence offset); the sharded flash-decode path supports both
    (DESIGN.md §10) whenever S divides the TP degree.
    scales: (ks_all, vs_all) (L, B, S, KV) when the cache is int8-quantized.
    Returns (out, k_all, v_all, new_scales).
    """
    B = x.shape[0]
    hd = cfg.hd
    q, k, v = _project_qkv(p, x, cfg, pos=pos)
    vec = jnp.ndim(insert_at) == 1

    if mesh is not None and k_all.shape[2] % mesh.shape["model"] == 0:
        # explicit flash-decode over the S-sharded cache (see
        # _decode_cached_shardmap) + fold in the current token's term
        num, denom, m_glob, k_all, v_all, new_scales = _decode_cached_shardmap(
            q, k, v, k_all, v_all, scales, layer, insert_at, valid_len,
            insert_at, mesh, dp or ("data",))
        out = _join_fresh(q, k, v, num, denom, m_glob)
    else:
        # mesh with a non-dividing S falls through to the local form (XLA
        # gathers the cache — correct, none of §5's bandwidth win; the
        # ServeEngine validates divisibility up front)
        # READ the stale slice first — a carry read after the update forces
        # XLA to materialise a cache copy per step; read-before-write aliases.
        k_raw = jax.lax.dynamic_index_in_dim(k_all, layer, 0, keepdims=False)
        v_raw = jax.lax.dynamic_index_in_dim(v_all, layer, 0, keepdims=False)
        k_l, v_l = k_raw, v_raw
        if scales is not None:
            ks_all, vs_all = scales
            ks_l = jax.lax.dynamic_index_in_dim(ks_all, layer, 0,
                                                keepdims=False)
            vs_l = jax.lax.dynamic_index_in_dim(vs_all, layer, 0,
                                                keepdims=False)
            k_l = dequantize_kv(k_raw, ks_l)
            v_l = dequantize_kv(v_raw, vs_l)
        # stale cache: current slot may hold an evicted ring entry — exclude
        # it; the fresh K/V enter through extra_kv.
        out = decode_attention(q, k_l, v_l, valid_len, exclude=insert_at,
                               extra_kv=(k, v))
        zero = jnp.zeros((), jnp.int32)
        if scales is not None:
            kq, ksc = quantize_kv(k)
            vq, vsc = quantize_kv(v)
            if vec:
                ks_l = _put_rows(ks_l, ksc, insert_at)
                vs_l = _put_rows(vs_l, vsc, insert_at)
                ks_all = jax.lax.dynamic_update_index_in_dim(
                    ks_all, ks_l.astype(ks_all.dtype), layer, 0)
                vs_all = jax.lax.dynamic_update_index_in_dim(
                    vs_all, vs_l.astype(vs_all.dtype), layer, 0)
            else:
                ks_all = jax.lax.dynamic_update_slice(
                    ks_all, ksc[None].astype(ks_all.dtype),
                    (layer, zero, insert_at, zero))
                vs_all = jax.lax.dynamic_update_slice(
                    vs_all, vsc[None].astype(vs_all.dtype),
                    (layer, zero, insert_at, zero))
            k, v = kq, vq
            new_scales = (ks_all, vs_all)
        else:
            new_scales = None
        if vec:
            # per-row write offsets: vmap a row-local dynamic_update_slice
            # over the batch/slot dimension
            k_all = jax.lax.dynamic_update_index_in_dim(
                k_all, _put_rows(k_raw, k, insert_at).astype(k_all.dtype),
                layer, 0)
            v_all = jax.lax.dynamic_update_index_in_dim(
                v_all, _put_rows(v_raw, v, insert_at).astype(v_all.dtype),
                layer, 0)
        else:
            k_all = jax.lax.dynamic_update_slice(
                k_all, k[None].astype(k_all.dtype),
                (layer, zero, insert_at, zero, zero))
            v_all = jax.lax.dynamic_update_slice(
                v_all, v[None].astype(v_all.dtype),
                (layer, zero, insert_at, zero, zero))
    out = dense(p["wo"], out.reshape(B, 1, cfg.n_kv * cfg.groups * cfg.hd),
                kind="row")
    return out, k_all, v_all, new_scales


def attn_apply(p, x, cfg: AttnConfig, *, pos=None, cache=None, cache_index=None,
               kv_override=None, kv_valid_len=None, return_kv=False,
               mesh=None):
    """General attention forward.

    x: (B, L, D).  pos: positions (B, L) or (3, B, L).  If ``cache`` is given
    (decode), new K/V are written at ``cache_index`` and attention runs over
    the cache.  ``kv_override``: (B, Lk, D) encoder memory for cross-attn
    (RoPE skipped, cache unused).  ``kv_valid_len``: decode semantics — every
    cache entry below this length is attendable (causality implicit: the
    cache holds only past tokens + the one just written); used both for
    linear caches (pos+1) and ring-buffer windows (min(pos+1, window)).
    Returns (out, new_cache).
    """
    B, L, _ = x.shape
    hd, KV, G = cfg.hd, cfg.n_kv, cfg.groups
    q = dense(p["wq"], x, kind="col").reshape(B, L, KV, G, hd)
    kv_src = x if kv_override is None else kv_override
    Lk = kv_src.shape[1]
    k = dense(p["wk"], kv_src, kind="col").reshape(B, Lk, KV, hd)
    v = dense(p["wv"], kv_src, kind="col").reshape(B, Lk, KV, hd)

    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)

    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    if kv_override is None:  # rotary only for self-attention
        q = rope(q.reshape(B, L, KV * G, hd), pos, cfg.rope_theta,
                 cfg.rope_sections).reshape(B, L, KV, G, hd)
        k = rope(k, pos, cfg.rope_theta, cfg.rope_sections)

    new_cache = None
    q_offset = 0
    kv_len = None
    if cache is not None:
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype),
                                                 cache_index, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype),
                                                 cache_index, axis=1)
        new_cache = {"k": kc, "v": vc}
        k, v = kc, vc
        q_offset = cache_index
        kv_len = cache_index + L

    causal = cfg.causal and kv_override is None
    window = cfg.window
    if kv_valid_len is not None:
        kv_len, causal, window, q_offset = kv_valid_len, False, 0, 0

    if mesh is not None and L > 1 and L % mesh.shape["model"] == 0:
        # GQA head counts rarely divide the TP axis (kv=4..32 vs 16); left
        # to sharding propagation, q/out/g replicate at (B, S, H, hd) —
        # sequence-sharding the attention internals keeps them 1/TP-sized
        # (flash fwd/bwd are row-local in Lq; dk/dv partials psum)
        from repro.distributed.sharding import dp_axes, named
        sp = named(mesh, P(dp_axes(mesh), "model", None, None, None))
        q = jax.lax.with_sharding_constraint(q, sp)

    if L == 1 and kv_valid_len is not None:   # decode fast path
        out = decode_attention(q, k, v, kv_len)
    else:
        out = flash_attention(q, k, v, causal=causal,
                              q_offset=q_offset, kv_len=kv_len,
                              window=window, kv_block=cfg.kv_block)
        if mesh is not None and L > 1 and L % mesh.shape["model"] == 0:
            from repro.distributed.sharding import dp_axes, named
            out = jax.lax.with_sharding_constraint(
                out, named(mesh, P(dp_axes(mesh), "model", None, None,
                                   None)))
    out = dense(p["wo"], out.reshape(B, L, KV * G * hd), kind="row")
    if return_kv:  # prefill: emit this layer's K/V as the cache plane
        return out, {"k": k, "v": v}
    return out, new_cache
