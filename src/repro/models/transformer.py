"""Model assembly for every LM-family architecture in the pool.

One entry point per phase:
    init_params(key, cfg)                       -> params pytree
    loss_fn(params, cfg, batch, mesh)           -> (loss, metrics)   [train]
    prefill(params, cfg, batch, mesh)           -> (logits, cache)   [prefill]
    decode_step(params, cfg, tokens, pos, cache, mesh) -> (logits, cache)
    init_cache(cfg, batch, max_len, dtype)      -> cache pytree

Families: dense / moe / vlm (precomputed patch embeddings in, M-RoPE),
audio (whisper enc-dec; precomputed frame embeddings in), ssm_rwkv (RWKV6),
hybrid (zamba2: Mamba2 stack + one shared attention block re-applied every
`shared_attn_every` layers with a concat-skip from the embeddings).

Depth is always a lax.scan over stacked layer params (O(1) HLO in depth);
`cfg.remat` checkpoints each block.  The paper's technique enters through
(a) `ffn_act` quantization sites and (b) dense() accepting codebook-index
weights (see models/layers.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import probes
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import rwkv as R
from repro.distributed.sharding import shard_act, dp_axes
from jax.sharding import PartitionSpec as P

__all__ = ["init_params", "loss_fn", "forward", "prefill", "decode_step",
           "init_cache", "init_paged_cache", "prefill_chunk", "verify_step",
           "attn_cfg", "moe_cfg", "ssm_cfg", "rwkv_cfg"]

_PAGED_FAMILIES = ("dense", "moe")   # KV-cache LMs the paged path serves


# --- sub-configs -------------------------------------------------------------

def attn_cfg(cfg, *, causal=True, window=None) -> A.AttnConfig:
    return A.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.hd, qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        rope_sections=cfg.rope_sections,
        window=cfg.window if window is None else window,
        causal=causal, kv_block=cfg.kv_block)


def moe_cfg(cfg) -> M.MoEConfig:
    return M.MoEConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                       n_experts=cfg.n_experts, top_k=cfg.top_k,
                       capacity_factor=cfg.moe_capacity,
                       token_chunks=cfg.moe_token_chunks, fsdp=cfg.fsdp,
                       act_kind=cfg.act_kind, act_levels=cfg.act_levels)


def ssm_cfg(cfg) -> S.SSMConfig:
    return S.SSMConfig(d_model=cfg.d_model, d_state=cfg.ssm_state,
                       head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
                       act_kind="silu", act_levels=cfg.act_levels)


def rwkv_cfg(cfg) -> R.RWKVConfig:
    return R.RWKVConfig(d_model=cfg.d_model, head_dim=cfg.rwkv_head_dim,
                        d_ff=cfg.d_ff, chunk=cfg.ssm_chunk,
                        act_levels=cfg.act_levels)


_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


def _dtype(cfg):
    return _DTYPES[cfg.dtype]


# --- init --------------------------------------------------------------------

def _stack_init(key, n: int, init_one):
    """vmap an init function over layer keys → stacked (n, ...) params."""
    return jax.vmap(init_one)(jax.random.split(key, n))


def _dense_block_init(key, cfg, dt):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.rms_norm_init(cfg.d_model, dt),
            "attn": A.attn_init(k1, attn_cfg(cfg), dt),
            "ln2": L.rms_norm_init(cfg.d_model, dt),
            "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dt)}


def _moe_block_init(key, cfg, dt):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.rms_norm_init(cfg.d_model, dt),
            "attn": A.attn_init(k1, attn_cfg(cfg), dt),
            "ln2": L.rms_norm_init(cfg.d_model, dt),
            "moe": M.moe_init(k2, moe_cfg(cfg), dt)}


def _rwkv_block_init(key, cfg, dt):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.rms_norm_init(cfg.d_model, dt),
            "tm": R.rwkv_tm_init(k1, rwkv_cfg(cfg), dt),
            "ln2": L.rms_norm_init(cfg.d_model, dt),
            "cm": R.rwkv_cm_init(k2, rwkv_cfg(cfg), dt)}


def _mamba_block_init(key, cfg, dt):
    return {"ln": L.rms_norm_init(cfg.d_model, dt),
            "ssm": S.ssm_init(key, ssm_cfg(cfg), dt)}


def _shared_block_init(key, cfg, dt):
    """Zamba shared transformer block: concat(h, embed) -> d, attn, mlp."""
    k0, k1, k2 = jax.random.split(key, 3)
    return {"in_proj": L.dense_init(k0, 2 * cfg.d_model, cfg.d_model, dt),
            "ln1": L.rms_norm_init(cfg.d_model, dt),
            "attn": A.attn_init(k1, attn_cfg(cfg), dt),
            "ln2": L.rms_norm_init(cfg.d_model, dt),
            "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dt)}


def _enc_block_init(key, cfg, dt):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.layer_norm_init(cfg.d_model, dt),
            "attn": A.attn_init(k1, attn_cfg(cfg, causal=False), dt),
            "ln2": L.layer_norm_init(cfg.d_model, dt),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dt)}


def _dec_block_init(key, cfg, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.layer_norm_init(cfg.d_model, dt),
            "attn": A.attn_init(k1, attn_cfg(cfg), dt),
            "ln_x": L.layer_norm_init(cfg.d_model, dt),
            "xattn": A.attn_init(k2, attn_cfg(cfg, causal=False), dt),
            "ln2": L.layer_norm_init(cfg.d_model, dt),
            "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, dt)}


def init_params(key, cfg):
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    p = {}
    if cfg.family != "vlm":
        p["embed"] = L.embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dt)
    else:
        p["embed"] = L.embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.padded_vocab, dt)
    p["final_norm"] = (L.layer_norm_init(cfg.d_model, dt)
                       if cfg.family == "audio"
                       else L.rms_norm_init(cfg.d_model, dt))

    if cfg.family in ("dense", "vlm"):
        p["blocks"] = _stack_init(keys[2], cfg.n_layers,
                                  lambda k: _dense_block_init(k, cfg, dt))
    elif cfg.family == "moe":
        p["blocks"] = _stack_init(keys[2], cfg.n_layers,
                                  lambda k: _moe_block_init(k, cfg, dt))
    elif cfg.family == "ssm_rwkv":
        p["blocks"] = _stack_init(keys[2], cfg.n_layers,
                                  lambda k: _rwkv_block_init(k, cfg, dt))
    elif cfg.family == "hybrid":
        p["blocks"] = _stack_init(keys[2], cfg.n_layers,
                                  lambda k: _mamba_block_init(k, cfg, dt))
        p["shared"] = _shared_block_init(keys[3], cfg, dt)
    elif cfg.family == "audio":
        p["enc_pos"] = {"table": (jax.random.normal(keys[4],
                        (cfg.enc_len, cfg.d_model)) * 0.02).astype(dt)}
        p["enc_blocks"] = _stack_init(keys[5], cfg.enc_layers,
                                      lambda k: _enc_block_init(k, cfg, dt))
        p["blocks"] = _stack_init(keys[2], cfg.n_layers,
                                  lambda k: _dec_block_init(k, cfg, dt))
        p["enc_norm"] = L.layer_norm_init(cfg.d_model, dt)
    else:
        raise ValueError(cfg.family)
    return p


# --- block forwards (single layer; scanned over the stack) -------------------

def _dense_block(p, x, cfg, mesh, pos, cache=None, ci=None, acfg=None,
                 vlen=None):
    acfg = acfg or attn_cfg(cfg)
    a, kv = A.attn_apply(p["attn"], L.rms_norm(p["ln1"], x), acfg,
                         pos=pos, cache=cache, cache_index=ci,
                         kv_valid_len=vlen, mesh=mesh)
    x = shard_act(x + a, mesh)
    if "moe" in p:
        y = M.moe_apply(p["moe"], L.rms_norm(p["ln2"], x), moe_cfg(cfg), mesh)
    else:
        y = L.swiglu(p["mlp"], L.rms_norm(p["ln2"], x),
                     cfg.act_kind, cfg.act_levels, mesh)
    return shard_act(x + y, mesh), kv


def _act_spec(cfg, mesh, x):
    """Pure-DP batch layout for sequential-scan families: sharding S or D
    over `model` wraps the time scan in per-layer gathers; batch over
    (dp × model) keeps every WKV step device-local (ZeRO-3 supplies the
    weights).  Falls back to the default policy when batch doesn't divide."""
    if mesh is None or not cfg.batch_over_model:
        return None
    ax = dp_axes(mesh) + ("model",)
    total = 1
    for a in ax:
        total *= mesh.shape[a]
    if x.shape[0] % total == 0:
        return P(ax, *([None] * (x.ndim - 1)))
    return None


def _rwkv_block(p, x, cfg, mesh, state=None, decode=False):
    rcfg = rwkv_cfg(cfg)
    f_tm = R.rwkv_tm_decode if decode else R.rwkv_tm_apply
    f_cm = R.rwkv_cm_decode if decode else R.rwkv_cm_apply
    spec = _act_spec(cfg, mesh, x)
    tm, st_tm = f_tm(p["tm"], L.rms_norm(p["ln1"], x), rcfg, state)
    x = shard_act(x + tm, mesh, spec)
    cm, st_cm = f_cm(p["cm"], L.rms_norm(p["ln2"], x), rcfg, state)
    x = shard_act(x + cm, mesh, spec)
    return x, {**st_tm, **st_cm}


def _mamba_block(p, x, cfg, mesh, cache=None, decode=False):
    scfg = ssm_cfg(cfg)
    if decode:
        y, new_cache = S.ssm_decode_step(p["ssm"], L.rms_norm(p["ln"], x),
                                         scfg, cache)
    else:
        y, new_cache = S.ssm_apply(p["ssm"], L.rms_norm(p["ln"], x), scfg), None
    return shard_act(x + y, mesh, _act_spec(cfg, mesh, x)), new_cache


def _shared_block(p, x, x0, cfg, mesh, pos, cache=None, ci=None, window=None,
                  vlen=None):
    h = L.dense(p["in_proj"], jnp.concatenate([x, x0], axis=-1))
    acfg = attn_cfg(cfg, window=window)
    a, kv = A.attn_apply(p["attn"], L.rms_norm(p["ln1"], h), acfg,
                         pos=pos, cache=cache, cache_index=ci,
                         kv_valid_len=vlen, mesh=mesh)
    h = h + a
    h = h + L.swiglu(p["mlp"], L.rms_norm(p["ln2"], h),
                     cfg.act_kind, cfg.act_levels, mesh)
    return shard_act(x + h, mesh, _act_spec(cfg, mesh, x)), kv


def _enc_block(p, x, cfg, mesh):
    acfg = attn_cfg(cfg, causal=False)
    a, _ = A.attn_apply(p["attn"], L.layer_norm(p["ln1"], x), acfg,
                        mesh=mesh)
    x = shard_act(x + a, mesh)
    y = L.mlp_block(p["mlp"], L.layer_norm(p["ln2"], x),
                    cfg.act_kind, cfg.act_levels, mesh)
    return shard_act(x + y, mesh)


def _dec_block(p, x, memory, cfg, mesh, pos, cache=None, ci=None, vlen=None):
    a, kv = A.attn_apply(p["attn"], L.layer_norm(p["ln1"], x),
                         attn_cfg(cfg), pos=pos, cache=cache, cache_index=ci,
                         kv_valid_len=vlen, mesh=mesh)
    x = shard_act(x + a, mesh)
    c, _ = A.attn_apply(p["xattn"], L.layer_norm(p["ln_x"], x),
                        attn_cfg(cfg, causal=False), kv_override=memory)
    x = shard_act(x + c, mesh)
    y = L.mlp_block(p["mlp"], L.layer_norm(p["ln2"], x),
                    cfg.act_kind, cfg.act_levels, mesh)
    return shard_act(x + y, mesh), kv


# --- scan helpers ------------------------------------------------------------

def _unroll(cfg):
    return True if cfg.scan_unroll else 1


def _scan(block_fn, x, stacked, cfg, with_cache=False, cache=None):
    """scan over stacked layer params (and per-layer caches)."""
    fn = jax.checkpoint(block_fn) if cfg.remat else block_fn

    if with_cache:
        def body(h, xs):
            p_l, c_l = xs
            h, c_new = fn(p_l, h, c_l)
            return h, c_new
        x, new_cache = jax.lax.scan(body, x, (stacked, cache),
                                    unroll=_unroll(cfg))
        return x, new_cache

    def body(h, p_l):
        h, _ = fn(p_l, h, None)
        return h, None
    x, _ = jax.lax.scan(body, x, stacked, unroll=_unroll(cfg))
    return x, None


# --- forward (train / prefill trunk) ------------------------------------------

def _logits(p, cfg, x):
    if cfg.tie_embeddings:
        t = (p["embed"]["codebook"][p["embed"]["w_idx"].astype(jnp.int32)]
             if "w_idx" in p["embed"] else p["embed"]["table"])
        logits = jnp.dot(x, t.T, preferred_element_type=jnp.float32)
    else:
        logits = L.dense(p["lm_head"], x, kind="col").astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:  # mask padded ids
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return logits


def _encoder(p, cfg, frames, mesh):
    x = frames.astype(_dtype(cfg)) + p["enc_pos"]["table"][None, :frames.shape[1]]

    def blk(p_l, h, _):
        return _enc_block(p_l, h, cfg, mesh), None
    x, _ = _scan(blk, x, p["enc_blocks"], cfg)
    return L.layer_norm(p["enc_norm"], x)


def forward(params, cfg, batch, mesh=None):
    """Trunk forward → logits (B, L, padded_vocab) f32.

    batch keys: 'tokens' (B, L) always (labels derived by shift);
    vlm: + 'embeds' (B, L, d), 'positions' (3, B, L);
    audio: + 'frames' (B, enc_len, d).
    """
    dt = _dtype(cfg)
    tokens = batch["tokens"]
    pos = None
    if cfg.family == "vlm":
        x = batch["embeds"].astype(dt)
        pos = batch.get("positions")
    else:
        x = L.embed_lookup(params["embed"], tokens).astype(dt)
    x = shard_act(x, mesh, _act_spec(cfg, mesh, x))

    if cfg.family in ("dense", "vlm", "moe"):
        def blk(p_l, h, _):
            h, _kv = _dense_block(p_l, h, cfg, mesh, pos)
            return h, None
        x, _ = _scan(blk, x, params["blocks"], cfg)

    elif cfg.family == "ssm_rwkv":
        def blk(p_l, h, _):
            h, _st = _rwkv_block(p_l, h, cfg, mesh)
            return h, None
        x, _ = _scan(blk, x, params["blocks"], cfg)

    elif cfg.family == "hybrid":
        G = cfg.shared_attn_every
        n_groups = cfg.n_layers // G
        x0 = x
        stacked = jax.tree.map(
            lambda a: a.reshape((n_groups, G) + a.shape[1:]), params["blocks"])
        shared = params["shared"]

        def group(h, p_g):
            h, _ = _shared_block(shared, h, x0, cfg, mesh, pos)

            def blk(p_l, hh, _):
                hh, _c = _mamba_block(p_l, hh, cfg, mesh)
                return hh, None
            h, _ = _scan(blk, h, p_g, cfg)
            return h, None
        if cfg.remat:
            group = jax.checkpoint(group)
        x, _ = jax.lax.scan(group, x, stacked, unroll=_unroll(cfg))

    elif cfg.family == "audio":
        memory = _encoder(params, cfg, batch["frames"], mesh)

        def blk(p_l, h, _):
            h, _kv = _dec_block(p_l, h, memory, cfg, mesh, pos)
            return h, None
        x, _ = _scan(blk, x, params["blocks"], cfg)
    else:
        raise ValueError(cfg.family)

    norm = L.layer_norm if cfg.family == "audio" else L.rms_norm
    x = norm(params["final_norm"], x)
    logits = _logits(params, cfg, x)
    if mesh is not None:
        lspec = _act_spec(cfg, mesh, logits)
        logits = shard_act(logits, mesh,
                           lspec or P(dp_axes(mesh), None, "model"))
    return logits


def loss_fn(params, cfg, batch, mesh=None):
    """Next-token CE (teacher forcing), mean over real (non-pad) targets."""
    logits = forward(params, cfg, batch, mesh)
    targets = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    lse = jax.nn.logsumexp(lg, axis=-1)
    true = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    mask = jnp.ones_like(targets, jnp.float32) if mask is None else mask[:, 1:]
    loss = jnp.sum((lse - true) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "ntokens": jnp.sum(mask)}


# --- caches & decode ----------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, mesh=None):
    """Decode cache pytree (per-family)."""
    hd, KV = cfg.hd, cfg.n_kv
    Lg = cfg.n_layers

    def _kv(layers):
        if cfg.kv_quant:
            return {"k": jnp.zeros((layers, batch, max_len, KV, hd), jnp.int8),
                    "v": jnp.zeros((layers, batch, max_len, KV, hd), jnp.int8),
                    "k_scale": jnp.zeros((layers, batch, max_len, KV),
                                         jnp.bfloat16),
                    "v_scale": jnp.zeros((layers, batch, max_len, KV),
                                         jnp.bfloat16)}
        return {"k": jnp.zeros((layers, batch, max_len, KV, hd), dtype),
                "v": jnp.zeros((layers, batch, max_len, KV, hd), dtype)}

    if cfg.family in ("dense", "vlm", "moe"):
        return {"kv": _kv(Lg), "pos": jnp.zeros((), jnp.int32)}

    if cfg.family == "ssm_rwkv":
        r = rwkv_cfg(cfg)
        H, Pd = r.n_heads, r.head_dim
        return {"s": jnp.zeros((Lg, batch, H, Pd, Pd), jnp.float32),
                "x_tm": jnp.zeros((Lg, batch, 1, cfg.d_model), dtype),
                "x_cm": jnp.zeros((Lg, batch, 1, cfg.d_model), dtype),
                "pos": jnp.zeros((), jnp.int32)}

    if cfg.family == "hybrid":
        s = ssm_cfg(cfg)
        G = cfg.shared_attn_every
        n_groups = cfg.n_layers // G
        # beyond ~64k the shared-attn cache becomes a ring buffer of
        # `long_window` (this is what makes the 500k cell sub-quadratic and
        # O(window) in memory; the SSM states carry the full context)
        win = min(max_len, cfg.long_window) if max_len > 65536 else max_len
        return {
            "h": jnp.zeros((Lg, batch, s.n_heads, s.d_state, s.head_dim),
                           jnp.float32),
            "conv": jnp.zeros((Lg, batch, s.conv_width - 1,
                               s.d_inner + 2 * s.n_groups * s.d_state), dtype),
            "shared_k": jnp.zeros((n_groups, batch, win, KV, hd), dtype),
            "shared_v": jnp.zeros((n_groups, batch, win, KV, hd), dtype),
            "pos": jnp.zeros((), jnp.int32)}

    if cfg.family == "audio":
        return {"kv": _kv(Lg),
                "memory": jnp.zeros((batch, cfg.enc_len, cfg.d_model), dtype),
                "pos": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.family)


def init_paged_cache(cfg, n_pages: int, page_size: int, dtype=jnp.bfloat16):
    """Paged KV page pool (DESIGN.md §8): (L, n_pages, page, KV, hd) arrays.

    ``dtype=jnp.int8`` stores pages quantized with per-token-per-head scales
    (``attention.quantize_kv`` — the serving-state analogue of the paper's
    §4 weight indices); any float dtype stores them plain.  Page 0 is the
    allocator's trash page (serving/kvcache.py): retired slots keep
    lockstep-decoding into it, so it is never handed out.
    """
    if cfg.family not in _PAGED_FAMILIES:
        raise NotImplementedError(
            f"paged KV cache serves families {_PAGED_FAMILIES}; got "
            f"{cfg.family!r}")
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv, cfg.hd)
    if dtype == jnp.int8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
                "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _paged_scales(cache):
    if "k_scale" in cache:
        return (cache["k_scale"], cache["v_scale"])
    return None


def prefill_chunk(params, cfg, batch, cache, mesh=None):
    """Chunked prefill: one page-sized chunk of ONE request's prompt.

    Long prompts stream through this in page-sized chunks instead of forcing
    a new power-of-two prefill bucket — the compile footprint of the paged
    engine is a single chunk shape.  batch keys:

        tokens    (1, C) int32, C == page size (final chunk right-padded —
                  padded keys are causally invisible to real queries and the
                  page's padded tail is fenced by the decode valid-length
                  mask until overwritten)
        start     scalar int32, absolute position of tokens[0] (page-aligned)
        length    scalar int32, real tokens in this chunk (logits are taken
                  at start+length−1)
        page_row  (P,) int32, the slot's page table
        write_pid scalar int32, physical page receiving this chunk's K/V
                  (0 = trash: shared prefix-cache pages are recomputed for
                  logits only, never rewritten)

    cache: paged pool (init_paged_cache).  Returns (logits (1, 1, V) at the
    chunk's last real position, new cache).
    """
    if cfg.family not in _PAGED_FAMILIES:
        raise NotImplementedError(cfg.family)
    dt = _dtype(cfg)
    dp = dp_axes(mesh) if mesh is not None else None
    tokens = batch["tokens"]
    start = jnp.asarray(batch["start"], jnp.int32)
    length = jnp.asarray(batch["length"], jnp.int32)
    page_table = jnp.asarray(batch["page_row"], jnp.int32)[None]    # (1, P)
    write_pid = jnp.asarray(batch["write_pid"], jnp.int32)
    B, C = tokens.shape
    pos = start + jnp.arange(C)[None]                               # (1, C)
    x = L.embed_lookup(params["embed"], tokens).astype(dt)
    acfg = attn_cfg(cfg)

    ps0 = cache.get("probes", {})
    if ps0:
        n_pages = cache["k"].shape[1]
        ps0 = probes.bump(ps0, "page_oob", jnp.sum(
            (page_table < 0) | (page_table >= n_pages)).astype(jnp.float32))

    def body(carry, p_l):
        h, kc, vc, sc, l, ps = carry
        with probes.layer(ps, l) as pb:
            a, kc, vc, sc = A.attn_prefill_chunk(
                p_l["attn"], L.rms_norm(p_l["ln1"], h), acfg, pos=pos,
                page_table=page_table, write_pid=write_pid, past_len=start,
                k_pool=kc, v_pool=vc, layer=l, scales=sc, mesh=mesh, dp=dp)
            h = h + a
            if "moe" in p_l:
                y = M.moe_apply(p_l["moe"], L.rms_norm(p_l["ln2"], h),
                                moe_cfg(cfg), mesh)
            else:
                y = L.swiglu(p_l["mlp"], L.rms_norm(p_l["ln2"], h),
                             cfg.act_kind, cfg.act_levels, mesh)
        return (h + y, kc, vc, sc, l + 1, pb.state), None

    (x, nk, nv, nsc, _, ps1), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"], _paged_scales(cache),
               jnp.zeros((), jnp.int32), ps0),
        params["blocks"], unroll=_unroll(cfg))
    new_cache = {**cache, "k": nk, "v": nv}
    if nsc is not None:
        new_cache.update(k_scale=nsc[0], v_scale=nsc[1])
    if ps0:
        new_cache["probes"] = probes.bump(ps1, "tokens",
                                          length.astype(jnp.float32))
    x_last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
    x_last = L.rms_norm(params["final_norm"], x_last)
    return _logits(params, cfg, x_last), new_cache


def _decode_step_paged(params, cfg, tokens, cache, mesh):
    """One paged decode step: per-slot page tables, (B,) positions.

    ``cache['pos']`` MUST be a (B,) vector (every batch row is a serving
    slot); logical position s of slot b lives at
    pool[page_table[b, s // page], s % page].  Retired slots carry an
    all-zero page-table row, so their lockstep writes land in the trash
    page and never touch pages reallocated to newcomers.
    """
    if cfg.family not in _PAGED_FAMILIES:
        raise NotImplementedError(cfg.family)
    dt = _dtype(cfg)
    dp = dp_axes(mesh) if mesh is not None else None
    pos = cache["pos"]
    pt = cache["page_table"]
    B = tokens.shape[0]
    page = cache["k"].shape[2]
    S_cap = pt.shape[1] * page
    ins = jnp.minimum(pos, S_cap - 1)
    vlen = jnp.minimum(pos, S_cap)          # fresh token enters via extra_kv
    write_pid = pt[jnp.arange(B), ins // page]
    write_off = ins % page
    x = L.embed_lookup(params["embed"], tokens).astype(dt)
    acfg = attn_cfg(cfg)

    ps0 = cache.get("probes", {})
    if ps0:
        n_pages = cache["k"].shape[1]
        ps0 = probes.bump(ps0, "page_oob", jnp.sum(
            (pt < 0) | (pt >= n_pages)).astype(jnp.float32))

    def body(carry, p_l):
        h, kc, vc, sc, l, ps = carry
        with probes.layer(ps, l) as pb:
            a, kc, vc, sc = A.attn_decode_paged(
                p_l["attn"], L.rms_norm(p_l["ln1"], h), acfg,
                pos=pos[:, None].astype(jnp.int32), page_table=pt,
                write_pid=write_pid, write_off=write_off, valid_len=vlen,
                k_pool=kc, v_pool=vc, layer=l, scales=sc, mesh=mesh, dp=dp)
            h = h + a
            if "moe" in p_l:
                y = M.moe_apply(p_l["moe"], L.rms_norm(p_l["ln2"], h),
                                moe_cfg(cfg), mesh)
            else:
                y = L.swiglu(p_l["mlp"], L.rms_norm(p_l["ln2"], h),
                             cfg.act_kind, cfg.act_levels, mesh)
        return (h + y, kc, vc, sc, l + 1, pb.state), None

    (x, nk, nv, nsc, _, ps1), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"], _paged_scales(cache),
               jnp.zeros((), jnp.int32), ps0),
        params["blocks"], unroll=_unroll(cfg))
    new_cache = {**cache, "k": nk, "v": nv, "pos": pos + 1}
    if nsc is not None:
        new_cache.update(k_scale=nsc[0], v_scale=nsc[1])
    if ps0:
        new_cache["probes"] = probes.bump(ps1, "tokens", float(B))
    x = L.rms_norm(params["final_norm"], x)
    return _logits(params, cfg, x), new_cache


def _verify_step_paged(params, cfg, tokens, cache, mesh):
    """Paged multi-token verify: K1 tokens per slot against gathered pages.

    Per-token write routing: logical position ``pos + i`` of slot b lives at
    page ``page_table[b, (pos+i) // page]``; positions past the slot's page
    span (speculative overshoot beyond the admission reservation — those
    tokens are guaranteed to be clamped away by the engine) and retired
    slots (all-zero page-table rows) route to the trash page 0.
    """
    if cfg.family not in _PAGED_FAMILIES:
        raise NotImplementedError(cfg.family)
    dt = _dtype(cfg)
    dp = dp_axes(mesh) if mesh is not None else None
    pos = cache["pos"]
    pt = cache["page_table"]
    B, K1 = tokens.shape
    page = cache["k"].shape[2]
    P = pt.shape[1]
    S_cap = P * page
    ppos = pos[:, None] + jnp.arange(K1)[None]                  # (B, K1)
    pidx = ppos // page
    write_pid = jnp.where(
        pidx < P, pt[jnp.arange(B)[:, None], jnp.minimum(pidx, P - 1)], 0)
    write_off = ppos % page
    vlen = jnp.minimum(pos, S_cap)
    x = L.embed_lookup(params["embed"], tokens).astype(dt)
    acfg = attn_cfg(cfg)

    ps0 = cache.get("probes", {})
    if ps0:
        n_pages = cache["k"].shape[1]
        ps0 = probes.bump(ps0, "page_oob", jnp.sum(
            (pt < 0) | (pt >= n_pages)).astype(jnp.float32))

    def body(carry, p_l):
        h, kc, vc, sc, l, ps = carry
        with probes.layer(ps, l) as pb:
            a, kc, vc, sc = A.attn_verify_paged(
                p_l["attn"], L.rms_norm(p_l["ln1"], h), acfg, pos=ppos,
                page_table=pt, write_pid=write_pid, write_off=write_off,
                valid_len=vlen, k_pool=kc, v_pool=vc, layer=l, scales=sc,
                mesh=mesh, dp=dp)
            h = h + a
            if "moe" in p_l:
                y = M.moe_apply(p_l["moe"], L.rms_norm(p_l["ln2"], h),
                                moe_cfg(cfg), mesh)
            else:
                y = L.swiglu(p_l["mlp"], L.rms_norm(p_l["ln2"], h),
                             cfg.act_kind, cfg.act_levels, mesh)
        return (h + y, kc, vc, sc, l + 1, pb.state), None

    (x, nk, nv, nsc, _, ps1), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"], _paged_scales(cache),
               jnp.zeros((), jnp.int32), ps0),
        params["blocks"], unroll=_unroll(cfg))
    new_cache = {**cache, "k": nk, "v": nv}
    if nsc is not None:
        new_cache.update(k_scale=nsc[0], v_scale=nsc[1])
    if ps0:
        new_cache["probes"] = probes.bump(ps1, "tokens", float(B * K1))
    x = L.rms_norm(params["final_norm"], x)
    return _logits(params, cfg, x), new_cache


def verify_step(params, cfg, tokens, cache, mesh=None):
    """Multi-token speculative verify (DESIGN.md §9).  tokens: (B, K1) — per
    slot, the pending last token followed by K draft proposals.  Returns
    (logits (B, K1, V) at EVERY position, new cache).

    ``logits[:, i]`` is the target distribution for the token *after*
    ``tokens[:, i]`` — one jitted forward replaces K1 sequential decode
    steps.  K/V for all K1 tokens are written at rows pos..pos+K1−1 per
    slot; ``cache['pos']`` comes back UNCHANGED — the caller advances it by
    however many tokens survive rejection sampling.  Rolling back after a
    rejection is therefore free: the rejected suffix is stale rows above
    ``pos``, fenced by every later step's valid-length mask exactly like a
    retired slot's tail.  ``cache['pos']`` must be the (B,) per-slot vector
    form (scalars are broadcast); a cache carrying a ``page_table`` takes
    the paged path.  KV-cache engine families only (dense/moe).
    """
    if "page_table" in cache:
        return _verify_step_paged(params, cfg, tokens, cache, mesh)
    if cfg.family not in _PAGED_FAMILIES:
        raise NotImplementedError(
            f"verify_step serves KV-cache families {_PAGED_FAMILIES}; got "
            f"{cfg.family!r}")
    dt = _dtype(cfg)
    B, K1 = tokens.shape
    pos_any = cache["pos"]
    pos_v = (pos_any if pos_any.ndim == 1
             else jnp.broadcast_to(pos_any, (B,))).astype(jnp.int32)
    S = cache["kv"]["k"].shape[2]
    ins = jnp.minimum(pos_v, S - K1)           # clamp: retired slots
    vlen = jnp.minimum(pos_v, S)
    ppos = pos_v[:, None] + jnp.arange(K1)[None]                # (B, K1) RoPE
    x = L.embed_lookup(params["embed"], tokens).astype(dt)
    acfg = attn_cfg(cfg)
    qkv = cfg.kv_quant

    ps0 = cache.get("probes", {})

    def body(carry, p_l):
        h, kc, vc, sc, l, ps = carry
        with probes.layer(ps, l) as pb:
            a, kc, vc, sc = A.attn_verify_cached(
                p_l["attn"], L.rms_norm(p_l["ln1"], h), acfg, pos=ppos,
                insert_at=ins, valid_len=vlen, k_all=kc, v_all=vc, layer=l,
                scales=sc, mesh=mesh,
                dp=dp_axes(mesh) if mesh is not None else None)
            h = h + a
            if "moe" in p_l:
                y = M.moe_apply(p_l["moe"], L.rms_norm(p_l["ln2"], h),
                                moe_cfg(cfg), mesh)
            else:
                y = L.swiglu(p_l["mlp"], L.rms_norm(p_l["ln2"], h),
                             cfg.act_kind, cfg.act_levels, mesh)
        return (h + y, kc, vc, sc, l + 1, pb.state), None

    sc0 = ((cache["kv"]["k_scale"], cache["kv"]["v_scale"]) if qkv else None)
    (x, nk, nv, nsc, _, ps1), _ = jax.lax.scan(
        body, (x, cache["kv"]["k"], cache["kv"]["v"], sc0,
               jnp.zeros((), jnp.int32), ps0),
        params["blocks"], unroll=_unroll(cfg))
    new_kv = {"k": nk, "v": nv}
    if qkv:
        new_kv.update(k_scale=nsc[0], v_scale=nsc[1])
    x = L.rms_norm(params["final_norm"], x)
    out_cache = {**cache, "kv": new_kv}
    if ps0:
        out_cache["probes"] = probes.bump(ps1, "tokens", float(B * K1))
    return _logits(params, cfg, x), out_cache


def decode_step(params, cfg, tokens, cache, mesh=None):
    """One decode step.  tokens: (B, 1) int32.  Returns (logits, new cache).

    ``cache['pos']`` is either a scalar (uniform batch: every row is at the
    same sequence offset — training smoke tests, dry-run cells) or a (B,)
    vector of per-slot positions (the ServeEngine's continuous batching,
    where batch rows are slots holding requests of different ages).  The
    vector form is supported for the KV-cache families (dense/vlm/moe/
    audio); recurrent-state families decode uniform batches only.

    A cache carrying a ``page_table`` is a paged page pool
    (``init_paged_cache``) and takes the paged path instead of the
    contiguous slab.
    """
    if "page_table" in cache:
        return _decode_step_paged(params, cfg, tokens, cache, mesh)
    dt = _dtype(cfg)
    pos_any = cache["pos"]
    B = tokens.shape[0]
    if pos_any.ndim == 1:
        if cfg.family not in ("dense", "vlm", "moe", "audio"):
            raise NotImplementedError(
                f"per-slot decode positions need a KV cache; family "
                f"{cfg.family!r} carries recurrent state")
        pos = pos_any[:, None].astype(jnp.int32)            # (B, 1) RoPE
    else:
        pos = jnp.broadcast_to(pos_any[None, None], (B, 1))
    pos_scalar = pos_any        # scalar in every branch below except dense kv
    x = L.embed_lookup(params["embed"], tokens).astype(dt)
    x = shard_act(x, mesh)

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        # Cache is carried through the layer scan so XLA's while-loop buffer
        # assignment updates it in place (a scan-xs/ys cache would be
        # double-buffered: +1× full cache of temp memory).
        memory = cache.get("memory")
        S = cache["kv"]["k"].shape[2]
        if pos_any.ndim == 1:
            # clamp: retired slots keep lockstep-decoding garbage until the
            # engine reuses them — never past the cache end
            ins = jnp.minimum(pos_any, S - 1)
            vlen = jnp.minimum(pos_any + 1, S)
        else:
            ins, vlen = pos_any, pos_any + 1
        norm = L.layer_norm if cfg.family == "audio" else L.rms_norm
        acfg = attn_cfg(cfg)

        qkv = cfg.kv_quant
        ps0 = cache.get("probes", {})

        def body(carry, p_l):
            h, kc, vc, sc, l, ps = carry
            with probes.layer(ps, l) as pb:
                a, kc, vc, sc = A.attn_decode_cached(
                    p_l["attn"], norm(p_l["ln1"], h), acfg, pos=pos,
                    insert_at=ins, valid_len=vlen,
                    k_all=kc, v_all=vc, layer=l, scales=sc,
                    mesh=mesh, dp=dp_axes(mesh) if mesh is not None else None)
                h = shard_act(h + a, mesh)
                if cfg.family == "audio":
                    c, _ = A.attn_apply(p_l["xattn"],
                                        L.layer_norm(p_l["ln_x"], h),
                                        attn_cfg(cfg, causal=False),
                                        kv_override=memory)
                    h = shard_act(h + c, mesh)
                    y = L.mlp_block(p_l["mlp"], L.layer_norm(p_l["ln2"], h),
                                    cfg.act_kind, cfg.act_levels, mesh)
                elif "moe" in p_l:
                    y = M.moe_apply(p_l["moe"], L.rms_norm(p_l["ln2"], h),
                                    moe_cfg(cfg), mesh)
                else:
                    y = L.swiglu(p_l["mlp"], L.rms_norm(p_l["ln2"], h),
                                 cfg.act_kind, cfg.act_levels, mesh)
                h = shard_act(h + y, mesh)
            return (h, kc, vc, sc, l + 1, pb.state), None

        sc0 = (cache["kv"]["k_scale"], cache["kv"]["v_scale"]) if qkv else None
        (x, nk, nv, nsc, _, ps1), _ = jax.lax.scan(
            body, (x, cache["kv"]["k"], cache["kv"]["v"], sc0,
                   jnp.zeros((), jnp.int32), ps0),
            params["blocks"], unroll=_unroll(cfg))
        new_kv = {"k": nk, "v": nv}
        if qkv:
            new_kv.update(k_scale=nsc[0], v_scale=nsc[1])
        new_cache = {**cache, "kv": new_kv, "pos": pos_scalar + 1}
        if ps0:
            new_cache["probes"] = probes.bump(ps1, "tokens", float(B))

    elif cfg.family == "ssm_rwkv":
        def body(h, xs):
            p_l, s, xtm, xcm = xs
            st = {"s": s, "x_tm": xtm, "x_cm": xcm}
            h, st2 = _rwkv_block(p_l, h, cfg, mesh, st, decode=True)
            return h, (st2["s"], st2["x_tm"], st2["x_cm"])
        x, (s2, xtm2, xcm2) = jax.lax.scan(
            body, x, (params["blocks"], cache["s"], cache["x_tm"],
                      cache["x_cm"]), unroll=_unroll(cfg))
        new_cache = {"s": s2, "x_tm": xtm2, "x_cm": xcm2,
                     "pos": pos_scalar + 1}

    elif cfg.family == "hybrid":
        G = cfg.shared_attn_every
        n_groups = cfg.n_layers // G
        win = cache["shared_k"].shape[2]              # static ring size
        ins = pos_scalar % win                        # ring insert position
        vlen = jnp.minimum(pos_scalar + 1, win)
        x0 = x
        acfg = attn_cfg(cfg)
        shared = params["shared"]
        mb = jax.tree.map(lambda a: a.reshape((n_groups, G) + a.shape[1:]),
                          params["blocks"])

        def group(carry, xs):
            h, sk, sv, g = carry
            p_g, hg, cg = xs
            # shared block with carried ring KV cache (in-place DUS)
            hin = L.dense(shared["in_proj"], jnp.concatenate([h, x0], -1))
            a, sk, sv, _ = A.attn_decode_cached(
                shared["attn"], L.rms_norm(shared["ln1"], hin), acfg,
                pos=pos, insert_at=ins, valid_len=vlen,
                k_all=sk, v_all=sv, layer=g,
                mesh=mesh, dp=dp_axes(mesh) if mesh is not None else None)
            hin = hin + a
            hin = hin + L.swiglu(shared["mlp"], L.rms_norm(shared["ln2"], hin),
                                 cfg.act_kind, cfg.act_levels, mesh)
            h = shard_act(h + hin, mesh)

            def body(hh, xs2):
                p_l, ch, cc = xs2
                hh, c2 = _mamba_block(p_l, hh, cfg, mesh,
                                      {"h": ch, "conv": cc}, decode=True)
                return hh, (c2["h"], c2["conv"])
            h, (nh, nc) = jax.lax.scan(body, h, (p_g, hg, cg))
            return (h, sk, sv, g + 1), (nh, nc)

        hg = cache["h"].reshape((n_groups, G) + cache["h"].shape[1:])
        cg = cache["conv"].reshape((n_groups, G) + cache["conv"].shape[1:])
        (x, nsk, nsv, _), (nh, nc) = jax.lax.scan(
            group, (x, cache["shared_k"], cache["shared_v"],
                    jnp.zeros((), jnp.int32)),
            (mb, hg, cg), unroll=_unroll(cfg))
        new_cache = {"h": nh.reshape(cache["h"].shape),
                     "conv": nc.reshape(cache["conv"].shape),
                     "shared_k": nsk, "shared_v": nsv,
                     "pos": pos_scalar + 1}
    else:
        raise ValueError(cfg.family)

    norm = L.layer_norm if cfg.family == "audio" else L.rms_norm
    x = norm(params["final_norm"], x)
    logits = _logits(params, cfg, x)
    return logits, new_cache


def prefill(params, cfg, batch, mesh=None):
    """Prefill: forward over the prompt; returns (last-position logits, cache).

    The cache is *emitted* as scan outputs (per-layer K/V planes / final SSM
    states) rather than written into a preallocated zero cache — avoids a
    full extra cache of temp memory in the lowered step.

    ``batch['lengths']`` ((B,) int32, optional) marks right-padded prompts:
    row b's real tokens occupy positions [0, lengths[b]).  Causal masking
    already keeps real queries from seeing the padded tail, so no extra
    attention mask is needed; the returned logits are taken at each row's
    last *real* position and ``cache['pos']`` comes back as the (B,) length
    vector — the layout ServeEngine's batched prefill and per-slot decode
    consume.  KV-cache families only: a recurrent state would march through
    the padding and corrupt itself.
    """
    dt = _dtype(cfg)
    cdt = jnp.bfloat16 if cfg.dtype == "bfloat16" else dt
    tokens = batch["tokens"]
    lengths = batch.get("lengths")
    if lengths is not None and cfg.family not in ("dense", "vlm", "moe",
                                                  "audio"):
        raise NotImplementedError(
            f"per-request prompt lengths need a KV cache; family "
            f"{cfg.family!r} carries recurrent state through the padding")
    B, Sq = tokens.shape
    pos = None
    if cfg.family == "vlm":
        x = batch["embeds"].astype(dt)
        pos = batch.get("positions")
    else:
        x = L.embed_lookup(params["embed"], tokens).astype(dt)
    x = shard_act(x, mesh)

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        memory = None
        if cfg.family == "audio":
            memory = _encoder(params, cfg, batch["frames"], mesh)

        def blk(p_l, h, _):
            if cfg.family == "audio":
                a, kv = A.attn_apply(p_l["attn"], L.layer_norm(p_l["ln1"], h),
                                     attn_cfg(cfg), pos=pos, return_kv=True,
                                     mesh=mesh)
                h = shard_act(h + a, mesh)
                c, _ = A.attn_apply(p_l["xattn"], L.layer_norm(p_l["ln_x"], h),
                                    attn_cfg(cfg, causal=False),
                                    kv_override=memory)
                h = shard_act(h + c, mesh)
                y = L.mlp_block(p_l["mlp"], L.layer_norm(p_l["ln2"], h),
                                cfg.act_kind, cfg.act_levels, mesh)
            else:
                a, kv = A.attn_apply(p_l["attn"], L.rms_norm(p_l["ln1"], h),
                                     attn_cfg(cfg), pos=pos, return_kv=True,
                                     mesh=mesh)
                h = shard_act(h + a, mesh)
                if "moe" in p_l:
                    y = M.moe_apply(p_l["moe"], L.rms_norm(p_l["ln2"], h),
                                    moe_cfg(cfg), mesh)
                else:
                    y = L.swiglu(p_l["mlp"], L.rms_norm(p_l["ln2"], h),
                                 cfg.act_kind, cfg.act_levels)
            h = shard_act(h + y, mesh)
            if cfg.kv_quant:
                kq, ksc = A.quantize_kv(kv["k"])
                vq, vsc = A.quantize_kv(kv["v"])
                return h, (kq, vq, ksc, vsc)
            return h, (kv["k"].astype(cdt), kv["v"].astype(cdt))

        ps0 = batch.get("probes") or {}
        if ps0:
            # Probe-instrumented body: same blk, carry extended with the
            # counters + a layer index (the plain prefill carry is just x, so
            # the off path below keeps its original, untouched trace).
            def bodyp(carry, p_l):
                h, ps, l = carry
                with probes.layer(ps, l) as pb:
                    h, plane = blk(p_l, h, None)
                return (h, pb.state, l + 1), plane
            (x, ps1, _), planes = jax.lax.scan(
                bodyp, (x, ps0, jnp.zeros((), jnp.int32)),
                params["blocks"], unroll=_unroll(cfg))
        else:
            def body(h, p_l):
                return blk(p_l, h, None)
            x, planes = jax.lax.scan(body, x, params["blocks"],
                                     unroll=_unroll(cfg))
            ps1 = {}
        if cfg.kv_quant:
            nk, nv, nks, nvs = planes
            new_kv = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
        else:
            nk, nv = planes
            new_kv = {"k": nk, "v": nv}
        new_cache = {"kv": new_kv, "pos": jnp.asarray(Sq, jnp.int32)}
        if ps0:
            n_tok = (jnp.sum(lengths).astype(jnp.float32)
                     if lengths is not None else float(B * Sq))
            new_cache["probes"] = probes.bump(ps1, "tokens", n_tok)
        if memory is not None:
            new_cache["memory"] = memory.astype(cdt)

    elif cfg.family == "ssm_rwkv":
        def body(h, p_l):
            h, st2 = _rwkv_prefill_block(p_l, h, cfg, mesh)
            return h, (st2["s"], st2["x_tm"].astype(cdt),
                       st2["x_cm"].astype(cdt))
        x, (s2, xtm2, xcm2) = jax.lax.scan(body, x, params["blocks"],
                                           unroll=_unroll(cfg))
        new_cache = {"s": s2, "x_tm": xtm2, "x_cm": xcm2,
                     "pos": jnp.asarray(Sq, jnp.int32)}

    elif cfg.family == "hybrid":
        # prefill trunk == forward; the (small) SSM states + windowed shared
        # KV are re-derivable; the dry-run cell measures the trunk.
        logits = forward(params, cfg, batch, mesh)
        return logits[:, -1:], init_cache(cfg, B, Sq, cdt)
    else:
        raise ValueError(cfg.family)

    norm = L.layer_norm if cfg.family == "audio" else L.rms_norm
    if lengths is None:
        x_last = x[:, -1:]
    else:
        # per-row gather at the last real position; cache pos → (B,) vector
        lv = lengths.astype(jnp.int32)
        x_last = x[jnp.arange(B)[:, None], jnp.maximum(lv - 1, 0)[:, None]]
        new_cache["pos"] = lv
    x = norm(params["final_norm"], x_last)
    return _logits(params, cfg, x), new_cache


def _rwkv_prefill_block(p_l, h, cfg, mesh):
    rcfg = rwkv_cfg(cfg)
    spec = _act_spec(cfg, mesh, h)
    tm, st_tm = R.rwkv_tm_apply(p_l["tm"], L.rms_norm(p_l["ln1"], h), rcfg)
    h = shard_act(h + tm, mesh, spec)
    cm, st_cm = R.rwkv_cm_apply(p_l["cm"], L.rms_norm(p_l["ln2"], h), rcfg)
    h = shard_act(h + cm, mesh, spec)
    return h, {**st_tm, **st_cm}
