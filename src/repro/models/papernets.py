"""The paper's own experiment networks (§3): MLP, auto-encoders, AlexNet-ish.

These are the nets the paper's tables/figures are produced on; our
benchmarks retrain scaled versions (CPU container) with the same
quantization hooks: ``act_levels`` (|A|) at every nonlinearity and external
periodic weight clustering (|W|) via ``repro.core.quantizer``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, ffn_act

__all__ = ["mlp_init", "mlp_apply", "fc_autoencoder_init",
           "fc_autoencoder_apply", "conv_autoencoder_init",
           "conv_autoencoder_apply", "alexnet_init", "alexnet_apply",
           "mlp_layer_sizes"]


# --- MNIST-style MLP (paper §3.1) ---------------------------------------------

def mlp_layer_sizes(d_in: int, hidden: list[int], d_out: int):
    dims = [d_in] + list(hidden) + [d_out]
    return list(zip(dims[:-1], dims[1:]))


def mlp_init(key, d_in: int, hidden: list[int], d_out: int):
    sizes = mlp_layer_sizes(d_in, hidden, d_out)
    keys = jax.random.split(key, len(sizes))
    return {f"layer{i}": dense_init(k, a, b, bias=True, std=(a ** -0.5))
            for i, (k, (a, b)) in enumerate(zip(keys, sizes))}


def mlp_apply(p, x, act_kind: str = "tanh", act_levels: int = 0):
    n = len(p)
    for i in range(n):
        x = dense(p[f"layer{i}"], x)
        if i < n - 1:
            x = ffn_act(x, act_kind, act_levels)
    return x


# --- FC auto-encoder (paper §3.2: 7 hidden layers, 50n..20n..50n) -------------

def fc_autoencoder_init(key, d_in: int, n: float = 1.0):
    hidden = [int(50 * n), int(50 * n), int(40 * n), int(20 * n),
              int(40 * n), int(50 * n), int(50 * n)]
    return mlp_init(key, d_in, hidden, d_in)


def fc_autoencoder_apply(p, x, act_kind: str = "tanh", act_levels: int = 0):
    return mlp_apply(p, x, act_kind, act_levels)


# --- Conv auto-encoder (paper §3.2) -------------------------------------------

def _conv_init(key, k: int, cin: int, cout: int):
    std = (2.0 / (k * k * cin)) ** 0.5      # He init (ReLU-family nets)
    return {"w": jax.random.normal(key, (k, k, cin, cout)) * std,
            "b": jnp.zeros((cout,))}


def _conv(p, x, stride: int = 1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _conv_t(p, x, stride: int = 2):
    y = jax.lax.conv_transpose(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def conv_autoencoder_init(key, n: float = 1.0, c_in: int = 3):
    """Paper: 4 conv 2×2 (50n,50n,40n,20n) + 3 conv-T (40n,50n,50n) +
    two 1×1 convs (20, c_in).  Strides (2,2,2,1)/(2,2,2) keep in/out sizes
    equal (the paper omits strides; recorded in DESIGN.md)."""
    d = [int(50 * n), int(50 * n), int(40 * n), int(20 * n)]
    dt = [int(40 * n), int(50 * n), int(50 * n)]
    ks = jax.random.split(key, 9)
    p = {}
    cin = c_in
    for i, c in enumerate(d):
        p[f"enc{i}"] = _conv_init(ks[i], 2, cin, c)
        cin = c
    for i, c in enumerate(dt):
        p[f"dec{i}"] = _conv_init(ks[4 + i], 2, cin, c)
        cin = c
    p["post0"] = _conv_init(ks[7], 1, cin, 20)
    p["post1"] = _conv_init(ks[8], 1, 20, c_in)
    return p


def conv_autoencoder_apply(p, x, act_kind: str = "tanh", act_levels: int = 0):
    a = lambda v: ffn_act(v, act_kind, act_levels)
    h = x
    for i, s in enumerate((2, 2, 2, 1)):
        h = a(_conv(p[f"enc{i}"], h, s))
    for i in range(3):
        h = a(_conv_t(p[f"dec{i}"], h, 2))
    h = a(_conv(p["post0"], h, 1))
    return _conv(p["post1"], h, 1)


# --- AlexNet-style classifier (paper §3.3), width-scalable --------------------

def alexnet_init(key, n_classes: int = 1000, width: float = 1.0,
                 c_in: int = 3, img: int = 64):
    w = lambda c: max(8, int(c * width))
    ks = jax.random.split(key, 8)
    p = {
        "c1": _conv_init(ks[0], 5, c_in, w(96)),
        "c2": _conv_init(ks[1], 5, w(96), w(256)),
        "c3": _conv_init(ks[2], 3, w(256), w(384)),
        "c4": _conv_init(ks[3], 3, w(384), w(384)),
        "c5": _conv_init(ks[4], 3, w(384), w(256)),
    }
    spatial = img // 16  # c1 stride2 + three pools
    feat = w(256) * spatial * spatial
    he = lambda fan: (2.0 / fan) ** 0.5
    p["f6"] = dense_init(ks[5], feat, w(1024), bias=True, std=he(feat))
    p["f7"] = dense_init(ks[6], w(1024), w(1024), bias=True, std=he(w(1024)))
    p["f8"] = dense_init(ks[7], w(1024), n_classes, bias=True,
                         std=he(w(1024)))
    return p


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def alexnet_apply(p, x, act_kind: str = "relu6", act_levels: int = 0,
                  dropout_rate: float = 0.0, key=None):
    a = lambda v: ffn_act(v, act_kind, act_levels)
    h = a(_conv(p["c1"], x, 2))
    h = _maxpool(h)
    h = a(_conv(p["c2"], h, 1))
    h = _maxpool(h)
    h = a(_conv(p["c3"], h, 1))
    h = a(_conv(p["c4"], h, 1))
    h = a(_conv(p["c5"], h, 1))
    h = _maxpool(h)
    h = h.reshape(h.shape[0], -1)
    h = a(dense(p["f6"], h))
    if dropout_rate and key is not None:
        h = h * jax.random.bernoulli(key, 1 - dropout_rate, h.shape) / (1 - dropout_rate)
    h = a(dense(p["f7"], h))
    if dropout_rate and key is not None:
        key2 = jax.random.fold_in(key, 1)
        h = h * jax.random.bernoulli(key2, 1 - dropout_rate, h.shape) / (1 - dropout_rate)
    return dense(p["f8"], h)
