"""Shared layers: quant-aware dense, norms, embeddings, FFN variants."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.activations import ActQuantConfig, act_apply
from repro.kernels import dispatch, probes

__all__ = [
    "dense_init", "dense", "rms_norm_init", "rms_norm", "layer_norm_init",
    "layer_norm", "embed_init", "embed_lookup", "ffn_act", "swiglu_init",
    "swiglu", "mlp_init", "mlp_block",
]


# --- dense (the quantization-aware workhorse) --------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               bias: bool = False, std: float | None = None):
    std = (d_in ** -0.5) if std is None else std
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, kind: str | None = None):
    """x @ W (+ b).  W is dense ('w') or codebook-indexed ('w_idx'+'codebook').

    The index form is the deployment representation from the paper's §4: the
    full weight matrix never exists in HBM — only narrow indices plus the
    |W|-entry codebook.  How the contraction runs is decided by the serving
    backend switch (``kernels.dispatch``, DESIGN.md §3):

    * ``dense`` (default) — gather the codebook, then a plain XLA dot;
      training and every non-serving path take this branch.
    * ``codebook`` — the Pallas ``codebook_matmul`` (dequantize-in-VMEM
      gather feeding the MXU; compiled on TPU, interpret elsewhere).
    * ``lut`` — the faithful §4 integer engine ``lut_matmul``: activations
      snapped to a level grid, int32 table-gather accumulation, no
      multiplications in the contraction.

    ``kind`` ('col' | 'row' | None) names the layer's tensor-parallel role
    per ``distributed.sharding.param_specs`` — consulted only when the
    active backend carries a mesh (DESIGN.md §10), where it decides whether
    the index matrix shards its output axis (col: no collective) or its
    reduction axis (row: one output psum).
    """
    if "w_idx" in p:
        if dispatch.matmul_backend() != "dense" and p["w_idx"].ndim == 2:
            # lut_table: optional precomputed §4 table attached by
            # dispatch.attach_lut_tables (ServeEngine does this at init)
            y = dispatch.backend_matmul(x, p["w_idx"], p["codebook"], kind,
                                        table=p.get("lut_table"))
            if "b" in p:
                y = y + p["b"].astype(x.dtype)
            return y
        w = p["codebook"][p["w_idx"].astype(jnp.int32)].astype(x.dtype)
    else:
        w = p["w"].astype(x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def kernel_of(p):
    """Materialized weight matrix of a dense param dict (for tests)."""
    if "w_idx" in p:
        return p["codebook"][p["w_idx"].astype(jnp.int32)]
    return p["w"]


# --- norms -------------------------------------------------------------------

def rms_norm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layer_norm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# --- embeddings --------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype=jnp.float32, std: float = 0.02):
    return {"table": (jax.random.normal(key, (vocab, d)) * std).astype(dtype)}


def embed_lookup(p, ids):
    if "w_idx" in p:  # codebook-compressed embedding table
        return p["codebook"][p["w_idx"][ids].astype(jnp.int32)]
    return p["table"][ids]


def embed_logits(p, x):
    """Tied-softmax logits x @ E^T (f32 for a stable CE)."""
    t = p["codebook"][p["w_idx"].astype(jnp.int32)] if "w_idx" in p else p["table"]
    return jnp.dot(x.astype(jnp.float32), t.astype(jnp.float32).T)


# --- FFN ---------------------------------------------------------------------

def ffn_act(x, kind: str, levels: int):
    """The paper's activation-quantization site.

    levels == 0: continuous nonlinearity (baseline).
    levels  > 0: quantized (`act_apply`) — requires a bounded kind; unbounded
                 kinds are swapped for relu6 exactly as the paper swaps
                 AlexNet's ReLU for ReLU6 (§3.3).
    """
    if levels <= 0:
        if kind == "silu":
            return jax.nn.silu(x)
        if kind == "gelu":
            return jax.nn.gelu(x)
        if kind == "relu":
            return jax.nn.relu(x)
        if kind == "relu6":
            return jnp.clip(x, 0.0, 6.0)
        if kind == "tanh":
            return jnp.tanh(x)
        raise ValueError(kind)
    bounded = {"silu": "relu6", "gelu": "relu6", "relu": "relu6"}.get(kind, kind)
    if bounded == "relu6":
        # Saturation probe: inputs outside the hard rails get pinned to an
        # endpoint level by the quantized nonlinearity.  Only relu6 has true
        # rail clipping (tanh/sigmoid saturate asymptotically, no clip).
        probes.tap_act(x, 0.0, 6.0)
    return act_apply(ActQuantConfig(bounded, levels), x)


def swiglu_init(key, d: int, ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": dense_init(k1, d, ff, dtype),
            "w3": dense_init(k2, d, ff, dtype),
            "w2": dense_init(k3, ff, d, dtype)}


def _ffn_hidden_constraint(h, mesh):
    """(B, S, ff) intermediate: ff over `model`, S gathered.  Without this,
    a sequence-sharded residual meeting a model-sharded w1 leaves XLA with
    conflicting layouts and it replicates the (B, S, ff) tensor — the
    largest activation in the network (≈5 GB/device at mistral dims)."""
    if mesh is None or h.shape[-1] % mesh.shape["model"] != 0:
        return h
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import dp_axes
    spec = P(dp_axes(mesh), *([None] * (h.ndim - 2)), "model")
    return jax.lax.with_sharding_constraint(h, NamedSharding(mesh, spec))


def swiglu(p, x, act_kind: str = "silu", act_levels: int = 0, mesh=None):
    h = (ffn_act(dense(p["w1"], x, kind="col"), act_kind, act_levels)
         * dense(p["w3"], x, kind="col"))
    h = _ffn_hidden_constraint(h, mesh)
    return dense(p["w2"], h, kind="row")


def mlp_init(key, d: int, ff: int, dtype=jnp.float32, bias: bool = True):
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, d, ff, dtype, bias=bias),
            "w2": dense_init(k2, ff, d, dtype, bias=bias)}


def mlp_block(p, x, act_kind: str = "gelu", act_levels: int = 0, mesh=None):
    h = ffn_act(dense(p["w1"], x, kind="col"), act_kind, act_levels)
    return dense(p["w2"], _ffn_hidden_constraint(h, mesh), kind="row")
