"""RWKV-6 ("Finch") — attention-free time-mix with data-dependent decay.

Per head (dim P), state S ∈ R^{P×P}:
    S_t = diag(w_t) · S_{t-1} + k_t ⊗ v_t
    y_t = r_t · (S_{t-1} + diag(u) · k_t ⊗ v_t)
with w_t = exp(−exp(w0 + LoRA(x_t))) the data-dependent decay (the Finch
novelty vs RWKV-5's static decay).  Token-shift mixes x_t with x_{t−1}.

Training/prefill runs a chunked scan: within a chunk of Q tokens the
contributions are computed with masked cumulative-decay einsums (quadratic
in Q, MXU-friendly); the state is carried across chunks — same layout as
our SSD kernel, so both SSM families share compile characteristics.
Decode is the O(1) recurrence (``rwkv_decode_step``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import (dense, dense_init, ffn_act, rms_norm,
                                 rms_norm_init)

__all__ = ["RWKVConfig", "rwkv_tm_init", "rwkv_tm_apply", "rwkv_tm_decode",
           "rwkv_cm_init", "rwkv_cm_apply", "rwkv_cm_decode",
           "init_rwkv_cache"]


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    head_dim: int = 64
    d_ff: int = 0                 # channel-mix hidden; 0 → 3.5·d
    lora_rank: int = 32
    chunk: int = 128
    act_kind: str = "relu"        # channel-mix uses squared relu
    act_levels: int = 0

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim

    @property
    def ff(self) -> int:
        return self.d_ff or int(3.5 * self.d_model)


def rwkv_tm_init(key, cfg: RWKVConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    return {
        "mix": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(dtype),
        "wr": dense_init(ks[1], d, d, dtype),
        "wk": dense_init(ks[2], d, d, dtype),
        "wv": dense_init(ks[3], d, d, dtype),
        "wg": dense_init(ks[4], d, d, dtype),
        "wo": dense_init(ks[5], d, d, dtype),
        "w0": (jnp.zeros((d,)) + jnp.log(jnp.e - 1)).astype(dtype),  # decay base
        "w_lora_a": dense_init(ks[6], d, cfg.lora_rank, dtype),
        "w_lora_b": dense_init(ks[7], cfg.lora_rank, d, dtype, std=0.01),
        "u": (jnp.ones((d,)) * 0.5).astype(dtype),                   # bonus
        "ln_out": rms_norm_init(d, dtype),
    }


def _token_shift(x, prev):
    """x_{t-1} stream. prev: (B, 1, D) last token of previous segment."""
    return jnp.concatenate([prev, x[:, :-1, :]], axis=1)


def _tm_projections(p, x, x_prev):
    mix = p["mix"].astype(x.dtype)
    xs = _token_shift(x, x_prev)
    def lerp(i):
        return x * mix[i][None, None, :] + xs * (1.0 - mix[i][None, None, :])
    r = dense(p["wr"], lerp(0))
    k = dense(p["wk"], lerp(1))
    v = dense(p["wv"], lerp(2))
    g = dense(p["wg"], lerp(3))
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(x)))
    dd = dense(p["w_lora_b"], jnp.tanh(dense(p["w_lora_a"], lerp(4))))
    logw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32)[None, None, :]
                             + dd.astype(jnp.float32), -8.0, 4.0))
    return r, k, v, g, logw             # logw = log decay ∈ (−∞, 0)


def _wkv_scan(r, k, v, u, logw, cfg: RWKVConfig, s0=None):
    """Exact WKV recurrence: outer scan over chunks (state carried), inner
    rematerialized scan over the Q tokens inside a chunk.

    Per-channel data-dependent decay makes the factored "chunked attention"
    form numerically unsafe (exp(±Σ log w) spans hundreds of nats), so we
    keep the recurrence exact and bound training memory by checkpointing at
    chunk granularity: backward recomputes the Q inner steps per chunk.

    r,k,v,logw: (B, L, H, P); u: (H, P).  Returns (y, s_last (B,H,P,P)).
    """
    B, L, H, P = r.shape
    Q = min(cfg.chunk, L)
    nC = L // Q
    assert nC * Q == L, (L, Q)

    def chunk(s, inp):
        rc, kc, vc, lwc = inp                    # (Q, B, H, P)

        def step(s, t_in):
            rt, kt, vt, lwt = t_in               # (B, H, P); bf16 streams
            rt = rt.astype(jnp.float32)
            kt = kt.astype(jnp.float32)
            vt = vt.astype(jnp.float32)
            # y_t = r · (S_{t-1} + diag(u) k ⊗ v)
            y = jnp.einsum("bhp,bhpq->bhq", rt, s) + \
                jnp.einsum("bhp,hp,bhp,bhq->bhq", rt, u, kt, vt)
            s = s * jnp.exp(lwt.astype(jnp.float32))[..., None] + \
                jnp.einsum("bhp,bhq->bhpq", kt, vt)
            # bf16 per-step outputs halve the stacked-ys HBM traffic; the
            # f32 state carry keeps the recurrence exact
            return s, y.astype(jnp.bfloat16)

        return jax.lax.scan(step, s, (rc, kc, vc, lwc))

    chunk = jax.checkpoint(chunk)
    to_chunks = lambda x: x.reshape(B, nC, Q, H, P).transpose(1, 2, 0, 3, 4)
    s_init = (jnp.zeros((B, H, P, P), jnp.float32) if s0 is None
              else s0.astype(jnp.float32))
    s_last, y = jax.lax.scan(chunk, s_init,
                             (to_chunks(r), to_chunks(k), to_chunks(v),
                              to_chunks(logw)))
    # y: (nC, Q, B, H, P) -> (B, L, H, P)
    return y.transpose(2, 0, 1, 3, 4).reshape(B, L, H, P), s_last


def rwkv_tm_apply(p, x, cfg: RWKVConfig, state=None):
    """Time-mix block (train/prefill).  x: (B, L, D)."""
    B, L, D = x.shape
    H, P = cfg.n_heads, cfg.head_dim
    x_prev = jnp.zeros((B, 1, D), x.dtype) if state is None else state["x_tm"]
    r, k, v, g, logw = _tm_projections(p, x, x_prev)
    # bf16 streams into the scan (per-step math upcasts; see _wkv_scan)
    rh = r.reshape(B, L, H, P).astype(jnp.bfloat16)
    kh = k.reshape(B, L, H, P).astype(jnp.bfloat16)
    vh = v.reshape(B, L, H, P).astype(jnp.bfloat16)
    lw = logw.reshape(B, L, H, P)
    u = p["u"].astype(jnp.float32).reshape(H, P)
    s0 = None if state is None else state["s"]
    # decay stays f32: bf16's ~8-bit mantissa would quantize exp(logw)≈1−ε
    # and compound over thousands of steps
    y, s_last = _wkv_scan(rh, kh, vh, u, lw.astype(jnp.float32), cfg, s0)
    y = rms_norm(p["ln_out"], y.reshape(B, L, D).astype(x.dtype))
    y = y * ffn_act(g, "silu", cfg.act_levels)
    out = dense(p["wo"], y)
    new_state = {"s": s_last, "x_tm": x[:, -1:, :]}
    return out, new_state


def rwkv_tm_decode(p, x, cfg: RWKVConfig, state):
    """O(1) decode step.  x: (B, 1, D)."""
    B, _, D = x.shape
    H, P = cfg.n_heads, cfg.head_dim
    r, k, v, g, logw = _tm_projections(p, x, state["x_tm"])
    rh = r.reshape(B, H, P).astype(jnp.float32)
    kh = k.reshape(B, H, P).astype(jnp.float32)
    vh = v.reshape(B, H, P).astype(jnp.float32)
    w = jnp.exp(logw.reshape(B, H, P))
    u = p["u"].astype(jnp.float32).reshape(H, P)
    s = state["s"].astype(jnp.float32)
    y = jnp.einsum("bhp,bhpq->bhq", rh, s) + \
        jnp.einsum("bhp,hp,bhp,bhq->bhq", rh, u, kh, vh)
    s_new = s * w[:, :, :, None] + jnp.einsum("bhp,bhq->bhpq", kh, vh)
    y = rms_norm(p["ln_out"], y.reshape(B, 1, D).astype(x.dtype))
    y = y * ffn_act(g, "silu", cfg.act_levels)
    return dense(p["wo"], y), {"s": s_new, "x_tm": x}


def rwkv_cm_init(key, cfg: RWKVConfig, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"mix": (jax.random.uniform(k1, (2, cfg.d_model)) * 0.5 + 0.25).astype(dtype),
            "wk": dense_init(k2, cfg.d_model, cfg.ff, dtype),
            "wv": dense_init(k3, cfg.ff, cfg.d_model, dtype)}


def _cm(p, x, xs, cfg: RWKVConfig):
    mix = p["mix"].astype(x.dtype)
    xk = x * mix[0][None, None] + xs * (1 - mix[0][None, None])
    h = dense(p["wk"], xk)
    h = ffn_act(jax.nn.relu(h) if cfg.act_levels == 0 else h,
                "relu", cfg.act_levels)
    h = h * h  # squared relu (rwkv)
    return dense(p["wv"], h)


def rwkv_cm_apply(p, x, cfg: RWKVConfig, state=None):
    B, L, D = x.shape
    x_prev = jnp.zeros((B, 1, D), x.dtype) if state is None else state["x_cm"]
    out = _cm(p, x, _token_shift(x, x_prev), cfg)
    return out, {"x_cm": x[:, -1:, :]}


def rwkv_cm_decode(p, x, cfg: RWKVConfig, state):
    out = _cm(p, x, state["x_cm"], cfg)
    return out, {"x_cm": x}


def init_rwkv_cache(cfg: RWKVConfig, batch: int, dtype=jnp.float32):
    H, P = cfg.n_heads, cfg.head_dim
    return {"s": jnp.zeros((batch, H, P, P), dtype),
            "x_tm": jnp.zeros((batch, 1, cfg.d_model), dtype),
            "x_cm": jnp.zeros((batch, 1, cfg.d_model), dtype)}
