"""Mamba2 (SSD) block — chunked state-space dual form, TPU-friendly.

Recurrence per head (state N, head dim P):
    h_t = exp(A·Δ_t) · h_{t-1} + Δ_t · B_t ⊗ x_t        h ∈ R^{N×P}
    y_t = C_t · h_t + D · x_t

Chunked evaluation (chunk Q): intra-chunk term is a masked quadratic
"attention" with decay weights; inter-chunk states pass through a short
lax.scan of length L/Q.  This keeps compute in MXU-sized einsums and the
sequential dependency O(L/Q) — the standard SSD layout, matching how the
paper's technique needs bounded activations only at the gate/output sites.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, rms_norm, rms_norm_init, ffn_act

__all__ = ["SSMConfig", "ssm_init", "ssm_apply", "ssm_decode_step",
           "init_ssm_cache"]


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 64        # N
    head_dim: int = 64       # P
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1        # B/C groups (GVA-style)
    chunk: int = 128
    act_kind: str = "silu"
    act_levels: int = 0

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_init(key, cfg: SSMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    din = cfg.d_inner
    G, N, H = cfg.n_groups, cfg.d_state, cfg.n_heads
    # fused in_proj: [z gate | x | B | C | dt]
    proj_out = 2 * din + 2 * G * N + H
    p = {
        "in_proj": dense_init(ks[0], cfg.d_model, proj_out, dtype),
        "out_proj": dense_init(ks[1], din, cfg.d_model, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, din + 2 * G * N))
                   * 0.2).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm": rms_norm_init(din, dtype),
    }
    return p


def _split(cfg: SSMConfig, zxbcdt):
    din, G, N, H = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z, x, B, C, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + G * N, 2 * din + 2 * G * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv, width K.  x: (B, L, C); w: (K, C).
    state: (B, K-1, C) tail of previous tokens (decode)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):, :]
    return out, new_state


def _ssd_chunked(xh, dt, A, B, C, cfg: SSMConfig, h0=None):
    """Chunked SSD scan.

    xh: (Bt, L, H, P); dt: (Bt, L, H) (post-softplus); A: (H,) negative;
    B, C: (Bt, L, G, N).  Returns (y (Bt,L,H,P), h_last (Bt,H,N,P)).
    """
    Bt, L, H, P = xh.shape
    G, N, Q = cfg.n_groups, cfg.d_state, min(cfg.chunk, L)
    nC = L // Q
    assert nC * Q == L, (L, Q)
    rep = H // G

    xc = xh.reshape(Bt, nC, Q, H, P)
    dtc = dt.reshape(Bt, nC, Q, H)
    Bc = B.reshape(Bt, nC, Q, G, N)
    Cc = C.reshape(Bt, nC, Q, G, N)

    # per-step log decay g = A*dt  (A < 0)
    g = dtc * A[None, None, None, :]                  # (Bt, nC, Q, H)
    gcum = jnp.cumsum(g, axis=2)                      # within-chunk cumsum
    gtot = gcum[:, :, -1, :]                          # (Bt, nC, H)

    # intra-chunk: y_i += Σ_{j<=i} C_i·B_j exp(gcum_i − gcum_j) dt_j x_j
    # NB: mask the *exponent* (upper triangle would overflow exp and leak
    # NaN through where()'s backward), then exp is safe everywhere.
    Lmat = gcum[:, :, :, None, :] - gcum[:, :, None, :, :]       # (Bt,nC,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(mask, Lmat, -jnp.inf))
    decay = jnp.where(mask, decay, 0.0)
    cb = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)                # (Bt,nC,Q,Q,G)
    cb = jnp.repeat(cb, rep, axis=-1)                            # groups → heads
    w_ij = cb * decay                                            # (Bt,nC,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w_ij, dtc, xc)

    # chunk states: S_c = Σ_j exp(gtot − gcum_j) dt_j B_j ⊗ x_j   (Bt,nC,H,N,P)
    sdec = jnp.exp(gtot[:, :, None, :] - gcum)                   # (Bt,nC,Q,H)
    Brep = jnp.repeat(Bc, rep, axis=-2)                          # (Bt,nC,Q,H,N)
    S = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", sdec * dtc, Brep, xc)

    # inter-chunk scan: h_c = exp(gtot_c)·h_{c-1} + S_c
    def body(h, inp):
        S_c, gt_c = inp
        h_new = h * jnp.exp(gt_c)[:, :, None, None] + S_c
        return h_new, h

    h_init = (jnp.zeros((Bt, H, N, P), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, h_prev = jax.lax.scan(
        body, h_init,
        (S.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         gtot.transpose(1, 0, 2).astype(jnp.float32)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                     # (Bt,nC,H,N,P)

    # inter-chunk contribution: y_i += C_i · exp(gcum_i) · h_{c-1}
    Crep = jnp.repeat(Cc, rep, axis=-2)                          # (Bt,nC,Q,H,N)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Crep * jnp.exp(gcum)[..., None],
                         h_prev.astype(Crep.dtype))
    y = (y_intra + y_inter).reshape(Bt, L, H, P)
    return y, h_last


def ssm_apply(p, x, cfg: SSMConfig):
    """Full Mamba2 block (train/prefill).  x: (B, L, D) → (B, L, D)."""
    Bt, L, _ = x.shape
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    z, xi, Bm, Cm, dt = _split(cfg, dense(p["in_proj"], x))
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"])
    conv_out = ffn_act(conv_out, cfg.act_kind, cfg.act_levels)
    xi, Bm, Cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = _ssd_chunked(xi.reshape(Bt, L, H, P).astype(jnp.float32), dt, A,
                        Bm.reshape(Bt, L, G, N).astype(jnp.float32),
                        Cm.reshape(Bt, L, G, N).astype(jnp.float32), cfg)
    y = y + xi.reshape(Bt, L, H, P).astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bt, L, cfg.d_inner).astype(x.dtype)
    y = rms_norm(p["norm"], y * ffn_act(z, cfg.act_kind, cfg.act_levels))
    return dense(p["out_proj"], y)


def init_ssm_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * cfg.n_groups * cfg.d_state), dtype),
    }


def ssm_decode_step(p, x, cfg: SSMConfig, cache):
    """Single-token decode.  x: (B, 1, D) → (out (B,1,D), new cache).

    O(1) in context length — the whole point of running the 500k-context
    cell on SSM members of the pool.
    """
    Bt = x.shape[0]
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    z, xi, Bm, Cm, dt = _split(cfg, dense(p["in_proj"], x))
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], cache["conv"])
    conv_out = ffn_act(conv_out, cfg.act_kind, cfg.act_levels)
    xi, Bm, Cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(Bt, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Bm.reshape(Bt, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(Bt, G, N), H // G, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * A[None, :])                               # (B, H)
    h = cache["h"].astype(jnp.float32) * decay[:, :, None, None] + \
        jnp.einsum("bh,bhn,bhp->bhnp", dt, Bh, xh)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h) + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bt, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(p["norm"], y * ffn_act(z, cfg.act_kind, cfg.act_levels))
    return dense(p["out_proj"], y), {"h": h.astype(cache["h"].dtype),
                                     "conv": conv_state.astype(cache["conv"].dtype)}
