"""Model zoo: composable JAX model definitions for the assigned architectures
plus the paper's own networks (MLP / auto-encoder / AlexNet-style).

All models are pure-functional (params pytree in, tensors out), use
scan-over-layers for O(1)-in-depth HLO, and integrate the paper's technique
via two hooks:

* activation-quantization sites (``repro.core.activations.act_apply``) at
  every bounded nonlinearity when ``cfg.act_levels > 0``;
* weight tensors that may be *either* dense floats (training) or
  ``{'w_idx', 'codebook'}`` index form (deployment — the §4 memory saving,
  served by ``repro.kernels.codebook_matmul`` on TPU and by an XLA
  gather+dot on other backends).
"""
