"""LR schedules: step -> multiplier (composed with OptConfig.lr)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "warmup_cosine", "step_decay"]


def constant():
    return lambda step: 1.0


def warmup_cosine(warmup: int, total: int, floor: float = 0.1):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return f


def step_decay(every: int, rate: float = 0.5):
    """The paper's AlexNet 'stepwise decaying learning rate'."""
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        return rate ** jnp.floor(s / every)
    return f
