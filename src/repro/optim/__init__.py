from repro.optim.optimizers import OptConfig, init_opt_state, apply_updates
from repro.optim.schedules import warmup_cosine, step_decay, constant
