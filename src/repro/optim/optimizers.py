"""Optimizers over parameter pytrees (the paper trains with ADAM and
RMSProp; SGD(+momentum) included for the §2.1 claim that quantized
activations train under "all of the currently popular training algorithms").

Pure functions; state is a pytree so it checkpoints/shards like params.
Moment dtype is configurable (bf16 moments for the ≥100B archs — see
DESIGN.md memory budget).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "apply_updates"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # sgd | momentum | rmsprop | adam | adamw
    lr: float = 1e-3               # peak lr (schedules multiply this)
    schedule: Callable | None = None   # step -> multiplier
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    rms_decay: float = 0.9
    grad_clip: float = 1.0         # global-norm clip; 0 disables
    moments_dtype: str = "float32"

    def lr_at(self, step):
        mult = self.schedule(step) if self.schedule is not None else 1.0
        return self.lr * mult


def _mdt(cfg):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moments_dtype]


def init_opt_state(params, cfg: OptConfig):
    dt = _mdt(cfg)
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    if cfg.name in ("adam", "adamw"):
        return {"m": zeros(), "v": zeros(), "count": jnp.zeros((), jnp.int32)}
    if cfg.name == "rmsprop":
        return {"v": zeros(), "count": jnp.zeros((), jnp.int32)}
    if cfg.name == "momentum":
        return {"m": zeros(), "count": jnp.zeros((), jnp.int32)}
    if cfg.name == "sgd":
        return {"count": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: OptConfig):
    """One optimizer step.  Returns (params, state, metrics)."""
    step = state["count"]
    lr = cfg.lr_at(step)
    gnorm = global_norm(grads)
    if cfg.grad_clip:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    dt = _mdt(cfg)

    def upd(p, g, m=None, v=None):
        g32 = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        new_m = new_v = None
        if cfg.name in ("adam", "adamw"):
            m32 = m.astype(jnp.float32)
            v32 = v.astype(jnp.float32)
            m32 = cfg.b1 * m32 + (1 - cfg.b1) * g32
            v32 = cfg.b2 * v32 + (1 - cfg.b2) * g32 * g32
            mh = m32 / (1 - cfg.b1 ** (step.astype(jnp.float32) + 1))
            vh = v32 / (1 - cfg.b2 ** (step.astype(jnp.float32) + 1))
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if cfg.name == "adamw" and cfg.weight_decay:
                delta = delta + cfg.weight_decay * p32
            new_m, new_v = m32.astype(dt), v32.astype(dt)
        elif cfg.name == "rmsprop":
            v32 = v.astype(jnp.float32)
            v32 = cfg.rms_decay * v32 + (1 - cfg.rms_decay) * g32 * g32
            delta = g32 / (jnp.sqrt(v32) + cfg.eps)
            new_v = v32.astype(dt)
        elif cfg.name == "momentum":
            m32 = m.astype(jnp.float32)
            m32 = cfg.momentum * m32 + g32
            delta = m32
            new_m = m32.astype(dt)
        else:  # sgd
            delta = g32
        return (p32 - lr * delta).astype(p.dtype), new_m, new_v

    if cfg.name in ("adam", "adamw"):
        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": m, "v": v, "count": step + 1}
    elif cfg.name == "rmsprop":
        out = jax.tree.map(lambda p, g, v: upd(p, g, v=v), params, grads,
                           state["v"])
        params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"v": v, "count": step + 1}
    elif cfg.name == "momentum":
        out = jax.tree.map(lambda p, g, m: upd(p, g, m=m), params, grads,
                           state["m"])
        params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": m, "count": step + 1}
    else:
        out = jax.tree.map(lambda p, g: upd(p, g), params, grads)
        params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"count": step + 1}

    return params, new_state, {"grad_norm": gnorm, "lr": lr}
