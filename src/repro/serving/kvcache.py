"""Paged, quantized KV-cache subsystem (DESIGN.md §8).

The serving-state counterpart of the paper's weight story: just as §4 stores
a weight as a narrow index into a tiny codebook, the paged cache stores
serving state as fixed-size pages (int8 + per-token-per-head scales,
``attention.quantize_kv``) allocated on demand from a global pool — max
concurrency becomes a function of actual tokens in flight, not
``max_batch × max_len``.

Split of responsibilities:

* **Device** (``transformer.init_paged_cache`` pytree): the page pool
  arrays ``(L, n_pages, page, KV, hd)`` [+ scales] plus the per-slot page
  table / position vectors threaded through ``prefill_chunk`` and the paged
  ``decode_step``.  Page 0 is the **trash page**: never allocated, the
  write target of retired slots lockstep-decoding until the loop exits, and
  the discard target for recomputed shared chunks.
* **Host** (``PagePool``, this module): free-list allocation, per-page
  refcounts, the content-addressed prefix cache, LRU eviction, and
  copy-on-write.  All host structures are O(n_pages) ints — no tensors.

Prefix caching is content-addressed by hash *chains*: page c of a prompt is
keyed by a rolling digest of ``(key(c−1), tokens_in_page_c)``, so a page is
shared only when the entire prefix matches — exactly the condition under
which its K/V (functions of all tokens ≤ its last position, at absolute
RoPE positions) are bit-identical.  Full prompt pages are registered right
after prefill
(immutable from then on; in-flight requests can already share them).  A
non-aligned prompt's partial tail page is registered at retirement: its
pollution from decode writes beyond the prompt is fenced by the reader's
valid-length mask, and any sharer copies-on-write before its own decode
writes land (``Admission.cow_tail``).

Admission (``admit``) is what the engine gates on: it returns None when the
pool cannot supply the request's worst-case page count (prompt + stop
tokens) even after evicting cache-only pages — free *pages*, not free
slots, are the capacity resource.

Under tensor parallelism (DESIGN.md §10) the device arrays shard their
*in-page token axis* over `model` — page ids, page tables, and therefore
every decision this allocator makes (allocation order, hash chains, CoW,
rollback, free-list state) are shard-invariant by construction: one host
allocator, one replicated page-table row per slot, per-shard S-slices of
each page.  Nothing in this module is TP-aware, deliberately.

Speculative rollback (DESIGN.md §9): ``truncate`` returns
rejection-emptied tail pages to the free list while keeping them
*reserved* for their request (``reserved_extra`` — invisible to new
admissions, so ``extend`` back up to the admission-time worst case can
never deadlock), and copy-on-write-splits a shared boundary page before
the request's next writes can land in it.

Fleet-shared prefix tier (DESIGN.md §15): when a ``SharedPrefixTier`` is
attached (``pool.shared_tier``), the pool consults it at admission time for
full prompt pages it does not hold locally — a tier hit scatters the host
copy into a fresh cache-only page *before* planning, so the plan then sees
an ordinary local hit and a hot system prompt is materialized once per
fleet, not once per replica.  ``register_prefill`` publishes newly
registered full pages back to the tier (captured right after prefill, while
still immutable, so tier bytes are bit-exact by construction); partial tail
pages never enter the tier — their decode pollution beyond the prompt makes
them replica-private.

Scheduler preemption (DESIGN.md §11): ``swap_out`` releases a preempted
request's page references after the engine copies their contents to a
host-side store — registered prefix pages survive at the cache's own
refcount, hashes intact — and ``swap_in`` re-allocates the full
reservation as fresh private pages for the engine to scatter the blob
back into.  ``free_claimable``/``pressure`` are introspection signals
(how close admission is to blocking) for schedulers and benchmarks; the
stock ``AsyncScheduler`` itself preempts on placement *failure* — admit/
swap-in returning "not yet" — rather than on a pressure threshold.
"""

from __future__ import annotations

import dataclasses
import hashlib

from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PagePool", "PoolStats", "Admission", "SharedPrefixTier",
           "chain_keys"]

# hash-chain seed for page 0 of every prompt
_ROOT = hashlib.blake2b(b"repro.kv.chain-root", digest_size=16).digest()


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _page_key(prev: bytes, page_tokens) -> bytes:
    """Next link of the rolling chain: BLAKE2b-128 of the previous key
    concatenated with the page's tokens as int64 bytes."""
    h = hashlib.blake2b(prev, digest_size=16)
    h.update(np.asarray(page_tokens, np.int64).tobytes())
    return h.digest()


def chain_keys(tokens, page_size: int) -> tuple[list, bytes | None]:
    """The prompt's content-addressed prefix chain: one key per FULL page
    (page c keyed by a digest of ``key(c−1)`` and the page's tokens) plus
    the partial tail page's key when the prompt is not page-aligned, else
    None.

    Keys are rolling 16-byte BLAKE2b digests: page c's key hashes the
    previous key (itself a digest of the whole prior chain) with page c's
    tokens, so equality still certifies that the *entire* prefix matches,
    but every key is O(1)-sized — building and comparing a prompt's chain
    is O(pages·page_size), where the earlier nested-tuple schema embedded
    the full prior chain in every key and cost O(pages²·page_size) per
    prompt.  Digests (unlike salted ``hash()``) are identical across
    processes and machines, which is what lets the fleet's shared prefix
    tier key pages fleet-wide with the same chain.

    This is THE key construction — ``PagePool.admit`` plans with it, the
    fleet router (serving/router.py) scores replica affinity with it, and
    ``SharedPrefixTier`` stores fleet-wide pages under it, so a
    router-predicted or tier-served hit is exactly an admit-time hit."""
    page = int(page_size)
    toks = np.asarray(tokens, np.int64)
    n_full = len(toks) // page
    keys, key = [], _ROOT
    for c in range(n_full):
        key = _page_key(key, toks[c * page:(c + 1) * page])
        keys.append(key)
    partial = None
    if len(toks) % page:
        partial = _page_key(key, toks[n_full * page:])
    return keys, partial


@dataclasses.dataclass
class PoolStats:
    """Cumulative pool counters (benchmarks read these)."""

    hit_pages: int = 0           # prompt pages reused from the prefix cache
    miss_pages: int = 0          # prompt pages computed fresh
    # pages materialized from the fleet's shared tier instead of computed
    # (each also lands in hit_pages via the admission plan that follows —
    # miss_pages alone remains "true recomputations")
    shared_hit_pages: int = 0
    cow_copies: int = 0
    evictions: int = 0
    peak_pages_in_use: int = 0
    peak_page_refs: int = 0      # refcount high-water across all pages
    truncated_pages: int = 0     # pages returned by speculative rollback
    # Swap counters spell their direction and count page *references*
    # released/re-acquired by the pool (the full reservation) — distinct
    # from the scheduler's pages_swapped_out/in, which count data pages
    # actually moved through the host blob.  serving/telemetry.py
    # re-exports both under one canonical vocabulary (pool.* vs sched.*).
    swapped_out_pages: int = 0   # page refs released by scheduler preemption
    swapped_in_pages: int = 0    # page refs re-acquired by swap-in

    @property
    def hit_rate(self) -> float:
        total = self.hit_pages + self.miss_pages
        return self.hit_pages / total if total else 0.0


@dataclasses.dataclass
class Admission:
    """One admitted request's page plan (host-side bookkeeping handle).

    pids:         physical page per logical page, length = worst-case pages
                  for prompt + stop tokens (decode never allocates mid-loop).
    n_chunks:     logical prompt pages (= prefill chunks).
    compute_from: first chunk index to run through ``prefill_chunk`` (earlier
                  chunks are full-page prefix-cache hits; the chunk holding
                  the last prompt token is always computed — its logits seed
                  sampling).
    write_pids:   per computed chunk, the physical page receiving its K/V —
                  0 (trash) for shared pages recomputed only for logits.
    full_keys:    (chunk_idx, chain_key) of every full prompt page, for
                  registration after prefill.
    partial_key:  chain key of a non-aligned prompt's tail page (registered
                  at retirement), else None.
    cow_tail:     logical index of a *shared* tail page the request must
                  copy-on-write before decode writes into it, else None.
    reserve:      admission-time worst-case page count — the request's
                  standing claim on the pool even while ``truncate`` has
                  released some of its pages (speculative rollback).
    n_live:       leading pids currently allocated; pids beyond it are 0
                  (trash) placeholders until ``extend`` re-grows the span.
    """

    pids: list
    n_chunks: int
    compute_from: int
    write_pids: list
    full_keys: list
    partial_key: bytes | None
    cow_tail: int | None
    reserve: int = 0
    n_live: int = 0


@partial(jax.jit, donate_argnums=(0,))
def _copy_page(cache, src, dst):
    """cache[:, dst] = cache[:, src] for every pool array (all layers).
    The pool is donated (the caller reassigns) so the copy is in place."""
    out = {}
    for name, arr in cache.items():
        pg = jax.lax.dynamic_slice_in_dim(arr, src, 1, axis=1)
        out[name] = jax.lax.dynamic_update_slice_in_dim(arr, pg, dst, axis=1)
    return out


@partial(jax.jit, donate_argnums=(0,))
def _write_page(cache, dst, page):
    """cache[:, dst] = page for every pool array (all layers): the scatter
    that materializes a shared-tier host page into this pool."""
    out = {}
    for name, arr in cache.items():
        pg = jnp.asarray(page[name], arr.dtype)[:, None]
        out[name] = jax.lax.dynamic_update_slice_in_dim(arr, pg, dst, axis=1)
    return out


class SharedPrefixTier:
    """Fleet-level content-addressed read-only page store (DESIGN.md §15).

    Keyed by the same rolling-digest chains ``chain_keys`` builds, so tier
    keying agrees bit-for-bit with admit-time planning and router probes.
    Values are host copies of FULL, immutable prompt pages — one
    ``(L, page, ...)`` array per cache plane — captured at registration
    time, right after prefill and before any decode write can land, so
    scattering a tier page into another replica's pool reproduces the
    exact bytes prefill would have written.  Partial tail pages (polluted
    beyond the prompt by decode, registered only at retirement) never
    enter the tier.

    LRU-bounded by ``capacity_bytes`` (None = unbounded).  Everything is
    a plain host dict mutated in the fleet's sorted-replica step order,
    so replays with a shared tier stay byte-identical and replica-order
    independent."""

    def __init__(self, capacity_bytes: int | None = None):
        self.capacity_bytes = capacity_bytes
        self.table: OrderedDict[bytes, dict] = OrderedDict()
        self.bytes = 0
        self.hits = 0            # pages materialized into a pool from here
        self.misses = 0          # chain walks stopped by a key held nowhere
        self.puts = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self.table)

    def __contains__(self, key) -> bool:
        return key in self.table

    def get(self, key):
        pages = self.table.get(key)
        if pages is not None:
            self.table.move_to_end(key)               # LRU touch
        return pages

    def put(self, key, pages: dict) -> None:
        if key in self.table:
            return
        self.table[key] = pages
        self.bytes += sum(int(a.nbytes) for a in pages.values())
        self.puts += 1
        if self.capacity_bytes is not None:
            while self.bytes > self.capacity_bytes and len(self.table) > 1:
                _, old = self.table.popitem(last=False)
                self.bytes -= sum(int(a.nbytes) for a in old.values())
                self.evictions += 1

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "puts": self.puts, "evictions": self.evictions,
                "bytes": self.bytes, "entries": len(self.table)}


class PagePool:
    """Block-pool page allocator + content-addressed prefix cache.

    Refcount protocol: allocation gives the requesting slot one reference;
    registration in the prefix cache adds one held by the cache; each
    sharer adds one.  Retirement drops the request's references — pages
    reaching zero return to the free list, registered pages survive at
    refcount 1 (cache-only) and are the LRU *eviction* pool when the free
    list runs dry.
    """

    def __init__(self, model, *, n_pages: int, page_size: int,
                 pages_per_slot: int, kv_dtype=jnp.bfloat16,
                 prefix_cache: bool = True):
        if n_pages < 2:
            raise ValueError("pool needs >= 2 pages (page 0 is the trash "
                             "page)")
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.pages_per_slot = int(pages_per_slot)
        self.prefix_enabled = bool(prefix_cache)
        self.cache = model.init_paged_cache(n_pages, page_size, kv_dtype)
        self.free: list[int] = list(range(n_pages - 1, 0, -1))
        self.ref = np.zeros(n_pages, np.int64)
        self.table: OrderedDict[bytes, int] = OrderedDict()  # key -> pid
        self.key_of: dict[int, bytes] = {}                   # pid -> key
        # fleet-level shared prefix tier (attached by Fleet.add_replica;
        # None = per-replica caching only, the pre-§15 behavior)
        self.shared_tier: SharedPrefixTier | None = None
        self.stats = PoolStats()
        # pages released by truncate() but still owed to their in-flight
        # request's reservation: invisible to new admissions so extend()
        # back up to the reserve can never deadlock (DESIGN.md §9)
        self.reserved_extra = 0

    # --- capacity -------------------------------------------------------------

    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1                       # minus the trash page

    def pages_in_use(self) -> int:
        return int((self.ref > 0).sum())

    def _evictable(self, exclude=()) -> int:
        """Cache-only pages reclaimable by eviction.  ``exclude``: pages an
        admission plan is about to share — taking a reference pins them, so
        they must not be counted as reclaimable supply for that same plan."""
        return sum(1 for pid in self.key_of
                   if self.ref[pid] == 1 and pid not in exclude)

    def can_admit(self, n_new: int, exclude=()) -> bool:
        return (len(self.free) + self._evictable(exclude)
                - self.reserved_extra >= n_new)

    def bytes_per_page(self) -> int:
        return sum(int(a.nbytes) for a in self.cache.values()) // self.n_pages

    def bytes_total(self) -> int:
        return sum(int(a.nbytes) for a in self.cache.values())

    def bytes_in_use(self) -> int:
        return self.pages_in_use() * self.bytes_per_page()

    def utilization(self) -> float:
        return self.pages_in_use() / self.usable_pages

    def pages_needed(self, prompt_len: int, stop: int) -> int:
        """Worst-case pages for a request: prompt + stop generated tokens
        (K/V written up to position prompt_len + stop − 2; no mid-loop
        allocation, so the whole span is reserved at admission)."""
        last = max(prompt_len, prompt_len + stop - 1)
        return max(_ceil_div(prompt_len, self.page_size),
                   _ceil_div(last, self.page_size))

    def free_claimable(self) -> int:
        """Pages a new admission could claim right now: the free list plus
        cache-only evictables, minus the rollback pages still owed to
        in-flight reservations (DESIGN.md §11)."""
        return len(self.free) + self._evictable() - self.reserved_extra

    def pressure(self) -> float:
        """Fraction of usable capacity NOT claimable by a new admission —
        0.0 is an idle pool, 1.0 means admission is fully blocked until an
        in-flight request retires or is preempted."""
        return 1.0 - self.free_claimable() / self.usable_pages

    # --- allocator ------------------------------------------------------------

    def _note_usage(self):
        used = self.pages_in_use()
        if used > self.stats.peak_pages_in_use:
            self.stats.peak_pages_in_use = used
        top = int(self.ref.max()) if self.ref.size else 0
        if top > self.stats.peak_page_refs:
            self.stats.peak_page_refs = top

    def _alloc(self) -> int:
        if not self.free:
            self._evict_one()
        pid = self.free.pop()
        self.ref[pid] = 1
        return pid

    def _evict_one(self):
        for key, pid in self.table.items():           # LRU order: front first
            if self.ref[pid] == 1:                    # cache-only holder
                del self.table[key]
                del self.key_of[pid]
                self._release(pid)
                self.stats.evictions += 1
                return
        raise RuntimeError("page pool exhausted: every page is referenced "
                           "by an in-flight request")

    def _release(self, pid: int):
        self.ref[pid] -= 1
        assert self.ref[pid] >= 0, f"refcount underflow on page {pid}"
        if self.ref[pid] == 0:
            self.free.append(pid)

    # --- prefix cache ---------------------------------------------------------

    def _lookup(self, key):
        pid = self.table.get(key)
        if pid is not None:
            self.table.move_to_end(key)               # LRU touch
        return pid

    def prefix_match_pages(self, tokens) -> int:
        """How many leading prompt pages this pool already holds: full
        pages matched along the hash chain, plus the partial tail when
        every full page before it matched — the same count ``admit``
        would share.  Read-only: no LRU touch, no refcount change, so a
        router may probe every replica's pool without perturbing any
        pool's eviction order (serving/router.py)."""
        if not self.prefix_enabled:
            return 0
        keys, partial = chain_keys(tokens, self.page_size)
        matched = 0
        for key in keys:
            if key not in self.table:
                return matched
            matched += 1
        if partial is not None and partial in self.table:
            matched += 1
        return matched

    def _register(self, key, pid: int):
        if key in self.table or pid in self.key_of:
            return                                    # racer already cached it
        self.table[key] = pid
        self.key_of[pid] = key
        self.ref[pid] += 1
        self._note_usage()

    def _adopt_shared(self, keys) -> None:
        """Promote shared-tier pages this pool lacks (DESIGN.md §15).

        Walks the prompt's full-page chain in order; each key missing
        locally but held by the fleet's shared tier is materialized as a
        local cache-only page (alloc → jitted scatter → register), so the
        planning pass right after finds an ordinary local hit.  The walk
        stops at the first key neither store holds — chain keying means no
        later page can hit either.  Each promotion is guarded by
        ``can_admit(1)``; a promoted page is cache-only (refcount 1) and
        immediately evictable, so a promotion outliving a failed admission
        costs nothing."""
        tier = self.shared_tier
        for key in keys:
            if key in self.table:
                continue
            pages = tier.get(key)
            if pages is None:
                tier.misses += 1
                return
            if not self.can_admit(1):
                return
            pid = self._alloc()
            self.cache = _write_page(self.cache, np.int32(pid), pages)
            self._register(key, pid)
            self._release(pid)     # drop the alloc ref; the cache's keeps it
            tier.hits += 1
            self.stats.shared_hit_pages += 1

    # --- request lifecycle ----------------------------------------------------

    def admit(self, tokens: list[int], stop: int) -> Admission | None:
        """Plan + allocate one request's pages, or None when the pool cannot
        supply them yet (admission waits on free pages, not free slots).

        Demand accounting: sharing a page pins it (its reference makes it
        unevictable for this very plan), and a shared partial tail still
        costs one private page — the engine copies-on-write before decode.
        When the sharing plan is unaffordable, the request is re-planned
        without prefix hits (eviction may then reclaim the cache-only pages
        it would have shared) before admission is deferred."""
        page = self.page_size
        plen = len(tokens)
        n_chunks = _ceil_div(plen, page)
        needed = self.pages_needed(plen, stop)
        if needed > self.pages_per_slot or needed > self.usable_pages:
            raise ValueError(
                f"request needs {needed} pages (prompt {plen} + {stop} new, "
                f"page {page}) but the slot holds {self.pages_per_slot} and "
                f"the pool {self.usable_pages}")

        n_full = plen // page
        rem = plen % page
        keys, partial_key = chain_keys(tokens, page)
        if self.prefix_enabled and self.shared_tier is not None and n_full:
            self._adopt_shared(keys)

        for use_prefix in ((True, False) if self.prefix_enabled else
                           (False,)):
            matched, hit_pids, partial_pid = 0, [], None
            if use_prefix:
                for c in range(n_full):
                    pid = self._lookup(keys[c])
                    if pid is None:
                        break
                    hit_pids.append(pid)
                    matched += 1
                if rem and matched == n_full:
                    partial_pid = self._lookup(partial_key)
            n_shared = matched + (1 if partial_pid is not None else 0)
            # + 1: the CoW page the engine allocates for a shared tail
            demand = (needed - n_shared
                      + (1 if partial_pid is not None else 0))
            pinned = set(hit_pids)
            if partial_pid is not None:
                pinned.add(partial_pid)
            if self.can_admit(demand, exclude=pinned):
                break
        else:
            return None

        pids = []
        for c in range(needed):
            if c < matched:
                pid = hit_pids[c]
                self.ref[pid] += 1
            elif c == n_chunks - 1 and partial_pid is not None:
                pid = partial_pid
                self.ref[pid] += 1
            else:
                pid = self._alloc()
            pids.append(pid)
        self._note_usage()

        shared = set(range(matched))
        if partial_pid is not None:
            shared.add(n_chunks - 1)
        compute_from = min(matched, n_chunks - 1)
        write_pids = [0 if c in shared else pids[c]
                      for c in range(compute_from, n_chunks)]
        self.stats.hit_pages += len(shared)
        self.stats.miss_pages += n_chunks - len(shared)
        return Admission(
            pids=pids, n_chunks=n_chunks, compute_from=compute_from,
            write_pids=write_pids,
            full_keys=[(c, keys[c]) for c in range(n_full)],
            partial_key=partial_key,
            cow_tail=(n_chunks - 1) if partial_pid is not None else None,
            reserve=needed, n_live=needed)

    def register_prefill(self, adm: Admission):
        """Register the request's full prompt pages (immutable once written;
        concurrent requests may share them immediately).  With a shared
        tier attached, pages the tier lacks are published fleet-wide too —
        captured now, while still decode-untouched, so tier bytes are
        bit-exact by construction."""
        if not self.prefix_enabled:
            return
        for c, key in adm.full_keys:
            self._register(key, adm.pids[c])
        if self.shared_tier is not None:
            for c, key in adm.full_keys:
                if key not in self.shared_tier:
                    self.shared_tier.put(
                        key, {name: np.asarray(arr[:, adm.pids[c]])
                              for name, arr in self.cache.items()})

    def cow(self, adm: Admission) -> int | None:
        """Copy-on-write the shared tail page before decode writes into it.

        Allocates a private page, copies the shared page's contents across
        all layers (one jitted dynamic-slice pair), swaps it into the
        admission, and drops the request's reference on the shared page.
        Returns the logical index rewritten (for the engine's page table),
        or None when no CoW is due.  The shared page is never written.
        """
        if adm.cow_tail is None:
            return None
        c = adm.cow_tail
        old = adm.pids[c]
        new = self._alloc()
        self.cache = _copy_page(self.cache, np.int32(old), np.int32(new))
        self._release(old)
        adm.pids[c] = new
        adm.cow_tail = None
        self.stats.cow_copies += 1
        self._note_usage()
        return c

    # --- speculative rollback (DESIGN.md §9) ----------------------------------

    def truncate(self, adm: Admission, n_tokens: int) -> int:
        """Roll a request's live page span back to ``n_tokens`` tokens.

        Speculative rejection empties tail pages; they return to the free
        list immediately (the pool pays for tokens actually alive, not for
        speculation that lost) but stay **reserved** for this request
        (``reserved_extra``): new admissions cannot claim them, so a later
        ``extend`` back up to the admission-time worst case never
        deadlocks.  The new boundary page — the one future decode/verify
        writes will land in — is copy-on-write split first when it is
        shared (refcount > 1: a prefix-cache registration or a concurrent
        sharer), so rollback can never scribble over bytes another holder
        still reads; its prefix-cache entry keeps pointing at the untouched
        original, hash intact.  Returns the number of pages released.
        Callers must rebuild their page-table row afterwards (both the CoW
        swap and the freed tail change the physical mapping).
        """
        keep = _ceil_div(max(n_tokens, 0), self.page_size)
        if keep > adm.n_live:
            raise ValueError(
                f"truncate to {n_tokens} tokens needs {keep} pages but only "
                f"{adm.n_live} are live — extend() first")
        if keep and n_tokens % self.page_size:
            c = keep - 1                     # partially-filled boundary page
            pid = adm.pids[c]
            if self.ref[pid] > 1:
                new = self._alloc()
                self.cache = _copy_page(self.cache, np.int32(pid),
                                        np.int32(new))
                self._release(pid)
                adm.pids[c] = new
                self.stats.cow_copies += 1
        freed = adm.n_live - keep
        for c in range(keep, adm.n_live):
            self._release(adm.pids[c])
            adm.pids[c] = 0
        adm.n_live = keep
        self.reserved_extra += freed
        self.stats.truncated_pages += freed
        return freed

    def extend(self, adm: Admission, n_tokens: int) -> None:
        """Re-grow a request's live span to cover ``n_tokens`` tokens,
        drawing back from the pages ``truncate`` released.  Capped at the
        admission-time reservation: speculative overshoot beyond it routes
        to the trash page instead — no page need exist for a token that is
        guaranteed to be clamped away."""
        need = min(_ceil_div(max(n_tokens, 0), self.page_size), adm.reserve)
        if need <= adm.n_live:
            return
        for c in range(adm.n_live, need):
            adm.pids[c] = self._alloc()
        self.reserved_extra -= need - adm.n_live
        adm.n_live = need
        self._note_usage()

    # --- scheduler preemption (DESIGN.md §11) ---------------------------------

    def swap_out(self, adm: Admission) -> int:
        """Drop a preempted request's page references.  The engine must have
        copied the live pages' contents to the host FIRST — released pages
        can be re-allocated and overwritten immediately.

        Prefix-cache state is untouched: pages this request registered (or
        shared) survive at the cache's own refcount, hash chains intact, so
        concurrent and future requests keep hitting them while the victim
        is swapped out.  Unlike ``retire``, the partial tail page is NOT
        registered — the request is coming back and will keep writing into
        its private copy.  The request's standing reservation is dropped
        too (``reserved_extra``): a swapped request holds no claim on the
        pool until ``swap_in`` re-admits it.  Returns the number of page
        references released."""
        n = adm.n_live
        for pid in adm.pids[:adm.n_live]:
            self._release(pid)
        self.reserved_extra -= adm.reserve - adm.n_live
        adm.pids = []
        adm.n_live = adm.reserve = 0
        self.stats.swapped_out_pages += n
        return n

    def swap_in(self, reserve_pages: int) -> Admission | None:
        """Re-admit a swapped-out request: allocate its full reservation
        again as fresh private pages (no prefix lookup — the host blob the
        engine scatters back is authoritative, and writing restored bytes
        into a shared page would corrupt other readers), or return None
        when the pool cannot supply it yet.  ``reserve_pages`` never
        exceeds the original admission's reservation, so a request that
        was admitted once can always be restored once enough pages drain."""
        if reserve_pages > self.pages_per_slot \
                or reserve_pages > self.usable_pages:
            raise ValueError(
                f"swap-in needs {reserve_pages} pages but the slot holds "
                f"{self.pages_per_slot} and the pool {self.usable_pages}")
        if not self.can_admit(reserve_pages):
            return None
        pids = [self._alloc() for _ in range(reserve_pages)]
        self._note_usage()
        self.stats.swapped_in_pages += reserve_pages
        return Admission(pids=pids, n_chunks=0, compute_from=0,
                         write_pids=[], full_keys=[], partial_key=None,
                         cow_tail=None, reserve=reserve_pages,
                         n_live=reserve_pages)

    def retire(self, adm: Admission):
        """Drop the retired request's page references.  A non-aligned
        prompt's tail page is registered first (decode pollution beyond the
        prompt is fenced by readers' valid-length masks and replaced under
        copy-on-write by any future sharer)."""
        if (self.prefix_enabled and adm.partial_key is not None
                and adm.n_chunks <= adm.n_live):
            self._register(adm.partial_key, adm.pids[adm.n_chunks - 1])
        for pid in adm.pids[:adm.n_live]:
            self._release(pid)
        self.reserved_extra -= adm.reserve - adm.n_live
        adm.n_live = adm.reserve = 0

    def reset_stats(self):
        self.stats = PoolStats()
