"""Serving: weight compression to index form + the batched inference
engine with its dense/codebook/lut matmul backends (DESIGN.md §3), the
paged KV cache (§8), and speculative decoding (§9)."""

from repro.serving.compress import to_codebook_params, index_dtype_for
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import Admission, PagePool, PoolStats
from repro.serving.spec import SpecConfig, SpecStats
from repro.kernels.dispatch import (BACKENDS, BackendSpec, LutSpec,
                                    make_lut_spec, use_backend)
