from repro.serving.compress import to_codebook_params, index_dtype_for
from repro.serving.engine import ServeEngine
