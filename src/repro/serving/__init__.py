"""Serving: weight compression to index form + the batched inference
engine with its dense/codebook/lut matmul backends (DESIGN.md §3), the
paged KV cache (§8), speculative decoding (§9), and the virtual-clock
request scheduler/server (§11)."""

from repro.serving.compress import to_codebook_params, index_dtype_for
from repro.serving.engine import SchedState, ServeEngine, SwapBlob
from repro.serving.fleet import Fleet, ReplicaProbe
from repro.serving.kvcache import (Admission, PagePool, PoolStats,
                                   SharedPrefixTier, chain_keys)
from repro.serving.router import FleetRouter
from repro.serving.scheduler import (AsyncScheduler, RequestHandle,
                                     StepCosts, VirtualClock)
from repro.serving.server import (Server, ServerReport, iter_trace,
                                  load_trace, poisson_trace,
                                  poisson_trace_iter, save_trace)
from repro.serving.spec import SpecConfig, SpecStats
from repro.serving.telemetry import NULL_TELEMETRY, Telemetry
from repro.kernels.dispatch import (BACKENDS, BackendSpec, LutSpec,
                                    make_lut_spec, use_backend)
