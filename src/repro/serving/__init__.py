"""Serving: weight compression to index form + the batched inference
engine with its dense/codebook/lut matmul backends (DESIGN.md §3)."""

from repro.serving.compress import to_codebook_params, index_dtype_for
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import Admission, PagePool, PoolStats
from repro.kernels.dispatch import BACKENDS, LutSpec, make_lut_spec, use_backend
