"""Speculative decoding: discretized drafts verified by the full model
(DESIGN.md §9).

The paper's spectrum of networks — "fully continuous versions down to
networks with bi-level weights and activations" — is exactly the
draft/target pairing speculative decoding wants: a heavily-discretized
draft (the same architecture through the coarse-grid ``lut`` backend, or a
parameter-free n-gram self-draft) proposes ``k`` tokens per round, and the
full-precision target scores all ``k+1`` positions in ONE batched forward
(``transformer.verify_step``) instead of ``k+1`` sequential decode steps.

Pieces (the ServeEngine wires them together):

* **SpecConfig / SpecStats** — the knobs and the acceptance counters.
* **ngram_propose** — device-side self-draft: match each slot's n-token
  context suffix against every earlier position, propose the continuation
  of the most recent match.  Pure gather/compare ops, so the whole
  speculative decode loop stays a ``lax.while_loop`` (contiguous path) —
  Python is re-entered O(#requests) times, same as baseline serve.
* **spec_accept** — the Leviathan-style rejection rule.  temperature=0
  reduces to "accept while the draft token equals the target argmax",
  which makes speculative output token-for-token identical to baseline
  decode; temperature>0 accepts ``x_i`` with prob ``min(1, p(x_i)/q(x_i))``
  and resamples the first rejection from ``norm(max(0, p − q))``, so the
  emitted prefix is distributionally unbiased with respect to the
  (top-k / top-p filtered) target.
* **filter_logits / target_dist** — shared top-k / nucleus filtering; the
  engine's ``_sample`` and the rejection rule use the SAME filtered
  distribution, so filtering composes with speculation instead of biasing
  it.

Rollback contract: verify writes K/V for all K1 tokens; acceptance advances
each slot's ``pos`` by the emitted count only.  Contiguous caches need no
surgery (stale rows above ``pos`` are fenced by valid-length masks);
paged caches additionally return rejection-emptied tail pages to the pool
through ``PagePool.truncate`` (they stay *reserved*, so the later
``extend`` can never deadlock — see serving/kvcache.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SpecConfig", "SpecStats", "filter_logits", "target_dist",
           "ngram_propose", "ngram_propose_host", "spec_accept"]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs for ServeEngine.

    draft:         'ngram' — parameter-free self-draft (repetition
                   completion; zero extra FLOPs, wins on repetitive
                   suffixes); 'model' — a second model of the same
                   architecture run at a lower discretization tier
                   (``draft_params`` + ``draft_backend``, e.g. index-form
                   params through a coarse ``lut`` grid).
    k:             draft tokens proposed per round (the verify forward
                   scores k+1 positions).
    ngram:         suffix length the self-draft matches.
    draft_params:  params for the 'model' draft (index form for
                   codebook/lut backends).
    draft_backend: matmul backend the draft traces under — may differ from
                   the target's (dispatch.BackendSpec scopes nest inside
                   one jitted step).
    lut_levels / lut_range: the draft's activation grid when
                   draft_backend='lut'; a coarser grid than the target's
                   IS the lower tier of the paper's spectrum.
    """

    draft: str = "ngram"
    k: int = 4
    ngram: int = 2
    draft_params: object = None
    draft_backend: str = "lut"
    lut_levels: int = 4096
    lut_range: tuple = (-16.0, 16.0)


@dataclasses.dataclass
class SpecStats:
    """Cumulative acceptance counters (benchmarks read these)."""

    rounds: int = 0            # verify forwards run
    proposed: int = 0          # draft tokens offered to verification
    accepted: int = 0          # draft tokens that survived (and were emitted)
    emitted: int = 0           # total tokens emitted by spec rounds

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    @property
    def tokens_per_round(self) -> float:
        return self.emitted / self.rounds if self.rounds else 0.0

    def add(self, rounds, proposed, accepted, emitted):
        self.rounds += int(rounds)
        self.proposed += int(proposed)
        self.accepted += int(accepted)
        self.emitted += int(emitted)

    def reset(self):
        self.rounds = self.proposed = self.accepted = self.emitted = 0


# --- top-k / top-p filtering -------------------------------------------------

def filter_logits(lg, top_k: int = 0, top_p: float = 1.0):
    """Top-k / nucleus filtering on (..., V) f32 logits (already divided by
    temperature).  Filtered entries drop to −1e30; ``top_k=0`` and
    ``top_p>=1`` are no-ops.  Ties at the top-p threshold are kept (the
    standard superset caveat); the argmax always survives, so greedy decode
    is invariant under any filter setting.
    """
    V = lg.shape[-1]
    if top_k and top_k < V:
        kth = jnp.sort(lg, axis=-1)[..., V - top_k, None]
        lg = jnp.where(lg < kth, NEG_INF, lg)
    if top_p < 1.0:
        srt = jnp.sort(lg, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep = (cum - probs) < top_p          # mass BEFORE the token < p
        thr = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
        lg = jnp.where(lg < thr, NEG_INF, lg)
    return lg


def target_dist(logits, temperature: float, top_k: int = 0,
                top_p: float = 1.0):
    """The sampling distribution both ``ServeEngine._sample`` and
    ``spec_accept`` draw from: temperature first, then filtering."""
    return jax.nn.softmax(filter_logits(logits / temperature, top_k, top_p),
                          axis=-1)


# --- drafts ------------------------------------------------------------------

def ngram_propose(ctx, ctx_len, *, k: int, n: int):
    """Parameter-free self-draft: propose the k tokens that followed the
    most recent earlier occurrence of each slot's n-token context suffix.

    ctx: (B, C) token history (prompt + emitted so far); ctx_len: (B,)
    valid lengths.  Pure compare/gather ops over the context buffer — cheap
    enough to live inside the jitted decode loop.  Matches whose
    continuation fits entirely inside the known context are preferred (a
    match right at the end proposes mostly-unknown tokens); slots with no
    match repeat their last token (any proposal is admissible — the verify
    pass is what decides).  Returns (B, k) int32.
    """
    B, C = ctx.shape
    pos = jnp.broadcast_to(jnp.arange(C)[None], (B, C))
    match = jnp.ones((B, C), bool)
    for i in range(n):
        suff = jnp.take_along_axis(
            ctx, jnp.maximum(ctx_len[:, None] - n + i, 0), axis=1)   # (B, 1)
        cand = jnp.take_along_axis(
            ctx, jnp.clip(pos - (n - 1) + i, 0, C - 1), axis=1)      # (B, C)
        match &= cand == suff
    # j = end position of a candidate match; the suffix itself ends at
    # ctx_len−1 and is excluded
    valid = (pos >= n - 1) & (pos <= ctx_len[:, None] - 2)
    full = valid & (pos + k <= ctx_len[:, None] - 1)    # continuation known
    j_full = jnp.max(jnp.where(match & full, pos, -1), axis=1)
    j_any = jnp.max(jnp.where(match & valid, pos, -1), axis=1)
    j = jnp.where(j_full >= 0, j_full, j_any)                        # (B,)
    take = j[:, None] + 1 + jnp.arange(k)[None]                      # (B, k)
    prop = jnp.take_along_axis(ctx, jnp.clip(take, 0, C - 1), axis=1)
    last = jnp.take_along_axis(ctx, jnp.maximum(ctx_len[:, None] - 1, 0),
                               axis=1)
    # positions past the known context (a match near the end) propose the
    # last token instead of reading stale buffer bytes
    prop = jnp.where(take <= ctx_len[:, None] - 1, prop, last)
    return jnp.where(j[:, None] >= 0, prop, last).astype(jnp.int32)


def ngram_propose_host(tokens, *, k: int, n: int) -> list[int]:
    """Host-side twin of ``ngram_propose`` for the Python-stepped paged
    path: one slot's token history in, k proposals out."""
    t = np.asarray(tokens, np.int64)
    L = len(t)
    if L < n + 1:
        return [int(t[-1])] * k
    suff = t[L - n:]
    best = -1
    for j in range(L - 2, n - 2, -1):                 # most recent first
        if np.array_equal(t[j - n + 1:j + 1], suff):
            if best < 0:
                best = j
            if j + k <= L - 1:                        # full continuation
                best = j
                break
    if best < 0:
        return [int(t[-1])] * k
    cont = t[best + 1:best + 1 + k]
    out = [int(c) for c in cont]
    return out + [int(t[-1])] * (k - len(out))


# --- rejection sampling ------------------------------------------------------

def spec_accept(logits, draft_tokens, draft_dist, key, *, temperature: float,
                top_k: int = 0, top_p: float = 1.0):
    """Resolve one verify round: accept a prefix of the draft, emit one
    extra token (the rejection's resample, or the bonus token when every
    proposal survived).

    logits: (B, K+1, V) target verify logits (vocab-sliced, f32).
    draft_tokens: (B, K) proposals.  draft_dist: (B, K, V) the draft's own
    (filtered, post-temperature) distribution at each position, or None for
    point-mass drafts (n-gram) — then q = onehot(draft) and the accept
    probability degenerates to p(x_i).

    Returns (n_acc (B,), toks (B, K+1)): ``toks[:, :n_acc]`` are the
    accepted draft tokens and ``toks[:, n_acc]`` the correction/bonus — the
    round emits ``n_acc + 1`` tokens (before the engine's stop-length
    clamp).  temperature=0: accept while draft == target argmax, correction
    = argmax ⇒ byte-identical to baseline greedy decode.  temperature>0:
    the Leviathan et al. 2023 rule over the top-k/p filtered target, which
    keeps the emitted distribution exactly the target's.
    """
    B, K1, V = logits.shape
    K = K1 - 1
    if temperature <= 0:
        tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B, K1)
        acc = draft_tokens == tgt[:, :K]
        n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
        # accepted drafts equal the argmax, so tgt doubles as the emission
        return n_acc, tgt
    p = target_dist(logits, temperature, top_k, top_p)            # (B, K1, V)
    p_draft = jnp.take_along_axis(p[:, :K], draft_tokens[..., None],
                                  axis=-1)[..., 0]                # (B, K)
    if draft_dist is None:
        q_draft = jnp.ones_like(p_draft)
        q_full = jax.nn.one_hot(draft_tokens, V, dtype=p.dtype)
    else:
        q_full = draft_dist
        q_draft = jnp.take_along_axis(q_full, draft_tokens[..., None],
                                      axis=-1)[..., 0]
    ku, kr = jax.random.split(key)
    u = jax.random.uniform(ku, (B, K))
    acc = u * jnp.maximum(q_draft, 1e-30) < p_draft   # u < min(1, p/q)
    n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
    rows = jnp.arange(B)
    idx = jnp.minimum(n_acc, K)
    p_sel = p[rows, idx]                                          # (B, V)
    q_pad = jnp.concatenate([q_full,
                             jnp.zeros((B, 1, V), q_full.dtype)], axis=1)
    res = jnp.clip(p_sel - q_pad[rows, idx], 0.0, None)
    # q ≥ p everywhere ⇒ empty residual (can only happen under filtering
    # mismatches / numerics); fall back to the target itself
    res = jnp.where(jnp.sum(res, axis=-1, keepdims=True) > 1e-9, res, p_sel)
    corr = jax.random.categorical(
        kr, jnp.log(jnp.maximum(res, 1e-30)), axis=-1).astype(jnp.int32)
    toks = jnp.concatenate(
        [draft_tokens, jnp.zeros((B, 1), jnp.int32)], axis=1)
    toks = toks.at[rows, idx].set(corr)
    return n_acc, toks
