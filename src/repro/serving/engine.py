"""Batched serving engine: jitted prefill, jitted decode loop, continuous
batching, and the multiply-free matmul backends (DESIGN.md §3).

The three pieces the seed engine lacked, now the hot path:

* **Prefill** consumes the whole (right-padded) prompt batch in ONE jitted
  call — ``transformer.prefill`` with ``batch['lengths']`` returns each
  row's logits at its last real position and a (B,) ``cache['pos']``
  vector.  Prompt lengths are bucketed to powers of two to bound
  recompiles.
* **Decode** is a ``lax.while_loop`` over ``decode_step`` with greedy /
  temperature sampling *inside* the loop: steady-state decode never
  re-enters Python per token and never syncs to the host.  Per-request
  stop lengths retire rows in place (retired rows lockstep-decode into
  their own clamped cache slot until the loop exits — wasted FLOPs, zero
  correctness impact, no recompile).
* **Continuous batching** (``serve``): the batch dimension is a pool of
  ``max_batch`` slots.  Each request prefills alone (per-bucket compile),
  is spliced into a free slot's cache rows at its own position offset, and
  decodes in lockstep with whatever else is in flight.  The decode loop
  runs with ``stop_on_event=True`` — it exits exactly when some request
  hits its stop length, Python harvests the finished slot, admits the next
  queued request into it (slot reuse == cache eviction: the newcomer's
  prefill overwrites the retiree's rows, and the per-slot ``pos``/valid
  length guarantee no cross-request attention leakage), and re-enters the
  loop.  Python runs O(#requests) times, not O(#tokens).

Backends (``backend=``, routed through ``kernels.dispatch`` at trace time):
``dense`` — gather + XLA dot (default); ``codebook`` — Pallas
``codebook_matmul`` (narrow indices in HBM, dequantize-in-VMEM); ``lut`` —
the paper's faithful §4 integer engine (``lut_matmul``; no multiplications
in the contraction).  ``codebook``/``lut`` require index-form params
(``serving.to_codebook_params``).  Engine families: KV-cache token LMs
(``dense``/``moe``); recurrent-state families would march their state
through the padding.

**Paged mode** (``paged=True``, DESIGN.md §8): ``serve`` swaps the dense
slab for a page pool (``serving.kvcache.PagePool``) — prompts stream
through page-sized prefill chunks (one compile shape, no bucket ladder),
decode runs against per-slot page tables, pages store int8 + scales
(``kv_dtype='int8'``), identical prompt prefixes share refcounted pages
(``prefix_cache``), and admission waits on free *pages* instead of free
slots.  ``generate`` stays contiguous — it is the equivalence reference
the paged path is tested against.

**Speculative mode** (``spec=SpecConfig(...)``, DESIGN.md §9): each decode
iteration becomes a *round* — a draft (parameter-free n-gram self-draft,
or a second model at a lower discretization tier under its own matmul
backend) proposes ``k`` tokens, the target scores all ``k+1`` positions in
one ``verify_step`` forward, and rejection sampling keeps the accepted
prefix plus one corrected/bonus token.  temperature=0 output is
token-for-token identical to baseline decode; temperature>0 output is
distributionally unbiased (and composes with ``top_k``/``top_p``).  The
contiguous spec loop is still a single ``lax.while_loop`` (the n-gram
draft is device-side); the paged spec path steps rounds from Python and
rolls rejected pages back through ``PagePool.truncate``/``extend``.

**Step-level scheduling API** (DESIGN.md §11): ``serve()`` owns its whole
request list; the ``sched_*`` / ``serve_step`` surface hands that control
flow to an external scheduler (``serving.scheduler.AsyncScheduler``)
instead — ``sched_state`` allocates the slot-pool state, ``sched_admit``
prefills one request into one slot, ``serve_step`` decodes a bounded
*quantum* of tokens per round (the SAME jitted while_loop, with per-round
stop lengths), and ``sched_swap_out``/``sched_swap_in`` move a preempted
request's KV state (contiguous slot rows, or its pool pages) to a
host-side ``SwapBlob`` and back, bit-exactly.  Requests arrive, wait,
stream, preempt, and resume — without this engine ever reading a clock.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import dispatch
from repro.models.model_zoo import Model
from repro.serving import probes as nprobes
from repro.serving.kvcache import PagePool
from repro.serving.telemetry import NULL_TELEMETRY
from repro.serving.spec import (SpecConfig, SpecStats, filter_logits,
                                ngram_propose, ngram_propose_host,
                                spec_accept)

__all__ = ["ServeEngine", "SchedState", "SwapBlob"]

_ENGINE_FAMILIES = ("dense", "moe")


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _splice_rows(buf, toks, start, m):
    """buf[b, start[b] + i] = toks[b, i] for i < m[b] — per-row variable-
    length append, expressed as a full-row select (no scatter: clipped
    duplicate column indices would have undefined write order)."""
    B, W = buf.shape
    rel = jnp.arange(W)[None] - start[:, None]               # (B, W)
    pick = (rel >= 0) & (rel < m[:, None])
    vals = jnp.take_along_axis(toks, jnp.clip(rel, 0, toks.shape[1] - 1),
                               axis=1)
    return jnp.where(pick, vals, buf)


def _index_form_stats(params):
    """(found_any, max fan-in over w_idx leaves, concatenated codebooks).

    Every codebook leaf is gathered (per_layer scope has one per tensor) so
    the LUT scale is chosen against the global max|w| — the no-overflow
    guarantee must hold for the worst layer, not the first one visited.
    """
    fan_in, books = 0, []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "w_idx" and leaf.ndim >= 2:
            fan_in = max(fan_in, int(leaf.shape[-2]))
        if name == "codebook":
            books.append(np.asarray(leaf[0] if leaf.ndim == 2 else leaf))
    book = np.concatenate(books) if books else None
    return fan_in > 0, fan_in, book


@dataclasses.dataclass
class SchedState:
    """Mutable slot-pool state for the step-level scheduling API
    (DESIGN.md §11).  One per scheduler session; every field is reassigned
    by the engine's ``sched_*`` calls — callers treat it as opaque.

    The per-slot vectors are HOST numpy mirrors of the ``serve()`` loop's
    carry: admissions/releases/swaps touch one slot at a time, and plain
    indexed writes are free where eager device scatters cost a dispatch
    each (the fleet path admits 100k+ requests per trace).  The jitted
    decode loop converts them on entry; ``serve_step`` writes the round's
    results back.  Contiguous engines own ``cache`` (device KV slab +
    per-slot pos); paged engines own the page-table mirror ``pt_np``, the
    per-slot ``pos`` vector, and the per-slot ``Admission`` handles (the
    pool itself lives on the engine)."""

    live: object                     # (B,) np.bool_ — slot occupied
    last: object                     # (B,) int32 — last sampled token
    n_gen: object                    # (B,) int32 — tokens emitted
    stops: object                    # (B,) int32 — per-slot stop length
    out: object                      # (B, max_len) int32 — emission buffer
    key: object                      # PRNG carry (temperature > 0)
    cache: dict | None = None        # contiguous KV cache (with (B,) pos)
    pt_np: object | None = None      # paged (B, P) page-table host mirror
    pos: object | None = None        # paged per-slot positions (host)
    adm: list | None = None          # paged per-slot Admission handles


@dataclasses.dataclass
class SwapBlob:
    """Host-side image of one preempted request's serving state — what
    ``sched_swap_out`` extracts and ``sched_swap_in`` restores, verbatim
    (restoration is bit-exact: no recompute, no re-quantization).

    ``data`` maps cache plane names to host arrays: the request's live
    pages ``(L, n_pages, page, ...)`` in paged mode, its slot's cache rows
    ``(L, pos, ...)`` in contiguous mode.  ``reserve`` is the paged
    admission-time page reservation ``swap_in`` must re-claim."""

    paged: bool
    pos: int                         # tokens whose K/V are written
    stop: int                        # request stop length
    n_gen: int                       # tokens emitted so far
    last: int                        # last sampled token
    reserve: int                     # paged page reservation to re-claim
    n_pages: int                     # pages of real data (swap-cost unit)
    out_row: object                  # emitted tokens (out-buffer prefix)
    data: dict                       # plane name -> host array


@dataclasses.dataclass
class ServeEngine:
    """Continuous-batching inference engine over one model + param set.

    max_batch:   slot-pool width for ``serve`` (``generate`` sizes its own
                 batch).
    max_len:     cache capacity; prompt_len + max_new must fit.
    temperature: 0 = greedy argmax; >0 = categorical sampling.
    mesh:        a ``('data', 'model')`` mesh turns the engine tensor-
                 parallel (DESIGN.md §10): weights column/row-shard over
                 `model` (index-form params shard only their integer
                 indices), the KV cache — contiguous slab or page pool —
                 shards its sequence/in-page axis, and prefill, the decode
                 while_loop, and spec verify rounds all stay jitted under
                 the mesh.  Requires ``max_len % tp == 0`` (paged:
                 ``page_size % tp == 0``).  tp=N output is token-for-token
                 identical to the mesh-less engine (tests/test_tp_serve.py).
    backend:     'dense' | 'codebook' | 'lut' (see module docstring).
    lut_levels / lut_range: activation grid of the 'lut' backend's
                 multiplication table (|A| entries over [a_min, a_max]).
    paged:       serve() through the paged KV cache (DESIGN.md §8): chunked
                 prefill, per-slot page tables, admission gated on free
                 *pages* rather than free slots.  generate() stays on the
                 contiguous slab (the paged-equivalence reference).
    page_size:   tokens per page (paged mode).
    kv_dtype:    'bf16' — pages in the model's cache float dtype (f32 for
                 f32 models, matching the contiguous slab); 'int8' —
                 quantized pages + per-token-per-head scales.
    prefix_cache: content-addressed sharing of full prompt pages across
                 requests (and serve() calls — the pool persists on the
                 engine).
    n_pages:     global pool size; 0 → 1 trash page + max_batch × ⌈max_len /
                 page_size⌉ (capacity parity with the contiguous slab).
    top_k/top_p: sampling filters (temperature > 0 only): keep the k
                 highest logits / the smallest nucleus whose mass reaches
                 p.  Rejection sampling in spec mode composes with the
                 SAME filtered distribution, so speculation stays unbiased.
    spec:        a ``serving.spec.SpecConfig`` enables speculative decoding
                 for ``serve()`` (DESIGN.md §9); ``generate()`` stays
                 baseline — it is the parity reference spec mode is tested
                 against.  ``spec_stats`` accumulates acceptance counters.
    """

    model: Model
    params: object
    max_len: int = 256
    temperature: float = 0.0
    mesh: object = None
    backend: str = "dense"
    max_batch: int = 8
    lut_levels: int = 4096
    lut_range: tuple = (-16.0, 16.0)
    paged: bool = False
    page_size: int = 16
    kv_dtype: str = "bf16"
    prefix_cache: bool = True
    n_pages: int = 0
    top_k: int = 0                 # 0 = off; sampling only (greedy is argmax)
    top_p: float = 1.0             # 1.0 = off; nucleus filtering
    spec: SpecConfig | None = None  # speculative decoding (DESIGN.md §9)
    telemetry: object = None       # serving.telemetry registry (§13); None
    #                                normalizes to the zero-cost null object
    probes: bool = False           # in-graph numerics probes (§14): thread
    #                                per-layer discretization-health counters
    #                                through prefill + the decode while_loop

    def __post_init__(self):
        if self.telemetry is None:
            self.telemetry = NULL_TELEMETRY
        cfg = self.model.cfg
        if cfg.family not in _ENGINE_FAMILIES:
            raise NotImplementedError(
                f"ServeEngine serves KV-cache token LMs {_ENGINE_FAMILIES}; "
                f"got family {cfg.family!r}")
        if self.backend not in dispatch.BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in "
                             f"{dispatch.BACKENDS}")
        has_idx, fan_in, book = _index_form_stats(self.params)
        self._lut_spec = None
        if self.backend != "dense":
            if not has_idx:
                raise ValueError(
                    f"backend {self.backend!r} needs codebook-index params "
                    "(run serving.to_codebook_params first)")
            if self.backend == "lut":
                self._lut_spec = dispatch.make_lut_spec(
                    book, fan_in, levels=self.lut_levels,
                    a_range=self.lut_range)
                # precompute the §4 tables once (DESIGN.md §12): without
                # this every scanned layer re-derives the |A|×|W| table
                # inside every decode step; with it the table is a plain
                # (replicated) param leaf the kernels gather from
                self.params = dispatch.attach_lut_tables(self.params,
                                                         self._lut_spec)
        self._cache_dtype = (jnp.float32 if cfg.dtype == "float32"
                             else jnp.bfloat16)

        if self.mesh is not None:
            if "model" not in self.mesh.axis_names:
                raise ValueError("ServeEngine mesh needs a 'model' axis "
                                 "(launch.mesh.make_local_mesh)")
            tp = self.mesh.shape["model"]
            if self.max_len % tp:
                raise ValueError(
                    f"max_len {self.max_len} must be a multiple of the TP "
                    f"degree {tp} (the cache shards its sequence axis over "
                    "`model`)")
            if self.paged and self.page_size % tp:
                raise ValueError(
                    f"page_size {self.page_size} must be a multiple of the "
                    f"TP degree {tp} (each shard owns an S-slice of every "
                    "page)")
            if self.spec is not None and self.max_len // tp < self.spec.k + 1:
                raise ValueError(
                    f"max_len/tp = {self.max_len // tp} cannot hold the "
                    f"k+1 = {self.spec.k + 1} verify rows of one shard")
            self.params = self._shard_params(self.params)

        bb = partial(dispatch.bind_backend, name=self.backend,
                     lut_spec=self._lut_spec, mesh=self.mesh)
        self._prefill = jax.jit(bb(self._prefill_fn))
        # the cache operand is donated everywhere it is threaded through:
        # callers always reassign from the result, and without donation XLA
        # copies the full pool/slab per call (per 16-token prefill chunk in
        # paged mode — O(pool) bandwidth for a one-page update)
        self._decode_loop = jax.jit(bb(self._loop_fn),
                                    static_argnames=("stop_on_event",),
                                    donate_argnums=(1,))
        self._admit = jax.jit(self._admit_fn,       # pure memory traffic
                              donate_argnums=(0,))
        self._grow = jax.jit(self._grow_fn)
        if self.kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype {self.kv_dtype!r} not in "
                             "('bf16', 'int8')")
        self._prefill_chunk = jax.jit(bb(self._prefill_chunk_fn),
                                      donate_argnums=(1,))
        self._pool: PagePool | None = None
        # step-level scheduling API (DESIGN.md §11): fixed-shape swap
        # movers — page-axis gather/scatter for the pool, a whole-slot
        # row splice for the contiguous slab — so preemption never grows
        # the compile cache past one program each
        self._gather_pages = jax.jit(lambda cache, pids: {
            k: jnp.take(v, pids, axis=1) for k, v in cache.items()})
        self._scatter_pages = jax.jit(self._scatter_pages_fn,
                                      donate_argnums=(0,))
        self._gather_rows = jax.jit(lambda kv, slot: {
            k: jax.lax.dynamic_index_in_dim(v, slot, axis=1, keepdims=False)
            for k, v in kv.items()})
        self._restore_slot = jax.jit(self._splice, donate_argnums=(0,))
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")

        # --- numerics probes (DESIGN.md §14) ---------------------------------
        self._ps = {}
        self._probe_audit = {}
        if self.probes:
            if self.spec is not None:
                raise NotImplementedError(
                    "numerics probes instrument the plain decode loops; "
                    "speculative serve() is not instrumented — build the "
                    "engine with probes=False or spec=None")
            self._ps = nprobes.init_state(cfg.n_layers)
            # w_idx is immutable at runtime: audit the clip-canonicalized
            # index ids once on the host instead of per decode step
            self._probe_audit = nprobes.static_index_audit(self.params)

        # --- speculative decoding (DESIGN.md §9) -----------------------------
        self.spec_stats = SpecStats()
        self._draft_bs = None
        if self.spec is not None:
            sp = self.spec
            if sp.draft not in ("ngram", "model"):
                raise ValueError(f"spec.draft {sp.draft!r} not in "
                                 "('ngram', 'model')")
            if sp.k < 1:
                raise ValueError(f"spec.k must be >= 1, got {sp.k}")
            if sp.draft == "model":
                if sp.draft_params is None:
                    raise ValueError("spec.draft='model' needs "
                                     "spec.draft_params")
                dhas, dfan, dbook = _index_form_stats(sp.draft_params)
                dlut = None
                if sp.draft_backend not in dispatch.BACKENDS:
                    raise ValueError(f"draft backend {sp.draft_backend!r} "
                                     f"not in {dispatch.BACKENDS}")
                if sp.draft_backend != "dense":
                    if not dhas:
                        raise ValueError(
                            f"draft backend {sp.draft_backend!r} needs "
                            "codebook-index draft_params")
                    if sp.draft_backend == "lut":
                        dlut = dispatch.make_lut_spec(
                            dbook, dfan, levels=sp.lut_levels,
                            a_range=sp.lut_range)
                        # same table precompute for the draft tier
                        self.spec = sp = dataclasses.replace(
                            sp, draft_params=dispatch.attach_lut_tables(
                                sp.draft_params, dlut))
                self._draft_bs = dispatch.BackendSpec(sp.draft_backend, dlut)
                self._draft_prefill = jax.jit(dispatch.bind_backend(
                    self._prefill_fn, name=sp.draft_backend, lut_spec=dlut))
                self._draft_propose_j = jax.jit(self._draft_propose,
                                                donate_argnums=(1,))
            # contiguous spec decode: one while_loop, k+1 tokens per round
            self._spec_loop = jax.jit(bb(self._spec_loop_fn),
                                      static_argnames=("stop_on_event",),
                                      donate_argnums=(2, 3, 4))
            self._admit_kv = jax.jit(self._admit_kv_fn, donate_argnums=(0,))
            # paged spec decode: Python-stepped rounds
            self._verify = jax.jit(bb(self._verify_fn), donate_argnums=(1,))
            self._accept = jax.jit(self._accept_fn)

    # --- tensor parallelism (DESIGN.md §10) ----------------------------------

    def _shard_params(self, params):
        """Place params per the serving TP policy: block matmuls ('w' or the
        integer 'w_idx') column/row-sharded over `model`, everything else —
        embeddings, norms, codebooks, LUT inputs — replicated."""
        from repro.distributed import sharding as SH

        specs = SH.serve_param_specs(params)
        sh = jax.tree_util.tree_map(
            lambda s: SH.named(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(params, sh)

    def _place_kv(self, cache):
        """Shard a contiguous cache's KV planes (L, B, S, KV[, hd]):
        sequence over `model`, batch over data when it divides (§5)."""
        if self.mesh is None:
            return cache
        from repro.distributed.sharding import dp_axes, named

        dp = dp_axes(self.mesh)
        dsz = 1
        for a in dp:
            dsz *= self.mesh.shape[a]
        kv = {}
        for name, arr in cache["kv"].items():
            b_ax = dp if arr.shape[1] % dsz == 0 else None
            spec = P(None, b_ax, "model", *([None] * (arr.ndim - 3)))
            kv[name] = jax.device_put(arr, named(self.mesh, spec))
        return {**cache, "kv": kv}

    def _place_pool(self, cache):
        """Shard page-pool arrays (L, n_pages, page, KV[, hd]): the in-page
        token axis over `model` — every shard owns an S-slice of every
        page, so page tables and allocator decisions stay shard-invariant
        (DESIGN.md §10)."""
        if self.mesh is None:
            return cache
        from repro.distributed.sharding import named

        return {name: jax.device_put(
                    arr, named(self.mesh,
                               P(None, None, "model",
                                 *([None] * (arr.ndim - 3)))))
                for name, arr in cache.items()}

    # --- jitted bodies -------------------------------------------------------

    def _prefill_fn(self, params, tokens, lengths, ps=None):
        batch = {"tokens": tokens, "lengths": lengths}
        if ps:
            batch["probes"] = ps     # probe counters ride the batch pytree
        return self.model.prefill(params, batch, self.mesh)

    # --- numerics probes (DESIGN.md §14) -------------------------------------
    #
    # The engine owns ONE accumulated probe state (`self._ps`).  Every jitted
    # call that should collect gets the state injected into its cache operand
    # immediately before the call and harvested immediately after — no pool /
    # slot cache ever *persists* a "probes" key, so swap blobs, prefix pages,
    # and admission splices are untouched.  The decode loops donate their
    # cache operand, hence the strict reassign-from-result discipline.

    def _ps_inject(self, cache):
        if self.probes:
            cache = {**cache, "probes": self._ps}
        return cache

    def _ps_extract(self, cache):
        if self.probes and "probes" in cache:
            self._ps = cache.pop("probes")
        return cache

    def numerics(self) -> dict:
        """Canonical numerics snapshot: per-layer saturation/headroom/KV
        error + the static index audit (empty when probes are off).  This
        is the telemetry 'numerics' provider."""
        if not self.probes:
            return {}
        return nprobes.summarize(self._ps, audit=self._probe_audit,
                                 backend=self.backend)

    def reset_probes(self) -> None:
        """Zero the accumulated counters (fresh measurement window)."""
        if self.probes:
            self._ps = nprobes.init_state(self.model.cfg.n_layers)

    def _sample(self, logits, key):
        """Greedy argmax, or temperature sampling through the top-k / top-p
        filters (filtering is a no-op for argmax: the max always survives).
        """
        lg = logits[:, -1, :self.model.cfg.vocab].astype(jnp.float32)
        if self.temperature > 0:
            lg = filter_logits(lg / self.temperature, self.top_k, self.top_p)
            return jax.random.categorical(key, lg).astype(jnp.int32)
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def _grow_fn(self, cache):
        """Pad prefill-emitted KV planes (S = prompt bucket) to max_len."""
        kv = {k: jnp.pad(v, [(0, 0), (0, 0), (0, self.max_len - v.shape[2])]
                         + [(0, 0)] * (v.ndim - 3))
              for k, v in cache["kv"].items()}
        return {**cache, "kv": kv}

    def _loop_fn(self, params, cache, last, active, n_gen, stops, out, key,
                 *, stop_on_event: bool):
        """while_loop decode: one iteration == one token for every slot.

        Exits when all slots are retired, the out-buffer width is exhausted,
        or (stop_on_event) the first time any slot hits its stop length —
        the continuous-batching admission point.
        """
        B, cap = out.shape

        def cond(c):
            _, _, active, _, _, _, _, steps, event = c
            go = jnp.any(active) & (steps < cap)
            if stop_on_event:
                go = go & ~event
            return go

        def body(c):
            cache, last, active, n_gen, stops, out, key, steps, _ = c
            logits, cache = self.model.decode(params, last[:, None], cache,
                                              self.mesh)
            key, sub = jax.random.split(key)
            nxt = jnp.where(active, self._sample(logits, sub), last)
            col = jnp.clip(n_gen, 0, cap - 1)
            cur = out[jnp.arange(B), col]
            out = out.at[jnp.arange(B), col].set(jnp.where(active, nxt, cur))
            n_gen = n_gen + active.astype(jnp.int32)
            newly = active & (n_gen >= stops)
            return (cache, nxt, active & ~newly, n_gen, stops, out, key,
                    steps + 1, jnp.any(newly))

        c = (cache, last, active, n_gen, stops, out, key,
             jnp.zeros((), jnp.int32), jnp.asarray(False))
        c = jax.lax.while_loop(cond, body, c)
        return c[0], c[1], c[2], c[3], c[5], c[6]   # cache,last,active,n_gen,out,key

    @staticmethod
    def _splice(cache, c1, slot):
        """Copy a batch-1 prefill cache into slot ``slot`` of a pooled
        contiguous cache (KV planes + per-slot pos)."""
        kv = dict(cache["kv"])
        for k, src in c1["kv"].items():
            start = (0, slot) + (0,) * (src.ndim - 2)
            kv[k] = jax.lax.dynamic_update_slice(
                cache["kv"][k], src.astype(cache["kv"][k].dtype), start)
        pos = cache["pos"].at[slot].set(c1["pos"][0])
        return {**cache, "kv": kv, "pos": pos}

    def _admit_fn(self, cache, c1, slot, first_tok, stop,
                  last, active, n_gen, stops, out):
        """Splice a freshly prefilled request (batch 1) into slot ``slot``.

        The newcomer's KV rows overwrite the retired occupant's prefix; its
        (smaller) ``pos`` plus the decode-time valid-length mask evict
        whatever stale suffix remains without touching it.
        """
        cache = self._splice(cache, c1, slot)
        row = jnp.zeros((out.shape[1],), out.dtype).at[0].set(first_tok)
        return (cache,
                last.at[slot].set(first_tok),
                # the prefill sample already produced token #1: a stop of 1
                # is done on arrival
                active.at[slot].set(stop > 1),
                n_gen.at[slot].set(1),
                stops.at[slot].set(stop),
                out.at[slot].set(row))

    def _admit_kv_fn(self, cache, c1, slot):
        """KV-only admission splice (the draft model's cache in spec mode —
        the engine-side state updates already happened on the target)."""
        return self._splice(cache, c1, slot)

    # --- speculative decoding (DESIGN.md §9) ---------------------------------

    def _draft_propose(self, dparams, dcache, last, key):
        """k autoregressive draft steps under the draft's OWN backend scope
        (it nests inside the target's — dispatch.BackendSpec).

        Returns (proposals (B, k), q_dist (B, k, V) | None, dcache).  The
        scan runs k+1 steps — the extra step writes the LAST proposal's K/V
        (its own sampled token is discarded), so after a fully-accepted
        round the draft cache is valid for every emitted token and the
        caller's rollback (``dcache['pos'] = accepted length``) never
        exposes an unwritten row.  Rejected draft rows become stale tail
        entries fenced by the valid-length mask, exactly like the target's.
        """
        sp = self.spec
        vocab = self.model.cfg.vocab
        with self._draft_bs.scope():
            def step(carry, k_i):
                dc, tok = carry
                logits, dc = self.model.decode(dparams, tok[:, None], dc,
                                               None)
                lg = logits[:, -1, :vocab].astype(jnp.float32)
                if self.temperature > 0:
                    lg = filter_logits(lg / self.temperature, self.top_k,
                                       self.top_p)
                    nxt = jax.random.categorical(k_i, lg).astype(jnp.int32)
                    dist = jax.nn.softmax(lg, axis=-1)
                else:
                    nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                    dist = jnp.zeros((), jnp.float32)   # unused at T=0
                return (dc, nxt), (nxt, dist)

            keys = jax.random.split(key, sp.k + 1)
            (dcache, _), (toks, dists) = jax.lax.scan(
                step, (dcache, last), keys)
        q_dist = (dists[:sp.k].transpose(1, 0, 2)
                  if self.temperature > 0 else None)
        return toks[:sp.k].T, q_dist, dcache

    def _accept_fn(self, logits, d_toks, q_dist, key):
        lg = logits[..., :self.model.cfg.vocab].astype(jnp.float32)
        return spec_accept(lg, d_toks, q_dist, key,
                           temperature=self.temperature,
                           top_k=self.top_k, top_p=self.top_p)

    def _verify_fn(self, params, cache, tokens):
        return self.model.verify(params, tokens, cache, self.mesh)

    def _spec_loop_fn(self, params, dparams, cache, dcache, ctx, ctx_len,
                      last, active, n_gen, stops, out, key, *,
                      stop_on_event: bool):
        """while_loop speculative decode: one iteration == one ROUND — k
        draft proposals, one k+1-token verify forward, rejection sampling —
        emitting 1..k+1 tokens per active slot.

        ctx (B, C) / ctx_len (B,) hold each slot's full token history
        (prompt + emitted): the n-gram self-draft reads it ON DEVICE, so
        Python is still re-entered only O(#requests) times.  Rollback is
        ``pos += emitted`` (< k+1 on rejection): the rejected suffix stays
        as stale cache rows above pos, fenced by the next round's
        valid-length mask.
        """
        sp = self.spec
        K, K1 = sp.k, sp.k + 1
        B, cap = out.shape

        def cond(c):
            active, steps, event = c[7], c[10], c[11]
            go = jnp.any(active) & (steps < cap)
            if stop_on_event:
                go = go & ~event
            return go

        def body(c):
            (cache, dcache, ctx, ctx_len, last, n_gen, stops, active, out,
             key, steps, _ev, stt) = c
            key, kd, ka = jax.random.split(key, 3)
            if sp.draft == "ngram":
                d_toks, q_dist = ngram_propose(
                    ctx, ctx_len, k=K, n=sp.ngram), None
            else:
                d_toks, q_dist, dcache = self._draft_propose(
                    dparams, dcache, last, kd)
            tokens = jnp.concatenate([last[:, None], d_toks], axis=1)
            logits, cache = self.model.verify(params, tokens, cache,
                                              self.mesh)
            n_acc, toks = self._accept_fn(logits, d_toks, q_dist, ka)
            remaining = jnp.maximum(stops - n_gen, 0)
            m = jnp.where(active, jnp.minimum(n_acc + 1, remaining), 0)
            # full-row emission splice (a scatter at clipped columns would
            # collide at the buffer edge; duplicate-index order is undefined)
            out = _splice_rows(out, toks, n_gen, m)
            ctx = _splice_rows(ctx, toks, ctx_len, m)
            last = jnp.where(
                active, toks[jnp.arange(B), jnp.maximum(m - 1, 0)], last)
            cache = {**cache, "pos": cache["pos"] + m}
            ctx_len = ctx_len + m
            n_gen = n_gen + m
            if sp.draft == "model":
                # draft rollback: its cache is valid for every emitted token
                # except the pending last (which it has not seen)
                dcache = {**dcache, "pos": jnp.maximum(ctx_len - 1, 0)}
            newly = active & (n_gen >= stops)
            n_act = jnp.any(active).astype(jnp.int32)
            stt = (stt[0] + n_act,                              # rounds
                   stt[1] + jnp.sum(jnp.where(active, K, 0)),   # proposed
                   stt[2] + jnp.sum(jnp.minimum(n_acc, m)),     # accepted
                   stt[3] + jnp.sum(m))                         # emitted
            return (cache, dcache, ctx, ctx_len, last, n_gen, stops,
                    active & ~newly, out, key, steps + 1, jnp.any(newly),
                    stt)

        z = jnp.zeros((), jnp.int32)
        c = (cache, dcache, ctx, ctx_len, last, n_gen, stops, active, out,
             key, z, jnp.asarray(False), (z, z, z, z))
        c = jax.lax.while_loop(cond, body, c)
        # cache,dcache,ctx,ctx_len,last,n_gen,active,out,key,stats
        return (c[0], c[1], c[2], c[3], c[4], c[5], c[7], c[8], c[9], c[12])

    def _serve_spec(self, prompts, stops_req, key):
        """Continuous batching with speculative rounds (contiguous cache):
        the baseline serve() skeleton, with the while_loop swapped for
        ``_spec_loop`` and a per-slot context buffer feeding the draft."""
        sp = self.spec
        n = len(prompts)
        B, cap, C = self.max_batch, max(stops_req), self.max_len

        cache = self._place_kv(self.model.init_cache(
            B, self.max_len, dtype=self._cache_dtype))
        cache = {**cache, "pos": jnp.zeros((B,), jnp.int32)}
        if sp.draft == "model":
            dparams = sp.draft_params
            dcache = self.model.init_cache(B, self.max_len,
                                           dtype=self._cache_dtype)
            dcache = {**dcache, "pos": jnp.zeros((B,), jnp.int32)}
        else:
            dparams = dcache = jnp.zeros((), jnp.int32)
        ctx = jnp.zeros((B, C), jnp.int32)
        ctx_len = jnp.zeros((B,), jnp.int32)
        last = jnp.zeros((B,), jnp.int32)
        active = jnp.zeros((B,), bool)
        n_gen = jnp.zeros((B,), jnp.int32)
        stops = jnp.ones((B,), jnp.int32)
        out = jnp.zeros((B, cap), jnp.int32)

        queue = deque(range(n))
        slot_rid: list[int | None] = [None] * B
        results: dict[int, list[int]] = {}

        while queue or any(r is not None for r in slot_rid):
            for b in [b for b in range(B) if slot_rid[b] is None]:
                if not queue:
                    break
                rid = queue.popleft()
                plen = len(prompts[rid])
                toks1, len1 = self._pad_prompts([prompts[rid]])
                lg1, c1 = self._prefill(self.params, toks1, len1)
                key, sub = jax.random.split(key)
                first = self._sample(lg1, sub)
                cache, last, active, n_gen, stops, out = self._admit(
                    cache, c1, b, first[0], stops_req[rid],
                    last, active, n_gen, stops, out)
                if sp.draft == "model":
                    _, dc1 = self._draft_prefill(dparams, toks1, len1)
                    dcache = self._admit_kv(dcache, dc1, b)
                row = np.zeros((C,), np.int32)
                row[:plen] = prompts[rid]
                row[plen] = int(first[0])
                ctx = ctx.at[b].set(jnp.asarray(row))
                ctx_len = ctx_len.at[b].set(plen + 1)
                slot_rid[b] = rid
            (cache, dcache, ctx, ctx_len, last, n_gen, active, out, key,
             stt) = self._spec_loop(
                self.params, dparams, cache, dcache, ctx, ctx_len, last,
                active, n_gen, stops, out, key, stop_on_event=True)
            self.spec_stats.add(*(int(s) for s in stt))
            act, gen = np.asarray(active), np.asarray(n_gen)
            out_np = np.asarray(out)
            for b in range(B):
                rid = slot_rid[b]
                if rid is not None and not act[b]:
                    results[rid] = (list(prompts[rid])
                                    + out_np[b, :gen[b]].tolist())
                    slot_rid[b] = None
        return [results[i] for i in range(n)]

    # --- paged path (DESIGN.md §8) -------------------------------------------

    def _prefill_chunk_fn(self, params, cache, tokens, page_row, start,
                          length, write_pid):
        return self.model.prefill_chunk(
            params, {"tokens": tokens, "start": start, "length": length,
                     "page_row": page_row, "write_pid": write_pid},
            cache, self.mesh)

    @property
    def pool(self) -> PagePool:
        """The engine's page pool (created lazily; persists across serve()
        calls so the prefix cache keeps earning hits)."""
        if self._pool is None:
            pps = -(-self.max_len // self.page_size)
            n_pages = self.n_pages or 1 + self.max_batch * pps
            dtype = (jnp.int8 if self.kv_dtype == "int8"
                     else self._cache_dtype)
            self._pool = PagePool(
                self.model, n_pages=n_pages, page_size=self.page_size,
                pages_per_slot=pps, kv_dtype=dtype,
                prefix_cache=self.prefix_cache)
            self._pool.cache = self._place_pool(self._pool.cache)
        return self._pool

    def dense_cache_bytes(self) -> int:
        """HBM bytes of the PR 1 contiguous slab at this engine's shape —
        the baseline the paged pool is compared against."""
        cache = jax.eval_shape(lambda: self.model.init_cache(
            self.max_batch, self.max_len, dtype=self._cache_dtype))
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in jax.tree_util.tree_leaves(cache["kv"]))

    def _chunked_prefill(self, pool, prompt, adm):
        """Stream one admitted prompt through page-sized chunks; returns the
        logits of the last real position (chunk compiles once: every call
        is the same (1, page) shape)."""
        page = self.page_size
        plen = len(prompt)
        row = jnp.asarray(np.asarray(adm.pids + [0] * (pool.pages_per_slot
                                                       - len(adm.pids)),
                                     np.int32))
        logits = None
        cache = self._ps_inject(pool.cache)
        for ci, c in enumerate(range(adm.compute_from, adm.n_chunks)):
            toks = np.zeros((1, page), np.int32)
            chunk = prompt[c * page:(c + 1) * page]
            toks[0, :len(chunk)] = chunk
            logits, cache = self._prefill_chunk(
                self.params, cache, jnp.asarray(toks), row,
                np.int32(c * page), np.int32(len(chunk)),
                np.int32(adm.write_pids[ci]))
        pool.cache = self._ps_extract(cache)
        return logits

    def _paged_admit(self, prompt, stop, key):
        """One request's paged admission sequence — pool admission,
        chunked prefill, prefix registration, CoW split, first-token
        sample — shared verbatim by serve() and the scheduler API so the
        two paths cannot drift.  Returns (adm, first_token, key), with
        adm None (key untouched) when the pool cannot admit yet."""
        pool = self.pool
        adm = pool.admit(list(prompt), stop)
        if adm is None:
            return None, None, key
        logits = self._chunked_prefill(pool, list(prompt), adm)
        pool.register_prefill(adm)
        pool.cow(adm)     # shared tail page → private before decode writes
        key, sub = jax.random.split(key)
        return adm, int(self._sample(logits, sub)[0]), key

    def _serve_paged(self, prompts, stops_req, key):
        pool = self.pool
        page = self.page_size
        for p, s in zip(prompts, stops_req):
            if pool.pages_needed(len(p), s) > pool.usable_pages:
                raise ValueError(
                    f"request (prompt {len(p)} + {s} new) can never fit the "
                    f"{pool.usable_pages}-page pool")
        n = len(prompts)
        B, cap, P = self.max_batch, max(stops_req), pool.pages_per_slot

        pt_np = np.zeros((B, P), np.int32)            # all-trash rows
        pos = jnp.zeros((B,), jnp.int32)
        last = jnp.zeros((B,), jnp.int32)
        active = jnp.zeros((B,), bool)
        n_gen = jnp.zeros((B,), jnp.int32)
        stops = jnp.ones((B,), jnp.int32)
        out = jnp.zeros((B, cap), jnp.int32)

        queue = deque(range(n))
        slot_rid: list[int | None] = [None] * B
        slot_adm: list = [None] * B
        results: dict[int, list[int]] = {}

        while queue or any(r is not None for r in slot_rid):
            # admission: gated on free PAGES (a free slot with an
            # under-provisioned pool waits; retirement frees pages)
            for b in [b for b in range(B) if slot_rid[b] is None]:
                if not queue:
                    break
                rid = queue[0]
                adm, first, key = self._paged_admit(prompts[rid],
                                                    stops_req[rid], key)
                if adm is None:
                    break                              # wait for pages
                queue.popleft()
                pt_np[b] = 0
                pt_np[b, :len(adm.pids)] = adm.pids
                stop = stops_req[rid]
                pos = pos.at[b].set(len(prompts[rid]))
                last = last.at[b].set(first)
                active = active.at[b].set(stop > 1)
                n_gen = n_gen.at[b].set(1)
                stops = stops.at[b].set(stop)
                out = out.at[b].set(
                    jnp.zeros((cap,), out.dtype).at[0].set(first))
                slot_rid[b], slot_adm[b] = rid, adm
            if queue and all(r is None for r in slot_rid):
                raise RuntimeError(
                    "paged admission deadlock: no request in flight and the "
                    "pool cannot admit the next one")
            cache = self._ps_inject({**pool.cache,
                                     "page_table": jnp.asarray(pt_np),
                                     "pos": pos})
            cache, last, active, n_gen, out, key = self._decode_loop(
                self.params, cache, last, active, n_gen, stops, out, key,
                stop_on_event=True)
            pos = cache["pos"]
            cache = self._ps_extract(cache)
            pool.cache = {k: v for k, v in cache.items()
                          if k not in ("page_table", "pos")}
            act, gen = np.asarray(active), np.asarray(n_gen)
            out_np = np.asarray(out)
            for b in range(B):
                rid = slot_rid[b]
                if rid is not None and not act[b]:
                    results[rid] = (list(prompts[rid])
                                    + out_np[b, :gen[b]].tolist())
                    pool.retire(slot_adm[b])
                    pt_np[b] = 0                      # retired → trash page
                    pos = pos.at[b].set(0)
                    slot_rid[b], slot_adm[b] = None, None
        return [results[i] for i in range(n)]

    def _serve_paged_spec(self, prompts, stops_req, key):
        """Paged continuous batching with speculative rounds, stepped from
        Python: before each round every active slot ``extend``s its live
        pages to cover the speculative span (pos + k + 1), and after
        rejection sampling ``truncate`` returns the emptied tail pages to
        the pool — rejected speculation is not just masked out (the
        contiguous rollback), its pages stop existing.  The freed pages
        stay reserved for the request, so the next extend cannot deadlock
        (serving/kvcache.py).
        """
        sp = self.spec
        pool = self.pool
        for p, s in zip(prompts, stops_req):
            if pool.pages_needed(len(p), s) > pool.usable_pages:
                raise ValueError(
                    f"request (prompt {len(p)} + {s} new) can never fit the "
                    f"{pool.usable_pages}-page pool")
        n = len(prompts)
        B, cap, P = self.max_batch, max(stops_req), pool.pages_per_slot
        K, K1 = sp.k, sp.k + 1

        pt_np = np.zeros((B, P), np.int32)
        pos_np = np.zeros((B,), np.int64)
        last_np = np.zeros((B,), np.int64)
        act_np = np.zeros((B,), bool)
        gen_np = np.zeros((B,), np.int64)
        stop_np = np.ones((B,), np.int64)
        out_np = np.zeros((B, cap), np.int64)
        slot_ctx: list[list | None] = [None] * B
        if sp.draft == "model":
            dparams = sp.draft_params
            dcache = self.model.init_cache(B, self.max_len,
                                           dtype=self._cache_dtype)
            dcache = {**dcache, "pos": jnp.zeros((B,), jnp.int32)}

        queue = deque(range(n))
        slot_rid: list[int | None] = [None] * B
        slot_adm: list = [None] * B
        results: dict[int, list[int]] = {}

        def set_row(b):
            pt_np[b] = 0
            pids = slot_adm[b].pids
            pt_np[b, :len(pids)] = pids

        while queue or any(r is not None for r in slot_rid):
            for b in [b for b in range(B) if slot_rid[b] is None]:
                if not queue:
                    break
                rid = queue[0]
                adm, first, key = self._paged_admit(prompts[rid],
                                                    stops_req[rid], key)
                if adm is None:
                    break
                queue.popleft()
                plen = len(prompts[rid])
                slot_rid[b], slot_adm[b] = rid, adm
                # release the worst-case tail: rounds extend() it back
                # page-by-page as speculation actually needs it
                pool.truncate(adm, plen)
                set_row(b)
                pos_np[b], last_np[b] = plen, first
                act_np[b] = stops_req[rid] > 1
                gen_np[b], stop_np[b] = 1, stops_req[rid]
                out_np[b] = 0
                out_np[b, 0] = first
                slot_ctx[b] = list(prompts[rid]) + [first]
                if sp.draft == "model":
                    toks1, len1 = self._pad_prompts([prompts[rid]])
                    _, dc1 = self._draft_prefill(dparams, toks1, len1)
                    dcache = self._admit_kv(dcache, dc1, b)
            if queue and all(r is None for r in slot_rid):
                raise RuntimeError(
                    "paged admission deadlock: no request in flight and the "
                    "pool cannot admit the next one")

            if any(act_np[b] for b in range(B) if slot_rid[b] is not None):
                # --- one speculative round over the in-flight slots ----------
                for b in range(B):
                    if slot_rid[b] is not None and act_np[b]:
                        pool.extend(slot_adm[b], int(pos_np[b]) + K1)
                        set_row(b)
                last_dev = jnp.asarray(last_np, jnp.int32)
                if sp.draft == "ngram":
                    d_np = np.zeros((B, K), np.int64)
                    for b in range(B):
                        if slot_rid[b] is not None and act_np[b]:
                            d_np[b] = ngram_propose_host(
                                slot_ctx[b], k=K, n=sp.ngram)
                    d_toks, q_dist = jnp.asarray(d_np, jnp.int32), None
                else:
                    key, kd = jax.random.split(key)
                    d_toks, q_dist, dcache = self._draft_propose_j(
                        dparams, dcache, last_dev, kd)
                tokens = jnp.concatenate([last_dev[:, None], d_toks], axis=1)
                cache = {**pool.cache, "page_table": jnp.asarray(pt_np),
                         "pos": jnp.asarray(pos_np, jnp.int32)}
                logits, cache = self._verify(self.params, cache, tokens)
                pool.cache = {k: v for k, v in cache.items()
                              if k not in ("page_table", "pos")}
                key, ka = jax.random.split(key)
                n_acc, toks = self._accept(logits, d_toks, q_dist, ka)
                n_acc, toks = np.asarray(n_acc), np.asarray(toks)
                proposed = accepted = emitted = 0
                for b in range(B):
                    if slot_rid[b] is None or not act_np[b]:
                        continue
                    m = int(min(n_acc[b] + 1, stop_np[b] - gen_np[b]))
                    emit = toks[b, :m].tolist()
                    out_np[b, gen_np[b]:gen_np[b] + m] = emit
                    slot_ctx[b].extend(int(t) for t in emit)
                    pos_np[b] += m
                    gen_np[b] += m
                    last_np[b] = emit[-1]
                    proposed += K
                    accepted += min(int(n_acc[b]), m)
                    emitted += m
                    # rollback: emptied speculative tail pages go home
                    pool.truncate(slot_adm[b], int(pos_np[b]))
                    set_row(b)
                    if gen_np[b] >= stop_np[b]:
                        act_np[b] = False
                self.spec_stats.add(1, proposed, accepted, emitted)
                if sp.draft == "model":
                    dpos = np.array(
                        [len(slot_ctx[b]) - 1 if slot_ctx[b] else 0
                         for b in range(B)], np.int32)
                    dcache = {**dcache, "pos": jnp.asarray(dpos)}

            for b in range(B):
                rid = slot_rid[b]
                if rid is not None and not act_np[b]:
                    results[rid] = (list(prompts[rid])
                                    + out_np[b, :gen_np[b]].tolist())
                    pool.retire(slot_adm[b])
                    pt_np[b] = 0
                    pos_np[b] = 0
                    slot_ctx[b] = None
                    slot_rid[b], slot_adm[b] = None, None
        return [results[i] for i in range(n)]

    # --- step-level scheduling API (DESIGN.md §11) ---------------------------

    def _scatter_pages_fn(self, cache, pids, pages):
        """cache[:, pids[i]] = pages[:, i] for every pool plane.  Padding
        entries of ``pids`` point at trash page 0 (duplicate writes of the
        same zero page — content is never read un-fenced)."""
        return {k: v.at[:, pids].set(pages[k].astype(v.dtype))
                for k, v in cache.items()}

    def sched_check(self, prompt, stop: int) -> None:
        """Validate one request against this engine's capacity; raises for
        a request that could NEVER be admitted (schedulers call this at
        submit time so impossible requests fail fast, not in the queue)."""
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if stop < 1:
            raise ValueError("max_new must be >= 1")
        if len(prompt) + stop > self.max_len:
            raise ValueError("prompt + max_new exceeds max_len")
        if self.paged:
            pool = self.pool
            needed = pool.pages_needed(len(prompt), stop)
            if needed > min(pool.pages_per_slot, pool.usable_pages):
                raise ValueError(
                    f"request needs {needed} pages but the slot holds "
                    f"{pool.pages_per_slot} and the pool "
                    f"{pool.usable_pages}")

    def sched_state(self, key=None) -> SchedState:
        """Allocate one scheduler session's slot-pool state.  The paged
        pool itself lives on the engine (prefix cache persists across
        sessions, exactly like ``serve()`` calls)."""
        if self.spec is not None:
            raise NotImplementedError(
                "the step-level API drives plain decode rounds; "
                "speculative serve() remains a batch mode")
        B = self.max_batch
        # per-slot vectors live as HOST mirrors: sched_admit/release/swap
        # touch one slot at a time, and eager device scatters would cost a
        # dispatch each (the fleet admits 100k+ requests per trace).  The
        # jitted decode loop converts them on entry; serve_step writes the
        # round's results back (it already syncs them for harvesting).
        st = SchedState(
            live=np.zeros((B,), bool), last=np.zeros((B,), np.int32),
            n_gen=np.zeros((B,), np.int32),
            stops=np.ones((B,), np.int32),
            out=np.zeros((B, self.max_len), np.int32),
            key=jax.random.PRNGKey(0) if key is None else key)
        if self.paged:
            st.pt_np = np.zeros((B, self.pool.pages_per_slot), np.int32)
            st.pos = np.zeros((B,), np.int32)
            st.adm = [None] * B
        else:
            cache = self._place_kv(self.model.init_cache(
                B, self.max_len, dtype=self._cache_dtype))
            st.cache = {**cache, "pos": jnp.zeros((B,), jnp.int32)}
        return st

    def sched_admit(self, st: SchedState, slot: int, prompt,
                    stop: int) -> int | None:
        """Prefill one request into free slot ``slot``.  Returns the first
        sampled token, or None when the paged pool cannot supply its page
        reservation yet (the admission gate — nothing is allocated)."""
        if self.paged:
            adm, first, st.key = self._paged_admit(prompt, stop, st.key)
            if adm is None:
                self.telemetry.count("engine.admit_blocked")
                return None
            st.adm[slot] = adm
            st.pt_np[slot] = 0
            st.pt_np[slot, :len(adm.pids)] = adm.pids
            st.pos[slot] = len(prompt)
            st.last[slot] = first
            st.n_gen[slot] = 1
            st.stops[slot] = stop
            st.out[slot] = 0
            st.out[slot, 0] = first
        else:
            toks1, len1 = self._pad_prompts([list(prompt)])
            lg1, c1 = self._prefill(self.params, toks1, len1,
                                    self._ps if self.probes else None)
            c1 = self._ps_extract(c1)
            st.key, sub = jax.random.split(st.key)
            firstd = self._sample(lg1, sub)
            act = st.live & (st.n_gen < st.stops)
            st.cache, last, _, n_gen, stops, out = self._admit(
                st.cache, c1, slot, firstd[0], stop,
                st.last, act, st.n_gen, st.stops, st.out)
            st.last, st.n_gen = np.array(last), np.array(n_gen)
            st.stops, st.out = np.array(stops), np.array(out)
            first = int(firstd[0])
        st.live[slot] = True
        return first

    def serve_step(self, st: SchedState, quantum: int = 1):
        """One bounded decode round: every live, unfinished slot emits up
        to ``quantum`` tokens in lockstep (the serve() while_loop with
        per-round stop lengths — same jitted program, same numerics).

        Returns ``(tokens, finished)``: the new tokens per slot this
        round, and the slots whose requests hit their true stop (the
        caller must harvest and ``sched_release`` them)."""
        act = st.live & (st.n_gen < st.stops)
        if not act.any():
            return {}, []
        prev = st.n_gen.copy()
        round_stops = np.minimum(st.stops, st.n_gen + quantum)
        if self.paged:
            cache = {**self.pool.cache, "page_table": jnp.asarray(st.pt_np),
                     "pos": jnp.asarray(st.pos)}
        else:
            cache = st.cache
        cache = self._ps_inject(cache)
        cache, last, _, n_gen, out, st.key = self._decode_loop(
            self.params, cache, st.last, act, st.n_gen, round_stops,
            st.out, st.key, stop_on_event=False)
        cache = self._ps_extract(cache)
        # np.asarray over a device array is a read-only view — copy so the
        # slot-wise sched_* writes stay plain numpy assignments
        st.last, st.n_gen = np.array(last), np.array(n_gen)
        st.out = np.array(out)
        if self.paged:
            st.pos = np.array(cache["pos"])
            self.pool.cache = {k: v for k, v in cache.items()
                               if k not in ("page_table", "pos")}
        else:
            st.cache = cache
        gen, stops, out_np = st.n_gen, st.stops, st.out
        toks, done = {}, []
        for b in range(len(st.live)):
            if not st.live[b]:
                continue
            if gen[b] > prev[b]:
                toks[b] = out_np[b, prev[b]:gen[b]].tolist()
            if gen[b] >= stops[b]:
                done.append(b)
        tel = self.telemetry
        if tel.enabled and toks:
            tel.count("engine.steps")
            tel.count("engine.tokens", sum(len(t) for t in toks.values()))
            tel.observe("engine.batch_occupancy", len(toks))
            tel.count("engine.stops_finished", len(done))
            tel.count("engine.stops_quantum", len(toks) - len(done))
        return toks, done

    def sched_release(self, st: SchedState, slot: int) -> None:
        """Retire a finished slot.  Paged: the request's pages go back to
        the pool (prefix registration included, like serve()); contiguous:
        the next admission's splice evicts the stale rows."""
        if self.paged:
            self.pool.retire(st.adm[slot])
            st.adm[slot] = None
            st.pt_np[slot] = 0
            st.pos[slot] = 0
        else:
            st.cache = {**st.cache,
                        "pos": st.cache["pos"].at[slot].set(0)}
        st.live[slot] = False

    def sched_swap_out(self, st: SchedState, slot: int) -> SwapBlob:
        """Preempt slot ``slot``: copy its KV state to a host-side blob,
        then release its device resources (paged: page refcounts drop,
        prefix-cache hashes survive — ``PagePool.swap_out``).  The copy
        happens strictly before the release: a released page can be
        re-allocated and overwritten immediately."""
        gen = int(st.n_gen[slot])
        stop = int(st.stops[slot])
        last = int(st.last[slot])
        out_row = st.out[slot, :gen].copy()
        if self.paged:
            pool, adm = self.pool, st.adm[slot]
            pos = int(st.pos[slot])
            n_data = -(-pos // self.page_size)
            reserve = adm.reserve
            pids = np.zeros((pool.pages_per_slot,), np.int32)
            pids[:adm.n_live] = adm.pids[:adm.n_live]
            pages = self._gather_pages(pool.cache, jnp.asarray(pids))
            data = {k: np.asarray(v[:, :n_data]) for k, v in pages.items()}
            pool.swap_out(adm)
            st.adm[slot] = None
            st.pt_np[slot] = 0
            st.pos[slot] = 0
            blob = SwapBlob(paged=True, pos=pos, stop=stop, n_gen=gen,
                            last=last, reserve=reserve, n_pages=n_data,
                            out_row=out_row, data=data)
        else:
            pos = int(np.asarray(st.cache["pos"])[slot])
            rows = self._gather_rows(st.cache["kv"], slot)
            data = {k: np.asarray(v)[:, :pos] for k, v in rows.items()}
            st.cache = {**st.cache,
                        "pos": st.cache["pos"].at[slot].set(0)}
            blob = SwapBlob(paged=False, pos=pos, stop=stop, n_gen=gen,
                            last=last, reserve=0,
                            n_pages=-(-pos // self.page_size),
                            out_row=out_row, data=data)
        st.live[slot] = False
        return blob

    def sched_swap_in(self, st: SchedState, slot: int,
                      blob: SwapBlob) -> bool:
        """Restore a swapped-out request into free slot ``slot`` —
        bit-exact (pages/rows written back verbatim), so a preempted
        request's continuation is token-identical to never having been
        preempted.  Returns False when the paged pool cannot supply the
        request's reservation yet.

        The blob need not come from THIS engine: fleet drain-time
        migration (DESIGN.md §15) restores a drained replica's blob on a
        survivor.  That only works between identically-shaped caches, so
        plane layout mismatches (a heterogeneous fleet) fail loudly here
        instead of scattering garbage."""
        if self.paged:
            pool = self.pool
            for k, v in pool.cache.items():
                d = blob.data.get(k)
                if d is None or tuple(d.shape[2:]) != tuple(v.shape[2:]) \
                        or d.shape[0] != v.shape[0]:
                    raise ValueError(
                        f"swap-in blob plane {k!r} does not match this "
                        f"engine's cache layout — migration requires "
                        f"identically-shaped replicas")
            adm = pool.swap_in(blob.reserve)
            if adm is None:
                self.telemetry.count("engine.swap_in_blocked")
                return False
            P = pool.pages_per_slot
            pids = np.zeros((P,), np.int32)
            pids[:blob.n_pages] = adm.pids[:blob.n_pages]
            pages = {}
            for k, v in pool.cache.items():
                pad = np.zeros((v.shape[0], P) + tuple(v.shape[2:]),
                               np.asarray(blob.data[k]).dtype)
                pad[:, :blob.n_pages] = blob.data[k]
                pages[k] = jnp.asarray(pad)
            pool.cache = self._scatter_pages(pool.cache, jnp.asarray(pids),
                                             pages)
            st.adm[slot] = adm
            st.pt_np[slot] = 0
            st.pt_np[slot, :len(adm.pids)] = adm.pids
            st.pos[slot] = blob.pos
        else:
            kv = {}
            for k, v in st.cache["kv"].items():
                pad = np.zeros((v.shape[0], 1) + tuple(v.shape[2:]),
                               np.asarray(blob.data[k]).dtype)
                pad[:, 0, :blob.pos] = blob.data[k]
                kv[k] = jnp.asarray(pad)
            c1 = {"kv": kv, "pos": jnp.asarray([blob.pos], jnp.int32)}
            st.cache = self._restore_slot(st.cache, c1, slot)
        st.out[slot] = 0
        st.out[slot, :blob.n_gen] = blob.out_row
        st.last[slot] = blob.last
        st.n_gen[slot] = blob.n_gen
        st.stops[slot] = blob.stop
        st.live[slot] = True
        return True

    # --- prompt plumbing -----------------------------------------------------

    def _pad_prompts(self, prompts):
        lens = [len(p) for p in prompts]
        if min(lens) < 1:
            raise ValueError("empty prompt")
        pb = _bucket(max(lens))
        if pb > self.max_len:
            raise ValueError(f"prompt bucket {pb} exceeds max_len "
                             f"{self.max_len}")
        toks = np.zeros((len(prompts), pb), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return jnp.asarray(toks), jnp.asarray(lens, jnp.int32)

    # --- public API ----------------------------------------------------------

    def generate(self, prompts: list[list[int]], max_new: int = 32,
                 key=None) -> list[list[int]]:
        """Greedy (or sampled) continuation for a fixed batch of prompts.

        One jitted prefill + one jitted decode loop; Python is re-entered
        exactly once, at the end.
        """
        B = len(prompts)
        toks, lengths = self._pad_prompts(prompts)
        if int(jnp.max(lengths)) + max_new > self.max_len:
            raise ValueError("prompt + max_new exceeds max_len")
        key = jax.random.PRNGKey(0) if key is None else key
        logits, cache = self._prefill(self.params, toks, lengths,
                                      self._ps if self.probes else None)
        cache = self._place_kv(self._grow(cache))
        key, sub = jax.random.split(key)
        first = self._sample(logits, sub)
        stops = jnp.full((B,), max_new, jnp.int32)
        n_gen = jnp.ones((B,), jnp.int32)
        active = n_gen < stops
        out = jnp.zeros((B, max_new), jnp.int32).at[:, 0].set(first)
        cache, _, _, n_gen, out, _ = self._decode_loop(
            self.params, cache, first, active, n_gen, stops, out, key,
            stop_on_event=False)
        self._ps_extract(cache)
        out = np.asarray(out)
        return [list(p) + out[i, :max_new].tolist()
                for i, p in enumerate(prompts)]

    def serve(self, prompts: list[list[int]], max_new=32,
              key=None) -> list[list[int]]:
        """Continuous batching over a queue of requests.

        ``max_new`` may be an int or a per-request list.  Requests beyond
        ``max_batch`` wait; every time one in flight finishes, its slot is
        harvested and the next queued request joins *between* decode steps.
        With ``paged=True`` admission additionally waits on free cache
        pages (the real capacity resource) and prompts stream through
        page-sized prefill chunks.  With ``spec`` set, decode runs in
        speculative rounds (k drafted tokens verified per forward,
        DESIGN.md §9) — temperature=0 output is identical to non-spec
        serve, token for token.  Returns prompt + continuation per request,
        in submission order.
        """
        n = len(prompts)
        stops_req = ([max_new] * n if isinstance(max_new, int)
                     else list(max_new))
        for p, s in zip(prompts, stops_req):
            if len(p) < 1:
                raise ValueError("empty prompt")
            if len(p) + s > self.max_len:
                raise ValueError("prompt + max_new exceeds max_len")
            if s < 1:
                raise ValueError("max_new must be >= 1")
            if self.spec is not None and len(p) + s + self.spec.k > self.max_len:
                raise ValueError(
                    "prompt + max_new + spec.k exceeds max_len (the verify "
                    "forward needs k rows of speculative headroom)")
        key = jax.random.PRNGKey(0) if key is None else key
        if self.paged:
            if self.spec is not None:
                return self._serve_paged_spec(prompts, stops_req, key)
            return self._serve_paged(prompts, stops_req, key)
        if self.spec is not None:
            return self._serve_spec(prompts, stops_req, key)
        B, cap = self.max_batch, max(stops_req)

        cache = self._place_kv(self.model.init_cache(
            B, self.max_len, dtype=self._cache_dtype))
        cache = {**cache, "pos": jnp.zeros((B,), jnp.int32)}
        last = jnp.zeros((B,), jnp.int32)
        active = jnp.zeros((B,), bool)
        n_gen = jnp.zeros((B,), jnp.int32)
        stops = jnp.ones((B,), jnp.int32)
        out = jnp.zeros((B, cap), jnp.int32)

        queue = deque(range(n))
        slot_rid: list[int | None] = [None] * B
        results: dict[int, list[int]] = {}

        while queue or any(r is not None for r in slot_rid):
            # admit into every free slot (join happens between decode steps)
            free = [b for b in range(B) if slot_rid[b] is None]
            for b in free:
                if not queue:
                    break
                rid = queue.popleft()
                toks1, len1 = self._pad_prompts([prompts[rid]])
                lg1, c1 = self._prefill(self.params, toks1, len1,
                                        self._ps if self.probes else None)
                c1 = self._ps_extract(c1)
                key, sub = jax.random.split(key)
                first = self._sample(lg1, sub)
                cache, last, active, n_gen, stops, out = self._admit(
                    cache, c1, b, first[0], stops_req[rid],
                    last, active, n_gen, stops, out)
                slot_rid[b] = rid
            # decode in lockstep until some request finishes (the event)
            cache = self._ps_inject(cache)
            cache, last, active, n_gen, out, key = self._decode_loop(
                self.params, cache, last, active, n_gen, stops, out, key,
                stop_on_event=True)
            cache = self._ps_extract(cache)
            # harvest retired slots (leave happens between decode steps)
            act = np.asarray(active)
            gen = np.asarray(n_gen)
            out_np = np.asarray(out)
            for b in range(B):
                rid = slot_rid[b]
                if rid is not None and not act[b]:
                    results[rid] = (list(prompts[rid])
                                    + out_np[b, :gen[b]].tolist())
                    slot_rid[b] = None
        return [results[i] for i in range(n)]
