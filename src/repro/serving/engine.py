"""Batched serving engine: jitted prefill, jitted decode loop, continuous
batching, and the multiply-free matmul backends (DESIGN.md §3).

The three pieces the seed engine lacked, now the hot path:

* **Prefill** consumes the whole (right-padded) prompt batch in ONE jitted
  call — ``transformer.prefill`` with ``batch['lengths']`` returns each
  row's logits at its last real position and a (B,) ``cache['pos']``
  vector.  Prompt lengths are bucketed to powers of two to bound
  recompiles.
* **Decode** is a ``lax.while_loop`` over ``decode_step`` with greedy /
  temperature sampling *inside* the loop: steady-state decode never
  re-enters Python per token and never syncs to the host.  Per-request
  stop lengths retire rows in place (retired rows lockstep-decode into
  their own clamped cache slot until the loop exits — wasted FLOPs, zero
  correctness impact, no recompile).
* **Continuous batching** (``serve``): the batch dimension is a pool of
  ``max_batch`` slots.  Each request prefills alone (per-bucket compile),
  is spliced into a free slot's cache rows at its own position offset, and
  decodes in lockstep with whatever else is in flight.  The decode loop
  runs with ``stop_on_event=True`` — it exits exactly when some request
  hits its stop length, Python harvests the finished slot, admits the next
  queued request into it (slot reuse == cache eviction: the newcomer's
  prefill overwrites the retiree's rows, and the per-slot ``pos``/valid
  length guarantee no cross-request attention leakage), and re-enters the
  loop.  Python runs O(#requests) times, not O(#tokens).

Backends (``backend=``, routed through ``kernels.dispatch`` at trace time):
``dense`` — gather + XLA dot (default); ``codebook`` — Pallas
``codebook_matmul`` (narrow indices in HBM, dequantize-in-VMEM); ``lut`` —
the paper's faithful §4 integer engine (``lut_matmul``; no multiplications
in the contraction).  ``codebook``/``lut`` require index-form params
(``serving.to_codebook_params``).  Engine families: KV-cache token LMs
(``dense``/``moe``); recurrent-state families would march their state
through the padding.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.models.model_zoo import Model

__all__ = ["ServeEngine"]

_ENGINE_FAMILIES = ("dense", "moe")


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _index_form_stats(params):
    """(found_any, max fan-in over w_idx leaves, concatenated codebooks).

    Every codebook leaf is gathered (per_layer scope has one per tensor) so
    the LUT scale is chosen against the global max|w| — the no-overflow
    guarantee must hold for the worst layer, not the first one visited.
    """
    fan_in, books = 0, []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "w_idx" and leaf.ndim >= 2:
            fan_in = max(fan_in, int(leaf.shape[-2]))
        if name == "codebook":
            books.append(np.asarray(leaf[0] if leaf.ndim == 2 else leaf))
    book = np.concatenate(books) if books else None
    return fan_in > 0, fan_in, book


@dataclasses.dataclass
class ServeEngine:
    """Continuous-batching inference engine over one model + param set.

    max_batch:   slot-pool width for ``serve`` (``generate`` sizes its own
                 batch).
    max_len:     cache capacity; prompt_len + max_new must fit.
    temperature: 0 = greedy argmax; >0 = categorical sampling.
    backend:     'dense' | 'codebook' | 'lut' (see module docstring).
    lut_levels / lut_range: activation grid of the 'lut' backend's
                 multiplication table (|A| entries over [a_min, a_max]).
    """

    model: Model
    params: object
    max_len: int = 256
    temperature: float = 0.0
    mesh: object = None
    backend: str = "dense"
    max_batch: int = 8
    lut_levels: int = 4096
    lut_range: tuple = (-16.0, 16.0)

    def __post_init__(self):
        cfg = self.model.cfg
        if cfg.family not in _ENGINE_FAMILIES:
            raise NotImplementedError(
                f"ServeEngine serves KV-cache token LMs {_ENGINE_FAMILIES}; "
                f"got family {cfg.family!r}")
        if self.backend not in dispatch.BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in "
                             f"{dispatch.BACKENDS}")
        has_idx, fan_in, book = _index_form_stats(self.params)
        self._lut_spec = None
        if self.backend != "dense":
            if not has_idx:
                raise ValueError(
                    f"backend {self.backend!r} needs codebook-index params "
                    "(run serving.to_codebook_params first)")
            if self.backend == "lut":
                self._lut_spec = dispatch.make_lut_spec(
                    book, fan_in, levels=self.lut_levels,
                    a_range=self.lut_range)
        self._cache_dtype = (jnp.float32 if cfg.dtype == "float32"
                             else jnp.bfloat16)

        bb = partial(dispatch.bind_backend, name=self.backend,
                     lut_spec=self._lut_spec)
        self._prefill = jax.jit(bb(self._prefill_fn))
        self._decode_loop = jax.jit(bb(self._loop_fn),
                                    static_argnames=("stop_on_event",))
        self._admit = jax.jit(self._admit_fn)       # pure memory traffic
        self._grow = jax.jit(self._grow_fn)

    # --- jitted bodies -------------------------------------------------------

    def _prefill_fn(self, params, tokens, lengths):
        return self.model.prefill(params, {"tokens": tokens,
                                           "lengths": lengths}, self.mesh)

    def _sample(self, logits, key):
        lg = logits[:, -1, :self.model.cfg.vocab].astype(jnp.float32)
        if self.temperature > 0:
            return jax.random.categorical(
                key, lg / self.temperature).astype(jnp.int32)
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def _grow_fn(self, cache):
        """Pad prefill-emitted KV planes (S = prompt bucket) to max_len."""
        kv = {k: jnp.pad(v, [(0, 0), (0, 0), (0, self.max_len - v.shape[2])]
                         + [(0, 0)] * (v.ndim - 3))
              for k, v in cache["kv"].items()}
        return {**cache, "kv": kv}

    def _loop_fn(self, params, cache, last, active, n_gen, stops, out, key,
                 *, stop_on_event: bool):
        """while_loop decode: one iteration == one token for every slot.

        Exits when all slots are retired, the out-buffer width is exhausted,
        or (stop_on_event) the first time any slot hits its stop length —
        the continuous-batching admission point.
        """
        B, cap = out.shape

        def cond(c):
            _, _, active, _, _, _, _, steps, event = c
            go = jnp.any(active) & (steps < cap)
            if stop_on_event:
                go = go & ~event
            return go

        def body(c):
            cache, last, active, n_gen, stops, out, key, steps, _ = c
            logits, cache = self.model.decode(params, last[:, None], cache,
                                              self.mesh)
            key, sub = jax.random.split(key)
            nxt = jnp.where(active, self._sample(logits, sub), last)
            col = jnp.clip(n_gen, 0, cap - 1)
            cur = out[jnp.arange(B), col]
            out = out.at[jnp.arange(B), col].set(jnp.where(active, nxt, cur))
            n_gen = n_gen + active.astype(jnp.int32)
            newly = active & (n_gen >= stops)
            return (cache, nxt, active & ~newly, n_gen, stops, out, key,
                    steps + 1, jnp.any(newly))

        c = (cache, last, active, n_gen, stops, out, key,
             jnp.zeros((), jnp.int32), jnp.asarray(False))
        c = jax.lax.while_loop(cond, body, c)
        return c[0], c[1], c[2], c[3], c[5], c[6]   # cache,last,active,n_gen,out,key

    def _admit_fn(self, cache, c1, slot, first_tok, stop,
                  last, active, n_gen, stops, out):
        """Splice a freshly prefilled request (batch 1) into slot ``slot``.

        The newcomer's KV rows overwrite the retired occupant's prefix; its
        (smaller) ``pos`` plus the decode-time valid-length mask evict
        whatever stale suffix remains without touching it.
        """
        kv = dict(cache["kv"])
        for k, src in c1["kv"].items():
            start = (0, slot) + (0,) * (src.ndim - 2)
            kv[k] = jax.lax.dynamic_update_slice(
                cache["kv"][k], src.astype(cache["kv"][k].dtype), start)
        pos = cache["pos"].at[slot].set(c1["pos"][0])
        cache = {**cache, "kv": kv, "pos": pos}
        row = jnp.zeros((out.shape[1],), out.dtype).at[0].set(first_tok)
        return (cache,
                last.at[slot].set(first_tok),
                # the prefill sample already produced token #1: a stop of 1
                # is done on arrival
                active.at[slot].set(stop > 1),
                n_gen.at[slot].set(1),
                stops.at[slot].set(stop),
                out.at[slot].set(row))

    # --- prompt plumbing -----------------------------------------------------

    def _pad_prompts(self, prompts):
        lens = [len(p) for p in prompts]
        if min(lens) < 1:
            raise ValueError("empty prompt")
        pb = _bucket(max(lens))
        if pb > self.max_len:
            raise ValueError(f"prompt bucket {pb} exceeds max_len "
                             f"{self.max_len}")
        toks = np.zeros((len(prompts), pb), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        return jnp.asarray(toks), jnp.asarray(lens, jnp.int32)

    # --- public API ----------------------------------------------------------

    def generate(self, prompts: list[list[int]], max_new: int = 32,
                 key=None) -> list[list[int]]:
        """Greedy (or sampled) continuation for a fixed batch of prompts.

        One jitted prefill + one jitted decode loop; Python is re-entered
        exactly once, at the end.
        """
        B = len(prompts)
        toks, lengths = self._pad_prompts(prompts)
        if int(jnp.max(lengths)) + max_new > self.max_len:
            raise ValueError("prompt + max_new exceeds max_len")
        key = jax.random.PRNGKey(0) if key is None else key
        logits, cache = self._prefill(self.params, toks, lengths)
        cache = self._grow(cache)
        key, sub = jax.random.split(key)
        first = self._sample(logits, sub)
        stops = jnp.full((B,), max_new, jnp.int32)
        n_gen = jnp.ones((B,), jnp.int32)
        active = n_gen < stops
        out = jnp.zeros((B, max_new), jnp.int32).at[:, 0].set(first)
        _, _, _, n_gen, out, _ = self._decode_loop(
            self.params, cache, first, active, n_gen, stops, out, key,
            stop_on_event=False)
        out = np.asarray(out)
        return [list(p) + out[i, :max_new].tolist()
                for i, p in enumerate(prompts)]

    def serve(self, prompts: list[list[int]], max_new=32,
              key=None) -> list[list[int]]:
        """Continuous batching over a queue of requests.

        ``max_new`` may be an int or a per-request list.  Requests beyond
        ``max_batch`` wait; every time one in flight finishes, its slot is
        harvested and the next queued request joins *between* decode steps.
        Returns prompt + continuation per request, in submission order.
        """
        n = len(prompts)
        stops_req = ([max_new] * n if isinstance(max_new, int)
                     else list(max_new))
        for p, s in zip(prompts, stops_req):
            if len(p) + s > self.max_len:
                raise ValueError("prompt + max_new exceeds max_len")
            if s < 1:
                raise ValueError("max_new must be >= 1")
        B, cap = self.max_batch, max(stops_req)
        key = jax.random.PRNGKey(0) if key is None else key

        cache = self.model.init_cache(B, self.max_len,
                                      dtype=self._cache_dtype)
        cache = {**cache, "pos": jnp.zeros((B,), jnp.int32)}
        last = jnp.zeros((B,), jnp.int32)
        active = jnp.zeros((B,), bool)
        n_gen = jnp.zeros((B,), jnp.int32)
        stops = jnp.ones((B,), jnp.int32)
        out = jnp.zeros((B, cap), jnp.int32)

        queue = deque(range(n))
        slot_rid: list[int | None] = [None] * B
        results: dict[int, list[int]] = {}

        while queue or any(r is not None for r in slot_rid):
            # admit into every free slot (join happens between decode steps)
            free = [b for b in range(B) if slot_rid[b] is None]
            for b in free:
                if not queue:
                    break
                rid = queue.popleft()
                toks1, len1 = self._pad_prompts([prompts[rid]])
                lg1, c1 = self._prefill(self.params, toks1, len1)
                key, sub = jax.random.split(key)
                first = self._sample(lg1, sub)
                cache, last, active, n_gen, stops, out = self._admit(
                    cache, c1, b, first[0], stops_req[rid],
                    last, active, n_gen, stops, out)
                slot_rid[b] = rid
            # decode in lockstep until some request finishes (the event)
            cache, last, active, n_gen, out, key = self._decode_loop(
                self.params, cache, last, active, n_gen, stops, out, key,
                stop_on_event=True)
            # harvest retired slots (leave happens between decode steps)
            act = np.asarray(active)
            gen = np.asarray(n_gen)
            out_np = np.asarray(out)
            for b in range(B):
                rid = slot_rid[b]
                if rid is not None and not act[b]:
                    results[rid] = (list(prompts[rid])
                                    + out_np[b, :gen[b]].tolist())
                    slot_rid[b] = None
        return [results[i] for i in range(n)]
