"""Batched serving engine: prefill + greedy/temperature decode loop.

Small but real: request batching up to ``max_batch``, left-padded prompts,
KV/state cache reuse, per-request stop lengths.  Used by the serve example
and the decode smoke tests; the dry-run lowers ``decode_step`` directly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model

__all__ = ["ServeEngine"]


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: object
    max_len: int = 256
    temperature: float = 0.0
    mesh: object = None

    def __post_init__(self):
        cfg = self.model.cfg
        self._decode = jax.jit(
            lambda p, t, c: self.model.decode(p, t, c, self.mesh))

    def generate(self, prompts: list[list[int]], max_new: int = 32,
                 key=None) -> list[list[int]]:
        """Greedy (or sampled) continuation for a batch of prompts."""
        cfg = self.model.cfg
        B = len(prompts)
        cache = self.model.init_cache(B, self.max_len, dtype=jnp.float32)
        # feed prompts token-by-token (prefill path exists but the step loop
        # exercises cache correctness end-to-end)
        maxp = max(len(p) for p in prompts)
        toks = np.zeros((B, maxp), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p     # right-aligned padding is skipped below
        out = [list(p) for p in prompts]
        logits = None
        for t in range(maxp):
            logits, cache = self._decode(self.params,
                                         jnp.asarray(toks[:, t:t + 1]), cache)
        key = key if key is not None else jax.random.PRNGKey(0)
        for step in range(max_new):
            lg = logits[:, -1, :cfg.vocab]
            if self.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, lg / self.temperature)
            else:
                nxt = jnp.argmax(lg, axis=-1)
            nxt = np.asarray(nxt, np.int32)
            for i in range(B):
                out[i].append(int(nxt[i]))
            logits, cache = self._decode(self.params,
                                         jnp.asarray(nxt)[:, None], cache)
        return out
