"""Deterministic serving telemetry: lifecycle spans, a metrics registry,
and Perfetto trace export under the injected virtual clock (DESIGN.md §13).

Observability with the same discipline the paper applies to memory
(core/export.py counts every deployed byte): every admission, preemption,
swapped page, decode round, and kernel dispatch is accounted for — and
because nothing under ``serving/`` reads the wall clock (the §11 rule,
pinned by tests/test_scheduler_sim.py), the account is *replayable*.  Two
replays of a seeded trace produce byte-identical metric snapshots and
event logs, so telemetry itself is a regression gate
(tests/test_telemetry.py, tests/golden_telemetry.json) instead of a
best-effort log.

Three surfaces, one object:

* **Spans** — ``Telemetry`` records lifecycle spans on named tracks:
  per-request (``queued → running ⇄ swapped → finished`` on the
  ``requests`` track), per-slot (``prefill`` / ``decode`` / ``swap_out`` /
  ``swap_in`` on the ``slots`` track), and per-round (``round`` on the
  ``sched`` track), all timestamped by the scheduler's injected clock.
  ``to_perfetto()`` emits the Chrome trace-event JSON that Perfetto
  (https://ui.perfetto.dev) opens directly — one process per track, one
  thread per request/slot; ``event_log()`` is the same record as
  structured rows.
* **Metrics registry** — typed counters / gauges / histograms plus
  snapshot-time *providers* that pull the per-subsystem stats objects
  (``PoolStats``, ``SpecStats``, the kernels' dispatch/tuning counters)
  into one ``snapshot()`` → canonical-JSON surface.  The canonical stat
  vocabulary lives here: swap counters always spell their direction
  (``*_swapped_out_*`` / ``*_swapped_in_*``), and the two swap units stay
  distinct — ``pool.swapped_out_pages`` counts page *references* released
  by ``PagePool.swap_out`` (the whole reservation), while
  ``sched.pages_swapped_out`` counts *data* pages actually moved through
  the host blob (what the swap cost model bills).  ``RequestHandle`` /
  ``ServerReport`` use the same ``pages_swapped_out`` spelling.
* **Zero overhead when disabled** — the default wiring is
  ``NULL_TELEMETRY``, whose methods are argument-swallowing no-ops with
  ``enabled=False``; hot paths guard their aggregation work behind
  ``tel.enabled``.  The smoke bench gates the disabled path at <2% tok/s
  vs an instrumented run (benchmarks/serve_throughput.py).

Determinism contract: every number in ``snapshot()`` / ``event_log()`` /
``to_perfetto()`` derives from the virtual clock, the seeded trace, or
deterministic allocator/tuner state — never the wall; floats are rounded
to 9 decimals (matching the scheduler's event-log rounding) and JSON is
dumped with sorted keys.  One caveat rides the kernels provider: the
autotune memory cache persists per process, so ``tuning.*`` hit/miss
splits are deltas from provider attach time and compare equal only across
*fresh-engine* replays (the contended reference pair is dense — its
kernel section is structurally present and identically zero).
"""

from __future__ import annotations

import json

__all__ = ["Telemetry", "NULL_TELEMETRY", "DEFAULT_BUCKETS", "TRACKS"]

# Histogram bucket upper edges (inclusive "≤ edge"; one overflow bucket
# rides above the last).  Occupancy / queue-depth style counts — small
# ints — so a coarse doubling ladder is enough.
DEFAULT_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128)

# Track name -> Perfetto pid.  Fixed assignment keeps exports stable.
# "counters" carries the "C" (counter-track) samples: load curves
# (queue depth, pool pressure, batch occupancy) and the §14 numerics
# series, drawn by Perfetto as area charts beside the lifecycle spans.
# Scoped tracks (a fleet replica's "r0.requests", "r0.slots", ... —
# DESIGN.md §15) get pids above these in first-appearance order, which
# is itself deterministic under a replayed trace.
TRACKS = {"requests": 1, "slots": 2, "sched": 3, "counters": 4}


def _canon(obj):
    """Canonicalize for byte-stable JSON: floats to 9 decimals (the
    scheduler's event rounding), numpy scalars to Python ints/floats."""
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, bool) or obj is None or isinstance(obj, str):
        return obj
    if isinstance(obj, int):
        return int(obj)
    if isinstance(obj, float):
        return round(float(obj), 9)
    if hasattr(obj, "item"):                      # numpy scalar
        return _canon(obj.item())
    return obj


class _Hist:
    """Fixed-edge histogram: per-bucket counts + count/sum/min/max."""

    __slots__ = ("edges", "counts", "count", "total", "lo", "hi")

    def __init__(self, edges):
        self.edges = tuple(edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.total = 0.0
        self.lo = None
        self.hi = None

    def observe(self, v):
        v = float(v)
        for i, e in enumerate(self.edges):
            if v <= e:
                self.counts[i] += 1
                break
        else:
            self.counts[-1] += 1
        self.count += 1
        self.total += v
        self.lo = v if self.lo is None else min(self.lo, v)
        self.hi = v if self.hi is None else max(self.hi, v)

    def to_json(self):
        return {"edges": list(self.edges), "counts": list(self.counts),
                "count": self.count, "sum": self.total,
                "min": self.lo, "max": self.hi}


class Telemetry:
    """Tracer + metrics registry over one injected clock.

    Construction is cheap and clock-less; whoever owns the virtual clock
    (``AsyncScheduler`` via ``Server(telemetry=...)``) calls
    ``bind_clock`` before emitting spans.  All methods are safe to call
    in any order; span begin/end pairs are keyed ``(track, tid, name)``.
    """

    enabled = True

    def __init__(self, clock=None):
        self.clock = clock
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, _Hist] = {}
        self._providers: list[tuple[str, object]] = []
        self._events: list[tuple] = []       # ("X", t0, t1, trk, tid, name)
        self._open: dict[tuple, float] = {}  # (trk, tid, name) -> t0
        self._kernels_attached = False

    def bind_clock(self, clock) -> None:
        self.clock = clock

    # --- metrics -------------------------------------------------------------

    def count(self, name: str, n: int = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + n

    def gauge(self, name: str, value) -> None:
        self._gauges[name] = value

    def observe(self, name: str, value, edges=None) -> None:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = _Hist(DEFAULT_BUCKETS if edges is None
                                          else edges)
        h.observe(value)

    def add_provider(self, prefix: str, fn) -> None:
        """Register a snapshot-time stats source: ``fn()`` returns a flat
        dict merged under ``prefix`` in every ``snapshot()``."""
        self._providers.append((prefix, fn))

    # --- spans ---------------------------------------------------------------

    def span(self, track: str, tid: int, name: str, t0: float,
             t1: float) -> None:
        """A complete span with explicit times — what clock-advance-
        delimited work (prefill, decode rounds, swaps) emits."""
        self._events.append(("X", round(t0, 9), round(t1, 9),
                             track, int(tid), name))

    def open_span(self, track: str, tid: int, name: str) -> None:
        self._open[(track, int(tid), name)] = self.clock.now()

    def close_span(self, track: str, tid: int, name: str) -> None:
        t0 = self._open.pop((track, int(tid), name), None)
        if t0 is not None:
            self.span(track, tid, name, t0, self.clock.now())

    def instant(self, track: str, tid: int, name: str) -> None:
        self._events.append(("I", round(self.clock.now(), 9),
                             track, int(tid), name))

    def counter(self, name: str, value) -> None:
        """One sample of a counter track at the current virtual time —
        a Perfetto "C" event on the ``counters`` process.  Same named
        series + monotone sample times = one load curve in the UI."""
        self._events.append(("C", round(self.clock.now(), 9), name,
                             round(float(value), 9)))

    # --- snapshot / export ---------------------------------------------------

    def snapshot(self) -> dict:
        """The whole registry — counters, gauges, histograms, and every
        provider's live stats — as one canonicalized dict.  Contains no
        wall-clock-derived field by construction (this module lives under
        ``serving/``, where the wall is banned)."""
        snap = {"counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.to_json()
                               for k, h in self._hists.items()}}
        for prefix, fn in self._providers:
            sect = snap.setdefault(prefix, {})
            sect.update(fn())
        return _canon(snap)

    def snapshot_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True,
                          separators=(",", ":"))

    def event_log(self) -> list:
        """Structured span/instant rows in emission order (which is itself
        deterministic under a replayed trace)."""
        out = []
        for ev in self._events:
            if ev[0] == "X":
                _, t0, t1, track, tid, name = ev
                out.append({"ph": "X", "t0": t0, "t1": t1, "track": track,
                            "tid": tid, "name": name})
            elif ev[0] == "C":
                _, t, name, value = ev
                out.append({"ph": "C", "t": t, "name": name,
                            "value": value})
            else:
                _, t, track, tid, name = ev
                out.append({"ph": "I", "t": t, "track": track, "tid": tid,
                            "name": name})
        return out

    def event_log_json(self) -> str:
        return json.dumps(self.event_log(), sort_keys=True,
                          separators=(",", ":"))

    def to_perfetto(self) -> dict:
        """Chrome trace-event JSON (the format Perfetto opens): "X"
        complete events on one process per track / one thread per
        request/slot, timestamps in microseconds of *virtual* time.
        Tracks beyond the fixed ``TRACKS`` set (a fleet's per-replica
        ``r0.requests``/``r0.slots``/... — DESIGN.md §15) are assigned
        pids in first-appearance order, deterministic under replay."""
        us = lambda t: int(round(t * 1e6))               # noqa: E731
        tracks = dict(TRACKS)
        for ev in self._events:                  # scoped-track discovery
            if ev[0] == "C":
                continue
            track = ev[3] if ev[0] == "X" else ev[2]
            if track not in tracks:
                tracks[track] = max(tracks.values()) + 1
        events, seen = [], set()
        for track, pid in sorted(tracks.items(), key=lambda kv: kv[1]):
            events.append({"ph": "M", "pid": pid, "name": "process_name",
                           "args": {"name": track}})
        for ev in self._events:
            if ev[0] == "C":
                # Counter tracks: Perfetto keys the series on (pid, name);
                # no thread metadata, the value rides args.value.  Scoped
                # series ("r0.sched.queue_depth") stay separate curves —
                # the UI keys them by name.
                _, t, name, value = ev
                events.append({"ph": "C", "pid": tracks["counters"],
                               "ts": us(t), "name": name,
                               "args": {"value": value}})
                continue
            track, tid = (ev[3], ev[4]) if ev[0] == "X" else (ev[2], ev[3])
            pid = tracks[track]
            if (pid, tid) not in seen:
                seen.add((pid, tid))
                events.append({"ph": "M", "pid": pid, "tid": tid,
                               "name": "thread_name",
                               "args": {"name": f"{track[:-1] if track.endswith('s') else track} {tid}"}})
            if ev[0] == "X":
                _, t0, t1, _, _, name = ev
                events.append({"ph": "X", "pid": pid, "tid": tid,
                               "ts": us(t0), "dur": us(t1 - t0),
                               "name": name, "cat": track})
            else:
                _, t, _, _, name = ev
                events.append({"ph": "i", "pid": pid, "tid": tid,
                               "ts": us(t), "name": name, "cat": track,
                               "s": "t"})
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"clock": "virtual"}}

    def export_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_perfetto(), f, indent=1)

    def export_metrics(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(json.dumps(self.snapshot(), sort_keys=True, indent=1)
                    + "\n")

    # --- scoping (fleet replicas, DESIGN.md §15) -----------------------------

    def scoped(self, scope: str) -> "_ScopedTelemetry":
        """A facade over THIS registry that prefixes every metric name,
        provider prefix, span track, and counter series with
        ``<scope>.`` — one shared snapshot/export, per-scope sections
        and tracks.  The fleet hands each replica's scheduler
        ``telemetry.scoped("r0")`` etc., so one Perfetto trace carries
        every replica's lifecycle spans side by side."""
        return _ScopedTelemetry(self, scope)

    # --- subsystem wiring ----------------------------------------------------

    def attach_engine(self, engine) -> None:
        """Point an engine's hot-path counters here and register its
        subsystem stats (page pool, spec acceptance, kernel dispatch) as
        snapshot-time providers.  Schedulers call this; batch users
        (examples, benchmarks) can call it directly."""
        engine.telemetry = self
        if getattr(engine, "paged", False):
            self.add_provider("pool", _pool_provider(engine))
        if getattr(engine, "spec", None) is not None \
                or getattr(engine, "spec_stats", None) is not None:
            self.add_provider("spec", _spec_provider(engine))
        if getattr(engine, "probes", False):
            # §14 numerics: the engine's accumulated probe counters become
            # one canonical `numerics` section in every snapshot()
            self.add_provider("numerics", engine.numerics)
        self.attach_kernel_counters()

    def attach_kernel_counters(self) -> None:
        """Register the kernels-layer counters (trace-time matmul routes,
        autotune cache hits, platform fallback routes) as a provider.
        Those counters are process-global (kernels/ must not import
        serving/), so the provider reports *deltas* from attach time —
        comparable across fresh-engine replays; see the module-docstring
        caveat on the persistent autotune memory cache.  Idempotent per
        registry (attaching several engines shares one baseline)."""
        if self._kernels_attached:
            return
        self._kernels_attached = True
        from repro.kernels import autotune, dispatch, ops

        base = _kernel_counts(autotune, dispatch, ops)

        def prov():
            cur = _kernel_counts(autotune, dispatch, ops)
            out = {}
            for k, v in cur.items():
                d = v - base.get(k, 0)
                # tuning.* keys are a fixed vocabulary — always present so
                # the snapshot schema is stable; route keys appear on use
                if d or k.startswith("tuning."):
                    out[k] = d
            return out

        self.add_provider("kernels", prov)

    # --- human summary -------------------------------------------------------

    def summary(self) -> str:
        """Compact end-of-run lines (what examples print instead of
        hand-rolled per-subsystem reports)."""
        s = self.snapshot()
        c = s.get("counters", {})
        g = lambda k: c.get(k, 0)                        # noqa: E731
        lines = []
        if g("sched.submitted"):                 # batch users have no scheduler
            lines.append(f"[telemetry] requests: {g('sched.admissions')} "
                         f"admitted, {g('sched.preemptions')} preempted "
                         f"({g('sched.pages_swapped_out')} pages out / "
                         f"{g('sched.pages_swapped_in')} in), "
                         f"{g('sched.finished')} finished")
            slo = g("sched.slo_hits") + g("sched.slo_misses")
            if slo:
                lines[-1] += f"; SLO {g('sched.slo_hits')}/{slo} met"
        if g("engine.steps"):
            lines.append(f"[telemetry] engine: {g('engine.steps')} decode "
                         f"rounds, {g('engine.tokens')} tokens, "
                         f"{g('engine.stops_finished')} finished / "
                         f"{g('engine.stops_quantum')} quantum-bounded "
                         f"slot-rounds, {g('engine.admit_blocked')} blocked "
                         "admissions")
        pool = s.get("pool")
        if pool:
            lines.append(f"[telemetry] pool: prefix hit rate "
                         f"{100 * pool['hit_rate']:.0f}% "
                         f"({pool['hit_pages']} hit / {pool['miss_pages']} "
                         f"miss), peak {pool['peak_pages_in_use']} pages / "
                         f"refcount high-water {pool['peak_page_refs']}, "
                         f"{pool['cow_copies']} CoW, "
                         f"{pool['evictions']} evictions, swap "
                         f"{pool['swapped_out_pages']} out / "
                         f"{pool['swapped_in_pages']} in")
        spec = s.get("spec")
        if spec and spec.get("proposed"):
            lines.append(f"[telemetry] spec: acceptance "
                         f"{100 * spec['acceptance_rate']:.0f}% "
                         f"({spec['accepted']}/{spec['proposed']} drafted), "
                         f"{spec['tokens_per_round']:.1f} tokens/round over "
                         f"{spec['rounds']} rounds")
        kern = s.get("kernels")
        if kern and any(not k.startswith("tuning.") for k in kern):
            routes = ", ".join(f"{k}={v}" for k, v in sorted(kern.items())
                               if not k.startswith("tuning."))
            lines.append(f"[telemetry] kernels: {routes}")
        num = s.get("numerics")
        if num and num.get("tokens"):
            sat = max(num.get("sat_rate") or [0.0])
            hr = min(num.get("headroom_bits") or [31.0])
            kv = max(num.get("kv_err_max") or [0.0])
            lines.append(f"[telemetry] numerics[{num.get('backend')}]: "
                         f"{int(num['tokens'])} tokens probed, worst-layer "
                         f"saturation {100 * sat:.3f}%, accumulator headroom "
                         f"{hr:.1f} bits min, kv round-trip err {kv:.2e} max, "
                         f"page_oob {int(num.get('page_oob', 0))}, widx_oob "
                         f"{int(num.get('widx_oob', 0))}")
        return "\n".join(lines) if lines else "[telemetry] nothing recorded"


def _pool_provider(engine):
    def prov():
        st = engine.pool.stats
        return {"hit_pages": st.hit_pages, "miss_pages": st.miss_pages,
                "shared_hit_pages": st.shared_hit_pages,
                "hit_rate": st.hit_rate, "cow_copies": st.cow_copies,
                "evictions": st.evictions,
                "peak_pages_in_use": st.peak_pages_in_use,
                "peak_page_refs": st.peak_page_refs,
                "truncated_pages": st.truncated_pages,
                "swapped_out_pages": st.swapped_out_pages,
                "swapped_in_pages": st.swapped_in_pages,
                "pages_in_use": engine.pool.pages_in_use(),
                "pressure": engine.pool.pressure()}
    return prov


def _spec_provider(engine):
    def prov():
        ss = engine.spec_stats
        return {"rounds": ss.rounds, "proposed": ss.proposed,
                "accepted": ss.accepted, "emitted": ss.emitted,
                "acceptance_rate": ss.acceptance_rate,
                "tokens_per_round": ss.tokens_per_round}
    return prov


def _kernel_counts(autotune, dispatch, ops) -> dict:
    out = {}
    for k, v in dispatch.matmul_call_counts().items():
        out[f"matmul.{k}"] = v
    for k, v in autotune.tuning_counts().items():
        out[f"tuning.{k}"] = v
    for k, v in ops.route_counts().items():
        out[f"route.{k}"] = v
    return out


class _ScopedTelemetry:
    """Name-prefixing view of a shared ``Telemetry`` registry: every
    counter/gauge/histogram name, provider prefix, span track, and
    counter-track series gains ``<scope>.``.  State lives in the base
    registry — ``snapshot``/``event_log``/exports delegate, so a fleet's
    scoped replicas all land in ONE canonical surface.  Kernel counters
    stay unscoped (they are process-global; scoping them would invent
    per-replica numbers that don't exist)."""

    enabled = True

    def __init__(self, base, scope: str):
        self._base = base
        self.scope = str(scope)

    def _n(self, name: str) -> str:
        return f"{self.scope}.{name}"

    def bind_clock(self, clock) -> None:
        self._base.bind_clock(clock)

    def count(self, name, n=1):
        self._base.count(self._n(name), n)

    def gauge(self, name, value):
        self._base.gauge(self._n(name), value)

    def observe(self, name, value, edges=None):
        self._base.observe(self._n(name), value, edges)

    def add_provider(self, prefix, fn):
        self._base.add_provider(self._n(prefix), fn)

    def span(self, track, tid, name, t0, t1):
        self._base.span(self._n(track), tid, name, t0, t1)

    def open_span(self, track, tid, name):
        self._base.open_span(self._n(track), tid, name)

    def close_span(self, track, tid, name):
        self._base.close_span(self._n(track), tid, name)

    def instant(self, track, tid, name):
        self._base.instant(self._n(track), tid, name)

    def counter(self, name, value):
        self._base.counter(self._n(name), value)

    def attach_engine(self, engine) -> None:
        """Same wiring as ``Telemetry.attach_engine`` with the providers
        registered under this scope ("r0.pool", "r0.spec", ...)."""
        engine.telemetry = self
        if getattr(engine, "paged", False):
            self.add_provider("pool", _pool_provider(engine))
        if getattr(engine, "spec", None) is not None \
                or getattr(engine, "spec_stats", None) is not None:
            self.add_provider("spec", _spec_provider(engine))
        if getattr(engine, "probes", False):
            self.add_provider("numerics", engine.numerics)
        self._base.attach_kernel_counters()

    def attach_kernel_counters(self) -> None:
        self._base.attach_kernel_counters()

    def scoped(self, scope: str) -> "_ScopedTelemetry":
        return _ScopedTelemetry(self._base, self._n(scope))

    def snapshot(self):
        return self._base.snapshot()

    def event_log(self):
        return self._base.event_log()

    def summary(self):
        return self._base.summary()


class _NullTelemetry:
    """The disabled default: every method is a no-op, ``enabled`` is
    False so hot paths skip their aggregation work entirely.  A single
    shared instance — never mutated, safe to hang on every engine."""

    enabled = False

    def bind_clock(self, clock):
        pass

    def count(self, name, n=1):
        pass

    def gauge(self, name, value):
        pass

    def observe(self, name, value, edges=None):
        pass

    def add_provider(self, prefix, fn):
        pass

    def span(self, track, tid, name, t0, t1):
        pass

    def open_span(self, track, tid, name):
        pass

    def close_span(self, track, tid, name):
        pass

    def instant(self, track, tid, name):
        pass

    def counter(self, name, value):
        pass

    def attach_engine(self, engine):
        pass

    def attach_kernel_counters(self):
        pass

    def scoped(self, scope):
        return self

    def snapshot(self):
        return {}

    def event_log(self):
        return []

    def summary(self):
        return "[telemetry] disabled"


NULL_TELEMETRY = _NullTelemetry()
