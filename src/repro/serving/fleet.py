"""Multi-replica fleet serving under one virtual clock (DESIGN.md §15).

``Fleet`` runs N independent ``ServeEngine`` replicas — each with its own
``PagePool`` and ``AsyncScheduler`` — behind a ``FleetRouter``
(serving/router.py) and ONE injected clock.  Requests are routed at
their ARRIVAL instant (never earlier: prefix affinity scores the pools'
live state), stepped in sorted-replica-id lockstep, and aggregated into
the same ``ServerReport`` the single server emits.

Determinism contract, extended from §11 to the fleet: same seed + trace
→ byte-identical merged event log, per-request token streams, and
report, across runs AND across replica *iteration order* — every loop
over replicas walks sorted ids, routing ties fall to the smallest id,
and the merged log's tie-break is (time, staged-before-scheduler,
replica id).  ``fleet(N=1)`` reduces exactly to ``Server.replay``:
one scheduler, same clock arithmetic, token-for-token output
(tests/test_fleet.py).

Scale: ``replay()`` accepts a streamed trace (generator with
non-decreasing arrivals — ``poisson_trace_iter``) with one row of
lookahead, and ``retain=False`` drops finished handles and folds the
event log into a running SHA-256 digest, so a 200k-request trace runs in
bounded memory (tests/test_fleet_scale.py).  The aggregate report is
built incrementally either way.

Swap accounting (the §13 dual-unit rule, fleet-level): the report sums
the schedulers' ``n_pages_swapped_out/in`` — *data* pages moved through
host blobs — across replicas, and never mixes in the pools'
``swapped_out_pages`` (page *references* released, ≥ the data count by
each preemption's unfilled reservation tail).  The two registries stay
side by side in telemetry (``r0.sched.*`` vs ``r0.pool.*``) and
tests/test_fleet.py cross-checks them against the report.

Drain (``drain`` / ``schedule_drain``) stops routing to a replica; by
default its queued and running requests finish (or swap out and resume)
in place, so a drained replica reaches zero load in bounded rounds.
With ``migrate_on_drain=True`` the fleet instead EXPELS every unfinished
request at drain time — running ones swap out to host blobs
(``AsyncScheduler.expel``), bit-exact by the §11 swap contract — and
re-enqueues them into the fleet's pending heap, so the very next routing
pass adopts them on survivors (``AsyncScheduler.adopt``) and warm work
outlives the dying replica.  Scale-up (``add_replica`` /
``schedule_scale``) makes a replica routable the instant it joins,
mid-trace included.

When every replica is draining, due arrivals are DEFERRED (left at the
head of the pending heap, retried each round) rather than crashing the
replay — they route the moment a scale-up lands.  A fleet that can never
deliver them (no scale scheduled, no drain progress possible) still
fails loudly via stall detection.  ``shed_policy``/``shed_threshold``
add admission backpressure on top (serving/router.py ``decide``):
arrivals facing a fleet whose least-pressured admitting replica is over
the threshold are shed (by SLO class) or deferred instead of queueing
unboundedly; shed requests are staged as ``shed`` events, counted in
``ServerReport.n_shed``, and listed in ``Fleet.shed_rids``.

``shared_prefix_tier=True`` (or an explicit ``SharedPrefixTier``) hangs
one fleet-level content-addressed page store under every paged replica's
pool: a local prefix miss consults the tier and scatters the page in
before recomputing, so a hot system prompt is materialized once per
fleet instead of once per replica (kvcache.py, DESIGN.md §15).

Per-replica telemetry rides the shared registry through
``Telemetry.scoped`` — one snapshot with ``r0.pool``/``r1.pool``
sections, one Perfetto export with per-replica track processes; fleet-
level ``fleet.migrated_pages`` / ``fleet.shed`` / ``prefix_tier.*``
counters land beside them.
"""

from __future__ import annotations

import hashlib
import heapq
import json

import numpy as np

from repro.serving.kvcache import SharedPrefixTier
from repro.serving.router import FleetRouter
from repro.serving.scheduler import FINISHED, AsyncScheduler, VirtualClock
from repro.serving.server import ServerReport
from repro.serving.telemetry import NULL_TELEMETRY

__all__ = ["Fleet", "ReplicaProbe"]


class ReplicaProbe:
    """Router-facing view of one live replica (the probe protocol
    ``FleetRouter`` scores): unfinished load, claimable capacity,
    admission pressure, and the pool's prefix-chain match length.
    Read-only by construction."""

    def __init__(self, fleet: "Fleet", rep: str):
        self._fleet = fleet
        self.rep = rep

    def load(self) -> int:
        return self._fleet.inflight[self.rep]

    def free_pages(self) -> int:
        sched = self._fleet.replicas[self.rep]
        if getattr(sched.engine, "paged", False):
            return sched.engine.pool.free_claimable()
        return sum(1 for h in sched.slots if h is None)

    def pressure(self) -> float:
        """0.0 idle → 1.0 admission blocked: the pool's own pressure
        signal for paged replicas, busy-slot fraction otherwise — the
        quantity the router's shed gate thresholds."""
        sched = self._fleet.replicas[self.rep]
        if getattr(sched.engine, "paged", False):
            return sched.engine.pool.pressure()
        n = len(sched.slots)
        return (sum(1 for h in sched.slots if h is not None) / n
                if n else 1.0)

    def prefix_match_pages(self, tokens) -> int:
        sched = self._fleet.replicas[self.rep]
        if getattr(sched.engine, "paged", False):
            return sched.engine.pool.prefix_match_pages(tokens)
        return 0


class Fleet:
    """N replicas, one router, one clock — the fleet-shaped ``Server``.

    ``engines``: a list (ids ``r0..rN-1``) or an id→engine dict.  Every
    replica shares the fleet's clock/costs/quantum and receives the same
    sampling ``key`` (replicas are independent engines, so equal keys
    keep N=1 parity and make relabeling a no-op).  ``retain=False`` is
    the large-trace mode: finished handles are released and the merged
    event log lives only in ``event_digest()``.

    ``migrate_on_drain``: expel a draining replica's unfinished requests
    and re-route them to survivors (default False — drained replicas
    finish in place, the PR 9 behavior).  ``shared_prefix_tier``: True
    for a fresh fleet-level ``SharedPrefixTier``, or an existing tier
    instance to share beyond this fleet.  ``shed_policy`` /
    ``shed_threshold``: router admission backpressure (serving/router.py
    ``decide``)."""

    def __init__(self, engines, *, clock=None, costs=None, quantum: int = 1,
                 preempt: bool = True, key=None, telemetry=None,
                 policy: str = "prefix", retain: bool = True,
                 migrate_on_drain: bool = False, shared_prefix_tier=None,
                 shed_policy: str = "none", shed_threshold: float = 0.95):
        self.clock = VirtualClock() if clock is None else clock
        self.costs = costs
        self.quantum = int(quantum)
        self.preempt = bool(preempt)
        self.key = key
        self.retain = bool(retain)
        self.migrate_on_drain = bool(migrate_on_drain)
        if shared_prefix_tier is True:
            self.tier = SharedPrefixTier()
        elif shared_prefix_tier is None or shared_prefix_tier is False:
            self.tier = None
        else:
            self.tier = shared_prefix_tier
        self.telemetry = NULL_TELEMETRY if telemetry is None else telemetry
        if self.telemetry.enabled:
            self.telemetry.bind_clock(self.clock)
            if self.tier is not None:
                self.telemetry.add_provider("prefix_tier", self.tier.stats)
        self.router = FleetRouter(policy=policy, shed_policy=shed_policy,
                                  shed_threshold=shed_threshold)
        self.replicas: dict[str, AsyncScheduler] = {}
        self.inflight: dict[str, int] = {}     # unfinished routed requests
        self.n_routed_to: dict[str, int] = {}
        self.migrated_from: dict[str, int] = {}
        self.handles: dict[int, object] = {}   # frid -> handle (retain mode)
        self.assigned: dict[int, tuple] = {}   # frid -> (rep, local rid)
        self._local2fleet: dict[str, dict] = {}
        self._rows: dict[int, dict] = {}       # frid -> row (until routed)
        self.pending: list[tuple] = []         # (arrival, frid) heap
        self._controls: list[tuple] = []       # (t, seq, kind, payload) heap
        self.n_migrated = 0                    # requests expelled at drain
        self.n_migrated_pages = 0              # blob data pages they carried
        self.n_shed = 0
        self.n_deferred = 0                    # arrivals deferred >= once
        self.shed_rids: list[int] = []
        self._deferred: set[int] = set()       # frids staged as deferred
        self._tier_sampled = None              # last (hits, bytes) sampled
        self._cseq = 0
        self._seq = 0
        self._staged: list[tuple] = []         # fleet events awaiting merge
        self.events: list[tuple] = []          # merged (t, rep, kind, frid)
        self._digest = hashlib.sha256()
        self._trace = None                     # streamed-replay iterator
        self._thead = None                     # its one-row lookahead
        self._agg = {"n": 0, "tokens": 0, "first_arrival": None,
                     "last_finish": None, "ttft": [], "tpot": [],
                     "slo_hit": 0, "slo_total": 0}
        items = (dict(engines) if isinstance(engines, dict)
                 else {f"r{i}": e for i, e in enumerate(engines)})
        for rep in sorted(items):            # canonical join order: a fleet
            self.add_replica(rep, items[rep])  # is a set, not a sequence
        if not self.replicas:
            raise ValueError("a fleet needs at least one replica")

    # --- membership ----------------------------------------------------------

    def add_replica(self, rep: str, engine) -> None:
        """Scale-up: build the replica's scheduler on the shared clock
        and make it routable immediately."""
        rep = str(rep)
        tel = self.telemetry
        sched = AsyncScheduler(
            engine, clock=self.clock, costs=self.costs,
            quantum=self.quantum, preempt=self.preempt, key=self.key,
            telemetry=tel.scoped(rep) if tel.enabled else None)
        self.replicas[rep] = sched
        self.inflight[rep] = 0
        self.n_routed_to[rep] = 0
        self.migrated_from[rep] = 0
        self._local2fleet[rep] = {}
        if self.tier is not None and getattr(engine, "paged", False):
            engine.pool.shared_tier = self.tier
        self.router.add(rep, ReplicaProbe(self, rep))
        self._stage("join", rep, -1)
        if tel.enabled:
            tel.count("fleet.replicas")
            tel.instant("fleet", 0, f"join:{rep}")

    def drain(self, rep: str) -> None:
        """Stop routing to ``rep``.  Default: it finishes its own queue
        in place.  With ``migrate_on_drain`` its unfinished requests are
        expelled (running ones as bit-exact swap blobs) and re-enqueued
        fleet-pending, so the next routing pass re-homes them on
        survivors — or defers them until a survivor exists."""
        self.router.drain(rep)
        self._stage("drain", rep, -1)
        if self.telemetry.enabled:
            self.telemetry.count("fleet.drains")
            self.telemetry.instant("fleet", 0, f"drain:{rep}")
        if self.migrate_on_drain:
            self._migrate_from(rep)

    def _migrate_from(self, rep: str) -> None:
        """Expel every unfinished request on ``rep`` (sorted fleet-id
        order — deterministic and replica-order independent) and push it
        back into the fleet's pending heap under its ORIGINAL arrival,
        carrying its live handle and, for started requests, the swap
        blob ``adopt`` will restore bit-exactly on the target."""
        sched = self.replicas[rep]
        tel = self.telemetry
        moved = sorted(
            (frid, lrid) for lrid, frid in self._local2fleet[rep].items()
            if lrid in sched.handles
            and sched.handles[lrid].state != FINISHED)
        for frid, lrid in moved:
            h, blob = sched.expel(lrid)
            self.inflight[rep] -= 1
            del self.assigned[frid]
            self._rows[frid] = {
                "arrival": h.arrival, "prompt": h.prompt,
                "max_new": h.max_new, "priority": h.priority,
                "slo_ttft": h.slo_ttft, "slo_tpot": h.slo_tpot,
                "handle": h, "blob": blob}
            heapq.heappush(self.pending, (h.arrival, frid))
            self.n_migrated += 1
            self.migrated_from[rep] += 1
            n_pg = blob.n_pages if blob is not None else 0
            self.n_migrated_pages += n_pg
            self._stage("migrate", rep, frid)
            if tel.enabled:
                tel.count("fleet.migrated")
                if n_pg:
                    tel.count("fleet.migrated_pages", n_pg)
                tel.instant("fleet", 0, f"migrate:{rep}")
        if moved and tel.enabled:
            tel.counter("fleet.migrated_pages", self.n_migrated_pages)

    def schedule_drain(self, t: float, rep: str) -> None:
        """Drain ``rep`` once the virtual clock reaches ``t``."""
        heapq.heappush(self._controls, (float(t), self._cseq, "drain", rep))
        self._cseq += 1

    def schedule_scale(self, t: float, rep: str, engine) -> None:
        """Add replica ``rep`` once the clock reaches ``t``.  ``engine``
        may be an engine or a zero-argument factory (deferring device
        allocation to join time)."""
        heapq.heappush(self._controls,
                       (float(t), self._cseq, "scale", (str(rep), engine)))
        self._cseq += 1

    # --- submission ----------------------------------------------------------

    def submit(self, prompt, max_new: int, *, priority: int = 0,
               arrival: float | None = None, slo_ttft: float | None = None,
               slo_tpot: float | None = None) -> int:
        """Register one request with the fleet; returns its fleet-wide
        request id.  Routing happens when the clock reaches the arrival
        (prefix affinity must see the pools as they are THEN)."""
        t = self.clock.now() if arrival is None else float(arrival)
        if t < self.clock.now():
            raise ValueError(
                f"arrival {t} is in the past (now={self.clock.now()})")
        return self._enqueue({
            "arrival": t, "prompt": list(prompt), "max_new": int(max_new),
            "priority": int(priority), "slo_ttft": slo_ttft,
            "slo_tpot": slo_tpot})

    def _enqueue(self, row: dict) -> int:
        frid = self._seq
        self._seq += 1
        self._rows[frid] = row
        heapq.heappush(self.pending, (row["arrival"], frid))
        a = self._agg
        if a["first_arrival"] is None or row["arrival"] < a["first_arrival"]:
            a["first_arrival"] = row["arrival"]
        if self.telemetry.enabled:
            self.telemetry.count("fleet.submitted")
        return frid

    # --- internals -----------------------------------------------------------

    def _stage(self, kind: str, rep: str, frid: int) -> None:
        self._staged.append((round(self.clock.now(), 9), rep, kind, frid))

    def _apply_controls(self) -> None:
        now = self.clock.now()
        while self._controls and self._controls[0][0] <= now:
            _, _, kind, payload = heapq.heappop(self._controls)
            if kind == "drain":
                self.drain(payload)
            else:
                rep, eng = payload
                self.add_replica(rep, eng() if callable(eng) else eng)

    def _pull_trace(self) -> None:
        if self._thead is None:
            return
        now = self.clock.now()
        while self._thead is not None and self._thead["arrival"] <= now:
            r = self._thead
            self._enqueue({
                "arrival": float(r["arrival"]), "prompt": r["prompt"],
                "max_new": r["max_new"],
                "priority": r.get("priority", 0),
                "slo_ttft": r.get("slo_ttft"),
                "slo_tpot": r.get("slo_tpot")})
            self._thead = next(self._trace, None)
            if (self._thead is not None
                    and self._thead["arrival"] < r["arrival"]):
                raise ValueError("streamed trace arrivals must be "
                                 "non-decreasing")

    def _route_due(self) -> bool:
        """Route due arrivals through the router's admission decision.
        A deferred head stays in ``pending`` (head-of-line, original
        order) and is retried next round — this is what lets a mid-trace
        arrival survive an all-drained window until a scale-up lands,
        and what backpressure's "defer" class waits on.  Returns True
        when anything was routed or shed (the round made progress)."""
        now = self.clock.now()
        tel = self.telemetry
        acted = False
        while self.pending and self.pending[0][0] <= now:
            _, frid = self.pending[0]
            row = self._rows[frid]
            kind, rep = self.router.decide(
                row["prompt"],
                has_slo=(row["slo_ttft"] is not None
                         or row["slo_tpot"] is not None))
            if kind == "defer":
                if frid not in self._deferred:   # stage + count ONCE
                    self._deferred.add(frid)
                    self.n_deferred += 1
                    self._stage("defer", "-", frid)
                    if tel.enabled:
                        tel.count("fleet.deferred")
                        tel.instant("fleet", 0, "defer")
                break
            heapq.heappop(self.pending)
            self._deferred.discard(frid)
            if kind == "shed":
                self._rows.pop(frid)
                self.n_shed += 1
                self.shed_rids.append(frid)
                self._stage("shed", "-", frid)
                if tel.enabled:
                    tel.count("fleet.shed")
                    tel.counter("fleet.shed", self.n_shed)
                    tel.instant("fleet", 0, "shed")
                acted = True
                continue
            self._route(frid, rep)
            acted = True
        return acted

    def _route(self, frid: int, rep: str) -> None:
        row = self._rows.pop(frid)
        sched = self.replicas[rep]
        h = row.get("handle")
        if h is not None:              # drain-time migration handover
            h = sched.adopt(h, blob=row.get("blob"))
        else:
            h = sched.submit(row["prompt"], row["max_new"],
                             priority=row["priority"],
                             arrival=row["arrival"],
                             slo_ttft=row["slo_ttft"],
                             slo_tpot=row["slo_tpot"],
                             allow_past_arrival=True)
        self._local2fleet[rep][h.rid] = frid
        self.assigned[frid] = (rep, h.rid)
        self.inflight[rep] += 1
        self.n_routed_to[rep] += 1
        if self.retain:
            self.handles[frid] = h
        self._stage("route", rep, frid)
        if self.telemetry.enabled:
            self.telemetry.count("fleet.routed")
            self.telemetry.instant("fleet", 0, f"route:{rep}")

    def _drain_events(self) -> None:
        """Merge this round's staged fleet events and every replica's
        scheduler events into the fleet log: stable-sorted by time (the
        only cross-replica ordering that exists), staged-first then
        sorted-replica order among equal times.  The merged rows feed
        the running digest; ``retain`` decides whether they are kept."""
        batch = self._staged
        self._staged = []
        for rep in sorted(self.replicas):
            sched = self.replicas[rep]
            if not sched.events:
                continue
            local = self._local2fleet[rep]
            batch.extend((t, rep, kind, local[rid])
                         for t, kind, rid in sched.events)
            sched.events.clear()
        if not batch:
            return
        batch.sort(key=lambda ev: ev[0])
        for ev in batch:
            self._digest.update(
                json.dumps(list(ev), separators=(",", ":")).encode())
            self._digest.update(b"\n")
            if ev[2] == "finish":
                self._on_finish(ev[1], ev[3])
        if self.retain:
            self.events.extend(batch)

    def _on_finish(self, rep: str, frid: int) -> None:
        sched = self.replicas[rep]
        _, lrid = self.assigned[frid]
        h = sched.handles[lrid]
        a = self._agg
        a["n"] += 1
        a["tokens"] += len(h.tokens)
        a["ttft"].append(h.ttft)
        a["tpot"].append(h.tpot)
        if a["last_finish"] is None or h.finished_at > a["last_finish"]:
            a["last_finish"] = h.finished_at
        if h.slo_ttft is not None or h.slo_tpot is not None:
            a["slo_total"] += 1
            a["slo_hit"] += int(h.slo_met())
        self.inflight[rep] -= 1
        if not self.retain:                   # large-trace mode: release
            del sched.handles[lrid]
            del self._local2fleet[rep][lrid]
            del self.assigned[frid]

    def _next_time(self):
        cands = []
        if self.pending:
            cands.append(self.pending[0][0])
        if self._controls:
            cands.append(self._controls[0][0])
        if self._thead is not None:
            cands.append(float(self._thead["arrival"]))
        return min(cands) if cands else None

    # --- the loop ------------------------------------------------------------

    def step(self) -> bool:
        """One fleet round: apply due controls, pull + route due
        arrivals, step every busy replica in sorted-id order, merge
        event logs.  Returns False once the whole fleet is idle."""
        self._apply_controls()
        self._pull_trace()
        progress = self._route_due()
        more = bool(self.pending or self._controls
                    or self._thead is not None)
        for rep in sorted(self.replicas):
            sched = self.replicas[rep]
            if sched.pending or sched.ready or sched.running:
                progress = sched.step(more_arrivals=more) or progress
        self._drain_events()
        tel = self.telemetry
        if tel.enabled and self.tier is not None:
            sample = (self.tier.hits, self.tier.bytes)
            if sample != self._tier_sampled:   # counter tracks on change
                self._tier_sampled = sample
                tel.counter("prefix_tier.hits", sample[0])
                tel.counter("prefix_tier.bytes", sample[1])
        if progress:
            return True
        nxt = self._next_time()
        if nxt is not None and nxt > self.clock.now():
            self.clock.advance(nxt - self.clock.now())  # idle-jump
            if tel.enabled:
                tel.instant("fleet", 0, "idle_jump")
            return True
        if nxt is not None:
            # the head arrival is due but deferred and no replica can
            # move — only a scheduled control (scale-up) can resolve it;
            # jump straight to the next one, or fail loudly
            if self._controls:
                self.clock.advance(self._controls[0][0] - self.clock.now())
                if tel.enabled:
                    tel.instant("fleet", 0, "idle_jump")
                return True
            raise RuntimeError(
                "fleet stalled: arrivals due but deferred with no "
                "admitting progress and no scale-up scheduled (all "
                "replicas draining, or shed threshold never clears)")
        if any(s.ready or s.running or s.pending
               for s in self.replicas.values()):
            raise RuntimeError(
                "fleet stalled: a replica is blocked with no traffic left")
        return False

    def run_until_idle(self, max_rounds: int = 10_000_000) -> None:
        for _ in range(max_rounds):
            if not self.step():
                return
        raise RuntimeError(f"fleet not idle after {max_rounds} rounds — "
                           "starvation or a stuck request")

    def replay(self, trace, *, drain_at=(), scale_at=(),
               max_rounds: int = 10_000_000) -> ServerReport:
        """Feed a trace (list, or generator with non-decreasing arrivals
        streamed with one row of lookahead) and drain the fleet.
        ``drain_at``: iterable of ``(t, rep)``; ``scale_at``: iterable of
        ``(t, rep, engine_or_factory)`` — both applied at virtual ``t``,
        before any routing at that instant."""
        for t, rep in drain_at:
            self.schedule_drain(t, rep)
        for t, rep, eng in scale_at:
            self.schedule_scale(t, rep, eng)
        if hasattr(trace, "__len__"):
            if not trace:
                raise ValueError("replay() needs a non-empty trace")
            for r in trace:
                self._enqueue({
                    "arrival": float(r["arrival"]), "prompt": r["prompt"],
                    "max_new": r["max_new"],
                    "priority": r.get("priority", 0),
                    "slo_ttft": r.get("slo_ttft"),
                    "slo_tpot": r.get("slo_tpot")})
        else:
            self._trace = iter(trace)
            self._thead = next(self._trace, None)
            if self._thead is None:
                raise ValueError("replay() needs a non-empty trace")
        self.run_until_idle(max_rounds)
        return self.report()

    # --- aggregation (the deterministic fleet record) ------------------------

    def report(self) -> ServerReport:
        """The fleet-wide ``ServerReport`` over every finished request.

        Swap fields sum the schedulers' *data*-page counters per replica
        (``n_pages_swapped_out/in``) — one unit, one sum; the pools'
        released-*reference* counters (``swapped_out_pages``) are a
        different unit (DESIGN.md §13) and deliberately never enter the
        report.  ``admission_order`` carries fleet-wide request ids; in
        large-trace mode (``retain=False``) the merged log lives only in
        ``event_digest()`` and the order is empty."""
        a = self._agg
        if not a["n"]:
            raise RuntimeError("nothing finished yet — replay a trace or "
                               "run_until_idle() first")
        pct = lambda xs, q: float(                          # noqa: E731
            np.percentile(np.asarray(xs, np.float64), q))
        scheds = self.replicas.values()
        return ServerReport(
            n_requests=a["n"],
            n_tokens=a["tokens"],
            makespan=a["last_finish"] - a["first_arrival"],
            p50_ttft=pct(a["ttft"], 50), p99_ttft=pct(a["ttft"], 99),
            p50_tpot=pct(a["tpot"], 50), p99_tpot=pct(a["tpot"], 99),
            preemptions=sum(s.n_preemptions for s in scheds),
            pages_swapped_out=sum(s.n_pages_swapped_out for s in scheds),
            pages_swapped_in=sum(s.n_pages_swapped_in for s in scheds),
            slo_attainment=(a["slo_hit"] / a["slo_total"]
                            if a["slo_total"] else 1.0),
            admission_order=[frid for _, _, kind, frid in self.events
                             if kind == "admit"],
            n_shed=self.n_shed)

    def event_digest(self) -> str:
        """SHA-256 over the merged event log so far — the O(1)-memory
        replay fingerprint the large-trace determinism test compares."""
        return self._digest.hexdigest()

    def prefix_hit_rate(self) -> float:
        """Fleet-wide prefix-cache hit rate: pooled hit/miss pages over
        every paged replica — what prefix-aware routing is measured on
        against round-robin (benchmarks/serve_throughput.py)."""
        hit = miss = 0
        for sched in self.replicas.values():
            if getattr(sched.engine, "paged", False):
                st = sched.engine.pool.stats
                hit += st.hit_pages
                miss += st.miss_pages
        return hit / (hit + miss) if hit + miss else 0.0

    def shared_tier_stats(self) -> dict | None:
        """The fleet tier's hit/byte counters, or None when no tier is
        attached — what the bench smoke and the tier-2 scale rig report."""
        return self.tier.stats() if self.tier is not None else None

    def materialized_pages(self) -> int:
        """Fleet-wide prompt pages actually COMPUTED (pooled
        ``miss_pages``) — the quantity the shared tier exists to shrink;
        tier- and locally-served pages never enter it."""
        return sum(s.engine.pool.stats.miss_pages
                   for s in self.replicas.values()
                   if getattr(s.engine, "paged", False))

    def replica_stats(self) -> dict:
        """Per-replica routing/preemption/swap counters, sorted ids —
        the registry side of the registry-vs-report swap cross-check."""
        out = {}
        for rep in sorted(self.replicas):
            s = self.replicas[rep]
            out[rep] = {
                "routed": self.n_routed_to[rep],
                "inflight": self.inflight[rep],
                "draining": rep in self.router.draining,
                "migrated_out": self.migrated_from[rep],
                "preemptions": s.n_preemptions,
                "pages_swapped_out": s.n_pages_swapped_out,
                "pages_swapped_in": s.n_pages_swapped_in}
        return out
