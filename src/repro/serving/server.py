"""Request-server facade + synthetic traffic over ``AsyncScheduler``
(DESIGN.md §11).

``Server`` wraps engine → scheduler into the long-running shape
``launch/serve.py --server`` exposes: ``submit()`` for live traffic
(arrival = the injected clock's now), ``replay()`` for a recorded or
synthetic trace — the deterministic CI mode — and a ``ServerReport``
(p50/p99 TTFT, TPOT, preemption counts, SLO attainment) after a drain.

Traffic: ``poisson_trace`` synthesises a seeded open-loop arrival
process (exponential inter-arrival gaps, mixed prompt/stop lengths,
priority classes, optional SLOs); ``save_trace``/``load_trace``
round-trip traces as JSON for ``--traffic replay``.  Same seed → same
trace → same scheduler decisions, bit for bit — the virtual-clock rule
means nothing here (or anywhere under ``serving/``) reads the wall.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.serving.scheduler import AsyncScheduler, VirtualClock

__all__ = ["Server", "ServerReport", "poisson_trace", "poisson_trace_iter",
           "save_trace", "load_trace", "iter_trace", "contended_trace",
           "CONTENDED_ENGINE_KW"]

# The reference contended workload: an engine one notch too small for
# the trace below, so admissions queue and priority preemptions fire.
# The CI smoke gate, the tier-1 replay-determinism test, and the tier-2
# tp=2 parity case all exercise THIS pair — edit it in one place only
# (contention at a given seed is a property of the pair; seeds 0/1 are
# probed to preempt in CI).
CONTENDED_ENGINE_KW = dict(max_len=48, max_batch=2, paged=True,
                           page_size=8, n_pages=9)


def contended_trace(seed: int, vocab: int, **over) -> list[dict]:
    """The reference 8-request contended trace for the engine shape in
    ``CONTENDED_ENGINE_KW`` (keyword overrides pass through to
    ``poisson_trace``, e.g. SLOs)."""
    return poisson_trace(seed, 8, rate=40.0, vocab=vocab, plen=(2, 9),
                         max_new=(2, 10), priorities=(0, 1), **over)


def poisson_trace_iter(seed: int, n: int, *, rate: float = 20.0,
                       vocab: int = 512, plen=(2, 10), max_new=(2, 12),
                       priorities=(0,), slo_ttft: float | None = None,
                       slo_tpot: float | None = None, shared_prefix=()):
    """Streamed form of ``poisson_trace``: yields one row at a time with
    O(1) rows live, so 100k+-request fleet traces (tests/test_fleet_scale
    .py) never materialize in RAM.  Same seed → the same row sequence as
    the list form, element for element.  ``shared_prefix``: tokens
    prepended to every prompt (the shared-system-prompt workload the
    prefix-aware router is measured on).  Arrivals are non-decreasing by
    construction — what ``Fleet.replay`` requires of a streamed trace."""
    rng = np.random.default_rng(seed)
    prefix = [int(x) for x in shared_prefix]
    t = 0.0
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        pl = int(rng.integers(plen[0], plen[1] + 1))
        yield {
            "arrival": round(t, 9),
            "prompt": prefix + [int(x) for x in rng.integers(0, vocab, pl)],
            "max_new": int(rng.integers(max_new[0], max_new[1] + 1)),
            "priority": int(rng.choice(priorities)),
            "slo_ttft": slo_ttft, "slo_tpot": slo_tpot}


def poisson_trace(seed: int, n: int, *, rate: float = 20.0,
                  vocab: int = 512, plen=(2, 10), max_new=(2, 12),
                  priorities=(0,), slo_ttft: float | None = None,
                  slo_tpot: float | None = None,
                  shared_prefix=()) -> list[dict]:
    """Seeded open-loop Poisson arrival trace: ``n`` requests at ``rate``
    arrivals per (virtual) second, prompt/stop lengths uniform over the
    given inclusive ranges, priority drawn uniformly from
    ``priorities``.  Pure function of its arguments."""
    return list(poisson_trace_iter(
        seed, n, rate=rate, vocab=vocab, plen=plen, max_new=max_new,
        priorities=priorities, slo_ttft=slo_ttft, slo_tpot=slo_tpot,
        shared_prefix=shared_prefix))


def save_trace(path: str, trace) -> None:
    """Write a trace (list OR generator) as a JSON array, one row per
    line — rows stream straight to disk, so saving a 100k+-request
    generator never materializes it."""
    with open(path, "w") as f:
        f.write("[")
        sep = "\n"
        for row in trace:
            f.write(sep)
            json.dump(row, f)
            sep = ",\n"
        f.write("\n]\n")


def load_trace(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


def iter_trace(path: str, chunk: int = 1 << 16):
    """Stream a JSON-array trace row by row with O(1) rows buffered —
    the replay-side twin of a generator ``save_trace``.  Accepts any
    JSON array of objects (not just line-delimited ones)."""
    dec = json.JSONDecoder()
    with open(path) as f:
        buf = f.read(chunk)
        i = 0
        while True:
            while True:                      # skip [ , whitespace
                while i < len(buf) and buf[i] in " \t\r\n,[":
                    i += 1
                if i < len(buf):
                    break
                more = f.read(chunk)
                if not more:
                    raise ValueError(f"{path}: truncated trace")
                buf, i = more, 0
            if buf[i] == "]":
                return
            try:
                row, end = dec.raw_decode(buf, i)
            except ValueError:
                more = f.read(chunk)         # row split across the buffer
                if not more:
                    raise
                buf, i = buf[i:] + more, 0
                continue
            yield row
            buf, i = buf[end:], 0


@dataclasses.dataclass
class ServerReport:
    """Aggregate + per-request metrics after a drained trace.  Every
    field is in injected-clock time — deterministic under a
    ``VirtualClock`` replay."""

    n_requests: int
    n_tokens: int
    makespan: float                  # first arrival -> last finish
    p50_ttft: float
    p99_ttft: float
    p50_tpot: float
    p99_tpot: float
    preemptions: int
    pages_swapped_out: int           # data pages preemption moved out
    pages_swapped_in: int            # data pages restore moved back
    slo_attainment: float            # over requests that set an SLO
    admission_order: list
    # arrivals rejected by fleet admission backpressure (DESIGN.md §15);
    # always 0 for the single server, which has no shed gate
    n_shed: int = 0

    @staticmethod
    def build(handles, sched) -> "ServerReport":
        pct = lambda xs, q: float(                          # noqa: E731
            np.percentile(np.asarray(xs, np.float64), q))
        sloed = [h for h in handles
                 if h.slo_ttft is not None or h.slo_tpot is not None]
        att = (sum(h.slo_met() for h in sloed) / len(sloed)
               if sloed else 1.0)
        return ServerReport(
            n_requests=len(handles),
            n_tokens=sum(len(h.tokens) for h in handles),
            makespan=(max(h.finished_at for h in handles)
                      - min(h.arrival for h in handles)),
            p50_ttft=pct([h.ttft for h in handles], 50),
            p99_ttft=pct([h.ttft for h in handles], 99),
            p50_tpot=pct([h.tpot for h in handles], 50),
            p99_tpot=pct([h.tpot for h in handles], 99),
            preemptions=sched.n_preemptions,
            pages_swapped_out=sum(h.pages_swapped_out for h in handles),
            pages_swapped_in=sched.n_pages_swapped_in,
            slo_attainment=att,
            admission_order=sched.admission_order)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Server:
    """Long-running request server: one engine, one scheduler, an
    injected clock.  ``replay()`` is the deterministic batch entry;
    ``submit()``/``poll()`` compose into live loops."""

    def __init__(self, engine, *, clock=None, costs=None, quantum: int = 1,
                 preempt: bool = True, key=None, telemetry=None):
        self.clock = VirtualClock() if clock is None else clock
        self.sched = AsyncScheduler(engine, clock=self.clock, costs=costs,
                                    quantum=quantum, preempt=preempt,
                                    key=key, telemetry=telemetry)
        self.telemetry = self.sched.telemetry

    def submit(self, prompt, max_new: int, **kw):
        return self.sched.submit(prompt, max_new, **kw)

    def poll(self) -> bool:
        """One scheduling round; False once idle."""
        return self.sched.step()

    def run_until_idle(self) -> None:
        self.sched.run_until_idle()

    def replay(self, trace: list[dict]) -> ServerReport:
        """Feed a trace's arrivals and drain it under the injected
        clock.  Returns the aggregate report; per-request handles stay
        readable on ``self.sched.handles``."""
        if not trace:
            raise ValueError("replay() needs a non-empty trace")
        handles = [self.sched.submit(
                       r["prompt"], r["max_new"],
                       priority=r.get("priority", 0),
                       arrival=r["arrival"],
                       slo_ttft=r.get("slo_ttft"),
                       slo_tpot=r.get("slo_tpot"))
                   for r in trace]
        self.sched.run_until_idle()
        return ServerReport.build(handles, self.sched)
