"""Request-server facade + synthetic traffic over ``AsyncScheduler``
(DESIGN.md §11).

``Server`` wraps engine → scheduler into the long-running shape
``launch/serve.py --server`` exposes: ``submit()`` for live traffic
(arrival = the injected clock's now), ``replay()`` for a recorded or
synthetic trace — the deterministic CI mode — and a ``ServerReport``
(p50/p99 TTFT, TPOT, preemption counts, SLO attainment) after a drain.

Traffic: ``poisson_trace`` synthesises a seeded open-loop arrival
process (exponential inter-arrival gaps, mixed prompt/stop lengths,
priority classes, optional SLOs); ``save_trace``/``load_trace``
round-trip traces as JSON for ``--traffic replay``.  Same seed → same
trace → same scheduler decisions, bit for bit — the virtual-clock rule
means nothing here (or anywhere under ``serving/``) reads the wall.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.serving.scheduler import AsyncScheduler, VirtualClock

__all__ = ["Server", "ServerReport", "poisson_trace", "save_trace",
           "load_trace", "contended_trace", "CONTENDED_ENGINE_KW"]

# The reference contended workload: an engine one notch too small for
# the trace below, so admissions queue and priority preemptions fire.
# The CI smoke gate, the tier-1 replay-determinism test, and the tier-2
# tp=2 parity case all exercise THIS pair — edit it in one place only
# (contention at a given seed is a property of the pair; seeds 0/1 are
# probed to preempt in CI).
CONTENDED_ENGINE_KW = dict(max_len=48, max_batch=2, paged=True,
                           page_size=8, n_pages=9)


def contended_trace(seed: int, vocab: int, **over) -> list[dict]:
    """The reference 8-request contended trace for the engine shape in
    ``CONTENDED_ENGINE_KW`` (keyword overrides pass through to
    ``poisson_trace``, e.g. SLOs)."""
    return poisson_trace(seed, 8, rate=40.0, vocab=vocab, plen=(2, 9),
                         max_new=(2, 10), priorities=(0, 1), **over)


def poisson_trace(seed: int, n: int, *, rate: float = 20.0,
                  vocab: int = 512, plen=(2, 10), max_new=(2, 12),
                  priorities=(0,), slo_ttft: float | None = None,
                  slo_tpot: float | None = None) -> list[dict]:
    """Seeded open-loop Poisson arrival trace: ``n`` requests at ``rate``
    arrivals per (virtual) second, prompt/stop lengths uniform over the
    given inclusive ranges, priority drawn uniformly from
    ``priorities``.  Pure function of its arguments."""
    rng = np.random.default_rng(seed)
    t, rows = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        pl = int(rng.integers(plen[0], plen[1] + 1))
        rows.append({
            "arrival": round(t, 9),
            "prompt": [int(x) for x in rng.integers(0, vocab, pl)],
            "max_new": int(rng.integers(max_new[0], max_new[1] + 1)),
            "priority": int(rng.choice(priorities)),
            "slo_ttft": slo_ttft, "slo_tpot": slo_tpot})
    return rows


def save_trace(path: str, trace: list[dict]) -> None:
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)


def load_trace(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


@dataclasses.dataclass
class ServerReport:
    """Aggregate + per-request metrics after a drained trace.  Every
    field is in injected-clock time — deterministic under a
    ``VirtualClock`` replay."""

    n_requests: int
    n_tokens: int
    makespan: float                  # first arrival -> last finish
    p50_ttft: float
    p99_ttft: float
    p50_tpot: float
    p99_tpot: float
    preemptions: int
    pages_swapped_out: int           # data pages preemption moved out
    pages_swapped_in: int            # data pages restore moved back
    slo_attainment: float            # over requests that set an SLO
    admission_order: list

    @staticmethod
    def build(handles, sched) -> "ServerReport":
        pct = lambda xs, q: float(                          # noqa: E731
            np.percentile(np.asarray(xs, np.float64), q))
        sloed = [h for h in handles
                 if h.slo_ttft is not None or h.slo_tpot is not None]
        att = (sum(h.slo_met() for h in sloed) / len(sloed)
               if sloed else 1.0)
        return ServerReport(
            n_requests=len(handles),
            n_tokens=sum(len(h.tokens) for h in handles),
            makespan=(max(h.finished_at for h in handles)
                      - min(h.arrival for h in handles)),
            p50_ttft=pct([h.ttft for h in handles], 50),
            p99_ttft=pct([h.ttft for h in handles], 99),
            p50_tpot=pct([h.tpot for h in handles], 50),
            p99_tpot=pct([h.tpot for h in handles], 99),
            preemptions=sched.n_preemptions,
            pages_swapped_out=sum(h.pages_swapped_out for h in handles),
            pages_swapped_in=sched.n_pages_swapped_in,
            slo_attainment=att,
            admission_order=sched.admission_order)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Server:
    """Long-running request server: one engine, one scheduler, an
    injected clock.  ``replay()`` is the deterministic batch entry;
    ``submit()``/``poll()`` compose into live loops."""

    def __init__(self, engine, *, clock=None, costs=None, quantum: int = 1,
                 preempt: bool = True, key=None, telemetry=None):
        self.clock = VirtualClock() if clock is None else clock
        self.sched = AsyncScheduler(engine, clock=self.clock, costs=costs,
                                    quantum=quantum, preempt=preempt,
                                    key=key, telemetry=telemetry)
        self.telemetry = self.sched.telemetry

    def submit(self, prompt, max_new: int, **kw):
        return self.sched.submit(prompt, max_new, **kw)

    def poll(self) -> bool:
        """One scheduling round; False once idle."""
        return self.sched.step()

    def run_until_idle(self) -> None:
        self.sched.run_until_idle()

    def replay(self, trace: list[dict]) -> ServerReport:
        """Feed a trace's arrivals and drain it under the injected
        clock.  Returns the aggregate report; per-request handles stay
        readable on ``self.sched.handles``."""
        if not trace:
            raise ValueError("replay() needs a non-empty trace")
        handles = [self.sched.submit(
                       r["prompt"], r["max_new"],
                       priority=r.get("priority", 0),
                       arrival=r["arrival"],
                       slo_ttft=r.get("slo_ttft"),
                       slo_tpot=r.get("slo_tpot"))
                   for r in trace]
        self.sched.run_until_idle()
        return ServerReport.build(handles, self.sched)
