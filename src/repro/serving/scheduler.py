"""Arrival-time-driven request scheduler over the step-level ServeEngine
API (DESIGN.md §11).

The serving layer's missing tense: PRs 1–4 serve a *batch* — ``serve()``
owns the whole request list up front and returns when the last request
finishes.  This module serves *traffic*: requests ARRIVE (each carries an
arrival time, a priority class, and optional SLOs), wait in an admission
queue, stream their tokens back through per-request callbacks/handles
the moment they are sampled, and are PREEMPTIBLE — when the head of the
queue cannot be placed, the scheduler swaps a victim's KV state out to a
host-side ``SwapBlob`` (paged: page refcounts released, prefix-cache
hashes retained; contiguous: the slot's cache rows) and restores it
bit-exactly once capacity drains, so a preempted-then-restored request's
tokens are identical to an uncontended run.

**Virtual-clock rule** (what makes this subsystem's tests an archetype):
nothing under ``serving/`` ever reads the wall clock — time is always
INJECTED through the ``clock`` handle, and every scheduling decision is
a pure function of (trace, cost model, pool state).  A multi-tenant
traffic trace therefore replays bit-identically in CI: same admissions,
same preemptions, same streams (tests/test_scheduler_sim.py).  Real
deployments inject a wall clock from OUTSIDE serving/ (launch/serve.py).

Scheduling policy — deterministic by construction:

* **Admission** is strict head-of-line in (priority desc, arrival, seq)
  order: the head is placed when a slot is free AND (paged) the pool can
  supply its worst-case page reservation — PR 2's free-pages admission
  gate, unchanged.  No bypass: a blocked head waits, it is never
  overtaken by a smaller request behind it.
* **Preemption**: when the head cannot be placed, a running victim of
  STRICTLY lower priority is swapped out (lowest priority first; among
  equals the most recently admitted — LIFO preserves the oldest
  requests' progress) and re-queued under its ORIGINAL key, so it
  resumes in its original order.  Equal priorities never preempt each
  other, which with head-of-line admission gives freedom from
  starvation: under a draining trace every blocker finishes in bounded
  rounds and the head is eventually placed.
* **Decode** runs in lockstep rounds of ``quantum`` tokens per slot
  (``ServeEngine.serve_step``); the clock advances by the injected cost
  model after every prefill, round, and swap.
"""

from __future__ import annotations

import dataclasses
import heapq

from repro.serving.telemetry import NULL_TELEMETRY

__all__ = ["AsyncScheduler", "RequestHandle", "StepCosts", "VirtualClock",
           "QUEUED", "RUNNING", "SWAPPED", "FINISHED"]

QUEUED, RUNNING, SWAPPED, FINISHED = ("queued", "running", "swapped",
                                      "finished")


class VirtualClock:
    """Injected time: ``now()`` reads it, ``advance()`` moves it.  The
    only clock the serving layer knows — simulation IS the production
    code path, just with a different instance plugged in."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"time cannot run backwards (dt={dt})")
        self._t += dt


@dataclasses.dataclass(frozen=True)
class StepCosts:
    """Deterministic virtual-time cost model (seconds per unit) — what
    the clock advances on each scheduling action.  The values are
    arbitrary but FIXED: simulated TTFT/TPOT are comparable across runs
    and replay exactly.  A wall-clock deployment ignores this (its clock
    advances itself)."""

    prefill_token: float = 1e-3      # per prompt token at admission
    decode_step: float = 2e-2        # per lockstep round token
    swap_page: float = 2e-3          # per page moved by swap-out/swap-in


def _blob_bytes(blob) -> int:
    """Host bytes held by a SwapBlob's KV payload.  Telemetry-only (the
    simulation suite's stub engines swap structureless blobs, so ``data``
    is optional here)."""
    data = getattr(blob, "data", None)
    if not data:
        return 0
    return sum(int(getattr(v, "nbytes", 0)) for v in data.values())


class RequestHandle:
    """One submitted request's live view: state, streamed tokens, and
    per-request metrics (TTFT/TPOT in injected-clock seconds)."""

    def __init__(self, sched, rid, prompt, max_new, *, priority, arrival,
                 slo_ttft, slo_tpot, on_token):
        self._sched = sched
        self.rid = rid
        self.prompt = list(prompt)
        self.max_new = int(max_new)
        self.priority = int(priority)
        self.arrival = float(arrival)
        self.slo_ttft = slo_ttft
        self.slo_tpot = slo_tpot
        self.on_token = on_token
        self.state = QUEUED
        self.tokens: list[int] = []          # generated tokens, streamed
        self.admitted_at = None              # first admission
        self.first_token_at = None
        self.finished_at = None
        self.n_preempt = 0
        # data pages moved to the host blob by preemption (swap-OUT
        # direction; the canonical direction-suffixed spelling shared with
        # PoolStats.swapped_out_pages and the telemetry registry — note
        # the pool counts released page *references*, this counts *data*
        # pages, so the two differ by the unfilled reservation tail)
        self.pages_swapped_out = 0
        self.slot = None
        self._admit_seq = -1                 # recency key for victim choice

    @property
    def ttft(self):
        """Time to first token (None until one streams)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.arrival

    @property
    def tpot(self):
        """Mean time per output token after the first (None until
        finished; 0.0 for single-token requests)."""
        if self.finished_at is None:
            return None
        if len(self.tokens) < 2:
            return 0.0
        return ((self.finished_at - self.first_token_at)
                / (len(self.tokens) - 1))

    def slo_met(self) -> bool:
        """True when every SLO this request set is met (vacuously true
        for a finished request that set none)."""
        if self.state != FINISHED:
            return False
        if self.slo_ttft is not None and self.ttft > self.slo_ttft:
            return False
        if self.slo_tpot is not None and self.tpot > self.slo_tpot:
            return False
        return True

    def result(self) -> list[int]:
        """prompt + generated tokens (valid once finished)."""
        if self.state != FINISHED:
            raise RuntimeError(f"request {self.rid} is {self.state}")
        return self.prompt + self.tokens

    def stream(self):
        """Yield this request's generated tokens as they are produced,
        driving the owning scheduler between yields until it finishes."""
        i = 0
        while True:
            while i < len(self.tokens):
                yield self.tokens[i]
                i += 1
            if self.state == FINISHED:
                return
            if not self._sched.step():
                raise RuntimeError(
                    f"scheduler idle but request {self.rid} is {self.state}")


class AsyncScheduler:
    """Request-level scheduler owning one ``ServeEngine`` session.

    ``submit()`` registers requests (future arrivals are held until the
    clock reaches them — trace replay); ``step()`` runs one scheduling
    round; ``run_until_idle()`` drains everything.  All decisions are
    logged to ``events`` — the deterministic replay record the simulation
    suite compares run-to-run."""

    def __init__(self, engine, *, clock=None, costs=None, quantum: int = 1,
                 preempt: bool = True, key=None, telemetry=None):
        if engine.spec is not None:
            raise NotImplementedError(
                "the scheduler drives plain decode rounds; speculative "
                "serve() remains a batch mode")
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        self.engine = engine
        self.clock = VirtualClock() if clock is None else clock
        self.costs = StepCosts() if costs is None else costs
        self.quantum = int(quantum)
        self.preempt_enabled = bool(preempt)
        self.st = engine.sched_state(key)
        self.slots: list[RequestHandle | None] = [None] * engine.max_batch
        self.pending: list[tuple] = []       # (arrival, rid) future heap
        self.ready: list[tuple] = []         # (-priority, arrival, rid)
        self.blobs: dict[int, object] = {}   # rid -> SwapBlob (preempted)
        self.handles: dict[int, RequestHandle] = {}
        self.events: list[tuple] = []        # (t, kind, rid) replay log
        self.n_preemptions = 0
        self.n_pages_swapped_out = 0         # data pages preemption moved
        self.n_pages_swapped_in = 0          # data pages restore moved back
        self._seq = 0
        self._admits = 0
        # the telemetry registry (serving/telemetry.py, DESIGN.md §13);
        # None = the zero-cost null object.  The scheduler owns the clock,
        # so it binds the tracer and wires the engine's subsystems here —
        # unconditionally, so a re-used engine's counters always point at
        # THIS session's registry (or the null object when disabled).
        self.telemetry = NULL_TELEMETRY if telemetry is None else telemetry
        engine.telemetry = self.telemetry
        if self.telemetry.enabled:
            self.telemetry.bind_clock(self.clock)
            self.telemetry.attach_engine(engine)

    # --- submission ----------------------------------------------------------

    def submit(self, prompt, max_new: int, *, priority: int = 0,
               arrival: float | None = None, slo_ttft: float | None = None,
               slo_tpot: float | None = None, on_token=None,
               allow_past_arrival: bool = False) -> RequestHandle:
        """Register one request.  ``arrival`` defaults to now; a future
        arrival is held back until the clock reaches it.  Raises
        immediately for a request that could never fit the engine.

        ``allow_past_arrival`` is the fleet-router path (serving/fleet.py):
        a router that routes a request the moment the clock reaches its
        arrival may hand it over slightly AFTER that instant (a replica's
        decode round advanced the shared clock first), and the handle must
        keep the ORIGINAL arrival so TTFT spans the routing delay.  A past
        arrival is harvested on the next round; for direct users it stays
        an error."""
        self.engine.sched_check(prompt, max_new)
        t = self.clock.now() if arrival is None else float(arrival)
        if t < self.clock.now() and not allow_past_arrival:
            raise ValueError(
                f"arrival {t} is in the past (now={self.clock.now()})")
        h = RequestHandle(self, self._seq, prompt, max_new,
                          priority=priority, arrival=t, slo_ttft=slo_ttft,
                          slo_tpot=slo_tpot, on_token=on_token)
        self._seq += 1
        self.handles[h.rid] = h
        heapq.heappush(self.pending, (t, h.rid))
        self._log("submit", h.rid)
        if self.telemetry.enabled:
            self.telemetry.count("sched.submitted")
            self.telemetry.instant("requests", h.rid, "submit")
        return h

    # --- internals -----------------------------------------------------------

    def _log(self, kind: str, rid: int) -> None:
        self.events.append((round(self.clock.now(), 9), kind, rid))

    def _harvest(self) -> None:
        now = self.clock.now()
        while self.pending and self.pending[0][0] <= now:
            _, rid = heapq.heappop(self.pending)
            h = self.handles[rid]
            heapq.heappush(self.ready, (-h.priority, h.arrival, rid))
            self._log("arrive", rid)
            if self.telemetry.enabled:
                self.telemetry.count("sched.arrivals")
                self.telemetry.instant("requests", rid, "arrive")
                self.telemetry.open_span("requests", rid, "queued")

    def next_arrival(self) -> float | None:
        return self.pending[0][0] if self.pending else None

    @property
    def running(self) -> list[RequestHandle]:
        return [h for h in self.slots if h is not None]

    def _free_slot(self) -> int | None:
        for b, h in enumerate(self.slots):
            if h is None:
                return b
        return None

    def _emit(self, h: RequestHandle, ts) -> None:
        now = self.clock.now()
        for t in ts:
            if h.first_token_at is None:
                h.first_token_at = now
            h.tokens.append(int(t))
            if h.on_token is not None:
                h.on_token(h, int(t), now)

    def _finish(self, slot: int) -> None:
        h = self.slots[slot]
        self.engine.sched_release(self.st, slot)
        self.slots[slot] = None
        h.slot = None
        h.state = FINISHED
        h.finished_at = self.clock.now()
        self._log("finish", h.rid)
        tel = self.telemetry
        if tel.enabled:
            tel.count("sched.finished")
            tel.close_span("requests", h.rid, "running")
            tel.instant("requests", h.rid, "finish")
            if h.slo_ttft is not None or h.slo_tpot is not None:
                tel.count("sched.slo_hits" if h.slo_met()
                          else "sched.slo_misses")

    # --- placement + preemption ----------------------------------------------

    def _place(self, h: RequestHandle, slot: int) -> bool:
        """Admit (fresh) or swap in (preempted) ``h`` into ``slot``."""
        eng = self.engine
        tel = self.telemetry
        if h.rid in self.blobs:
            blob = self.blobs[h.rid]
            t0 = self.clock.now()
            if not eng.sched_swap_in(self.st, slot, blob):
                return False
            del self.blobs[h.rid]
            self.clock.advance(self.costs.swap_page * blob.n_pages)
            self.n_pages_swapped_in += blob.n_pages
            self._log("resume", h.rid)
            if tel.enabled:
                tel.count("sched.resumes")
                tel.count("sched.pages_swapped_in", blob.n_pages)
                tel.count("sched.swap_bytes_in", _blob_bytes(blob))
                tel.span("slots", slot, "swap_in", t0, self.clock.now())
                tel.close_span("requests", h.rid, "swapped")
                tel.open_span("requests", h.rid, "running")
                tel.instant("requests", h.rid, "resume")
        else:
            t0 = self.clock.now()
            first = eng.sched_admit(self.st, slot, h.prompt, h.max_new)
            if first is None:
                return False
            self.clock.advance(self.costs.prefill_token * len(h.prompt))
            if h.admitted_at is None:
                h.admitted_at = self.clock.now()
            self._log("admit", h.rid)
            if tel.enabled:
                tel.count("sched.admissions")
                tel.span("slots", slot, "prefill", t0, self.clock.now())
                tel.close_span("requests", h.rid, "queued")
                tel.open_span("requests", h.rid, "running")
                tel.instant("requests", h.rid, "admit")
            self._emit(h, [first])           # prefill samples token #1
        h.state = RUNNING
        h.slot = slot
        h._admit_seq = self._admits
        self._admits += 1
        self.slots[slot] = h
        if len(h.tokens) >= h.max_new:       # max_new=1: done on arrival
            self._finish(slot)
        return True

    def _reclaim_reaches(self, h: RequestHandle) -> bool:
        """Upper-bound check before paged preemption: could evicting
        EVERY strictly-lower-priority victim possibly cover ``h``'s page
        reservation?  If not, swapping victims out is futile — the head
        waits instead of paying swap costs for nothing.  (Worst-case
        demand: prefix-cache hits can only lower it.)"""
        pool = self.engine.pool
        need = (self.blobs[h.rid].reserve if h.rid in self.blobs
                else pool.pages_needed(len(h.prompt), h.max_new))
        avail = pool.free_claimable() + sum(
            self.st.adm[v.slot].n_live for v in self.running
            if v.priority < h.priority)
        return avail >= need

    def _pick_victim(self, below_priority: int) -> RequestHandle | None:
        """Lowest-priority, most-recently-admitted running request
        strictly below ``below_priority`` — LIFO among equals preserves
        the oldest requests' progress."""
        cands = [h for h in self.running if h.priority < below_priority]
        if not cands:
            return None
        return min(cands, key=lambda h: (h.priority, -h._admit_seq))

    def _preempt(self, victim: RequestHandle) -> None:
        slot, t0 = victim.slot, self.clock.now()
        blob = self.engine.sched_swap_out(self.st, slot)
        self.clock.advance(self.costs.swap_page * blob.n_pages)
        self.blobs[victim.rid] = blob
        self.slots[slot] = None
        victim.slot = None
        victim.state = SWAPPED
        victim.n_preempt += 1
        victim.pages_swapped_out += blob.n_pages
        self.n_preemptions += 1
        self.n_pages_swapped_out += blob.n_pages
        heapq.heappush(self.ready,
                       (-victim.priority, victim.arrival, victim.rid))
        self._log("preempt", victim.rid)
        tel = self.telemetry
        if tel.enabled:
            tel.count("sched.preemptions")
            tel.count("sched.pages_swapped_out", blob.n_pages)
            tel.count("sched.swap_bytes_out", _blob_bytes(blob))
            tel.span("slots", slot, "swap_out", t0, self.clock.now())
            tel.close_span("requests", victim.rid, "running")
            tel.open_span("requests", victim.rid, "swapped")
            tel.instant("requests", victim.rid, "preempt")

    # --- cross-scheduler migration (DESIGN.md §15) ----------------------------

    def expel(self, rid: int):
        """Remove an unfinished request so a fleet can migrate it to a
        survivor replica at drain time.  Returns ``(handle, blob)``:

        * RUNNING — swapped out first (the same bit-exact blob path as
          preemption, billed at the same per-page cost), blob returned;
        * SWAPPED — hands over the blob it already has;
        * QUEUED — leaves empty-handed (``blob=None``; nothing placed yet,
          the target just admits it fresh).

        The handle keeps its identity — original arrival/priority (it
        re-queues on the target under its ORIGINAL key), streamed tokens,
        TTFT — and is re-homed with ``adopt``.  Not counted as a
        preemption: ``sched.pages_swapped_out`` and the handle's page
        counter do grow (data really moved), ``n_preemptions`` does not."""
        h = self.handles.pop(rid)
        if h.state == FINISHED:
            raise ValueError(f"request {rid} already finished")
        tel = self.telemetry
        blob = None
        if h.state == RUNNING:
            slot, t0 = h.slot, self.clock.now()
            blob = self.engine.sched_swap_out(self.st, slot)
            self.clock.advance(self.costs.swap_page * blob.n_pages)
            self.slots[slot] = None
            h.slot = None
            h.state = SWAPPED
            h.pages_swapped_out += blob.n_pages
            self.n_pages_swapped_out += blob.n_pages
            if tel.enabled:
                tel.count("sched.pages_swapped_out", blob.n_pages)
                tel.count("sched.swap_bytes_out", _blob_bytes(blob))
                tel.span("slots", slot, "swap_out", t0, self.clock.now())
                tel.close_span("requests", rid, "running")
        else:
            if h.state == SWAPPED:
                blob = self.blobs.pop(rid)
            # QUEUED and SWAPPED both sit in a queue heap — purge the rid
            # (SWAPPED was re-queued by _preempt under its original key)
            self.ready = [e for e in self.ready if e[2] != rid]
            heapq.heapify(self.ready)
            self.pending = [e for e in self.pending if e[1] != rid]
            heapq.heapify(self.pending)
            if tel.enabled:
                tel.close_span("requests", rid,
                               "swapped" if h.state == SWAPPED else "queued")
        self._log("expel", rid)
        if tel.enabled:
            tel.count("sched.expelled")
            tel.instant("requests", rid, "expel")
        return h, blob

    def adopt(self, h: RequestHandle, blob=None) -> RequestHandle:
        """Re-home an expelled request here (the other half of ``expel``).
        The handle gains a fresh local rid and queues under its original
        (priority, arrival) key; with a blob its next placement takes the
        bit-exact ``sched_swap_in`` path instead of a fresh prefill, so
        the remaining tokens are byte-identical to never having moved."""
        self.engine.sched_check(h.prompt, h.max_new)
        h._sched = self
        h.rid = self._seq
        self._seq += 1
        h.slot = None
        self.handles[h.rid] = h
        if blob is not None:
            h.state = SWAPPED
            self.blobs[h.rid] = blob
        else:
            h.state = QUEUED
        heapq.heappush(self.ready, (-h.priority, h.arrival, h.rid))
        self._log("adopt", h.rid)
        tel = self.telemetry
        if tel.enabled:
            tel.count("sched.adopted")
            tel.instant("requests", h.rid, "adopt")
            tel.open_span("requests", h.rid,
                          "swapped" if blob is not None else "queued")
        return h

    def _admit_ready(self) -> int:
        """Place queue heads until one blocks (strict head-of-line).
        A blocked head may preempt strictly-lower-priority victims, one
        at a time, until it fits or no victims remain."""
        placed = 0
        while self.ready:
            _, _, rid = self.ready[0]
            h = self.handles[rid]
            while True:
                slot = self._free_slot()
                if slot is not None and self._place(h, slot):
                    heapq.heappop(self.ready)
                    placed += 1
                    break
                victim = (self._pick_victim(h.priority)
                          if self.preempt_enabled else None)
                if victim is None:
                    return placed            # head-of-line wait
                if (slot is not None and self.engine.paged
                        and not self._reclaim_reaches(h)):
                    return placed            # pages blocked; eviction
                self._preempt(victim)        # can't reach — don't thrash
        return placed

    # --- the loop ------------------------------------------------------------

    def step(self, more_arrivals: bool = False) -> bool:
        """One scheduling round: harvest arrivals, admit (preempting if
        needed), decode one quantum, stream new tokens, harvest
        finishers.  Returns False once fully idle (nothing pending,
        queued, or in flight).

        ``more_arrivals``: the caller (a fleet, serving/fleet.py) still
        has traffic or clock advances to inject from OUTSIDE this
        scheduler.  A round that makes no progress with a non-empty
        queue then returns False instead of raising — a higher-priority
        arrival may yet become the head and unblock placement — and the
        caller owns starvation detection once its traffic runs out."""
        tel = self.telemetry
        t_round0 = self.clock.now()
        self._harvest()
        placed = self._admit_ready()
        if tel.enabled:
            tel.observe("sched.queue_depth", len(self.ready))
            # counter-track samples (Perfetto "C" events): the load curves
            # beside the lifecycle spans, stamped by the virtual clock
            tel.counter("sched.queue_depth", len(self.ready))
            if getattr(self.engine, "paged", False):
                tel.counter("pool.pressure", self.engine.pool.pressure())
        t_dec0 = self.clock.now()
        toks, done = self.engine.serve_step(self.st, self.quantum)
        if toks:
            # a round is as long as its longest slot actually decoded —
            # slots can retire mid-quantum, and billing the full quantum
            # would inflate TPOT/makespan deterministically
            self.clock.advance(self.costs.decode_step
                               * max(len(t) for t in toks.values()))
            if tel.enabled:
                for slot in sorted(toks):
                    tel.span("slots", slot, "decode", t_dec0,
                             self.clock.now())
                tel.counter("engine.batch_occupancy", len(toks))
                if getattr(self.engine, "probes", False):
                    # §14 numerics as counter tracks — small (L,) device
                    # reads per round, sampled only when telemetry is on
                    num = self.engine.numerics()
                    if num.get("sat_rate"):
                        tel.counter("numerics.sat_rate_max",
                                    max(num["sat_rate"]))
                    if num.get("headroom_bits"):
                        tel.counter("numerics.headroom_bits_min",
                                    min(num["headroom_bits"]))
                    if num.get("kv_err_max"):
                        tel.counter("numerics.kv_err_max",
                                    max(num["kv_err_max"]))
            for slot in sorted(toks):
                self._emit(self.slots[slot], toks[slot])
        for slot in done:
            self._finish(slot)
        if placed or toks or done:
            if tel.enabled:
                tel.count("sched.rounds")
                tel.span("sched", 0, "round", t_round0, self.clock.now())
            return True
        nxt = self.next_arrival()
        if nxt is not None:                  # idle-jump to the next event
            self.clock.advance(nxt - self.clock.now())
            if tel.enabled:
                tel.instant("sched", 0, "idle_jump")
            return True
        if not (self.ready or self.running):
            return False
        if more_arrivals:
            return False                     # the caller has more to inject
        raise RuntimeError(
            "scheduler stalled: admission blocked with no request in "
            "flight and no future arrivals")

    def run_until_idle(self, max_rounds: int = 1_000_000) -> None:
        """Drive rounds until every submitted request has finished."""
        for _ in range(max_rounds):
            if not self.step():
                return
        raise RuntimeError(f"not idle after {max_rounds} rounds — "
                           "starvation or a stuck request")

    # --- introspection (the deterministic replay record) ---------------------

    @property
    def admission_order(self) -> list[int]:
        return [rid for _, kind, rid in self.events if kind == "admit"]

    @property
    def preemption_log(self) -> list[tuple]:
        return [(t, rid) for t, kind, rid in self.events
                if kind == "preempt"]
