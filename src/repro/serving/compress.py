"""Serving-side weight compression: dense params → codebook-index form.

This is the deployment artifact of the paper's §4 on TPU: every clustered
matrix is replaced in-place by ``{'w_idx': intN, 'codebook': f32[|W|]}``;
``models.layers.dense`` (and the embedding lookup) dispatch on that
structure, so the *same* model code serves both representations.  HBM
weight bytes drop by itemsize(f32→int8/int16) ≈ 2–4× (vs bf16), which is
the §Roofline memory-term win for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import clustering
from repro.core.quantizer import WeightQuantConfig, QuantizerState, param_filter

__all__ = ["to_codebook_params", "index_dtype_for"]


def index_dtype_for(n_weights: int):
    if n_weights <= 256:
        return jnp.int8
    if n_weights <= 65536:
        return jnp.int16
    return jnp.int32


def to_codebook_params(params, cfg: WeightQuantConfig, state: QuantizerState,
                       min_size: int = 4096,
                       stacked_prefixes=("blocks", "enc_blocks")):
    """Convert every clustered ≥2-D tensor to index form.

    Tensors below ``min_size`` (norm scales, biases, small vectors) stay
    dense — their indices would cost more than they save.  Requires a
    codebook (cluster_params must have run at least once).

    Leaves under ``stacked_prefixes`` carry a leading layer dim consumed by
    the lax.scan over layers; their codebook is tiled to (L, |W|) so the
    scan slices a (identical) per-layer copy — 4·L·|W| bytes of duplication,
    noise next to the index planes.
    """
    if not state.codebooks:
        raise ValueError("no codebook; run cluster_params first")
    keep = param_filter(cfg)
    idt = index_dtype_for(cfg.num_weights)

    def visit(path_parts, leaf):
        path = "/".join(path_parts)
        tail = path_parts[-1] if path_parts else ""
        if tail not in ("w", "table") or leaf.ndim < 2 or leaf.size < min_size \
                or not keep(path):
            return None  # unchanged
        book = state.codebooks.get("" if cfg.scope == "global" else path)
        if book is None:
            return None
        idx = clustering.assign_to_centers(
            leaf.astype(jnp.float32).reshape(-1), book).reshape(leaf.shape)
        book = jnp.asarray(book)
        if path_parts[0] in stacked_prefixes:
            book = jnp.broadcast_to(book[None], (leaf.shape[0],) + book.shape)
        return {"w_idx": idx.astype(idt), "codebook": book}

    def walk(node, parts):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if isinstance(v, dict):
                    out[k] = walk(v, parts + [k])
                else:
                    rep = visit(parts + [k], v)
                    if rep is not None and k == "w":
                        # replace the whole {'w': ...} entry with index form
                        return {**{kk: vv for kk, vv in node.items()
                                   if kk != "w"}, **rep}
                    if rep is not None and k == "table":
                        return {**{kk: vv for kk, vv in node.items()
                                   if kk != "table"}, **rep}
                    out[k] = v
            return out
        return node

    return walk(params, [])
