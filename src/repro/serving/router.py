"""Fleet routing policy: prefix- and load-aware replica choice
(DESIGN.md §15).

``FleetRouter`` picks which of N replicas receives each request.  It
sees replicas only through small probe objects (load, free pages,
prefix-match length), so the policy is testable over stub engines
(tests/test_fleet.py) and the fleet facade (serving/fleet.py) just wires
real ``AsyncScheduler``/``PagePool`` probes in.

Policy — deterministic and replica-order-independent by construction:

* **prefix** (default): score every admitting replica by
  ``(prefix pages already cached, -unfinished load, free pages)`` and
  take the maximum; the prefix length uses the PagePool's own
  content-addressed hash chain (``kvcache.chain_keys``), so a predicted
  hit is exactly an admit-time hit.  Ties fall to the lexicographically
  smallest replica id.
* **round_robin**: cycle through admitting replicas in sorted-id order
  — the baseline the prefix policy is benchmarked against
  (benchmarks/serve_throughput.py ``bench_fleet``).

Candidates are always enumerated in sorted-id order, never dict
insertion order, so a fleet constructed with its replicas permuted
routes identically — the acceptance property tests/test_fleet.py pins.

**Drain** removes a replica from the candidate set without touching its
queue: in-flight and already-queued requests finish (or swap out and
resume) on the replica itself; only NEW routes skip it.  **Scale-up**
(``add``) makes a replica a candidate immediately.  The virtual-clock
rule applies here as everywhere under ``serving/``: nothing reads the
wall, so route decisions replay bit-identically.
"""

from __future__ import annotations

__all__ = ["FleetRouter", "POLICIES"]

POLICIES = ("prefix", "round_robin")


class FleetRouter:
    """Replica chooser over probe objects.

    A probe must expose ``load()`` (unfinished requests assigned),
    ``free_pages()`` (claimable capacity), and
    ``prefix_match_pages(tokens)`` (leading prompt pages the replica's
    pool already holds).  ``serving/fleet.py.ReplicaProbe`` adapts the
    real engine stack; tests drive stubs."""

    def __init__(self, policy: str = "prefix"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {POLICIES}")
        self.policy = policy
        self.probes: dict[str, object] = {}
        self.draining: set[str] = set()
        self.n_routed = 0                    # doubles as the RR cursor

    # --- membership ----------------------------------------------------------

    def add(self, rep: str, probe) -> None:
        if rep in self.probes:
            raise ValueError(f"replica {rep!r} already registered")
        self.probes[rep] = probe

    def drain(self, rep: str) -> None:
        """Stop routing to ``rep``.  Its queued/running requests are
        untouched — the replica drains itself."""
        if rep not in self.probes:
            raise KeyError(f"unknown replica {rep!r}")
        self.draining.add(rep)

    @property
    def admitting(self) -> list[str]:
        """Routable replica ids, in the canonical (sorted) order every
        routing decision iterates."""
        return [r for r in sorted(self.probes) if r not in self.draining]

    # --- the decision --------------------------------------------------------

    def route(self, prompt) -> str:
        """Choose the replica for one request's prompt."""
        reps = self.admitting
        if not reps:
            raise RuntimeError("no admitting replica (all drained?)")
        if self.policy == "round_robin":
            rep = reps[self.n_routed % len(reps)]
        else:
            # max() keeps the FIRST maximum, and reps is sorted, so full
            # ties deterministically fall to the smallest replica id.
            rep = max(reps, key=lambda r: (
                self.probes[r].prefix_match_pages(prompt),
                -self.probes[r].load(),
                self.probes[r].free_pages()))
        self.n_routed += 1
        return rep
