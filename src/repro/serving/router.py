"""Fleet routing policy: prefix- and load-aware replica choice, plus
admission backpressure (DESIGN.md §15).

``FleetRouter`` picks which of N replicas receives each request.  It
sees replicas only through small probe objects (load, free pages,
prefix-match length, pressure), so the policy is testable over stub
engines (tests/test_fleet.py) and the fleet facade (serving/fleet.py)
just wires real ``AsyncScheduler``/``PagePool`` probes in.

Policy — deterministic and replica-order-independent by construction:

* **prefix** (default): score every admitting replica by
  ``(prefix pages already cached, -unfinished load, free pages)`` and
  take the maximum; the prefix length uses the PagePool's own
  content-addressed hash chain (``kvcache.chain_keys``), so a predicted
  hit is exactly an admit-time hit.  Ties fall to the lexicographically
  smallest replica id.
* **round_robin**: cycle through admitting replicas in sorted-id order
  — the baseline the prefix policy is benchmarked against
  (benchmarks/serve_throughput.py ``bench_fleet``).

The round-robin cursor is *membership-aware*: it remembers the last
replica id handed work and advances to the next admitting id above it
(wrapping), so a drain or scale-up mid-rotation keeps the cycle fair.
(The original cursor was ``n_routed % len(reps)`` — a raw route count
surviving membership changes, which skewed the modulo after any drain or
scale and could starve a replica indefinitely.)  ``n_routed`` is now a
pure statistics counter.

Candidates are always enumerated in sorted-id order, never dict
insertion order, so a fleet constructed with its replicas permuted
routes identically — the acceptance property tests/test_fleet.py pins.

**Drain** removes a replica from the candidate set without touching its
queue; only NEW routes skip it (with ``Fleet(migrate_on_drain=True)``
the fleet additionally expels its unfinished requests and re-routes them
here).  **Scale-up** (``add``) makes a replica a candidate immediately.

**Backpressure** (``decide``): instead of queueing unboundedly, an
arrival can be *deferred* (left at the head of the fleet's pending heap
and retried next round) or *shed* (rejected outright) when every
admitting replica is over the pressure threshold.  ``shed_policy``
selects who sheds: ``"none"`` (default — route regardless, the pre-§15
behavior), ``"defer"`` (nobody sheds, everyone waits out the pressure),
``"slo"`` (requests carrying an SLO shed — they would blow their targets
queueing behind a saturated fleet anyway, so fail fast and let best-
effort work wait), ``"all"`` (every arrival sheds under pressure).  An
empty admitting set always defers — a mid-trace arrival between a drain
and a later scale-up waits for the new replica instead of killing the
replay.  The virtual-clock rule applies here as everywhere under
``serving/``: nothing reads the wall, so decisions replay
bit-identically.
"""

from __future__ import annotations

__all__ = ["FleetRouter", "POLICIES", "SHED_POLICIES"]

POLICIES = ("prefix", "round_robin")
SHED_POLICIES = ("none", "defer", "slo", "all")


class FleetRouter:
    """Replica chooser over probe objects.

    A probe must expose ``load()`` (unfinished requests assigned),
    ``free_pages()`` (claimable capacity), ``prefix_match_pages(tokens)``
    (leading prompt pages the replica's pool already holds), and
    ``pressure()`` (0.0 idle → 1.0 admission blocked).
    ``serving/fleet.py.ReplicaProbe`` adapts the real engine stack;
    tests drive stubs."""

    def __init__(self, policy: str = "prefix", *,
                 shed_policy: str = "none", shed_threshold: float = 0.95):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {POLICIES}")
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"unknown shed policy {shed_policy!r}; "
                             f"choose from {SHED_POLICIES}")
        self.policy = policy
        self.shed_policy = shed_policy
        self.shed_threshold = float(shed_threshold)
        self.probes: dict[str, object] = {}
        self.draining: set[str] = set()
        self.n_routed = 0                    # statistics only, NOT a cursor
        self.n_shed = 0
        self._rr_last: str | None = None     # membership-aware RR cursor

    # --- membership ----------------------------------------------------------

    def add(self, rep: str, probe) -> None:
        if rep in self.probes:
            raise ValueError(f"replica {rep!r} already registered")
        self.probes[rep] = probe

    def drain(self, rep: str) -> None:
        """Stop routing to ``rep``.  Its queued/running requests are
        untouched — the replica drains itself (or the fleet migrates
        them; see ``Fleet.drain``)."""
        if rep not in self.probes:
            raise KeyError(f"unknown replica {rep!r}")
        self.draining.add(rep)

    @property
    def admitting(self) -> list[str]:
        """Routable replica ids, in the canonical (sorted) order every
        routing decision iterates."""
        return [r for r in sorted(self.probes) if r not in self.draining]

    # --- the decision --------------------------------------------------------

    def pressure(self) -> float:
        """The fleet-is-full signal the shed gate thresholds: the MINIMUM
        pressure over admitting replicas (the least-loaded candidate is
        where a route would land; shedding is justified only when even it
        is saturated).  1.0 when nothing admits."""
        reps = self.admitting
        if not reps:
            return 1.0
        return min(float(self.probes[r].pressure()) for r in reps)

    def decide(self, prompt, *, has_slo: bool = False):
        """Admission decision for one arrival: ``("route", rep)``,
        ``("defer", None)`` (leave it pending, retry next round) or
        ``("shed", None)`` (reject it outright).  See the module
        docstring for the shed-policy semantics."""
        if not self.admitting:
            return ("defer", None)
        if (self.shed_policy != "none"
                and self.pressure() >= self.shed_threshold):
            if self.shed_policy == "all" or (self.shed_policy == "slo"
                                             and has_slo):
                self.n_shed += 1
                return ("shed", None)
            return ("defer", None)
        return ("route", self.route(prompt))

    def route(self, prompt) -> str:
        """Choose the replica for one request's prompt.  Raises when
        nothing admits — callers that can wait use ``decide``, which
        defers instead."""
        reps = self.admitting
        if not reps:
            raise RuntimeError("no admitting replica (all drained?)")
        if self.policy == "round_robin":
            rep = reps[0]
            if self._rr_last is not None:
                for r in reps:
                    if r > self._rr_last:
                        rep = r
                        break
            self._rr_last = rep
        else:
            # max() keeps the FIRST maximum, and reps is sorted, so full
            # ties deterministically fall to the smallest replica id.
            rep = max(reps, key=lambda r: (
                self.probes[r].prefix_match_pages(prompt),
                -self.probes[r].load(),
                self.probes[r].free_pages()))
        self.n_routed += 1
        return rep
