"""Serving-side numerics observability (DESIGN.md §14).

The in-graph collection machinery lives in ``repro.kernels.probes`` (it
must be importable from ``models/`` without touching ``serving/``); this
module is the host-side half the engine and telemetry stack consume:

* ``static_index_audit`` — one-time host scan of the *immutable* weight
  index planes: how many ids are stored negative (narrow signed dtypes;
  resolved by the gathers' Python-style wrap) and how many remain
  outside ``[0, |W|)`` even after the wrap — the addresses the kernels'
  ``mode="clip"`` gathers would silently pin.  ``w_idx`` never changes
  at runtime, so auditing it per decode step would be pure waste; the
  engine checks it once at init and reports the counts alongside the
  dynamic counters.
* ``summarize`` — turn raw device counters into the canonical
  ``numerics`` snapshot section: per-layer saturation rates, int32
  accumulator headroom in bits, KV round-trip max/mean error, matmul
  call counts, page-table OOB totals, and the static index audit.
* ``golden_entry`` / ``sentinel_check`` — the drift-sentinel policy:
  what ``GOLDEN_UPDATE=1`` commits to ``tests/golden_numerics.json`` for
  each golden scenario, and how a fresh measurement is compared against
  those committed bounds (exact equality for static integer counts,
  bounded drift for measured floats, hard floors for safety margins —
  accumulator headroom must stay > 0 bits everywhere, which is the
  runtime validation of ``choose_scale``'s static no-overflow pick).

No wall clocks here: everything is either pure arithmetic on counters
or a deterministic walk of a params pytree.
"""

from __future__ import annotations

import math

import jax
import numpy as np

# Re-export the functional core so serving code (and tests) can treat
# this module as the single probes entry point.
from repro.kernels.probes import (  # noqa: F401
    MAXES, PER_LAYER, SCALARS, active, bump, init_state, layer, record,
    tap_act, tap_kv, tap_matmul,
)

INT32_MAX = float(2**31 - 1)

#: headroom reported for layers whose accumulator never moved — the full
#: signed-int32 magnitude budget.
FULL_HEADROOM_BITS = 31.0

__all__ = ["static_index_audit", "summarize", "golden_entry",
           "sentinel_check", "run_golden_scenarios", "init_state", "layer",
           "bump", "record", "active", "tap_act", "tap_kv", "tap_matmul",
           "INT32_MAX", "FULL_HEADROOM_BITS", "PER_LAYER", "MAXES",
           "SCALARS", "GOLDEN_PROMPTS", "GOLDEN_MAX_NEW",
           "GOLDEN_SCENARIOS"]


def static_index_audit(params) -> dict:
    """Count weight-index ids the kernels' gathers canonicalize.

    Walks every ``{"w_idx", "codebook"}`` site in the params tree (the
    shape the compression pass emits and ``dispatch``/``layers.dense``
    route on).  ``widx_neg`` counts ids *stored* negative — legitimate
    for narrow signed dtypes (|W|=256 in int8 stores ids ≥ 128 as
    negatives) and resolved by the gathers' Python-style wrap
    (``id + |W|``).  ``widx_oob`` counts ids still outside ``[0, |W|)``
    *after* that wrap — genuinely bad addresses the clip-mode gathers
    would silently pin.  Returns ``{"widx_neg", "widx_oob",
    "widx_total"}`` as plain ints.
    """
    neg = oob = total = 0

    def walk(node) -> None:
        nonlocal neg, oob, total
        if not isinstance(node, dict):
            return
        if "w_idx" in node and "codebook" in node:
            w = np.asarray(jax.device_get(node["w_idx"])).astype(np.int64)
            if np.issubdtype(np.asarray(node["w_idx"]).dtype, np.integer):
                n_w = int(np.asarray(node["codebook"]).shape[-1])
                canon = np.where(w < 0, w + n_w, w)
                neg += int((w < 0).sum())
                oob += int(((canon < 0) | (canon >= n_w)).sum())
                total += int(w.size)
        for v in node.values():
            walk(v)

    walk(params)
    return {"widx_neg": neg, "widx_oob": oob, "widx_total": total}


def summarize(state: dict, *, audit: dict | None = None,
              backend: str = "dense") -> dict:
    """Raw device counters -> the canonical ``numerics`` section.

    Derived series: ``sat_rate`` (clipped elements / elements seen),
    ``headroom_bits`` (log2(INT32_MAX / acc_max), capped at the full
    31-bit budget when a layer's accumulator never moved), and
    ``kv_err_mean``.  Floats are canonicalized by the telemetry
    ``snapshot()``; values here are plain Python numbers.
    """
    if not state:
        return {}
    host = jax.device_get(state)
    sat = [float(v) for v in host["act_sat"]]
    tot = [float(v) for v in host["act_total"]]
    acc = [float(v) for v in host["acc_max"]]
    kv_sum = [float(v) for v in host["kv_err_sum"]]
    kv_cnt = [float(v) for v in host["kv_err_cnt"]]
    headroom = [min(FULL_HEADROOM_BITS, math.log2(INT32_MAX / a))
                if a > 0.0 else FULL_HEADROOM_BITS for a in acc]
    out = {
        "backend": backend,
        "tokens": float(host["tokens"]),
        "page_oob": float(host["page_oob"]),
        "matmul_calls": [float(v) for v in host["matmul_calls"]],
        "act_sat": sat,
        "act_total": tot,
        "sat_rate": [s / t if t > 0.0 else 0.0 for s, t in zip(sat, tot)],
        "acc_max": acc,
        "headroom_bits": headroom,
        "kv_err_max": [float(v) for v in host["kv_err_max"]],
        "kv_err_mean": [s / c if c > 0.0 else 0.0
                        for s, c in zip(kv_sum, kv_cnt)],
    }
    out.update(audit or {})
    return out


# --- drift sentinels ---------------------------------------------------------
#
# Bounds policy (committed via GOLDEN_UPDATE=1 into golden_numerics.json):
#   * static integer counts (widx_*, page_oob) must match EXACTLY — the
#     index planes are deterministic artifacts of the compression seed;
#   * measured floats (saturation rate, KV error) may drift by the
#     platform slack below — XLA reduction order differs across
#     backends/ISAs — but never past 1.25x + an absolute epsilon;
#   * accumulator headroom has a hard floor at > 0 bits (overflow margin
#     exists at all) and may not fall more than 1 bit below golden.

SAT_RATE_SLACK = 1.25
SAT_RATE_EPS = 2e-3
KV_ERR_SLACK = 1.25
KV_ERR_EPS = 1e-4
HEADROOM_DROP_BITS = 1.0

#: The golden sentinel scenarios: one fixed prompt set served through
#: every backend × cache-mode combination (int8 pages on the paged rows
#: so the KV round-trip probe sees real quantization).  ONE definition
#: shared by tests/test_probes.py (which blesses golden_numerics.json)
#: and benchmarks/serve_throughput.py --smoke (which gates against it) —
#: the counters only compare when the scenarios match exactly.
GOLDEN_PROMPTS = [[1, 2, 3], [4, 5, 6, 7], [9, 10]]
GOLDEN_MAX_NEW = 6
GOLDEN_SCENARIOS = {
    "dense/contig": ("dense", {}),
    "dense/paged": ("dense",
                    {"paged": True, "page_size": 8, "kv_dtype": "int8"}),
    "codebook/contig": ("codebook", {}),
    "codebook/paged": ("codebook",
                       {"paged": True, "page_size": 8, "kv_dtype": "int8"}),
    "lut/contig": ("lut", {}),
    "lut/paged": ("lut",
                  {"paged": True, "page_size": 8, "kv_dtype": "int8"}),
}


def run_golden_scenarios(model, params, cparams) -> dict:
    """Serve the golden prompts through every sentinel scenario with
    probes on; returns ``{scenario: numerics}``.  The engine import is
    deferred — ``engine.py`` imports this module at load time."""
    from repro.serving.engine import ServeEngine

    out = {}
    for name, (be, kw) in GOLDEN_SCENARIOS.items():
        p = params if be == "dense" else cparams
        eng = ServeEngine(model, p, max_len=48, max_batch=2, backend=be,
                          probes=True, **kw)
        eng.serve(GOLDEN_PROMPTS, max_new=GOLDEN_MAX_NEW)
        out[name] = eng.numerics()
    return out


def golden_entry(num: dict) -> dict:
    """What one golden scenario commits: worst-layer summaries + the
    exact static index counts."""
    return {
        "sat_rate_max": max(num.get("sat_rate") or [0.0]),
        "headroom_bits_min": min(num.get("headroom_bits")
                                 or [FULL_HEADROOM_BITS]),
        "kv_err_max": max(num.get("kv_err_max") or [0.0]),
        "widx_neg": int(num.get("widx_neg", 0)),
        "widx_oob": int(num.get("widx_oob", 0)),
        "tokens": float(num.get("tokens", 0.0)),
    }


def sentinel_check(num: dict, golden: dict | None) -> list:
    """Compare a fresh numerics snapshot against committed bounds.

    Returns a list of human-readable failure strings; empty means the
    sentinels pass.  ``golden`` None/empty fails loudly — a missing
    entry means the scenario was never blessed.
    """
    if not num:
        return ["empty numerics snapshot (probes not enabled?)"]
    if not golden:
        return ["no golden entry committed for this scenario "
                "(run with GOLDEN_UPDATE=1 to bless it)"]
    fails = []
    for k in ("widx_neg", "widx_oob"):
        if int(num.get(k, 0)) != int(golden.get(k, 0)):
            fails.append(f"{k}: measured {num.get(k, 0)} != "
                         f"golden {golden.get(k, 0)} (static counts must "
                         f"match exactly)")
    if float(num.get("page_oob", 0.0)) != 0.0:
        fails.append(f"page_oob: {num['page_oob']} page-table ids outside "
                     f"[0, n_pages) (expected 0)")

    sat = max(num.get("sat_rate") or [0.0])
    sat_bound = golden.get("sat_rate_max", 0.0) * SAT_RATE_SLACK + SAT_RATE_EPS
    if sat > sat_bound:
        fails.append(f"sat_rate_max: {sat:.6f} > bound {sat_bound:.6f} "
                     f"(golden {golden.get('sat_rate_max', 0.0):.6f})")

    hr = min(num.get("headroom_bits") or [FULL_HEADROOM_BITS])
    if hr <= 0.0:
        fails.append(f"headroom_bits_min: {hr:.2f} — int32 accumulator "
                     f"margin exhausted (choose_scale guarantee violated)")
    gold_hr = golden.get("headroom_bits_min")
    if gold_hr is not None and hr < gold_hr - HEADROOM_DROP_BITS:
        fails.append(f"headroom_bits_min: {hr:.2f} fell more than "
                     f"{HEADROOM_DROP_BITS} bit below golden {gold_hr:.2f}")

    kv = max(num.get("kv_err_max") or [0.0])
    kv_bound = golden.get("kv_err_max", 0.0) * KV_ERR_SLACK + KV_ERR_EPS
    if kv > kv_bound:
        fails.append(f"kv_err_max: {kv:.6g} > bound {kv_bound:.6g} "
                     f"(golden {golden.get('kv_err_max', 0.0):.6g})")
    return fails
