from repro.checkpoint.checkpointer import (save, restore, latest_step,
                                           AsyncCheckpointer)
