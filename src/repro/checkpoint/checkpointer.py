"""Checkpointing: atomic, async-capable, elastic-reshard on restore.

Layout:  <dir>/step_<n>/   manifest.json  +  one .npy per leaf
Atomicity: write into ``step_<n>.tmp`` then ``os.rename`` (restart-safe —
a crash mid-save leaves only a .tmp that restore ignores).

Elastic re-shard: leaves are stored unsharded (single-host container); on
restore the caller passes a mesh + spec tree and each leaf is device_put
with its NamedSharding — a checkpoint taken on a (16,16) mesh restores onto
(2,16,16) or onto 1 CPU device identically.  On a real multi-host cluster
the same manifest format would be backed by per-shard files; the restore
API (target specs decide placement) is the part the trainer contracts on.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_MANIFEST = "manifest.json"


def _paths_of(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for kp, _ in leaves:
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]
        names.append("__".join(parts) or "leaf")
    return names, [v for _, v in leaves], treedef


def save(directory: str, step: int, tree, extra: dict | None = None) -> str:
    """Blocking atomic save.  ``extra``: small JSON metadata (data cursor,
    quantizer codebook step, rng seed...)."""
    names, leaves, _ = _paths_of(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{i:05d}_{name[:80]}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({"name": name, "file": fn,
                                   "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, _MANIFEST))]
    return max(steps) if steps else None


def restore(directory: str, step: int, like, mesh=None, specs=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  With (mesh, specs): each leaf is placed with its
    NamedSharding (elastic re-shard).  Returns (tree, extra)."""
    from jax.sharding import NamedSharding, PartitionSpec

    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    names, like_leaves, treedef = _paths_of(like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    is_spec = lambda x: x is None or isinstance(x, PartitionSpec)
    spec_leaves = (jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
                   if specs is not None else [None] * len(names))
    out = []
    for name, like_leaf, spec in zip(names, like_leaves, spec_leaves):
        e = by_name.get(name)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(path, e["file"]))
        if tuple(arr.shape) != tuple(like_leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {like_leaf.shape}")
        if mesh is not None and spec is not None:
            out.append(jax.device_put(arr, NamedSharding(mesh, spec)))
        else:
            out.append(jax.device_put(arr.astype(like_leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training (one in flight)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra=None):
        self.wait()
        # device_get on the main thread (consistent snapshot), write async
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            save(self.directory, step, host_tree, extra)
            self._gc()
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
