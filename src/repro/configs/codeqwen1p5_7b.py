"""Config module for --arch codeqwen1.5-7b (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import codeqwen1p5_7b as config

CONFIG = config()
