"""Config module for --arch paper-autoencoder (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import paper_autoencoder as config

CONFIG = config()
