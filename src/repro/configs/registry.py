"""The 10 assigned architectures (exact figures from the brief) + paper nets.

Source tags from the assignment are kept as comments.  Every entry is a
zero-arg factory so importing this module allocates nothing.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig


def zamba2_2p7b():
    # [hybrid] 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
    # ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242; hf]
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv=32, d_ff=10240, vocab=32000, head_dim=80,
        ssm_state=64, shared_attn_every=6, rope_theta=1e4, ssm_chunk=64,
        supports_long=True, dtype="bfloat16", microbatches=4)


def qwen2_vl_7b():
    # [vlm] 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 —
    # M-RoPE, dynamic resolution [arXiv:2409.12191; hf]
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
        n_heads=28, n_kv=4, d_ff=18944, vocab=152064, head_dim=128,
        rope_theta=1e6, rope_sections=(16, 24, 24), tie_embeddings=False,
        dtype="bfloat16")


def whisper_small():
    # [audio] 12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865 —
    # enc-dec, conv frontend (stub) [arXiv:2212.04356]
    return ModelConfig(
        name="whisper-small", family="audio", n_layers=12, d_model=768,
        n_heads=12, n_kv=12, d_ff=3072, vocab=51865, head_dim=64,
        enc_layers=12, enc_len=1500, rope_theta=1e4, act_kind="gelu",
        tie_embeddings=True, dtype="bfloat16")


def qwen3_1p7b():
    # [dense] 28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936 —
    # qk_norm, GQA [hf:Qwen/Qwen3-8B]
    return ModelConfig(
        name="qwen3-1.7b", family="dense", n_layers=28, d_model=2048,
        n_heads=16, n_kv=8, d_ff=6144, vocab=151936, head_dim=128,
        qk_norm=True, rope_theta=1e6, dtype="bfloat16")


def mistral_large_123b():
    # [dense] 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768
    # [hf:mistralai/Mistral-Large-Instruct-2407]
    return ModelConfig(
        name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
        n_heads=96, n_kv=8, d_ff=28672, vocab=32768, head_dim=128,
        rope_theta=1e6, tie_embeddings=False, dtype="bfloat16",
        moments_dtype="bfloat16", microbatches=8)


def codeqwen1p5_7b():
    # [dense] 32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416 —
    # qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B]
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv=32, d_ff=13440, vocab=92416, head_dim=128,
        rope_theta=1e6, tie_embeddings=False, dtype="bfloat16",
        kv_quant=True)


def llama3p2_3b():
    # [dense] 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256 —
    # small llama3 [hf:meta-llama/Llama-3.2]
    return ModelConfig(
        name="llama3.2-3b", family="dense", n_layers=28, d_model=3072,
        n_heads=24, n_kv=8, d_ff=8192, vocab=128256, head_dim=128,
        rope_theta=5e5, dtype="bfloat16")


def grok1_314b():
    # [moe] 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
    # MoE 8e top-2 [hf:xai-org/grok-1]
    return ModelConfig(
        name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
        n_heads=48, n_kv=8, d_ff=32768, vocab=131072, head_dim=128,
        n_experts=8, top_k=2, rope_theta=1e4, tie_embeddings=False,
        dtype="bfloat16", moments_dtype="bfloat16", microbatches=16,
        moe_token_chunks=8, kv_quant=True)


def qwen3_moe_30b_a3b():
    # [moe] 48L d_model=2048 32H (GQA kv=4) d_ff=768 vocab=151936,
    # MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B]
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv=4, d_ff=768, vocab=151936, head_dim=128,
        qk_norm=True, n_experts=128, top_k=8, rope_theta=1e6,
        tie_embeddings=False, dtype="bfloat16", microbatches=4)


def rwkv6_7b():
    # [ssm] 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
    # Finch, data-dependent decay [arXiv:2404.05892]
    return ModelConfig(
        name="rwkv6-7b", family="ssm_rwkv", n_layers=32, d_model=4096,
        n_heads=64, n_kv=0, d_ff=14336, vocab=65536, rwkv_head_dim=64,
        supports_long=True, dtype="bfloat16", batch_over_model=True)


# --- the paper's own networks (benchmarks §3) --------------------------------

def paper_mnist():
    """Fully-connected MNIST classifier (paper §3.1); hidden width/depth are
    overridden by the benchmark sweep."""
    return ModelConfig(
        name="paper-mnist", family="paper", n_layers=2, d_model=64,
        n_heads=1, n_kv=1, d_ff=64, vocab=10, act_kind="tanh",
        has_decoder=False)


def paper_autoencoder():
    """Conv + FC auto-encoders (paper §3.2)."""
    return ModelConfig(
        name="paper-autoencoder", family="paper", n_layers=7, d_model=50,
        n_heads=1, n_kv=1, d_ff=50, vocab=0, act_kind="tanh",
        has_decoder=False)


def paper_alexnet():
    """AlexNet-style conv classifier (paper §3.3), scaled for CPU."""
    return ModelConfig(
        name="paper-alexnet", family="paper", n_layers=8, d_model=96,
        n_heads=1, n_kv=1, d_ff=1024, vocab=1000, act_kind="relu6",
        has_decoder=False)


CONFIGS = {
    "zamba2-2.7b": zamba2_2p7b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "whisper-small": whisper_small,
    "qwen3-1.7b": qwen3_1p7b,
    "mistral-large-123b": mistral_large_123b,
    "codeqwen1.5-7b": codeqwen1p5_7b,
    "llama3.2-3b": llama3p2_3b,
    "grok-1-314b": grok1_314b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "rwkv6-7b": rwkv6_7b,
    "paper-mnist": paper_mnist,
    "paper-autoencoder": paper_autoencoder,
    "paper-alexnet": paper_alexnet,
}

ASSIGNED = [n for n in CONFIGS if not n.startswith("paper-")]
