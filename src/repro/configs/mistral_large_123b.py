"""Config module for --arch mistral-large-123b (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import mistral_large_123b as config

CONFIG = config()
