"""Config module for --arch qwen3-1.7b (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import qwen3_1p7b as config

CONFIG = config()
