"""Config module for --arch qwen3-moe-30b-a3b (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import qwen3_moe_30b_a3b as config

CONFIG = config()
