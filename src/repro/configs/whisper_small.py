"""Config module for --arch whisper-small (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import whisper_small as config

CONFIG = config()
