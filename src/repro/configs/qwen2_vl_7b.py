"""Config module for --arch qwen2-vl-7b (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import qwen2_vl_7b as config

CONFIG = config()
