"""Config module for --arch paper-mnist (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import paper_mnist as config

CONFIG = config()
