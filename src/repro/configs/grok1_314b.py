"""Config module for --arch grok-1-314b (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import grok1_314b as config

CONFIG = config()
