"""Config module for --arch paper-alexnet (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import paper_alexnet as config

CONFIG = config()
