"""Config module for --arch rwkv6-7b (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import rwkv6_7b as config

CONFIG = config()
