"""Config module for --arch llama3.2-3b (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import llama3p2_3b as config

CONFIG = config()
