"""Config module for --arch zamba2-2.7b (see registry.py for the exact figures and source tag)."""

from repro.configs.registry import zamba2_2p7b as config

CONFIG = config()
