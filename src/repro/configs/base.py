"""ModelConfig — one dataclass describing every architecture in the pool,
plus the assigned input-shape grid (train_4k / prefill_32k / decode_32k /
long_500k).
"""

from __future__ import annotations

import dataclasses

from repro.core.quantizer import WeightQuantConfig

FAMILIES = ("dense", "moe", "vlm", "audio", "ssm_rwkv", "hybrid", "paper")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # see FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 → d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    rope_sections: tuple = ()     # M-RoPE (vlm)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity: float = 1.25
    moe_token_chunks: int = 1
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    shared_attn_every: int = 6    # zamba: shared block cadence
    rwkv_head_dim: int = 64
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_len: int = 0
    # paper technique (defaults: continuous baseline; flip for quant runs)
    act_kind: str = "silu"
    act_levels: int = 0
    wq: WeightQuantConfig = dataclasses.field(default_factory=WeightQuantConfig)
    # numerics / structure
    dtype: str = "float32"
    remat: bool = True
    tie_embeddings: bool = True
    window: int = 0               # sliding-window attention (0 = full)
    long_window: int = 8192       # window used for the long_500k cell (hybrid)
    vocab_pad: int = 256
    kv_block: int = 1024          # flash attention KV chunk
    kv_quant: bool = False        # int8 KV cache (serving; halves cache HBM)
    fsdp: bool = True             # ZeRO-3 weight storage (train); serving
                                  # paths run with fsdp=False (TP-only)
    batch_over_model: bool = False  # pure-DP: batch over (dp × model); the
                                  # right layout for sequential-scan families
                                  # (RWKV) where TP/SP only add collectives
    # capability flags
    supports_long: bool = False   # sub-quadratic decode => run long_500k
    has_decoder: bool = True
    moments_dtype: str = "float32"  # adam moment dtype (bf16 for ≥100B)
    scan_unroll: bool = False     # unroll layer scans (roofline FLOP probes)
    microbatches: int = 1         # grad-accumulation splits of the global batch

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.vocab_pad) * self.vocab_pad

    def shapes(self):
        """The assigned shape cells that apply to this architecture."""
        out = ["train_4k", "prefill_32k"]
        if self.has_decoder:
            out.append("decode_32k")
            if self.supports_long:
                out.append("long_500k")
        return out

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def quantized(self, levels: int = 32, n_weights: int = 1000,
                  method: str = "laplacian_l1") -> "ModelConfig":
        """The paper's working point: |A|=32, |W|=1000."""
        return self.replace(act_levels=levels,
                            wq=WeightQuantConfig(num_weights=n_weights,
                                                 method=method))

    def reduced(self) -> "ModelConfig":
        """CPU-smoke-size config of the same family (per brief)."""
        kw = dict(
            n_layers=4 if self.family == "hybrid" else min(self.n_layers, 2),
            shared_attn_every=2,
            d_model=128, d_ff=256, vocab=512,
            n_heads=4, n_kv=min(self.n_kv, 4) if self.n_kv else 0,
            head_dim=32, enc_len=min(self.enc_len, 16),
            enc_layers=min(self.enc_layers, 2),
            ssm_head_dim=32, rwkv_head_dim=32, ssm_chunk=16,
            kv_block=64, window=min(self.window, 64) if self.window else 0,
            long_window=64, dtype="float32", microbatches=1, moe_token_chunks=1,
        )
        if self.n_experts:
            kw.update(n_experts=min(self.n_experts, 8), top_k=min(self.top_k, 2))
        if self.rope_sections:
            kw.update(rope_sections=(4, 6, 6))  # sums to head_dim/2 = 16
        return self.replace(**kw)
