"""Architecture configs: the 10 assigned archs + the paper's own networks.

``get(name)`` returns the full production ModelConfig; ``get(name).reduced()``
the CPU-smoke-test variant of the same family.
"""

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec
from repro.configs import registry as _registry


def get(name: str) -> ModelConfig:
    return _registry.CONFIGS[name]()


def names():
    return sorted(_registry.CONFIGS)
