"""Fault tolerance for the training loop.

Mechanisms (all exercised by tests; the failure source is simulated since
the container has no real fleet):

* **checkpoint/restart** — the trainer always starts by scanning the
  checkpoint dir and resuming from the latest complete snapshot (atomic
  rename guarantees completeness).  State includes params, optimizer
  moments, quantizer codebooks, the data cursor and the RNG key, so a
  killed-and-restarted run reproduces the uninterrupted loss curve exactly.
* **failure injection** — ``FailureInjector`` raises (or hard-exits) at a
  configured step, driven by env ``REPRO_FAIL_AT_STEP`` / constructor.
* **straggler mitigation** — ``StragglerMonitor`` tracks a robust moving
  estimate of step time; steps slower than ``factor``× the median are
  counted and (policy) either logged, or — on a real fleet — would trigger
  the elastic path: checkpoint, drop the slow host from the coordination
  service, re-lower on the shrunken mesh (elastic re-shard is implemented
  in checkpoint.restore; the swap is driven by the launcher).
"""

from __future__ import annotations

import dataclasses
import os
import time

__all__ = ["FailureInjector", "StragglerMonitor", "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_step: int | None = None
    mode: str = "raise"            # 'raise' | 'exit'

    def __post_init__(self):
        env = os.environ.get("REPRO_FAIL_AT_STEP")
        if env is not None and self.fail_at_step is None:
            self.fail_at_step = int(env)

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            if self.mode == "exit":
                os._exit(42)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    warmup: int = 3
    _times: list = dataclasses.field(default_factory=list)
    stragglers: int = 0

    def observe(self, seconds: float) -> bool:
        """Record a step time; returns True if this step was a straggler."""
        self._times.append(seconds)
        if len(self._times) <= self.warmup:
            return False
        hist = sorted(self._times[:-1])
        median = hist[len(hist) // 2]
        is_straggler = seconds > self.factor * max(median, 1e-6)
        if is_straggler:
            self.stragglers += 1
        return is_straggler

    class timer:
        def __init__(self, monitor):
            self.monitor = monitor

        def __enter__(self):
            self.t0 = time.monotonic()
            return self

        def __exit__(self, *exc):
            self.seconds = time.monotonic() - self.t0
            self.straggler = self.monitor.observe(self.seconds)
            return False
