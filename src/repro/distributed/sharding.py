"""Sharding policy: parameter path → PartitionSpec, activation constraints.

Meshes (launch/mesh.py): single-pod ``('data', 'model') = (16, 16)``,
multi-pod ``('pod', 'data', 'model') = (2, 16, 16)``.

Policy (DP+FSDP over ('pod','data'), TP over 'model'):
* embeddings / lm_head: vocab over `model` (TP softmax), replicated over dp.
* attention / FFN / SSM projections: column-parallel mats shard the output
  (heads·hd or ff) dim over `model` and the input (d) dim over `data`
  (ZeRO-3 storage; XLA all-gathers at use); row-parallel mats the reverse.
* MoE expert weights: see ``repro.models.moe.moe_param_specs``.
* small vectors (norm scales, A_log, biases): replicated.
* optimizer moments: same spec as their parameter, but additionally sharded
  over `pod` where the parameter was pod-replicated (ZeRO across pods).
* activations: batch over ('pod','data'); logits vocab over `model`;
  decode KV caches: batch over dp, sequence over `model` (flash-decode
  layout — see DESIGN.md §5).
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["dp_axes", "param_specs", "serve_param_specs", "shard_act",
           "named", "cache_spec", "moments_spec"]


def dp_axes(mesh) -> tuple:
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def named(mesh, spec):
    return NamedSharding(mesh, spec)


def shard_act(x, mesh, spec=None):
    """Constrain an activation to the standard layout.

    (B, S, D) residual streams additionally shard S over `model` when it
    divides (sequence parallelism): the scan-carried per-layer residuals —
    the dominant live tensors under remat — shrink by the TP degree, and
    XLA materializes the all-gather (into attention/FFN) + reduce-scatter
    (out of them) pairs that define SP.  Decode steps (S == 1) and ragged
    shapes fall back to batch-only sharding.
    """
    if mesh is None:
        return x
    if spec is None:
        seq_ax = None
        if x.ndim == 3 and x.shape[1] % mesh.shape[_M] == 0 and x.shape[1] > 1:
            seq_ax = _M
        spec = P(dp_axes(mesh), seq_ax, *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, named(mesh, spec))


# Parameter rules: (path regex, rank) → spec builder.  F = fsdp axis.
_F = "data"
_M = "model"


def _rule(path: str, shape: tuple, fsdp: bool = True) -> P:
    r = len(shape)
    tail = path.split("/")[-1]
    anc = path
    F = _F if fsdp else None

    def wrap(spec):  # prepend Nones for stacked (layer/group) leading dims
        extra = r - len(spec)
        return P(*([None] * extra), *spec)

    # --- embeddings / heads --------------------------------------------------
    if re.search(r"(embed|lm_head|pos_embed)", anc):
        if "pos_embed" in anc:
            return wrap((None, None))
        if "lm_head" in anc:
            return wrap((F, _M))       # (d, V)
        return wrap((_M, None))        # (V, d)
    # --- MoE expert tensors (E, d, f) / (E, f, d) handled by caller ----------
    # --- norms & small vectors ------------------------------------------------
    if tail in ("scale", "bias", "A_log", "D", "dt_bias", "w0", "u", "mix", "b"):
        return P(*([None] * r))
    if "conv_w" in anc:
        return wrap((None, _M))        # (K, C): channels over model
    # --- column-parallel (input d contracted, output sharded over model) -----
    if tail in ("w", "w_idx") and re.search(
            r"(wq|wk|wv|w1|w3|wg|wr|in_proj|w_lora_a|up)", anc):
        return wrap((F, _M))
    # --- row-parallel (input sharded over model, output d) -------------------
    if tail in ("w", "w_idx") and re.search(
            r"(wo|w2|out_proj|w_lora_b|down)", anc):
        return wrap((_M, F))
    if tail == "w" and re.search(r"router", anc):
        return P(*([None] * r))
    if tail in ("w", "w_idx"):         # generic 2-D: FSDP only
        return wrap((F, None))
    return P(*([None] * r))


def param_specs(params, cfg=None, moe_cfg=None, mesh=None, fsdp=True):
    """Spec pytree matching ``params``.  MoE expert leaves are delegated."""
    from repro.models.moe import moe_param_specs

    msize = mesh.shape[_M] if mesh is not None else 1
    moe_specs = (moe_param_specs(moe_cfg, msize) if moe_cfg is not None
                 else None)

    def visit(path_parts, leaf):
        path = "/".join(path_parts)
        if moe_specs is not None and re.search(r"/(w1|w3|w2)$", "/" + path) \
                and "moe" in path:
            base = moe_specs[path_parts[-1]]
            extra = leaf.ndim - len(base)
            return P(*([None] * extra), *base)
        if path_parts[-1] == "codebook":
            return P(*([None] * leaf.ndim))
        return _rule(path, leaf.shape, fsdp)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for kp, v in leaves:
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]
        out.append(visit(parts, v))
    return jax.tree_util.tree_unflatten(treedef, out)


def serve_param_specs(params):
    """Serving-time TP placement (DESIGN.md §10): no FSDP, no DP on
    weights.  Block matmuls — dense ``w`` or integer ``w_idx`` — keep their
    column/row `model` sharding from ``_rule`` (only the *indices* shard in
    index form; ``kernels.dispatch`` shard-maps the contraction to match);
    everything else replicates: embeddings/lm_head (decode touches one row
    per token — sharding them buys bytes but costs a gather per step),
    codebooks and norm vectors (tiny by construction).
    """
    def visit(path_parts, leaf):
        path = "/".join(path_parts)
        if (path_parts[-1] in ("w", "w_idx") and leaf.ndim >= 2
                and "blocks" in path and "moe" not in path):
            spec = _rule(path, leaf.shape, fsdp=False)
            if _M in spec:
                return spec
        return P(*([None] * leaf.ndim))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for kp, v in leaves:
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]
        out.append(visit(parts, v))
    return jax.tree_util.tree_unflatten(treedef, out)


def moments_spec(spec: P, param_shape: tuple, mesh) -> P:
    """Optimizer-moment spec: param spec + ZeRO over 'pod' on the largest
    still-unsharded dim (only on multi-pod meshes)."""
    if mesh is None or "pod" not in mesh.axis_names:
        return spec
    parts = list(spec) + [None] * (len(param_shape) - len(spec))
    pod = mesh.shape["pod"]
    best, best_size = None, 0
    for i, (p, s) in enumerate(zip(parts, param_shape)):
        if p is None and s % pod == 0 and s > best_size:
            best, best_size = i, s
    if best is None:
        return spec
    parts[best] = "pod"
    return P(*parts)


def cache_spec(mesh, kind: str = "kv") -> P:
    """Decode-cache layout: (L, B, S, KV, hd) — batch over dp, S over model
    (sequence-split flash decode); SSM states: heads over model."""
    dp = dp_axes(mesh)
    if kind == "kv":
        return P(None, dp, _M, None, None)
    if kind == "ssm":                    # (L, B, H, N, P)
        return P(None, dp, _M, None, None)
    if kind == "vec":                    # (L, B, 1/K, C)
        return P(None, dp, None, _M)
    raise ValueError(kind)
