"""Distribution substrate: sharding policies, fault tolerance, gradient
compression, pipeline-parallel option."""
