"""JAX version compatibility for the sharding API surface.

The repo targets the jax 0.4.37 pin (requirements.txt) but was written
against the newer spellings; these shims accept both:

* ``make_mesh(shape, axes)`` — newer jax takes ``axis_types=(AxisType.Auto,
  ...)``; 0.4.x has neither the kwarg nor the enum (Auto is the default
  behaviour there anyway).
* ``shard_map(...)`` — top-level ``jax.shard_map`` with ``check_vma=``
  landed after 0.4.x; the older home is ``jax.experimental.shard_map``
  with the flag spelled ``check_rep=``.
"""

from __future__ import annotations

import jax

__all__ = ["make_mesh", "shard_map"]


def make_mesh(shape, axes):
    AxisType = getattr(jax.sharding, "AxisType", None)
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
