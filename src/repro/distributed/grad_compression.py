"""Codebook gradient compression for the cross-pod all-reduce.

The paper's Fig. 3/4 observation — network weight (and, empirically,
gradient) distributions are near-Laplacian — justifies reusing its §2.2
closed-form Laplacian-L1 quantizer as a *gradient codec*: 8-bit indices into
a 256-entry closed-form codebook, with error feedback (the residual is
carried into the next step, so the compression is unbiased over time).

Deployment point: DP inside a pod rides the full-precision psum that XLA
emits (ICI, cheap); the *pod* axis crosses DCN where bytes are 25–50×
more expensive — that hop is compressed 4× (f32→int8; 2× vs bf16).

``compressed_psum_tree`` is mesh-agnostic: it runs inside shard_map over
the named axis; the launcher wires it over 'pod'.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.clustering import laplacian_l1_levels

__all__ = ["lap_quantize", "lap_dequantize", "compressed_psum_tree",
           "init_error_state"]

_LEVELS = 256


def _unit_centers() -> jnp.ndarray:
    """Closed-form L1-optimal centers for a unit Laplacian, |W|=256."""
    pos = laplacian_l1_levels(_LEVELS)       # even N → positive half
    c = np.concatenate([-pos[::-1], pos])
    return jnp.asarray(np.sort(c), jnp.float32)


_UNIT = _unit_centers()


def lap_quantize(x: jnp.ndarray):
    """x (float) -> (idx uint8, mean f32, scale f32). Per-tensor statistics.

    Centers are mean ± scale·L_i with L_i the closed-form grid; ``scale`` is
    set from mean |x − a| (the Laplacian MLE of its scale parameter b), so
    the codebook needs only two scalars on the wire.
    """
    xf = x.astype(jnp.float32).reshape(-1)
    a = jnp.mean(xf)
    b = jnp.mean(jnp.abs(xf - a)) + 1e-12
    centers = a + b * _UNIT
    bounds = (centers[:-1] + centers[1:]) / 2.0
    idx = jnp.searchsorted(bounds, xf, side="right").astype(jnp.uint8)
    return idx.reshape(x.shape), a, b


def lap_dequantize(idx: jnp.ndarray, a, b):
    centers = a + b * _UNIT
    return centers[idx.astype(jnp.int32)]


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum_tree(grads, err, axis: str):
    """All-reduce ``grads`` over ``axis`` with 8-bit Laplacian codec + error
    feedback.  Must run inside shard_map where ``axis`` is manual.

    Returns (mean-reduced grads, new error state).
    """
    # jax.lax.axis_size is post-0.4.x; psum(1) is the portable spelling
    n = (jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size")
         else jax.lax.psum(1, axis))

    def one(g, e):
        v = g.astype(jnp.float32) + e
        idx, a, b = lap_quantize(v)
        q = lap_dequantize(idx, a, b).reshape(v.shape)
        new_e = v - q
        # wire format: uint8 indices + 2 scalars; all_gather then sum.
        idx_all = jax.lax.all_gather(idx, axis)          # (n, ...)
        a_all = jax.lax.all_gather(a, axis)
        b_all = jax.lax.all_gather(b, axis)
        deq = jax.vmap(lambda i, aa, bb:
                       lap_dequantize(i, aa, bb).reshape(v.shape))(
            idx_all, a_all, b_all)
        return (jnp.sum(deq, axis=0) / n).astype(g.dtype), new_e

    out = jax.tree.map(one, grads, err)
    red = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return red, new_err


def compression_ratio(param_dtype=jnp.float32) -> float:
    """Wire-bytes ratio vs uncompressed all-reduce of ``param_dtype``."""
    return jnp.dtype(param_dtype).itemsize / 1.0
