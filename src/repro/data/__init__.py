from repro.data.synthetic import (TokenPipeline, pseudo_mnist_batch,
                                  smooth_images, parabola_batch)
