"""Deterministic synthetic data pipelines (offline container — no datasets).

* ``TokenPipeline`` — LM token stream with learnable bigram/repeat structure
  (loss can fall well below uniform entropy, so training curves are
  meaningful).  Stateless per step: batch(step) is a pure function of
  (seed, step), which is what makes checkpoint/restart exact: the restored
  trainer re-reads the same cursor.
* ``pseudo_mnist_batch`` — 10 fixed smooth prototypes + jitter + noise,
  28×28, for the paper's §3.1 classification benchmark.
* ``smooth_images`` — band-limited random images for the §3.2 auto-encoder.
* ``parabola_batch`` — the §2.1 Fig. 2 toy regression.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    repeat_p: float = 0.6     # P(next token == current) — learnable structure

    def batch_at(self, step: int) -> dict:
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=step))
        toks = np.empty((self.batch, self.seq), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        rep = rng.random((self.batch, self.seq - 1)) < self.repeat_p
        fresh = rng.integers(0, self.vocab, (self.batch, self.seq - 1))
        for t in range(1, self.seq):
            toks[:, t] = np.where(rep[:, t - 1], toks[:, t - 1],
                                  fresh[:, t - 1])
        return {"tokens": jnp.asarray(toks)}


_PROTO_CACHE = {}


def _prototypes(n_classes: int, side: int, seed: int = 7):
    key = (n_classes, side, seed)
    if key not in _PROTO_CACHE:
        rng = np.random.Generator(np.random.Philox(seed))
        f = rng.normal(size=(n_classes, 4, 4))
        big = np.zeros((n_classes, side, side))
        big[:, :4, :4] = f
        proto = np.real(np.fft.ifft2(big, axes=(1, 2)))
        proto = proto / (np.abs(proto).max(axis=(1, 2), keepdims=True) + 1e-9)
        _PROTO_CACHE[key] = proto.astype(np.float32)
    return _PROTO_CACHE[key]


def pseudo_mnist_batch(step: int, batch: int = 128, side: int = 28,
                       n_classes: int = 10, noise: float = 0.25,
                       seed: int = 0):
    rng = np.random.Generator(np.random.Philox(key=seed, counter=step))
    proto = _prototypes(n_classes, side)
    labels = rng.integers(0, n_classes, batch)
    imgs = proto[labels].copy()
    # random shift ±2px
    sh = rng.integers(-2, 3, (batch, 2))
    imgs = np.stack([np.roll(np.roll(im, s0, 0), s1, 1)
                     for im, (s0, s1) in zip(imgs, sh)])
    imgs += rng.normal(scale=noise, size=imgs.shape)
    return {"x": jnp.asarray(imgs.reshape(batch, -1), jnp.float32),
            "y": jnp.asarray(labels, jnp.int32)}


def smooth_images(step: int, batch: int = 32, side: int = 32, chans: int = 3,
                  seed: int = 0, bands: int = 6):
    rng = np.random.Generator(np.random.Philox(key=seed, counter=step))
    f = np.zeros((batch, side, side, chans), np.complex128)
    f[:, :bands, :bands, :] = (rng.normal(size=(batch, bands, bands, chans))
                               + 1j * rng.normal(size=(batch, bands, bands, chans)))
    img = np.real(np.fft.ifft2(f, axes=(1, 2)))
    img = img / (np.abs(img).max(axis=(1, 2, 3), keepdims=True) + 1e-9)
    return {"x": jnp.asarray(img, jnp.float32)}


def parabola_batch(step: int, batch: int = 256, seed: int = 0):
    rng = np.random.Generator(np.random.Philox(key=seed, counter=step))
    x = rng.uniform(-1, 1, (batch, 1))
    return {"x": jnp.asarray(x, jnp.float32),
            "y": jnp.asarray(x * x, jnp.float32)}


def class_images(step: int, batch: int = 64, side: int = 64, chans: int = 3,
                 n_classes: int = 1000, noise: float = 0.3, seed: int = 1):
    """ImageNet-like synthetic classification (AlexNet benchmark)."""
    rng = np.random.Generator(np.random.Philox(key=seed, counter=step))
    proto = _prototypes(n_classes, side, seed=11)
    labels = rng.integers(0, n_classes, batch)
    imgs = proto[labels][..., None].repeat(chans, axis=-1)
    imgs = imgs + rng.normal(scale=noise, size=imgs.shape)
    return {"x": jnp.asarray(imgs, jnp.float32),
            "y": jnp.asarray(labels, jnp.int32)}
