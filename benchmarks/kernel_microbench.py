"""Kernel microbenchmarks: routed lut/codebook matmuls vs dense, per shape.

Times the *routed* ops (``kernels.ops`` — i.e. exactly what the serving
engine executes: tuned Pallas on TPU, tuned XLA fallbacks elsewhere) on
the serving model's hot contraction shapes, against a dense f32
``jnp.dot`` of the same shape measured in the same process.  Each entry
reports:

* ``us``          — microseconds per call (amortized over a scan of
                    ``--calls`` distinct activations against fixed
                    weights/table, the decode access pattern; a bare
                    timing loop would measure dispatch overhead on these
                    shapes, and a scan over one input would let XLA hoist
                    the whole contraction out of the loop).
* ``tok_equiv_s`` — rows/sec through the site (M rows ≈ M tokens for a
                    decode-shaped call), the absolute number.
* ``rel_dense``   — kernel time / dense time for the same (M, K, N),
                    same run.  This ratio is the machine-portable
                    regression signal: CI boxes differ in absolute speed
                    but the kernel and its dense baseline move together.
* ``config``      — the launch config the autotune cache resolved
                    (``kernels.autotune``), so a perf change can be told
                    apart from a tuning change in the diff.

Every run first asserts parity against ``kernels.ref`` on each shape —
bit-exact for lut (integer accumulators), small f32 tolerance for
codebook — so a "fast" number can never come from a wrong kernel.

Full runs write ``benchmarks/BENCH_kernels.json`` (``--json-out``), the
checked-in baseline.  ``--smoke`` (the CI gate) writes its measurements
to ``BENCH_kernels.smoke.json`` instead and exits nonzero if any entry's
``rel_dense`` regressed more than ``--tol`` (default 20%) against the
checked-in baseline, or if parity fails.

    PYTHONPATH=src python benchmarks/kernel_microbench.py            # refresh baseline
    PYTHONPATH=src python benchmarks/kernel_microbench.py --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ops, ref

# The serving model's contraction sites (d_model=128, d_ff=256 config used
# by tests and BENCH_serve):  (K, N) in {attn proj, ffn up, ffn down} ×
# M in {1: single-slot decode, 8: batch-8 decode, 64: prefill tile}.
SHAPES = [(m, k, n)
          for m in (1, 8, 64)
          for (k, n) in ((128, 128), (128, 256), (256, 128))]
LUT_TABLE = (4096, 256)       # (|A| = act levels, |W| = weight codes)
BOOK = 256                    # codebook entries


def _inputs(kernel, m, k, n, calls, seed):
    """Seeded inputs: stacked per-call activations, fixed weights/table."""
    rng = np.random.default_rng(seed)
    if kernel == "lut":
        r, c = LUT_TABLE
        a = jnp.asarray(rng.integers(0, r, (calls, m, k)), jnp.int32)
        w = jnp.asarray(rng.integers(0, c, (k, n)), jnp.int32)
        t = jnp.asarray(rng.integers(-1000, 1000, LUT_TABLE), jnp.int32)
        return a, w, t
    x = jnp.asarray(rng.standard_normal((calls, m, k)), jnp.float32)
    wi = jnp.asarray(rng.integers(0, BOOK, (k, n)), jnp.int32)
    book = jnp.asarray(rng.standard_normal((BOOK,)), jnp.float32)
    return x, wi, book


def _timed(op, stacked, *fixed, reps):
    """Min-of-reps seconds per call: scan the op over the stacked leading
    axis with an accumulating carry (distinct input each step, result
    consumed — nothing for XLA to hoist or elide)."""
    calls = stacked.shape[0]

    @jax.jit
    def run(stacked, *fixed):
        def body(c, s):
            return c + op(s, *fixed).astype(jnp.float32).sum(), None
        return jax.lax.scan(body, jnp.float32(0), stacked)[0]

    jax.block_until_ready(run(stacked, *fixed))            # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run(stacked, *fixed))
        best = min(best, time.perf_counter() - t0)
    return best / calls


def _parity(kernel, m, k, n, seed):
    """Routed op vs kernels.ref on this shape; raises on mismatch."""
    a, w, t = _inputs(kernel, m, k, n, 1, seed)
    if kernel == "lut":
        got = ops.lut_matmul(a[0], w, t)
        want = ref.lut_matmul_ref(a[0], w, t)
        if not bool(jnp.all(got == want)):
            raise AssertionError(f"lut parity m{m}k{k}n{n}")
    else:
        got = ops.codebook_matmul(a[0], w, t)
        want = ref.codebook_matmul_ref(a[0], w, t)
        err = float(jnp.max(jnp.abs(got - want)))
        if err > 1e-4:
            raise AssertionError(f"codebook parity m{m}k{k}n{n}: {err}")


def measure_entry(kernel, m, k, n, *, calls, reps, seed):
    """One (kernel, shape) entry: routed-op and dense timings + config."""
    plat = "tpu" if ops.supports_compiled_pallas() else "xla"
    table_shape = LUT_TABLE if kernel == "lut" else (BOOK,)
    dt_key = "int32" if kernel == "lut" else "float32"
    op = ops.lut_matmul if kernel == "lut" else ops.codebook_matmul
    stacked, wfix, tfix = _inputs(kernel, m, k, n, calls, seed)
    dt = _timed(op, stacked, wfix, tfix, reps=reps)
    dense_w = jnp.asarray(
        np.random.default_rng(seed + 1).standard_normal((k, n)), jnp.float32)
    dt_dense = _timed(lambda x, w: jnp.dot(x, w), stacked.astype(jnp.float32),
                      dense_w, reps=reps)
    cfg = autotune.kernel_config(kernel, m, k, n, dtype=dt_key, plat=plat,
                                 table_shape=table_shape)
    return {"us": round(dt * 1e6, 2),
            "dense_us": round(dt_dense * 1e6, 2),
            "tok_equiv_s": round(m / dt, 1),
            "rel_dense": round(dt / dt_dense, 3),
            "config": cfg}


def run_bench(*, calls, reps, seed):
    plat = "tpu" if ops.supports_compiled_pallas() else "xla"
    entries = {}
    for kernel in ("lut", "codebook"):
        for (m, k, n) in SHAPES:
            _parity(kernel, m, k, n, seed)
            key = f"{kernel}|m{m}k{k}n{n}"
            ent = measure_entry(kernel, m, k, n, calls=calls, reps=reps,
                                seed=seed)
            entries[key] = ent
            print(f"[{key:24s}] {ent['us']:9.1f}us"
                  f"  dense {ent['dense_us']:7.1f}us"
                  f"  rel {ent['rel_dense']:7.2f}  cfg {ent['config']}")
    return {"meta": {"plat": plat, "calls": calls, "reps": reps,
                     "seed": seed, "lut_table": list(LUT_TABLE),
                     "codebook": BOOK},
            "entries": entries}


def smoke_gate(result, baseline_path, tol, *, retries, calls, reps, seed):
    """>tol relative-throughput regression vs the checked-in baseline on
    any entry fails the gate.  rel_dense compares kernel-to-dense in the
    SAME run, so the gate is portable across machines of different
    absolute speed.  Entries over the limit are re-measured up to
    ``retries`` times (best rel kept) before counting as regressions —
    single-digit-µs denominators make one-shot ratios noisy, and a real
    regression reproduces on every retry."""
    try:
        with open(baseline_path) as f:
            base = json.load(f)["entries"]
    except FileNotFoundError:
        print(f"[smoke] FAIL: no baseline at {baseline_path}")
        return False
    ok = True
    for key, ent in result["entries"].items():
        if key not in base:
            print(f"[smoke] WARN: {key} not in baseline (new entry)")
            continue
        want = base[key]["rel_dense"]
        lim = want * (1.0 + tol)
        attempt = 0
        while ent["rel_dense"] > lim and attempt < retries:
            attempt += 1
            kernel, shp = key.split("|")
            m, rest = shp[1:].split("k")
            k, n = rest.split("n")
            redo = measure_entry(kernel, int(m), int(k), int(n), calls=calls,
                                 reps=reps, seed=seed + attempt)
            if redo["rel_dense"] < ent["rel_dense"]:
                ent = result["entries"][key] = redo
        got = ent["rel_dense"]
        verdict = "ok" if got <= lim else "REGRESSED"
        if got > lim:
            ok = False
        retried = f" (retries {attempt})" if attempt else ""
        print(f"[smoke] {key:24s} rel {got:7.2f} vs baseline {want:7.2f}"
              f" (limit {lim:7.2f}) {verdict}{retried}")
    missing = set(base) - set(result["entries"])
    if missing:
        print(f"[smoke] FAIL: baseline entries not measured: {sorted(missing)}")
        ok = False
    print(f"[smoke] {'PASS' if ok else 'FAIL'}")
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--calls", type=int, default=64,
                    help="scan length per timing (distinct activations)")
    ap.add_argument("--reps", type=int, default=5, help="min-of-N outer reps")
    ap.add_argument("--retries", type=int, default=2,
                    help="re-measurements allowed per over-limit smoke entry")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="gate rel_dense against the checked-in baseline")
    ap.add_argument("--tol", type=float, default=0.20,
                    help="allowed rel_dense regression fraction in --smoke")
    ap.add_argument("--baseline", default="benchmarks/BENCH_kernels.json")
    ap.add_argument("--json-out", default=None,
                    help="output path (default: baseline path, or "
                         "BENCH_kernels.smoke.json under --smoke)")
    args = ap.parse_args(argv)

    result = run_bench(calls=args.calls, reps=args.reps, seed=args.seed)
    ok = True
    if args.smoke:
        ok = smoke_gate(result, args.baseline, args.tol,
                        retries=args.retries, calls=args.calls,
                        reps=args.reps, seed=args.seed)
    out = args.json_out or ("benchmarks/BENCH_kernels.smoke.json"
                            if args.smoke else args.baseline)
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"[json] wrote {out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
