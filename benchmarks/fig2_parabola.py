"""Paper Fig. 2: fitting a parabola with 2 hidden units under tanhD(L)."""

from __future__ import annotations

from functools import partial

import jax

from benchmarks._common import train_regressor
from repro.data.synthetic import parabola_batch
from repro.models import papernets as PN


def run(steps=600):
    rows = []
    for label, kind, levels in [("tanh", "tanh", 0), ("relu6", "relu6", 0),
                                ("tanhD(2)", "tanh", 2),
                                ("tanhD(8)", "tanh", 8),
                                ("tanhD(256)", "tanh", 256)]:
        init = lambda k: PN.mlp_init(k, 1, [2], 1)
        apply = partial(_apply, kind)
        _, _, mse = train_regressor(init, apply, parabola_batch,
                                    steps=steps, lr=2e-2, act_levels=levels)
        rows.append(("fig2_parabola", label, f"{mse:.5f}"))
    return rows


def _apply(kind, p, x, act_levels):
    return PN.mlp_apply(p, x, kind, act_levels)


if __name__ == "__main__":
    for r in run():
        print(",".join(r))
