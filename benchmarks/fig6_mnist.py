"""Paper Fig. 6: MNIST-style classification — activation levels × |W| ×
hidden width (pseudo-MNIST; offline container)."""

from __future__ import annotations

from functools import partial

from benchmarks._common import recall_at, train_classifier
from repro.data.synthetic import pseudo_mnist_batch
from repro.models import papernets as PN


def _apply(kind, p, x, act_levels, key):
    return PN.mlp_apply(p, x, kind, act_levels)


def run(steps=250):
    rows = []
    grid = [
        ("tanh", 0, 0), ("relu6", 0, 0),
        ("tanhD(8)", 8, 0), ("tanhD(32)", 32, 0),
        ("tanh |W|=100", 0, 100), ("tanh |W|=1000", 0, 1000),
        ("tanhD(32) |W|=100", 32, 100), ("tanhD(32) |W|=1000", 32, 1000),
    ]
    data = lambda s: pseudo_mnist_batch(s, 64, noise=0.45)
    data_eval = lambda s: pseudo_mnist_batch(s, 128, noise=0.45)
    for hidden in (4, 16):
        for label, levels, nw in grid:
            kind = "relu6" if label.startswith("relu") else "tanh"
            init = lambda k: PN.mlp_init(k, 784, [hidden, hidden], 10)
            params, _, _ = train_classifier(
                init, partial(_apply, kind), data,
                steps=steps, act_levels=levels, n_weights=nw,
                cluster_every=60)
            acc = recall_at(partial(_apply, kind), data_eval,
                            params, levels)[1]
            rows.append(("fig6_mnist", f"h{hidden} {label}", f"{acc:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(r))
