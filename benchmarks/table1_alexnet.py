"""Paper Table 1: AlexNet experiments, CPU-scaled.

Width-scaled AlexNet on synthetic 200-class images.  Rows mirror the
paper's experiment numbers: #0 ReLU baseline, #1 ReLU6, #3 |A|=32,
#5 |A|=8, #6 k-means |W|=1000 (2% subsample, no dropout), #7 k-means
|W|=100, #9 Laplacian |W|=1000 (no dropout).  The "quantized inputs"
column quantizes pixels to |A| levels (paper's rightmost columns).
"""

from __future__ import annotations

from functools import partial

import jax.numpy as jnp

from benchmarks._common import recall_at, train_classifier
from repro.core.activations import quantize_input
from repro.data.synthetic import class_images
from repro.models import papernets as PN

N_CLASSES = 200
IMG = 32
WIDTH = 0.25


def _apply(kind, qin, p, x, act_levels, key):
    if qin and act_levels:
        x = quantize_input(x, act_levels, -1.0, 1.0)
    return PN.alexnet_apply(p, x, kind, act_levels, dropout_rate=0.0,
                            key=None)


def _data(s, batch=32):
    return class_images(s, batch=batch, side=IMG, n_classes=N_CLASSES)


def run(steps=400):
    rows = []
    exps = [
        ("#0 relu",            "relu",  0,   0,    None,          False),
        ("#1 relu6",           "relu6", 0,   0,    None,          False),
        ("#3 |A|=32",          "relu6", 32,  0,    None,          False),
        ("#5 |A|=8",           "relu6", 8,   0,    None,          False),
        ("#6 kmeans2% |W|=1000", "relu6", 32, 1000, "kmeans",     False),
        ("#7 kmeans2% |W|=100", "relu6", 32, 100,  "kmeans",      False),
        ("#9 laplacian |W|=1000", "relu6", 32, 1000, "laplacian_l1", False),
        ("#3q |A|=32 qin",     "relu6", 32,  0,    None,          True),
        ("#9q lap |W|=1000 qin", "relu6", 32, 1000, "laplacian_l1", True),
    ]
    for label, kind, levels, nw, method, qin in exps:
        init = lambda k: PN.alexnet_init(k, N_CLASSES, WIDTH, img=IMG)
        params, _, _ = train_classifier(
            init, partial(_apply, kind, qin), _data, steps=steps,
            lr=1e-3, act_levels=levels, n_weights=nw,
            cluster_every=100, method=method or "kmeans",
            subsample=0.02 if method == "kmeans" else 1.0)
        rec = recall_at(partial(_apply, kind, qin), _data, params, levels)
        rows.append(("table1_alexnet", label,
                     f"r@1={rec[1]:.3f} r@5={rec[5]:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(r))
