"""§Roofline: three-term analysis per (arch × shape × mesh) from the
dry-run artifacts (see launch/dryrun.py for how the numbers are produced
and trip-count-corrected).

Terms (seconds/step/device — TPU v5e):
    compute    = flops_per_device / 197e12        (bf16 MXU peak)
    memory     = bytes_per_device / 819e9         (HBM bandwidth)
    collective = coll_bytes_per_device / 50e9     (per-link ICI bandwidth)

MODEL_FLOPS = 6·N·D (train, dense) / 6·N_active·D (MoE) / 2·N·tokens
(decode); the useful-compute ratio MODEL_FLOPS / (chips · flops_per_device)
flags remat/dispatch waste.
"""

from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def _param_counts(arch: str):
    import repro.configs as C
    from repro.models.model_zoo import build
    from repro.launch.steps import abstract_params
    cfg = C.get(arch)
    params = abstract_params(build(cfg))
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    total = emb = expert = 0
    for kp, v in leaves:
        path = "/".join(str(getattr(k, "key", k)) for k in kp)
        n = 1
        for s in v.shape:
            n *= s
        total += n
        if "embed" in path or "lm_head" in path:
            emb += n
        if "/moe/w" in "/" + path:
            expert += n
    n_body = total - emb
    if cfg.n_experts:
        active = n_body - expert + expert * cfg.top_k / cfg.n_experts
    else:
        active = n_body
    return total, n_body, active, cfg


def model_flops(arch: str, shape: dict):
    total, n_body, active, cfg = _param_counts(arch)
    toks = shape["global_batch"] * shape["seq_len"]
    if shape["kind"] == "train":
        return 6.0 * active * toks
    if shape["kind"] == "prefill":
        return 2.0 * active * toks
    return 2.0 * active * shape["global_batch"]   # decode: 1 new token/seq


def analyse(rec: dict) -> dict:
    from repro.configs.base import SHAPES
    import dataclasses
    if rec.get("status") != "ok":
        return rec
    corr = rec.get("corrected") or {}
    flops = corr.get("flops", rec["flops_per_device"])
    bts = corr.get("bytes", rec["bytes_per_device"])
    coll = corr.get("coll",
                    rec["collectives_per_device"].get("total", 0))
    t_c = flops / PEAK_FLOPS
    t_m = bts / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    sh = dataclasses.asdict(SHAPES[rec["shape"]])
    mf = model_flops(rec["arch"], sh)
    useful = mf / max(flops * rec["n_devices"], 1.0)
    # roofline fraction: useful model compute versus the time the dominant
    # term pins the step to, at peak
    bound_s = max(terms.values())
    frac = (mf / rec["n_devices"] / PEAK_FLOPS) / max(bound_s, 1e-30)
    return {**rec, "terms": terms, "dominant": dominant,
            "model_flops": mf, "useful_ratio": useful,
            "roofline_fraction": frac}


def run(results_dir: str = "dryrun_results"):
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") == "skipped":
            rows.append(("roofline", rec["cell"], "SKIP:" + rec["reason"]))
            continue
        if rec.get("status") != "ok":
            rows.append(("roofline", rec.get("cell", f), "ERROR"))
            continue
        a = analyse(rec)
        t = a["terms"]
        rows.append(("roofline", a["cell"],
                     f"compute={t['compute_s'] * 1e3:.2f}ms "
                     f"memory={t['memory_s'] * 1e3:.2f}ms "
                     f"collective={t['collective_s'] * 1e3:.2f}ms "
                     f"dom={a['dominant'].split('_')[0]} "
                     f"useful={a['useful_ratio']:.2f} "
                     f"roofline_frac={a['roofline_fraction']:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(r))
