"""Serving throughput: seed per-token Python loop vs the jitted ServeEngine
across backends and batch sizes, the paged-KV-cache memory story, and
speculative decoding (DESIGN.md §9).

Measures tokens/sec and mean per-request latency for:

* ``seed``     — the pre-engine path: one jitted ``decode_step`` per token,
                 prompt fed token-by-token, host sync + Python dispatch
                 between every step (reproduced verbatim below).
* ``dense``    — jitted prefill + ``lax.while_loop`` decode (ServeEngine).
* ``codebook`` — same loop with matmuls through the Pallas
                 ``codebook_matmul`` (interpret mode off-TPU).
* ``lut``      — same loop through the faithful §4 integer engine.
* ``paged``    — the paged KV cache (DESIGN.md §8): chunked prefill,
                 int8 pages, prefix caching.  Alongside tok/s it reports
                 KV-cache HBM bytes (peak pages in use vs the dense slab),
                 page-pool utilization, and the prefix-cache hit rate on a
                 shared-prefix workload (N requests, one system prompt).
* ``spec``     — speculative decoding with the n-gram self-draft on a
                 repetitive-suffix workload (prompts whose greedy
                 continuation settles into a constant run — probed against
                 the live model): tokens/sec vs baseline decode plus the
                 per-step acceptance rate.
* ``server``   — arrival-driven load (DESIGN.md §11): a seeded Poisson
                 trace with priority classes through the virtual-clock
                 ``AsyncScheduler`` over a deliberately tight page pool —
                 p50/p99 TTFT and TPOT (virtual seconds, deterministic),
                 preemption/pages-swapped counts, SLO attainment, and a
                 wall-clock tok/s figure.  The smoke gate asserts the
                 contended streams stay token-identical to batch serve()
                 and that preemptions actually fired.
* ``fleet``    — multi-replica serving (DESIGN.md §15): the N=1
                 reduction gate (one-replica fleet == single Server) and
                 a three-way A/B on a grouped shared-prefix workload —
                 round-robin vs prefix-aware routing vs prefix routing
                 plus the fleet-level ``SharedPrefixTier``.  Gated on
                 the hit-rate ordering tier > prefix > round-robin AND
                 on the tier materializing (computing) fewer prompt
                 pages than affinity routing alone — cross-replica
                 deduplication must be real, not just well-routed.

Every run (full and ``--smoke``) also emits a machine-readable
``BENCH_serve.json`` (``--json-out``) — tokens/sec per backend/batch, KV
bytes, prefix hit rate, spec acceptance — so the perf trajectory is
tracked across PRs.  All workload generation derives from ``--seed``
(default 0): prompts, shared prefixes, and the spec probe candidates are
identical run-to-run, so the numbers and the ``--smoke`` CI gate are
reproducible.

``--tp 1 2 4`` additionally measures tensor-parallel serving
(DESIGN.md §10) at each degree — tok/s and per-device KV bytes, each
degree in its own subprocess with that many forced host devices — and
merges a ``tp`` section into ``BENCH_serve.json``, so the perf trajectory
captures *scaling*, not just single-chip numbers.

Acceptance targets: the jitted decode loop >= 5x the seed per-token loop at
batch 8 (ISSUE 1); the paged int8 cache >= 2x smaller than the bf16 dense
slab at equal batch with a measured prefix hit rate > 0 (ISSUE 2); spec
decode token-identical to baseline at temperature 0 with acceptance > 0
and >1x decode speedup on the repetitive-suffix workload (ISSUE 3).

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        [--batches 1 8] [--max-new 16] [--layers 2] [--smoke]

``--smoke`` runs a fast regression gate (used by CI): the paged checks
above plus the spec-decode gate — exits nonzero otherwise.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core.export import kv_cache_bytes
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, SpecConfig, to_codebook_params


def seed_generate(model, params, prompts, max_new, max_len):
    """The seed engine's generate(), verbatim: token-by-token everything."""
    cfg = model.cfg
    B = len(prompts)
    cache = model.init_cache(B, max_len, dtype=jnp.float32)
    decode = jax.jit(lambda p, t, c: model.decode(p, t, c, None))
    maxp = max(len(p) for p in prompts)
    toks = np.zeros((B, maxp), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    out = [list(p) for p in prompts]
    logits = None
    for t in range(maxp):
        logits, cache = decode(params, jnp.asarray(toks[:, t:t + 1]), cache)
    for _ in range(max_new):
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)
        nxt = np.asarray(nxt, np.int32)
        for i in range(B):
            out[i].append(int(nxt[i]))
        logits, cache = decode(params, jnp.asarray(nxt)[:, None], cache)
    return out


def bench(fn, reps):
    fn()                                   # warmup: compile everything
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def shared_prefix_prompts(rng, vocab, n, prefix_len, suffix_len):
    """N requests behind one system prompt — the prefix-cache workload."""
    system = [int(t) for t in rng.integers(0, vocab, prefix_len)]
    return [system + [int(t) for t in rng.integers(0, vocab, suffix_len)]
            for _ in range(n)]


def repetitive_workload(eng, vocab, *, n_prompts=2, motif_len=3, reps=6,
                        max_new=64, max_seeds=80, seed=0):
    """Prompts whose BASELINE greedy continuation settles into a constant
    run — the workload the n-gram self-draft is built for.  Random-init
    models fall into short cycles, but *which* prompts cycle depends on the
    weights, so candidates are probed against the live model (a full
    max_batch-wide serve per probe batch, not one request at a time).
    Candidate motifs derive from ``seed`` — same seed, same workload."""
    good = []
    B = eng.max_batch
    cands = [[int(t) for t in
              np.random.default_rng((seed, s)).integers(0, vocab, motif_len)]
             * reps
             for s in range(max_seeds)]
    for i in range(0, max_seeds, B):
        batch = cands[i:i + B]
        for p, out in zip(batch, eng.serve(batch, max_new=max_new)):
            tail = out[len(p):]
            if len(set(tail[6:])) == 1:
                good.append(p)
        if len(good) >= n_prompts:
            break
    return good[:n_prompts]


def bench_spec(model, params, *, max_new=64, k=6, reps=3, seed=0):
    """n-gram speculative decode vs baseline on the repetitive-suffix
    workload.  Returns a JSON-ready dict with a ``parity`` flag (the
    smoke gate turns parity=False into a FAIL instead of crashing the
    remaining checks), or None when no cycling prompt was found."""
    probe = ServeEngine(model, params, max_len=96, max_batch=4)
    prompts = repetitive_workload(probe, model.cfg.vocab, max_new=max_new,
                                  seed=seed)
    if len(prompts) < 2:
        return None
    ml = len(prompts[0]) + max_new + 8
    base = ServeEngine(model, params, max_len=ml, max_batch=4)
    spec = ServeEngine(model, params, max_len=ml, max_batch=4,
                       spec=SpecConfig(draft="ngram", k=k))
    want = base.serve(prompts, max_new=max_new)         # warm + reference
    got = spec.serve(prompts, max_new=max_new)          # warm
    tb = min(bench(lambda: base.serve(prompts, max_new=max_new), 1)
             for _ in range(reps))
    ts = min(bench(lambda: spec.serve(prompts, max_new=max_new), 1)
             for _ in range(reps))
    spec.spec_stats.reset()
    spec.serve(prompts, max_new=max_new)                # measured stats pass
    st = spec.spec_stats
    n_tok = len(prompts) * max_new
    return {"draft": "ngram", "k": k, "n_prompts": len(prompts),
            "max_new": max_new, "parity": got == want,
            "baseline_tok_s": n_tok / tb, "spec_tok_s": n_tok / ts,
            "speedup": tb / ts, "acceptance_rate": st.acceptance_rate,
            "tokens_per_round": st.tokens_per_round, "rounds": st.rounds}


def bench_server(model, params, *, seed=0, telemetry=None):
    """Arrival-driven serving through the AsyncScheduler (DESIGN.md §11)
    on a contended configuration: a seeded Poisson trace with two
    priority classes over a page pool too small to hold every arrival,
    so admissions queue and preemptions fire.  All scheduling metrics
    are virtual-clock (deterministic for a given seed); only ``wall_s``
    and ``tok_s`` are wall-clock timing fields."""
    from repro.serving.server import (CONTENDED_ENGINE_KW, Server,
                                      contended_trace)

    # seed+1 on the shared contended (engine, trace) pair preempts for
    # the default --seed 0 (gated in smoke); any seed stays
    # deterministic end-to-end
    trace = contended_trace(seed + 1, model.cfg.vocab,
                            slo_ttft=0.3, slo_tpot=0.05)
    eng = ServeEngine(model, params, **CONTENDED_ENGINE_KW)
    srv = Server(eng, telemetry=telemetry)
    t0 = time.perf_counter()
    rep = srv.replay(trace)
    wall = time.perf_counter() - t0

    # parity gate: the contended, preempted streams must equal an
    # uncontended batch serve of the same requests (temperature 0)
    ref = ServeEngine(model, params,
                      max_len=CONTENDED_ENGINE_KW["max_len"], max_batch=2)
    want = ref.serve([r["prompt"] for r in trace],
                     max_new=[r["max_new"] for r in trace])
    handles = [srv.sched.handles[i] for i in range(len(trace))]
    parity = [h.result() for h in handles] == want
    return {"n_requests": rep.n_requests, "n_tokens": rep.n_tokens,
            "parity": parity, "preemptions": rep.preemptions,
            "pages_swapped_out": rep.pages_swapped_out,
            "pages_swapped_in": rep.pages_swapped_in,
            "slo_attainment": rep.slo_attainment,
            "p50_ttft": rep.p50_ttft, "p99_ttft": rep.p99_ttft,
            "p50_tpot": rep.p50_tpot, "p99_tpot": rep.p99_tpot,
            "makespan": rep.makespan,
            "admission_order": rep.admission_order,
            "wall_s": wall, "tok_s": rep.n_tokens / wall}


def grouped_prefix_trace(seed, vocab, n, *, n_groups=4, page=8, rate=60.0):
    """The fleet routing workload: every request opens with one of
    ``n_groups`` two-page system prompts plus a private tail — prefix
    affinity keeps each group's chain hot on one replica, round-robin
    scatters it across all pools."""
    rng = np.random.default_rng(seed)
    prefixes = [[int(t) for t in rng.integers(0, vocab, 2 * page)]
                for _ in range(n_groups)]
    t, rows = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        g = int(rng.integers(n_groups))
        tail = [int(x) for x in
                rng.integers(0, vocab, int(rng.integers(1, page)))]
        rows.append({"arrival": round(t, 9), "prompt": prefixes[g] + tail,
                     "max_new": int(rng.integers(2, 6)), "priority": 0,
                     "slo_ttft": None, "slo_tpot": None})
    return rows


def bench_fleet(model, params, *, seed=0, n_replicas=4, n_requests=80,
                n_groups=8):
    """Multi-replica fleet serving (DESIGN.md §15): the N=1 reduction
    gate (a one-replica fleet's report must equal the single Server's on
    the contended trace) and a three-way routing/dedup A/B on the
    grouped shared-prefix workload — round-robin vs prefix-affinity
    routing vs prefix routing + the fleet-level ``SharedPrefixTier``.
    The fleet-wide prefix hit rate is the routing policy's score;
    ``materialized_pages`` (prompt pages actually computed) is the
    tier's: the tier must serve cross-replica hits that affinity alone
    cannot, so hit(tier) > hit(prefix) > hit(round_robin) and the tier
    row materializes the fewest pages.  Event digests are virtual-clock
    deterministic; only ``wall_s``/``tok_s`` are timing fields."""
    from repro.serving import Fleet, Server
    from repro.serving.server import CONTENDED_ENGINE_KW, contended_trace

    trace = contended_trace(seed + 1, model.cfg.vocab,
                            slo_ttft=0.3, slo_tpot=0.05)
    srv = Server(ServeEngine(model, params, **CONTENDED_ENGINE_KW))
    rep_s = srv.replay(trace)
    f1 = Fleet([ServeEngine(model, params, **CONTENDED_ENGINE_KW)])
    rep_f = f1.replay(trace)
    n1_parity = rep_f.to_json() == rep_s.to_json()

    # more groups than the per-replica pools can pin: hot prefixes churn
    # out of the LRU, affinity breaks, and only the fleet tier can serve
    # the re-materialization — the regime the tier exists for
    grouped = grouped_prefix_trace(
        seed, model.cfg.vocab, n_requests, n_groups=n_groups,
        page=CONTENDED_ENGINE_KW["page_size"])
    rows = {}
    for name, policy, tier in (("round_robin", "round_robin", False),
                               ("prefix", "prefix", False),
                               ("prefix_tier", "prefix", True)):
        fleet = Fleet([ServeEngine(model, params, **CONTENDED_ENGINE_KW)
                       for _ in range(n_replicas)], policy=policy,
                      shared_prefix_tier=tier)
        t0 = time.perf_counter()
        rep = fleet.replay(grouped)
        wall = time.perf_counter() - t0
        rows[name] = {"prefix_hit_rate": fleet.prefix_hit_rate(),
                      "materialized_pages": fleet.materialized_pages(),
                      "shared_tier": fleet.shared_tier_stats(),
                      "event_digest": fleet.event_digest(),
                      "preemptions": rep.preemptions,
                      "p50_ttft": rep.p50_ttft, "p99_ttft": rep.p99_ttft,
                      "p50_tpot": rep.p50_tpot, "p99_tpot": rep.p99_tpot,
                      "makespan": rep.makespan, "n_tokens": rep.n_tokens,
                      "routed": fleet.n_routed_to,
                      "wall_s": wall, "tok_s": rep.n_tokens / wall}
    return {"n_replicas": n_replicas, "n_requests": n_requests,
            "n_groups": n_groups, "n1_parity": n1_parity, "policies": rows}


def _telemetry_paths(json_out: str) -> tuple[str, str]:
    """Sidecar paths next to the bench JSON (derived from --json-out so
    concurrent runs with distinct outputs never collide)."""
    base = json_out[:-5] if json_out.endswith(".json") else json_out
    return base + ".metrics.json", base + ".trace.json"


def telemetry_overhead(model, params, *, seed=0, reps=3):
    """The disabled-telemetry overhead gate: serving with telemetry OFF
    (the default NULL_TELEMETRY wiring) must not be measurably slower
    than before the instrumentation landed.  A pre-telemetry absolute
    tok/s baseline is not machine-portable (same reasoning as the kernel
    microbench's rel_dense ratios), so the gate drains the same contended
    trace through the same warm engine with telemetry off vs fully on and
    requires off-time <= 1.02x on-time — the instrumented run does
    strictly more work, so this bounds the disabled path's cost at <2%
    tok/s without needing a historical binary."""
    from repro.serving.server import (CONTENDED_ENGINE_KW, Server,
                                      contended_trace)
    from repro.serving.telemetry import Telemetry

    trace = contended_trace(seed + 1, model.cfg.vocab)
    eng = ServeEngine(model, params, **CONTENDED_ENGINE_KW)

    def drain(tel):
        return Server(eng, telemetry=tel).replay(trace).n_tokens

    n_tok = drain(None)                      # warm the jit caches

    def best(mk):
        t = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            drain(mk())
            t = min(t, time.perf_counter() - t0)
        return t

    t_off = best(lambda: None)
    t_on = best(Telemetry)
    for _ in range(2):                       # absorb scheduler jitter
        if t_off <= 1.02 * t_on:
            break
        t_off = min(t_off, best(lambda: None))
        t_on = min(t_on, best(Telemetry))
    return {"n_tokens": n_tok,
            "telemetry_off_tok_s": n_tok / t_off,
            "telemetry_on_tok_s": n_tok / t_on,
            "overhead_pct": (t_off / t_on - 1.0) * 100.0}


def probe_overhead(model, params, *, seed=0, reps=3):
    """The probes-ON overhead gate (<2% tok/s, DESIGN.md §14): drain the
    same contended trace through warm probes-off vs probes-on engines.
    The instrumented forward carries a handful of (L,) f32 counters
    through the decode while_loop; min-of-reps timing with two
    re-measure rounds absorbs scheduler jitter, mirroring the telemetry
    gate above."""
    from repro.serving.server import (CONTENDED_ENGINE_KW, Server,
                                      contended_trace)

    trace = contended_trace(seed + 1, model.cfg.vocab)
    off = ServeEngine(model, params, **CONTENDED_ENGINE_KW)
    on = ServeEngine(model, params, probes=True, **CONTENDED_ENGINE_KW)

    def drain(eng):
        return Server(eng).replay(trace).n_tokens

    n_tok = drain(off)                       # warm both jit caches
    drain(on)

    def best(eng):
        t = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            drain(eng)
            t = min(t, time.perf_counter() - t0)
        return t

    t_off, t_on = best(off), best(on)
    for _ in range(2):                       # absorb scheduler jitter
        if t_on <= 1.02 * t_off:
            break
        t_off = min(t_off, best(off))
        t_on = min(t_on, best(on))
    return {"n_tokens": n_tok,
            "probes_off_tok_s": n_tok / t_off,
            "probes_on_tok_s": n_tok / t_on,
            "overhead_pct": (t_on / t_off - 1.0) * 100.0}


_GOLDEN_NUMERICS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "tests", "golden_numerics.json")
_GOLDEN_NUMERICS_CFG = ("qwen3-1.7b", 2)     # (arch, layers) the golden blesses


def numerics_sentinels(model, params, arch, layers):
    """Re-measure the golden probe scenarios and check them against the
    committed bounds (tests/golden_numerics.json — blessed by
    tests/test_probes.py with GOLDEN_UPDATE=1).  Returns
    (numerics-by-scenario, failure strings).  Skipped (None, []) when
    the bench model differs from the golden config — counters only
    compare on identical weights."""
    from repro.serving import probes as nprobes

    if (arch, layers) != _GOLDEN_NUMERICS_CFG:
        print(f"[smoke] numerics sentinels skipped: golden is blessed for "
              f"{_GOLDEN_NUMERICS_CFG}, bench ran ({arch}, {layers})")
        return None, []
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 200,
                               jax.random.PRNGKey(1))
    cparams = to_codebook_params(pq, wq, state, min_size=256)
    nums = nprobes.run_golden_scenarios(model, params, cparams)
    with open(_GOLDEN_NUMERICS) as f:
        golden = json.load(f)
    fails = []
    for name, num in nums.items():
        for msg in nprobes.sentinel_check(num, golden.get(name)):
            fails.append(f"numerics[{name}]: {msg}")
    worst_hr = min(min(n["headroom_bits"]) for n in nums.values())
    worst_sat = max(max(n["sat_rate"]) for n in nums.values())
    worst_kv = max(max(n["kv_err_max"]) for n in nums.values())
    print(f"[smoke] numerics sentinels over {len(nums)} scenarios: "
          f"headroom min {worst_hr:.1f} bits, sat rate max "
          f"{100 * worst_sat:.2f}%, kv err max {worst_kv:.4f} "
          f"({'FAIL' if fails else 'PASS'})")
    return nums, fails


_TP_SENTINEL = "TP_BENCH_RESULT "


def tp_child(model, cfg, params, args) -> dict:
    """One TP degree's measurement, inside its own forced-device process:
    contiguous and paged-int8 serve tok/s plus per-device KV bytes (both
    cache layouts shard their sequence axis over `model`, so bytes/device
    = total/tp — the scaling the §10 layout buys)."""
    tp = args.tp_child
    mesh = None
    if tp > 1:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(1, tp)
    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.max_new + 8
    max_len += (-max_len) % tp
    prompts = [[int(t) for t in rng.integers(0, cfg.vocab, args.prompt_len)]
               for _ in range(8)]
    n_tok = len(prompts) * args.max_new

    eng = ServeEngine(model, params, max_len=max_len, max_batch=8, mesh=mesh)
    dt = bench(lambda: eng.serve(prompts, max_new=args.max_new), args.reps)
    peng = ServeEngine(model, params, max_len=max_len, max_batch=8,
                       mesh=mesh, paged=True, page_size=args.page_size,
                       kv_dtype="int8")
    pdt = bench(lambda: peng.serve(prompts, max_new=args.max_new), args.reps)
    return {"tp": tp, "devices": len(jax.devices()),
            "tok_s": n_tok / dt, "paged_int8_tok_s": n_tok / pdt,
            "kv_slab_bytes_per_device": eng.dense_cache_bytes() // tp,
            "kv_pool_bytes_per_device": peng.pool.bytes_total() // tp}


def run_tp(args) -> int:
    """Fan --tp degrees out to subprocesses (XLA's device count is fixed at
    backend init, so each degree gets its own process) and merge the rows
    into --json-out without disturbing the full-run payload."""
    rows = []
    for tp in args.tp:
        if args.page_size % tp:
            print(f"[tp] skip tp={tp}: page size {args.page_size} is not a "
                  f"multiple of it")
            continue
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={tp} "
                            + env.get("XLA_FLAGS", "")).strip()
        cmd = [sys.executable, os.path.abspath(__file__),
               "--tp-child", str(tp), "--arch", args.arch,
               "--layers", str(args.layers), "--seed", str(args.seed),
               "--prompt-len", str(args.prompt_len),
               "--max-new", str(args.max_new),
               "--page-size", str(args.page_size),
               "--reps", str(args.reps), "--json-out", ""]
        out = subprocess.run(cmd, env=env, capture_output=True, text=True)
        row = None
        for line in reversed(out.stdout.splitlines()):
            if line.startswith(_TP_SENTINEL):
                row = json.loads(line[len(_TP_SENTINEL):])
                break
        if row is None:
            print(f"[tp] tp={tp} FAILED\n{out.stdout}\n{out.stderr}")
            return 1
        rows.append(row)
        print(f"[tp] tp={tp}: {row['tok_s']:.1f} tok/s contiguous, "
              f"{row['paged_int8_tok_s']:.1f} tok/s paged-int8, "
              f"KV/device {row['kv_slab_bytes_per_device'] / 1e3:.1f}KB slab "
              f"/ {row['kv_pool_bytes_per_device'] / 1e3:.1f}KB pool")
    if args.json_out:
        data = {}
        if os.path.exists(args.json_out):
            with open(args.json_out) as f:
                data = json.load(f)
        data["tp"] = {"arch": args.arch, "layers": args.layers,
                      "seed": args.seed, "rows": rows}
        with open(args.json_out, "w") as f:
            json.dump(data, f, indent=2)
        print(f"[json] merged tp rows into {args.json_out}")
    return 0


def write_bench_json(path, payload):
    payload = {"bench": "serve_throughput",
               "device": jax.default_backend(), **payload}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[json] wrote {path}")


def paged_report(eng, cfg, max_len):
    """(peak paged bytes, bf16 dense-slab bytes, utilization, hit rate)."""
    st = eng.pool.stats
    peak = eng.pool.bytes_per_page() * st.peak_pages_in_use
    slab = kv_cache_bytes(cfg.n_layers, cfg.n_kv, cfg.hd,
                          eng.max_batch * max_len, dtype_bytes=2)
    return peak, slab, st.peak_pages_in_use / eng.pool.usable_pages, st.hit_rate


def run_paged(model, cfg, params, prompts, max_new, max_len, page, reps,
              kv_dtype="int8"):
    eng = ServeEngine(model, params, max_len=max_len, max_batch=8,
                      paged=True, page_size=page, kv_dtype=kv_dtype)
    dt = bench(lambda: eng.serve(prompts, max_new=max_new), reps)
    eng.pool.reset_stats()
    eng.serve(prompts, max_new=max_new)       # measured pass for the stats
    return eng, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--skip-lut", action="store_true",
                    help="lut runs the Pallas interpreter per dense layer; "
                         "skip it for quick runs")
    ap.add_argument("--smoke", action="store_true",
                    help="fast paged + spec regression gate (CI)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload PRNG seed (prompts, shared prefixes, "
                         "spec probe motifs) — fixed default keeps "
                         "BENCH_serve.json and --smoke reproducible")
    ap.add_argument("--tp", type=int, nargs="+", default=None,
                    help="measure TP serving at these degrees (each in a "
                         "subprocess with that many forced host devices) "
                         "and merge a 'tp' section into --json-out")
    ap.add_argument("--tp-child", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--json-out", default="BENCH_serve.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced().replace(n_layers=args.layers,
                                                   dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.max_new + 8
    rng = np.random.default_rng(args.seed)

    if args.tp_child:
        print(_TP_SENTINEL + json.dumps(tp_child(model, cfg, params, args)))
        return
    if args.tp:
        if args.smoke:
            ap.error("--tp is a standalone mode (per-degree subprocesses); "
                     "run --smoke separately so its gate actually executes")
        sys.exit(run_tp(args))
    if args.smoke:
        sys.exit(smoke(model, cfg, params, rng, args.json_out,
                       seed=args.seed, arch=args.arch))

    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    cparams = to_codebook_params(pq, wq, state, min_size=1024)

    rows = []
    speedup_at_8 = None
    for B in args.batches:
        prompts = [list(rng.integers(0, cfg.vocab, args.prompt_len))
                   for _ in range(B)]
        n_tok = B * args.max_new

        dt_seed = bench(lambda: seed_generate(model, params, prompts,
                                              args.max_new, max_len),
                        args.reps)
        rows.append(("seed", B, n_tok / dt_seed, dt_seed / B * 1e3))

        backends = ["dense", "codebook"] + ([] if args.skip_lut else ["lut"])
        for be in backends:
            p = params if be == "dense" else cparams
            eng = ServeEngine(model, p, max_len=max_len, backend=be)
            dt = bench(lambda: eng.generate(prompts, max_new=args.max_new),
                       args.reps)
            rows.append((be, B, n_tok / dt, dt / B * 1e3))
            if be == "dense" and B == 8:
                speedup_at_8 = dt_seed / dt

        eng, dt = run_paged(model, cfg, params, prompts, args.max_new,
                            max_len, args.page_size, args.reps)
        rows.append(("paged-int8", B, n_tok / dt, dt / B * 1e3))
        peak, slab, util, _ = paged_report(eng, cfg, max_len)
        print(f"[paged] B={B}: peak KV {peak / 1e6:.3f}MB vs bf16 slab "
              f"{slab / 1e6:.3f}MB ({slab / max(peak, 1):.1f}x smaller), "
              f"pool utilization {100 * util:.0f}%")

    # shared-prefix workload: one long system prompt, distinct user tails
    n_req = max(args.batches)
    shared = shared_prefix_prompts(rng, cfg.vocab, n_req,
                                   4 * args.page_size, args.prompt_len)
    smax = len(shared[0]) + args.max_new + 8
    eng = ServeEngine(model, params, max_len=smax, max_batch=8, paged=True,
                      page_size=args.page_size, kv_dtype="int8")
    t0 = time.perf_counter()
    eng.serve(shared, max_new=args.max_new)
    dts = time.perf_counter() - t0
    peak, slab, util, hit = paged_report(eng, cfg, smax)
    print(f"[paged] shared-prefix ({n_req} requests, common "
          f"{4 * args.page_size}-token system prompt): "
          f"{n_req * args.max_new / dts:.1f} tok/s, prefix hit rate "
          f"{100 * hit:.0f}%, peak KV {peak / 1e6:.3f}MB vs bf16 slab "
          f"{slab / 1e6:.3f}MB")

    # speculative decoding on the repetitive-suffix workload
    spec = bench_spec(model, params, seed=args.seed)
    if spec is None:
        print("[spec] no cycling prompt found on this model — skipped")
    else:
        print(f"[spec] ngram k={spec['k']}: {spec['spec_tok_s']:.1f} tok/s "
              f"vs baseline {spec['baseline_tok_s']:.1f} "
              f"({spec['speedup']:.2f}x), acceptance "
              f"{100 * spec['acceptance_rate']:.0f}%, "
              f"{spec['tokens_per_round']:.1f} tok/round"
              + ("" if spec["parity"] else
                 " — WARNING: diverged from baseline at temperature 0"))

    # arrival-driven scheduler load (DESIGN.md §11), instrumented so the
    # registry snapshot + Perfetto trace land next to the bench JSON
    from repro.serving.telemetry import Telemetry
    tel = Telemetry()
    server = bench_server(model, params, seed=args.seed, telemetry=tel)
    print(f"[server] {server['n_requests']} arrivals: ttft p50/p99 "
          f"{server['p50_ttft']:.3f}/{server['p99_ttft']:.3f}s, tpot "
          f"p50/p99 {server['p50_tpot']:.3f}/{server['p99_tpot']:.3f}s "
          f"(virtual), {server['preemptions']} preemptions "
          f"({server['pages_swapped_out']} pages swapped out), SLO attainment "
          f"{100 * server['slo_attainment']:.0f}%, {server['tok_s']:.1f} "
          f"tok/s wall"
          + ("" if server["parity"] else
             " — WARNING: diverged from batch serve"))

    # multi-replica fleet routing (DESIGN.md §15)
    fleet = bench_fleet(model, params, seed=args.seed)
    fp = fleet["policies"]
    print(f"[fleet] {fleet['n_replicas']} replicas, {fleet['n_requests']} "
          f"grouped-prefix arrivals: hit rate shared-tier "
          f"{100 * fp['prefix_tier']['prefix_hit_rate']:.0f}% vs prefix "
          f"{100 * fp['prefix']['prefix_hit_rate']:.0f}% vs round-robin "
          f"{100 * fp['round_robin']['prefix_hit_rate']:.0f}%; tier "
          f"materialized {fp['prefix_tier']['materialized_pages']} pages "
          f"vs {fp['prefix']['materialized_pages']} without, "
          f"{fp['prefix']['tok_s']:.1f} tok/s wall"
          + ("" if fleet["n1_parity"] else
             " — WARNING: fleet(N=1) diverged from the single server"))

    print(f"\n{'backend':<10} {'batch':>5} {'tok/s':>10} {'ms/request':>12}")
    for name, B, tps, lat in rows:
        print(f"{name:<10} {B:>5} {tps:>10.1f} {lat:>12.1f}")

    if speedup_at_8 is not None:
        ok = speedup_at_8 >= 5.0
        print(f"\n[target] jitted dense loop vs seed loop at batch 8: "
              f"{speedup_at_8:.1f}x ({'PASS' if ok else 'FAIL'}: >= 5x)")

    if args.json_out:
        write_bench_json(args.json_out, {
            "mode": "full", "arch": args.arch, "layers": args.layers,
            "rows": [{"backend": n, "batch": b, "tok_s": t,
                      "ms_per_request": l} for n, b, t, l in rows],
            "seed_speedup_at_8": speedup_at_8,
            "paged": {"kv_peak_bytes": peak, "bf16_slab_bytes": slab,
                      "pool_utilization": util, "prefix_hit_rate": hit},
            "spec": spec, "server": server, "fleet": fleet})
        mpath, tpath = _telemetry_paths(args.json_out)
        tel.export_metrics(mpath)
        tel.export_trace(tpath)
        print(f"[telemetry] metrics -> {mpath}, Perfetto trace -> {tpath}")


def smoke(model, cfg, params, rng, json_out="", seed=0,
          arch="qwen3-1.7b") -> int:
    """CI gate for the paged + speculative paths; returns an exit code."""
    prompts = [list(map(int, rng.integers(0, cfg.vocab, n)))
               for n in (3, 7, 5, 9)]
    max_new, max_len, page = 6, 32, 4
    fails = []

    contig = ServeEngine(model, params, max_len=max_len, max_batch=2)
    want = contig.serve(prompts, max_new=max_new)
    paged = ServeEngine(model, params, max_len=max_len, max_batch=2,
                        paged=True, page_size=page)
    got = paged.serve(prompts, max_new=max_new)
    if got != want:
        fails.append("paged bf16 serve diverged from the contiguous engine")

    eng8 = ServeEngine(model, params, max_len=max_len, max_batch=2,
                       paged=True, page_size=page, kv_dtype="int8")
    eng8.serve(prompts, max_new=max_new)
    peak, slab, util, _ = paged_report(eng8, cfg, max_len)
    ratio = slab / max(peak, 1)
    print(f"[smoke] int8 paged peak {peak / 1e3:.1f}KB vs bf16 slab "
          f"{slab / 1e3:.1f}KB: {ratio:.1f}x (need >= 2x), utilization "
          f"{100 * util:.0f}%")
    if ratio < 2.0:
        fails.append(f"cache-memory reduction {ratio:.2f}x < 2x")

    shared = shared_prefix_prompts(rng, cfg.vocab, 4, 2 * page, 3)
    engs = ServeEngine(model, params, max_len=max_len, max_batch=2,
                       paged=True, page_size=page, kv_dtype="int8")
    engs.serve(shared, max_new=4)
    hit = engs.pool.stats.hit_rate
    print(f"[smoke] shared-prefix hit rate {100 * hit:.0f}% (need > 0)")
    if hit <= 0:
        fails.append("prefix cache registered no hits on the shared-prefix "
                     "workload")

    # --- speculative decoding (DESIGN.md §9) ---------------------------------
    # temperature=0 parity vs baseline decode, contiguous AND paged
    sc = SpecConfig(draft="ngram", k=3)
    spec_c = ServeEngine(model, params, max_len=max_len, max_batch=2,
                         spec=sc).serve(prompts, max_new=max_new)
    if spec_c != want:
        fails.append("spec decode (contiguous) diverged from baseline at "
                     "temperature 0")
    spec_p = ServeEngine(model, params, max_len=max_len, max_batch=2,
                         paged=True, page_size=page,
                         spec=sc).serve(prompts, max_new=max_new)
    if spec_p != want:
        fails.append("spec decode (paged) diverged from baseline at "
                     "temperature 0")
    # >1x decode speedup with acceptance > 0 on the repetitive workload
    spec = bench_spec(model, params, seed=seed)
    if spec is None:
        fails.append("no repetitive-suffix workload found to gate spec "
                     "decode speedup")
    else:
        print(f"[smoke] spec ngram: {spec['speedup']:.2f}x vs baseline "
              f"(need > 1x), acceptance {100 * spec['acceptance_rate']:.0f}%"
              f" (need > 0)")
        if not spec["parity"]:
            fails.append("spec decode diverged from baseline at temperature "
                         "0 on the repetitive-suffix workload")
        if spec["acceptance_rate"] <= 0:
            fails.append("spec decode accepted no draft tokens")
        if spec["speedup"] <= 1.0:
            fails.append(f"spec decode speedup {spec['speedup']:.2f}x <= 1x "
                         "on the repetitive-suffix workload")

    # --- scheduler/server (DESIGN.md §11) ------------------------------------
    # contended arrival-driven trace: preemptions must fire and the
    # preempted-then-restored streams must equal batch serve()
    from repro.serving.telemetry import Telemetry
    tel = Telemetry()
    server = bench_server(model, params, seed=seed, telemetry=tel)
    print(f"[smoke] server: {server['preemptions']} preemptions on the "
          f"trace, ttft p99 {server['p99_ttft']:.3f}s virtual, SLO "
          f"attainment {100 * server['slo_attainment']:.0f}%")
    if not server["parity"]:
        fails.append("scheduler streams diverged from batch serve() on the "
                     "contended trace")
    # contention is a property of the (seed, pool-shape) pair; only the
    # default seed's trace is probed to preempt, so only it is gated
    if seed == 0 and server["preemptions"] <= 0:
        fails.append("seed-0 trace produced no preemptions — the "
                     "scheduler gate is vacuous")

    # --- multi-replica fleet (DESIGN.md §15) ---------------------------------
    # fleet(N=1) must reduce to the single server; on the grouped
    # shared-prefix workload the hit-rate ordering must be
    # tier > prefix-alone > round-robin, and the shared tier must
    # deduplicate (fewest prompt pages actually computed)
    fleet = bench_fleet(model, params, seed=seed)
    pol = fleet["policies"]
    hit_t = pol["prefix_tier"]["prefix_hit_rate"]
    hit_p = pol["prefix"]["prefix_hit_rate"]
    hit_rr = pol["round_robin"]["prefix_hit_rate"]
    mat_t = pol["prefix_tier"]["materialized_pages"]
    mat_p = pol["prefix"]["materialized_pages"]
    print(f"[smoke] fleet: N=1 parity {fleet['n1_parity']}, hit rate "
          f"tier {100 * hit_t:.0f}% > prefix {100 * hit_p:.0f}% > "
          f"round-robin {100 * hit_rr:.0f}% (ordering gated); tier "
          f"materialized {mat_t} pages vs {mat_p} without "
          f"(tier hits {pol['prefix_tier']['shared_tier']['hits']})")
    if not fleet["n1_parity"]:
        fails.append("fleet(N=1) report diverged from the single Server on "
                     "the contended trace")
    if hit_p <= hit_rr:
        fails.append(f"prefix-aware routing hit rate {hit_p:.3f} did not "
                     f"beat round-robin {hit_rr:.3f} on the grouped "
                     "shared-prefix workload")
    if hit_t <= hit_p:
        fails.append(f"shared-tier hit rate {hit_t:.3f} did not beat "
                     f"prefix-routing-alone {hit_p:.3f} — the tier served "
                     "no cross-replica hits")
    if mat_t >= mat_p:
        fails.append(f"shared tier materialized {mat_t} pages vs {mat_p} "
                     "without it — no deduplication")

    # --- telemetry overhead gate (DESIGN.md §13) -----------------------------
    over = telemetry_overhead(model, params, seed=seed)
    print(f"[smoke] telemetry: off {over['telemetry_off_tok_s']:.1f} vs on "
          f"{over['telemetry_on_tok_s']:.1f} tok/s — disabled path costs "
          f"{over['overhead_pct']:+.2f}% (need < 2%)")
    if over["overhead_pct"] >= 2.0:
        fails.append(f"telemetry-disabled serving paid "
                     f"{over['overhead_pct']:.2f}% vs the instrumented run "
                     "(gate: < 2%)")

    # --- numerics probes: overhead + drift sentinels (DESIGN.md §14) ---------
    pover = probe_overhead(model, params, seed=seed)
    print(f"[smoke] probes: off {pover['probes_off_tok_s']:.1f} vs on "
          f"{pover['probes_on_tok_s']:.1f} tok/s — instrumented decode "
          f"costs {pover['overhead_pct']:+.2f}% (need < 2%)")
    if pover["overhead_pct"] >= 2.0:
        fails.append(f"probes-on serving paid {pover['overhead_pct']:.2f}% "
                     "vs probes-off (gate: < 2%)")
    nums, nfails = numerics_sentinels(model, params, arch, cfg.n_layers)
    fails.extend(nfails)

    if json_out:
        write_bench_json(json_out, {
            "mode": "smoke",
            "paged": {"kv_peak_bytes": peak, "bf16_slab_bytes": slab,
                      "reduction_x": ratio, "prefix_hit_rate": hit},
            "spec": spec, "server": server, "fleet": fleet,
            "telemetry_overhead": over, "probe_overhead": pover,
            "fails": fails})
        mpath, tpath = _telemetry_paths(json_out)
        tel.export_metrics(mpath)
        tel.export_trace(tpath)
        print(f"[telemetry] metrics -> {mpath}, Perfetto trace -> {tpath}")
        if nums is not None:
            base = json_out[:-5] if json_out.endswith(".json") else json_out
            npath = base + ".numerics.json"
            with open(npath, "w") as f:
                json.dump(nums, f, indent=1, sort_keys=True)
            print(f"[numerics] scenario report -> {npath}")

    for f in fails:
        print(f"[smoke] FAIL: {f}")
    print(f"[smoke] {'FAIL' if fails else 'PASS'}")
    return 1 if fails else 0


if __name__ == "__main__":
    main()
