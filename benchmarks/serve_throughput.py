"""Serving throughput: seed per-token Python loop vs the jitted ServeEngine
across backends and batch sizes, plus the paged-KV-cache memory story.

Measures tokens/sec and mean per-request latency for:

* ``seed``     — the pre-engine path: one jitted ``decode_step`` per token,
                 prompt fed token-by-token, host sync + Python dispatch
                 between every step (reproduced verbatim below).
* ``dense``    — jitted prefill + ``lax.while_loop`` decode (ServeEngine).
* ``codebook`` — same loop with matmuls through the Pallas
                 ``codebook_matmul`` (interpret mode off-TPU).
* ``lut``      — same loop through the faithful §4 integer engine.
* ``paged``    — the paged KV cache (DESIGN.md §8): chunked prefill,
                 int8 pages, prefix caching.  Alongside tok/s it reports
                 KV-cache HBM bytes (peak pages in use vs the dense slab),
                 page-pool utilization, and the prefix-cache hit rate on a
                 shared-prefix workload (N requests, one system prompt).

Acceptance targets: the jitted decode loop >= 5x the seed per-token loop at
batch 8 (ISSUE 1); the paged int8 cache >= 2x smaller than the bf16 dense
slab at equal batch with a measured prefix hit rate > 0 (ISSUE 2).

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        [--batches 1 8] [--max-new 16] [--layers 2] [--smoke]

``--smoke`` runs a fast paged-path regression gate (used by CI): paged
bf16 must match the contiguous engine token-for-token, the int8 page pool
must undercut the bf16 slab >= 2x, and the shared-prefix workload must
register cache hits — exits nonzero otherwise.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core.export import kv_cache_bytes
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params


def seed_generate(model, params, prompts, max_new, max_len):
    """The seed engine's generate(), verbatim: token-by-token everything."""
    cfg = model.cfg
    B = len(prompts)
    cache = model.init_cache(B, max_len, dtype=jnp.float32)
    decode = jax.jit(lambda p, t, c: model.decode(p, t, c, None))
    maxp = max(len(p) for p in prompts)
    toks = np.zeros((B, maxp), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    out = [list(p) for p in prompts]
    logits = None
    for t in range(maxp):
        logits, cache = decode(params, jnp.asarray(toks[:, t:t + 1]), cache)
    for _ in range(max_new):
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)
        nxt = np.asarray(nxt, np.int32)
        for i in range(B):
            out[i].append(int(nxt[i]))
        logits, cache = decode(params, jnp.asarray(nxt)[:, None], cache)
    return out


def bench(fn, reps):
    fn()                                   # warmup: compile everything
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def shared_prefix_prompts(rng, vocab, n, prefix_len, suffix_len):
    """N requests behind one system prompt — the prefix-cache workload."""
    system = [int(t) for t in rng.integers(0, vocab, prefix_len)]
    return [system + [int(t) for t in rng.integers(0, vocab, suffix_len)]
            for _ in range(n)]


def paged_report(eng, cfg, max_len):
    """(peak paged bytes, bf16 dense-slab bytes, utilization, hit rate)."""
    st = eng.pool.stats
    peak = eng.pool.bytes_per_page() * st.peak_pages_in_use
    slab = kv_cache_bytes(cfg.n_layers, cfg.n_kv, cfg.hd,
                          eng.max_batch * max_len, dtype_bytes=2)
    return peak, slab, st.peak_pages_in_use / eng.pool.usable_pages, st.hit_rate


def run_paged(model, cfg, params, prompts, max_new, max_len, page, reps,
              kv_dtype="int8"):
    eng = ServeEngine(model, params, max_len=max_len, max_batch=8,
                      paged=True, page_size=page, kv_dtype=kv_dtype)
    dt = bench(lambda: eng.serve(prompts, max_new=max_new), reps)
    eng.pool.reset_stats()
    eng.serve(prompts, max_new=max_new)       # measured pass for the stats
    return eng, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--skip-lut", action="store_true",
                    help="lut runs the Pallas interpreter per dense layer; "
                         "skip it for quick runs")
    ap.add_argument("--smoke", action="store_true",
                    help="fast paged-path regression gate (CI)")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced().replace(n_layers=args.layers,
                                                   dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.max_new + 8
    rng = np.random.default_rng(0)

    if args.smoke:
        sys.exit(smoke(model, cfg, params, rng))

    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    cparams = to_codebook_params(pq, wq, state, min_size=1024)

    rows = []
    speedup_at_8 = None
    for B in args.batches:
        prompts = [list(rng.integers(0, cfg.vocab, args.prompt_len))
                   for _ in range(B)]
        n_tok = B * args.max_new

        dt_seed = bench(lambda: seed_generate(model, params, prompts,
                                              args.max_new, max_len),
                        args.reps)
        rows.append(("seed", B, n_tok / dt_seed, dt_seed / B * 1e3))

        backends = ["dense", "codebook"] + ([] if args.skip_lut else ["lut"])
        for be in backends:
            p = params if be == "dense" else cparams
            eng = ServeEngine(model, p, max_len=max_len, backend=be)
            dt = bench(lambda: eng.generate(prompts, max_new=args.max_new),
                       args.reps)
            rows.append((be, B, n_tok / dt, dt / B * 1e3))
            if be == "dense" and B == 8:
                speedup_at_8 = dt_seed / dt

        eng, dt = run_paged(model, cfg, params, prompts, args.max_new,
                            max_len, args.page_size, args.reps)
        rows.append(("paged-int8", B, n_tok / dt, dt / B * 1e3))
        peak, slab, util, _ = paged_report(eng, cfg, max_len)
        print(f"[paged] B={B}: peak KV {peak / 1e6:.3f}MB vs bf16 slab "
              f"{slab / 1e6:.3f}MB ({slab / max(peak, 1):.1f}x smaller), "
              f"pool utilization {100 * util:.0f}%")

    # shared-prefix workload: one long system prompt, distinct user tails
    n_req = max(args.batches)
    shared = shared_prefix_prompts(rng, cfg.vocab, n_req,
                                   4 * args.page_size, args.prompt_len)
    smax = len(shared[0]) + args.max_new + 8
    eng = ServeEngine(model, params, max_len=smax, max_batch=8, paged=True,
                      page_size=args.page_size, kv_dtype="int8")
    t0 = time.perf_counter()
    eng.serve(shared, max_new=args.max_new)
    dts = time.perf_counter() - t0
    peak, slab, util, hit = paged_report(eng, cfg, smax)
    print(f"[paged] shared-prefix ({n_req} requests, common "
          f"{4 * args.page_size}-token system prompt): "
          f"{n_req * args.max_new / dts:.1f} tok/s, prefix hit rate "
          f"{100 * hit:.0f}%, peak KV {peak / 1e6:.3f}MB vs bf16 slab "
          f"{slab / 1e6:.3f}MB")

    print(f"\n{'backend':<10} {'batch':>5} {'tok/s':>10} {'ms/request':>12}")
    for name, B, tps, lat in rows:
        print(f"{name:<10} {B:>5} {tps:>10.1f} {lat:>12.1f}")

    if speedup_at_8 is not None:
        ok = speedup_at_8 >= 5.0
        print(f"\n[target] jitted dense loop vs seed loop at batch 8: "
              f"{speedup_at_8:.1f}x ({'PASS' if ok else 'FAIL'}: >= 5x)")


def smoke(model, cfg, params, rng) -> int:
    """CI gate for the paged path; returns a process exit code."""
    prompts = [list(map(int, rng.integers(0, cfg.vocab, n)))
               for n in (3, 7, 5, 9)]
    max_new, max_len, page = 6, 32, 4
    fails = []

    contig = ServeEngine(model, params, max_len=max_len, max_batch=2)
    want = contig.serve(prompts, max_new=max_new)
    paged = ServeEngine(model, params, max_len=max_len, max_batch=2,
                        paged=True, page_size=page)
    got = paged.serve(prompts, max_new=max_new)
    if got != want:
        fails.append("paged bf16 serve diverged from the contiguous engine")

    eng8 = ServeEngine(model, params, max_len=max_len, max_batch=2,
                       paged=True, page_size=page, kv_dtype="int8")
    eng8.serve(prompts, max_new=max_new)
    peak, slab, util, _ = paged_report(eng8, cfg, max_len)
    ratio = slab / max(peak, 1)
    print(f"[smoke] int8 paged peak {peak / 1e3:.1f}KB vs bf16 slab "
          f"{slab / 1e3:.1f}KB: {ratio:.1f}x (need >= 2x), utilization "
          f"{100 * util:.0f}%")
    if ratio < 2.0:
        fails.append(f"cache-memory reduction {ratio:.2f}x < 2x")

    shared = shared_prefix_prompts(rng, cfg.vocab, 4, 2 * page, 3)
    engs = ServeEngine(model, params, max_len=max_len, max_batch=2,
                       paged=True, page_size=page, kv_dtype="int8")
    engs.serve(shared, max_new=4)
    hit = engs.pool.stats.hit_rate
    print(f"[smoke] shared-prefix hit rate {100 * hit:.0f}% (need > 0)")
    if hit <= 0:
        fails.append("prefix cache registered no hits on the shared-prefix "
                     "workload")

    for f in fails:
        print(f"[smoke] FAIL: {f}")
    print(f"[smoke] {'FAIL' if fails else 'PASS'}")
    return 1 if fails else 0


if __name__ == "__main__":
    main()
