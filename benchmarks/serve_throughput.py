"""Serving throughput: seed per-token Python loop vs the jitted ServeEngine
across backends and batch sizes.

Measures tokens/sec and mean per-request latency for:

* ``seed``     — the pre-engine path: one jitted ``decode_step`` per token,
                 prompt fed token-by-token, host sync + Python dispatch
                 between every step (reproduced verbatim below).
* ``dense``    — jitted prefill + ``lax.while_loop`` decode (ServeEngine).
* ``codebook`` — same loop with matmuls through the Pallas
                 ``codebook_matmul`` (interpret mode off-TPU).
* ``lut``      — same loop through the faithful §4 integer engine.

Acceptance target (ISSUE 1): the jitted decode loop is >= 5x the seed
per-token loop at batch 8 on CPU.

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        [--batches 1 8] [--max-new 16] [--layers 2]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core.quantizer import WeightQuantConfig, cluster_params, init_state
from repro.models.model_zoo import build
from repro.serving import ServeEngine, to_codebook_params


def seed_generate(model, params, prompts, max_new, max_len):
    """The seed engine's generate(), verbatim: token-by-token everything."""
    cfg = model.cfg
    B = len(prompts)
    cache = model.init_cache(B, max_len, dtype=jnp.float32)
    decode = jax.jit(lambda p, t, c: model.decode(p, t, c, None))
    maxp = max(len(p) for p in prompts)
    toks = np.zeros((B, maxp), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    out = [list(p) for p in prompts]
    logits = None
    for t in range(maxp):
        logits, cache = decode(params, jnp.asarray(toks[:, t:t + 1]), cache)
    for _ in range(max_new):
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)
        nxt = np.asarray(nxt, np.int32)
        for i in range(B):
            out[i].append(int(nxt[i]))
        logits, cache = decode(params, jnp.asarray(nxt)[:, None], cache)
    return out


def bench(fn, reps):
    fn()                                   # warmup: compile everything
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--skip-lut", action="store_true",
                    help="lut runs the Pallas interpreter per dense layer; "
                         "skip it for quick runs")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced().replace(n_layers=args.layers,
                                                   dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wq = WeightQuantConfig(num_weights=256, method="kmeans")
    pq, state = cluster_params(params, wq, init_state(wq), 1000,
                               jax.random.PRNGKey(1))
    cparams = to_codebook_params(pq, wq, state, min_size=1024)
    max_len = args.prompt_len + args.max_new + 8

    rng = np.random.default_rng(0)
    rows = []
    speedup_at_8 = None
    for B in args.batches:
        prompts = [list(rng.integers(0, cfg.vocab, args.prompt_len))
                   for _ in range(B)]
        n_tok = B * args.max_new

        dt_seed = bench(lambda: seed_generate(model, params, prompts,
                                              args.max_new, max_len),
                        args.reps)
        rows.append(("seed", B, n_tok / dt_seed, dt_seed / B * 1e3))

        backends = ["dense", "codebook"] + ([] if args.skip_lut else ["lut"])
        for be in backends:
            p = params if be == "dense" else cparams
            eng = ServeEngine(model, p, max_len=max_len, backend=be)
            dt = bench(lambda: eng.generate(prompts, max_new=args.max_new),
                       args.reps)
            rows.append((be, B, n_tok / dt, dt / B * 1e3))
            if be == "dense" and B == 8:
                speedup_at_8 = dt_seed / dt

    print(f"\n{'backend':<10} {'batch':>5} {'tok/s':>10} {'ms/request':>12}")
    for name, B, tps, lat in rows:
        print(f"{name:<10} {B:>5} {tps:>10.1f} {lat:>12.1f}")

    if speedup_at_8 is not None:
        ok = speedup_at_8 >= 5.0
        print(f"\n[target] jitted dense loop vs seed loop at batch 8: "
              f"{speedup_at_8:.1f}x ({'PASS' if ok else 'FAIL'}: >= 5x)")


if __name__ == "__main__":
    main()
