"""Paper §4 memory table: packed / entropy-coded model size on really
trained + clustered networks, plus the A×W table overhead and the LUT-vs-
matmul CPU timing (the paper's lookups-vs-multiplies claim; inverted on
TPU, DESIGN.md §2)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._common import timer, train_classifier
from repro.core import clustering, fixedpoint as fp
from repro.core.activations import ActQuantConfig
from repro.core.export import memory_report
from repro.core.lut import LutConfig, build_tables
from repro.core.quantizer import codebook_indices
from repro.data.synthetic import pseudo_mnist_batch
from repro.kernels import ops
from repro.models import papernets as PN


def _apply(p, x, act_levels, key):
    return PN.mlp_apply(p, x, "tanh", act_levels)


def run(steps=250):
    rows = []
    # train a real clustered net so the index distribution is the trained one
    init = lambda k: PN.mlp_init(k, 784, [128, 128], 10)
    params, qstate, wq = train_classifier(
        init, _apply, lambda s: pseudo_mnist_batch(s, 64), steps=steps,
        act_levels=32, n_weights=1000, cluster_every=60)
    idx_tree, books = codebook_indices(params, wq, qstate)
    rep = memory_report(idx_tree, 1000, 32)
    rows.append(("memory_savings", "trained-mlp",
                 rep.row().replace(",", ";")))
    rows.append(("memory_savings", "savings_vs_fp32",
                 f"{100 * rep.savings_vs_fp32:.1f}%"))
    rows.append(("memory_savings", "entropy_savings_vs_fp32",
                 f"{100 * rep.entropy_savings_vs_fp32:.1f}%"))
    rows.append(("memory_savings", "bits_per_weight",
                 f"{rep.entropy_bits_per_w:.2f}"))
    # projection to the paper's AlexNet scale (50M params): the A×W table
    # amortises away; packed savings -> the pure 10-vs-32-bit ratio, and the
    # entropy figure uses OUR measured index entropy (the paper's <7 bits
    # reflects their AlexNet's peakier trained histogram — recorded in
    # EXPERIMENTS.md as a distribution-dependent claim).
    n50 = 50_000_000
    packed50 = 1 - (n50 * rep.index_bits / 8 + rep.table_bytes) / (4 * n50)
    ent50 = 1 - (n50 * rep.entropy_bits_per_w / 8 + rep.table_bytes) / (4 * n50)
    rows.append(("memory_savings", "projected_50M_packed",
                 f"{100 * packed50:.1f}%"))
    rows.append(("memory_savings", "projected_50M_entropy",
                 f"{100 * ent50:.1f}%"))

    # LUT engine vs float matmul: µs per layer on CPU
    act = ActQuantConfig("tanh", 32)
    book = np.asarray(books[""])
    tabs = build_tables(book, LutConfig(act=act, table_entries=4096),
                        fan_in=785)
    w = params["layer0"]["w"]
    wi = clustering.assign_to_centers(w, jnp.asarray(book)).astype(jnp.int32)
    x = pseudo_mnist_batch(0, 64)["x"]
    xi = fp.input_to_indices(jnp.tanh(x), act)

    t_float = timer(jax.jit(lambda x, w: x @ w), x, w)
    t_int = timer(jax.jit(partial(fp.int_linear, tables=tabs)), xi, wi, None)
    rows.append(("lut_speed", "float_matmul_us", f"{t_float:.0f}"))
    rows.append(("lut_speed", "int_lut_engine_us", f"{t_int:.0f}"))

    # Pallas (interpret) sanity timing for the TPU codebook path
    t_cb = timer(lambda: ops.codebook_matmul(
        x, wi.astype(jnp.int16), jnp.asarray(book)))
    rows.append(("lut_speed", "codebook_matmul_interpret_us", f"{t_cb:.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(r))
