"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.report [dryrun_results] > sections.md
"""

from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks.roofline import analyse


def _fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def dryrun_table(recs):
    lines = [
        "| cell | mesh | compile s | args GB/dev | temp GB/dev | "
        "flops/dev | HBM bytes/dev | collective bytes/dev (top ops) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['cell']} | — | — | — | — | — | — | "
                         f"SKIP: {r['reason']} |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['cell']} | — | ERROR | | | | | |")
            continue
        coll = r["collectives_per_device"]
        top = sorted(((k, v) for k, v in coll.items() if k != "total"),
                     key=lambda kv: -kv[1])[:3]
        tops = " ".join(f"{k}:{v / 1e9:.2f}G" for k, v in top) or "none"
        corr = r.get("corrected") or {}
        lines.append(
            f"| {r['cell']} | {r['mesh']} | {r['compile_seconds']} | "
            f"{_fmt_bytes(r['mem']['argument_bytes'])} | "
            f"{_fmt_bytes(r['mem']['temp_bytes'])} | "
            f"{corr.get('flops', r['flops_per_device']):.3e} | "
            f"{corr.get('bytes', r['bytes_per_device']):.3e} | {tops} |")
    return "\n".join(lines)


def roofline_table(recs, mesh="single"):
    lines = [
        "| arch × shape | compute ms | memory ms | collective ms | dominant "
        "| useful FLOP ratio | roofline fraction |",
        "|---|---|---|---|---|---|---|",
    ]
    worst = []
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        a = analyse(r)
        t = a["terms"]
        cell = f"{a['arch']} × {a['shape']}"
        lines.append(
            f"| {cell} | {t['compute_s'] * 1e3:.2f} | "
            f"{t['memory_s'] * 1e3:.2f} | {t['collective_s'] * 1e3:.3f} | "
            f"{a['dominant'].replace('_s', '')} | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']:.2f} |")
        worst.append((a["roofline_fraction"], cell, a["dominant"]))
    worst.sort()
    summary = ["", "Worst roofline fractions (hillclimb candidates):"]
    for frac, cell, dom in worst[:5]:
        summary.append(f"- {cell}: {frac:.2f} ({dom.replace('_s', '')}-bound)")
    return "\n".join(lines + summary)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results"
    recs = [json.load(open(f)) for f in sorted(glob.glob(os.path.join(d, "*.json")))]
    base = [r for r in recs if "__q" not in r.get("cell", "")]
    print("### Dry-run table (per-device numbers; trip-count-corrected "
          "flops/bytes)\n")
    print(dryrun_table(base))
    print("\n\n### Roofline (single-pod 16×16)\n")
    print(roofline_table(base, "single"))
    print("\n\n### Roofline (multi-pod 2×16×16)\n")
    print(roofline_table(base, "multi"))


if __name__ == "__main__":
    main()
