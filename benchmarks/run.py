"""Benchmark orchestrator — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,case,value`` CSV.  --full uses paper-closer step counts
(CPU-hours); default is the quick profile used by bench_output.txt.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    scale = 3 if args.full else 1

    from benchmarks import (fig2_parabola, fig6_mnist, fig7_autoencoder,
                            memory_savings, roofline, table1_alexnet,
                            table2_comparison)

    plan = [
        ("fig2_parabola", lambda: fig2_parabola.run(steps=400 * scale)),
        ("fig6_mnist", lambda: fig6_mnist.run(steps=200 * scale)),
        ("fig7_autoencoder", lambda: fig7_autoencoder.run(steps=200 * scale)),
        ("table1_alexnet", lambda: table1_alexnet.run(steps=300 * scale)),
        ("memory_savings", lambda: memory_savings.run(steps=200 * scale)),
        ("roofline", roofline.run),
    ]
    t1_rows = None
    print("benchmark,case,value")
    for name, fn in plan:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # report, keep going
            print(f"{name},ERROR,{e!r}")
            continue
        if name == "table1_alexnet":
            t1_rows = rows
        for r in rows:
            print(",".join(r))
        print(f"{name},_wall_seconds,{time.time() - t0:.1f}", flush=True)
    if (not args.only) or "table2" in args.only:
        for r in table2_comparison.run(t1_rows):
            print(",".join(r))


if __name__ == "__main__":
    main()
