"""Paper Table 2: accuracy deltas under quantization, ours vs prior work.

Prior-work numbers are the paper's reported figures (we cannot rerun DoReFa
/ QNN / XNOR here); our delta comes from the Table-1 benchmark runs
(baseline #1 relu6 vs quantized #9 laplacian) at this container's scale.
"""

from __future__ import annotations

PRIOR = [
    ("WAGE (Wu 2018)",      None,  -4.8),
    ("DoReFa (Zhou 2016)",  -2.9,  None),
    ("QNN (Hubara 2016)",   -5.6,  -6.5),
    ("XNOR-Nets (2016)",    -12.4, -11.0),
    ("Fixed-point (Lin 2015)", None, -57.7),
]


def run(table1_rows=None):
    rows = []
    ours = {}
    if table1_rows:
        for _, label, val in table1_rows:
            d = dict(kv.split("=") for kv in val.split())
            ours[label] = (float(d["r@1"]) * 100, float(d["r@5"]) * 100)
    if "#1 relu6" in ours and "#9 laplacian |W|=1000" in ours:
        b1, b5 = ours["#1 relu6"]
        q1, q5 = ours["#9 laplacian |W|=1000"]
        rows.append(("table2", "ours (this repro, scaled)",
                     f"d@1={q1 - b1:+.1f} d@5={q5 - b5:+.1f}"))
    rows.append(("table2", "ours (paper-reported)", "d@1=-0.3 d@5=-0.6"))
    for name, d1, d5 in PRIOR:
        rows.append(("table2", name + " (paper-reported)",
                     f"d@1={d1 if d1 is not None else 'n/a'} "
                     f"d@5={d5 if d5 is not None else 'n/a'}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(r))
